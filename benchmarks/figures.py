"""Paper-figure reproductions (one function per table/figure).

Each returns (derived_value, detail_dict); run.py times them and emits the
``name,us_per_call,derived`` CSV contract.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (PAPER_DNNS, eval_accuracy, get_taps,
                               get_trained, layer_macs)
from repro.configs.base import MoRConfig
from repro.core.calibration import (finalize_regression, init_accumulator,
                                    update_accumulator)
from repro.core.clustering import closest_neighbor_graph, cluster_layer
from repro.core.policy import build_mor_layer


def fig1_negative_fraction() -> Tuple[float, Dict]:
    """Paper Fig. 1: % of computations producing negative ReLU inputs
    (paper: 35-69%, mean 55%)."""
    out = {}
    for name in PAPER_DNNS:
        taps = get_taps(name)
        macs = layer_macs(name)
        macs = macs[:len(taps)]
        neg = [float((t["relu_in"] < 0).mean()) for t in taps]
        w = np.asarray(macs[:len(neg)], np.float64)
        out[name] = float(np.average(neg, weights=w / w.sum()))
    return float(np.mean(list(out.values()))), out


def fig3_mac_breakdown() -> Tuple[float, Dict]:
    """Paper Fig. 3: fraction of MACs in ReLU-activated layers (=MoR
    addressable compute)."""
    out = {}
    for name in PAPER_DNNS:
        cfg, params, _, _ = get_trained(name)
        macs = layer_macs(name)
        if cfg.family == "tds":
            # conv+relu and fc1+relu are addressable; fc2 is not
            addr = sum(macs)
            total = addr + len(params["layers"]) * 64 * cfg.d_ff * cfg.d_model
        else:
            addr = total = sum(macs)
        out[name] = addr / total
    return float(np.mean(list(out.values()))), out


def _fit_layers(name: str):
    """Per-layer (m, b, c) + weight matrices from taps."""
    taps = get_taps(name)
    cfg, params, state, _ = get_trained(name)
    if cfg.family == "cnn":
        from repro.models.cnn import layer_weight_matrices
        ws = layer_weight_matrices(params)
        pairs = list(zip(taps, ws))
    else:
        from repro.models.tds import layer_weight_matrices
        ws = layer_weight_matrices(params)
        pairs = [(taps[2 * i + 1], ws[i]) for i in range(len(ws))]
    fits = []
    for tap, w in pairs:
        acc = init_accumulator(tap["p_bin"].shape[-1])
        acc = update_accumulator(acc, jnp.asarray(tap["p_bin"]),
                                 jnp.asarray(tap["p_base"]))
        m, b, c = finalize_regression(acc)
        fits.append((np.asarray(m), np.asarray(b), np.asarray(c),
                     np.asarray(w)))
    return cfg, params, state, fits, pairs


def fig5_correlation() -> Tuple[float, Dict]:
    """Paper Fig. 5: distribution of Pearson correlation between binary
    and base-precision pre-activations."""
    out = {}
    buckets = [0.0, 0.5, 0.6, 0.7, 0.8, 0.9, 1.01]
    for name in PAPER_DNNS:
        _, _, _, fits, _ = _fit_layers(name)
        c = np.concatenate([f[2] for f in fits])
        hist, _ = np.histogram(np.abs(c), buckets)
        out[name] = {"mean": float(np.abs(c).mean()),
                     "hist_0_.5_.6_.7_.8_.9": (hist / hist.sum()).round(3
                                                                    ).tolist()}
    return float(np.mean([v["mean"] for v in out.values()])), out


def fig8_angles() -> Tuple[float, Dict]:
    """Paper Fig. 8: distribution of closest-neighbour angles (random
    high-dim vectors would concentrate at 80-90 deg; trained nets lower)."""
    out = {}
    for name in PAPER_DNNS:
        cfg, params, state, _ = get_trained(name)
        if cfg.family == "cnn":
            from repro.models.cnn import layer_weight_matrices
            ws = layer_weight_matrices(params)
        else:
            from repro.models.tds import layer_weight_matrices
            ws = layer_weight_matrices(params)
        angs = []
        for w in ws:
            _, a = closest_neighbor_graph(np.asarray(w, np.float32))
            angs.append(a)
        a = np.concatenate(angs)
        out[name] = {"mean_deg": float(a.mean()),
                     "frac_below_80": float((a < 80).mean()),
                     "frac_below_45": float((a < 45).mean())}
    return float(np.mean([v["mean_deg"] for v in out.values()])), out


_SWEEP_MEMO: Dict = {}
_CLUSTER_MEMO: Dict = {}


def _sweep(name: str, thresholds, hybrid: bool) -> List[Dict]:
    """Threshold sweep: accuracy delta + ops saved (binary-alone if not
    hybrid — paper Fig. 6 — else the full Mixture-of-Rookies, Fig. 9)."""
    memo_key = (name, tuple(thresholds), hybrid)
    if memo_key in _SWEEP_MEMO:
        return _SWEEP_MEMO[memo_key]
    cfg, params, state, fits, pairs = _fit_layers(name)
    base_acc = eval_accuracy(name, cfg, params, state)
    macs = layer_macs(name)
    if cfg.family == "tds":
        macs = macs[1::2]  # fc layers carry the MoR savings
    if hybrid and name not in _CLUSTER_MEMO:
        _CLUSTER_MEMO[name] = [cluster_layer(w, 90.0)
                               for (_, _, _, w) in fits]
    rows = []
    for T in thresholds:
        mcfg = MoRConfig(enabled=True, corr_threshold=T)
        mors = []
        for i, ((m, b, c, w), mac) in enumerate(zip(fits, macs)):
            cl = _CLUSTER_MEMO[name][i] if hybrid else None
            mors.append(build_mor_layer(m, b, c, cl, mcfg))
        # evaluate in exact mode (the accelerator's semantics)
        if cfg.family == "cnn":
            from repro.models import cnn as cnn_mod
            import jax
            from repro.data.pipeline import synthetic_image_batch
            fracs = []
            d = synthetic_image_batch(cfg, 32, seed=5, step=0)
            _, _, aux = cnn_mod.forward(params, state, cfg,
                                        jnp.asarray(d["images"]),
                                        train=False, mor=mors,
                                        mor_mode="exact")
            fracs = [float(s["frac_computed"]) for s in aux["mor_stats"]]
            acc = eval_accuracy(name, cfg, params, state, mor=mors,
                                mor_mode="exact")
        else:
            from repro.models import tds as tds_mod
            from repro.data.pipeline import synthetic_frames_batch
            import jax
            d = synthetic_frames_batch(cfg, 8, 64, seed=5, step=0)
            _, aux = tds_mod.forward(params, cfg,
                                     {"frames": jnp.asarray(d["frames"])},
                                     mor=mors, mor_mode="exact")
            fracs = [float(s["frac_computed"]) for s in aux["mor_stats"]]
            acc = eval_accuracy(name, cfg, params, state, mor=mors,
                                mor_mode="exact")
        w = np.asarray(macs[:len(fracs)], np.float64)
        ops_saved = float(np.average(1.0 - np.asarray(fracs),
                                     weights=w / w.sum()))
        rows.append({"T": T, "ops_saved": ops_saved,
                     "acc_delta": acc - base_acc})
    _SWEEP_MEMO[memo_key] = rows
    return rows


THRESHOLDS = [0.95, 0.9, 0.8, 0.7, 0.6]


def fig6_threshold_binary_alone() -> Tuple[float, Dict]:
    out = {n: _sweep(n, THRESHOLDS, hybrid=False) for n in PAPER_DNNS}
    best = max(r["ops_saved"] for rows in out.values() for r in rows
               if r["acc_delta"] > -0.01)
    return best, out


def fig9_hybrid() -> Tuple[float, Dict]:
    out = {n: _sweep(n, THRESHOLDS, hybrid=True) for n in PAPER_DNNS}
    best = max(r["ops_saved"] for rows in out.values() for r in rows
               if r["acc_delta"] > -0.01)
    return best, out


def fig12_breakdown() -> Tuple[float, Dict]:
    """Paper Fig. 12: prediction-category fractions at the operating T."""
    from repro.core.predictor import hybrid_predict, prediction_breakdown
    out = {}
    for name in PAPER_DNNS:
        cfg, params, state, fits, pairs = _fit_layers(name)
        cats = []
        for (m, b, c, w), (tap, _) in zip(fits, pairs):
            cl = cluster_layer(w, 90.0)
            mor = build_mor_layer(m, b, c, cl,
                                  MoRConfig(corr_threshold=0.7))
            x = None  # exact mode: use stored preacts
            pre = jnp.asarray(tap["p_base"])[:, mor["perm"]]
            relu_in = jnp.asarray(tap["relu_in"])[:, mor["perm"]]
            computed = hybrid_predict(
                jnp.zeros((pre.shape[0], w.shape[0])),  # x unused w/ preact
                jnp.asarray(w)[:, mor["perm"]], mor, preact_full=pre)
            # binary rookie needs x: recompute from taps instead
            p_bin = jnp.asarray(tap["p_bin"])[:, mor["perm"]]
            p_hat = mor["m"] * p_bin + mor["b"]
            p_hat = p_hat * mor["bn_scale"] + mor["bn_bias"]
            proxy_pre = jnp.take(pre, mor["proxy_slot"], axis=-1)
            skip = ((proxy_pre < 0) & (p_hat < 0) & mor["enable"]
                    & ~mor["is_proxy"])
            cats.append({k: float(v) for k, v in
                         prediction_breakdown(relu_in, ~skip).items()})
        out[name] = {k: float(np.mean([c[k] for c in cats]))
                     for k in cats[0]}
    mean_incorrect_zero = float(np.mean(
        [v["incorrect_zero"] for v in out.values()]))
    return mean_incorrect_zero, out


# --- Fig. 13: modeled accelerator speedup/energy --------------------------
# Cost model mirroring the paper's accelerator (§4-6): per layer,
#   t = max(MACs / (CUs*width), dram_bytes / bytes_per_cycle)
#   skipping removes both the MACs and the weight fetches of skipped
#   neurons; the binary predictor is overlapped (adds no time) and costs
#   ~1/8 MAC energy per binary op (paper: binCUs are 'much simpler').
_MAC_E = 1.0            # relative energy / 8-bit MAC
_DRAM_E = 40.0          # relative energy / byte (DRAM dominates)
_BIN_E = _MAC_E / 8.0


def fig13_speedup_energy() -> Tuple[float, Dict]:
    rows9 = fig9_hybrid()[1]
    out = {}
    for name in PAPER_DNNS:
        ok = [r for r in rows9[name] if r["acc_delta"] > -0.01]
        op = max(ok, key=lambda r: r["ops_saved"]) if ok \
            else rows9[name][0]
        s = op["ops_saved"]
        macs = sum(layer_macs(name))
        dram = macs  # ~1 weight byte per MAC in these layers (8-bit)
        t_base = max(macs / 64.0, dram / 8.0)
        t_mor = max(macs * (1 - s) / 64.0, dram * (1 - s) / 8.0)
        e_base = macs * _MAC_E + dram * _DRAM_E
        e_mor = (macs * (1 - s) * _MAC_E + dram * (1 - s) * _DRAM_E
                 + macs * _BIN_E / 8)   # binary dot on 1/8 the ops width
        out[name] = {"speedup": t_base / t_mor,
                     "energy_saving": 1 - e_mor / e_base,
                     "ops_saved": s, "T_acc_delta": op["acc_delta"]}
    return (float(np.mean([v["speedup"] for v in out.values()])), out)


# --- Observability: per-layer skip table from a metrics snapshot ----------
def obs_skip_table(metrics: Dict) -> str:
    """Markdown per-layer tile-skip table from an obs registry snapshot
    (``MetricsRegistry.snapshot()``): for every (group, layer[, expert])
    series of ``repro_mor_tiles_total`` / ``_skipped_total``, the exact
    device-counted tile totals plus the realised skip fraction and the
    fixed-point mean live fraction from ``repro_mor_frac_tiles_live``."""
    tot = {tuple(sorted(v["labels"].items())): v["value"]
           for v in metrics.get("repro_mor_tiles_total",
                                {}).get("values", [])}
    skp = {tuple(sorted(v["labels"].items())): v["value"]
           for v in metrics.get("repro_mor_tiles_skipped_total",
                                {}).get("values", [])}
    live = {tuple(sorted(v["labels"].items())): v["value"]
            for v in metrics.get("repro_mor_frac_tiles_live",
                                 {}).get("values", [])}
    if not tot:
        return "(no MoR tile counters in this snapshot)"
    md = ["| group | layer | expert | tiles | skipped | skip frac | "
          "mean live frac |", "|---|---|---|---|---|---|---|"]
    for key in sorted(tot):
        lab = dict(key)
        t, s = tot[key], skp.get(key, 0.0)
        md.append(f"| {lab.get('group', '-')} | {lab.get('layer', '-')} | "
                  f"{lab.get('expert') or '-'} | {t:.0f} | {s:.0f} | "
                  f"{s / max(t, 1):.3f} | {live.get(key, 0.0):.3f} |")
    out = "\n".join(md)
    # speculative-decoding acceptance (engine-global device counters,
    # ISSUE 9) — a footer line, not a per-layer row: drafts span layers
    drafted = sum(v["value"] for v in metrics.get(
        "repro_spec_tokens_drafted_total", {}).get("values", []))
    accepted = sum(v["value"] for v in metrics.get(
        "repro_spec_tokens_accepted_total", {}).get("values", []))
    if drafted:
        out += (f"\n\nSpeculative decoding: {drafted:.0f} tokens drafted, "
                f"{accepted:.0f} accepted — acceptance rate "
                f"{accepted / max(drafted, 1):.3f}.")
    return out

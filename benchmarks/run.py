"""Benchmark harness: one entry per paper table/figure + the roofline
aggregation.  Prints ``name,us_per_call,derived`` CSV (timing = wall time
of the reproduction; derived = the figure's headline number).

``--scenario serve-engine`` instead benchmarks the continuous-batching
serving engine on a fixed mixed prompt-length trace (dense vs tiled vs
kernel execution, engine vs static-batch), emitting ``BENCH_serve.json``
— the CI smoke job runs it reduced-size."""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _run(name, fn):
    t0 = time.time()
    derived, detail = fn()
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived:.4f}", flush=True)
    return {"name": name, "us_per_call": us, "derived": derived,
            "detail": detail}


QUANTILE = 0.95     # tile-liveness quantile for capacity calibration


def scenario_serve_engine(modes=("dense", "tiled", "kernel"),
                          n_requests: int = 16, prompt_min: int = 8,
                          prompt_max: int = 96, gen_min: int = 4,
                          gen_len: int = 96, n_slots: int = 4,
                          chunk: int = 16, compute_scale: bool = True,
                          out: str = "BENCH_serve.json") -> dict:
    """Fixed mixed-length trace (heterogeneous prompts AND generation
    lengths) through the serving engine, per mode, plus the static-batch
    baseline for the tiled mode.  ``compute_scale`` adds rows at
    d_model=256/d_ff=1024/L=4 — the scale where per-dispatch compute
    dominates Python dispatch overhead, i.e. what the engine-vs-static
    comparison looks like off the toy config — in BOTH cache layouts,
    so the paged indirection's overhead is visible next to the slotted
    baseline.  Prefix caching is OFF here (the best-of-3 harness re-runs
    one trace, so the cache would hit its own prior passes and the
    tokens-dispatched accounting would stop meaning throughput); the
    dedicated shared-prompt benchmark is --scenario serve-prefix."""
    from repro.launch.serve import main as serve_main

    def run_mode(mode, extra, label, prefix_cache=False):
        argv = ["--arch", "granite-3-2b", "--reduced",
                "--batch", str(n_slots), "--requests", str(n_requests),
                "--prompt-min", str(prompt_min),
                "--prompt-max", str(prompt_max),
                "--gen-min", str(gen_min),
                "--gen-len", str(gen_len), "--chunk", str(chunk),
                "--mor", mode, "--calib-steps", "2"] + extra
        if not prefix_cache:
            argv.append("--no-prefix-cache")
        rep = serve_main(argv)
        row = {
            "tokens_per_s": rep["tokens_per_s"],
            "decode_tokens_per_s": rep["decode_tokens_per_s"],
            "requests": rep["requests_finished"],
            "dispatches": rep["dispatches"],
        }
        for k in ("static_batch_tokens_per_s", "engine_speedup_vs_static",
                  "token_agreement_vs_dense", "per_layer_capacity",
                  "calibrated_tokens_per_s", "per_layer_frac_tiles_live",
                  "obs", "static_capacity"):
            if k in rep:
                row[k] = rep[k]
        print(f"serve_engine_{label},0,{rep['tokens_per_s']:.1f}",
              flush=True)
        return row

    rows = {}
    for mode in modes:
        extra = []
        if mode != "dense":
            extra += ["--calibrate-capacity", str(QUANTILE)]
        if mode == "tiled":
            extra += ["--baseline", "--compare"]
        rows[mode] = run_mode(mode, extra, mode)
    if compute_scale:
        rows["dense@d256"] = run_mode(
            "dense", ["--dims", "256,1024,4", "--chunk", "32",
                      "--baseline"], "dense_d256")
        rows["dense@d256-slotted"] = run_mode(
            "dense", ["--dims", "256,1024,4", "--chunk", "32",
                      "--baseline", "--layout", "slotted"],
            "dense_d256_slotted")
        # layout_cost: paged / slotted throughput at the compute-bound
        # point — ≥ 1.0 means the paged indirection is free (or wins,
        # via in-place pool updates + active-window attends)
        for k in ("tokens_per_s", "decode_tokens_per_s"):
            rows["dense@d256"][f"layout_cost_{k}"] = round(
                rows["dense@d256"][k]
                / max(rows["dense@d256-slotted"][k], 1e-9), 3)
        # obs A/B at the same compute-dominated point: the full obs stack
        # (device-resident dispatch counters accumulated inside the
        # compiled step + the span tracer) vs the plain engine.  The
        # counters ride the step's return tuple and drain only at flush,
        # so the cost budget is < 3% tokens/s (acceptance criterion).
        # Separate-process A/B is hopeless for a 3% question on a shared
        # CPU (run-to-run spread ~10-20%), so both engines live in THIS
        # process and timed passes alternate off/on — best-of-N per side
        # over interleaved walls cancels the drift both sides see.
        import jax

        from repro.configs import get_config, reduce_config
        from repro.launch.serve import _run_engine, _trace
        from repro.models import get_model
        from repro.obs import Observability
        cfg = reduce_config(get_config("granite-3-2b")).replace(
            serve_chunk=32, d_model=256, d_ff=1024, n_layers=4)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        reqs = _trace(cfg, n_requests, prompt_min, prompt_max, gen_min,
                      gen_len, 0)
        kw = dict(mor=None, mor_mode="dense", n_slots=n_slots,
                  max_len=prompt_max + gen_len + 2, chunk=32,
                  prefix_cache=False)
        eng_off, _, _ = _run_engine(cfg, params, reqs, **kw)
        eng_on, _, rep_on = _run_engine(cfg, params, reqs,
                                        obs=Observability(), **kw)
        walls = {"off": float("inf"), "on": float("inf")}
        for _ in range(5):
            for label, eng in (("off", eng_off), ("on", eng_on)):
                eng.reset_counters()
                t0 = time.time()
                eng.run(list(reqs))
                walls[label] = min(walls[label], time.time() - t0)
        n_tok = rep_on["prefill_tokens"] + rep_on["decode_tokens"]
        rows["dense@d256-obs"] = {
            "tokens_per_s": n_tok / walls["on"],
            "decode_tokens_per_s": rep_on["decode_tokens"] / walls["on"],
            "paired_off_tokens_per_s": n_tok / walls["off"],
            "requests": rep_on["requests_finished"],
            "dispatches": rep_on["dispatches"],
            "obs": rep_on["obs"],
        }
        obs_overhead = round(1.0 - walls["off"] / walls["on"], 4)
        print(f"serve_engine_dense_d256_obs,0,{n_tok / walls['on']:.1f}",
              flush=True)
        print(f"serve_engine_obs_overhead,0,{obs_overhead:.4f}",
              flush=True)
    # obs demo: tiled mode with a static 0.5 capacity clamp (random-init
    # weights predict everything live, so the clamp is what makes the
    # tile-skip counters nonzero) and a shared prompt prefix (nonzero
    # prefix-hit counters) — the registry snapshot, device counters and
    # TTFT/ITL summaries land in BENCH_serve.json for the EXPERIMENTS.md
    # observability section
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        mpath = os.path.join(td, "metrics.json")
        tpath = os.path.join(td, "trace.json")
        rep_obs = serve_main(
            ["--arch", "granite-3-2b", "--reduced",
             "--batch", str(n_slots), "--requests", str(n_requests),
             "--prompt-min", str(prompt_min),
             "--prompt-max", str(max(prompt_max // 2, prompt_min)),
             "--gen-min", str(gen_min), "--gen-len", str(max(gen_len // 4, 4)),
             "--chunk", str(chunk), "--mor", "tiled", "--calib-steps", "2",
             "--capacity", "0.5", "--shared-prefix", str(2 * chunk),
             "--metrics-json", mpath, "--trace-out", tpath])
        metrics = json.load(open(mpath))["metrics"]
        trace = json.load(open(tpath))
    from repro.obs import validate_chrome_trace
    obs_demo = {
        "metrics": metrics,
        "device_metrics": rep_obs["obs"]["device_metrics"],
        "tracing": rep_obs["obs"]["tracing"],
        "tokens_per_s": rep_obs["tokens_per_s"],
        "trace_events": len(trace.get("traceEvents", [])),
        "trace_problems": validate_chrome_trace(trace),
        "static_capacity": rep_obs.get("static_capacity"),
    }
    print(f"serve_engine_obs_demo,0,{rep_obs['tokens_per_s']:.1f}",
          flush=True)
    result = {"trace": {"n_requests": n_requests, "prompt_min": prompt_min,
                        "prompt_max": prompt_max, "gen_min": gen_min,
                        "gen_len": gen_len, "n_slots": n_slots,
                        "chunk": chunk, "arch": "granite-3-2b (reduced)",
                        "quantile": QUANTILE,
                        "compute_scale": compute_scale},
              "modes": rows,
              "obs_demo": obs_demo}
    if compute_scale:
        result["obs_overhead"] = obs_overhead
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


def scenario_serve_prefix(archs=("granite-3-2b", "rwkv6-3b"),
                          n_requests: int = 8, prefix_len: int = 48,
                          suffix_min: int = 4, suffix_max: int = 24,
                          gen_len: int = 16, n_slots: int = 2,
                          chunk: int = 8,
                          out: str = "BENCH_prefix.json") -> dict:
    """Prefix caching on a shared-prompt trace (ISSUE 4): every request
    carries the same ``prefix_len``-token system prompt plus a unique
    suffix — the workload paged KV + prefix caching dedups.  Per arch
    (attention = shared full pages, ssm = recurrent-state snapshots),
    runs the SAME trace cold (prefix cache off) and warm (on), asserts
    zero token divergence, and reports the hit rate, chunks/pages
    skipped and the warm-vs-cold speedup."""
    import jax
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.launch.serve import _run_engine, _trace
    from repro.models import get_model

    rows = {}
    for arch in archs:
        cfg = reduce_config(get_config(arch)).replace(serve_chunk=chunk)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        reqs = _trace(cfg, n_requests, suffix_min, suffix_max, gen_len // 2,
                      gen_len, 0, shared_prefix=prefix_len)
        max_len = prefix_len + suffix_max + gen_len + 2
        kw = dict(mor=None, mor_mode="dense", n_slots=n_slots,
                  max_len=max_len, chunk=chunk)
        _, res_cold, rep_cold = _run_engine(cfg, params, reqs,
                                            prefix_cache=False, **kw)
        _, res_warm, rep_warm = _run_engine(cfg, params, reqs,
                                            prefix_cache=True, **kw)
        assert res_cold == res_warm, f"{arch}: prefix cache changed tokens"
        pc = rep_warm["prefix_cache"]
        # throughput on the SAME trace: tokens *served* per second
        # (prompt + generated), not tokens *dispatched* — a prefix hit
        # serves prompt tokens without dispatching them, which is the
        # whole point
        n_trace = sum(len(p) + g for p, g in reqs)
        row = {
            "cold_trace_tokens_per_s": n_trace / rep_cold["wall_s"],
            "warm_trace_tokens_per_s": n_trace / rep_warm["wall_s"],
            "speedup": round(rep_cold["wall_s"] / rep_warm["wall_s"], 3),
            "cold_prefill_tokens": rep_cold["prefill_tokens"],
            "warm_prefill_tokens": rep_warm["prefill_tokens"],
            "cold_dispatches": rep_cold["dispatches"],
            "warm_dispatches": rep_warm["dispatches"],
            "hit_rate": pc["hit_rate"],
            "chunks_skipped": pc["chunks_skipped"],
            "pages_shared": pc["pages_shared"],
            "pages_cowed": pc["pages_cowed"],
            "snapshots": pc["snapshots"],
            "snap_restores": pc["snap_restores"],
            "tokens_match": True,
        }
        print(f"serve_prefix_{arch},0,{row['speedup']:.3f}", flush=True)
        rows[arch] = row
    result = {"trace": {"n_requests": n_requests, "prefix_len": prefix_len,
                        "suffix_min": suffix_min, "suffix_max": suffix_max,
                        "gen_len": gen_len, "n_slots": n_slots,
                        "chunk": chunk, "archs": list(archs),
                        "note": "reduced configs; warm = best-of-3 after "
                                "a warmup pass, so the warm rows measure "
                                "a fully-populated prefix cache"},
              "archs": rows}
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


def scenario_serve_sharded(n_requests: int = 16, prompt_min: int = 8,
                           prompt_max: int = 96, gen_min: int = 4,
                           gen_len: int = 96, n_slots: int = 4,
                           chunk: int = 16,
                           out: str = "BENCH_sharded.json") -> dict:
    """Mesh-sharded paged serving (ISSUE 5): the serve-engine mixed
    trace (prompts 8-96 x gens 4-96) through
    ``Engine(layout="paged-sharded")`` on a page mesh over every visible
    device vs the single-device paged engine.  Asserts token-identical
    output with the prefix cache ON and OFF, nonzero page high-water on
    EVERY shard, and exactly ONE flash-merge collective per attention
    layer in the compiled decode step (the acceptance criteria).  Run
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` on a
    single host; on real multi-device hardware the same flag-free
    invocation shards over the accelerators.  Throughput rows are a
    layout-cost datapoint on forced host devices (the shards contend
    for the same CPU), NOT a speedup claim — the win this layout buys
    is KV capacity: per-device page memory drops by 1/P (reported as
    ``kv_pages_per_shard`` vs the single-device pool)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.launch.mesh import make_page_mesh
    from repro.launch.serve import _run_engine, _trace
    from repro.models import get_model

    n_dev = len(jax.devices())
    assert n_dev >= 2, (
        "serve-sharded needs a multi-device mesh: run with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4")
    mesh = make_page_mesh(n_dev)
    cfg = reduce_config(get_config("granite-3-2b")).replace(
        serve_chunk=chunk)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg, n_requests, prompt_min, prompt_max, gen_min,
                  gen_len, 0)
    max_len = prompt_max + gen_len + 2
    kw = dict(mor=None, mor_mode="dense", n_slots=n_slots,
              max_len=max_len, chunk=chunk)
    rows = {}
    eng_sh = None
    for prefix in (False, True):
        label = "prefix_on" if prefix else "prefix_off"
        eng_p, res_p, rep_p = _run_engine(cfg, params, reqs,
                                          prefix_cache=prefix, **kw)
        eng_sh, res_m, rep_m = _run_engine(cfg, params, reqs,
                                           layout="paged-sharded",
                                           mesh=mesh, prefix_cache=prefix,
                                           **kw)
        assert res_p == res_m, f"{label}: sharded tokens diverge"
        sh = rep_m["sharding"]
        hw = sh["kv_pages_hiwater_per_shard"]
        assert all(n > 0 for n in hw), f"{label}: empty shard {hw}"
        rows[label] = {
            "paged_tokens_per_s": rep_p["tokens_per_s"],
            "sharded_tokens_per_s": rep_m["tokens_per_s"],
            "layout_cost": round(rep_p["tokens_per_s"]
                                 / max(rep_m["tokens_per_s"], 1e-9), 3),
            "dispatches": rep_m["dispatches"],
            "kv_pages_single_device": eng_p.pool.n_pages,
            "kv_pages_per_shard": sh["kv_pages_per_shard"],
            "kv_pages_hiwater_per_shard": hw,
            "tokens_match": True,
        }
        print(f"serve_sharded_{label},0,{rep_m['tokens_per_s']:.1f}",
              flush=True)
    # one collective per attention layer per dispatch: the compiled
    # decode step's layer scan carries exactly one all-gather (the
    # packed flash merge) and no other collective
    lowered = eng_sh._step.lower(
        params, None, eng_sh.cache, jnp.zeros((n_slots, 1), jnp.int32),
        jnp.ones((n_slots,), jnp.int32), jnp.ones((n_slots,), bool),
        eng_sh._pending, eng_sh._base_key, None)
    lines = lowered.as_text().splitlines()
    n_ag = sum(1 for ln in lines
               if "all_gather" in ln or "all-gather" in ln)
    n_other = sum(1 for ln in lines
                  if "all_reduce" in ln or "all-reduce" in ln
                  or "collective_permute" in ln
                  or "collective-permute" in ln)
    # the paged layer loop is UNROLLED (per-layer tuple pool leaves, so
    # scatters stay in-place): the lowered step shows one all-gather
    # per layer rather than one inside a scan body
    assert n_ag == cfg.n_layers and n_other == 0, \
        (n_ag, cfg.n_layers, n_other)
    result = {"trace": {"arch": "granite-3-2b (reduced)",
                        "n_requests": n_requests,
                        "prompt_min": prompt_min, "prompt_max": prompt_max,
                        "gen_min": gen_min, "gen_len": gen_len,
                        "n_slots": n_slots, "chunk": chunk,
                        "n_shards": n_dev,
                        "note": "forced host devices share one CPU: the "
                                "tok/s rows price the shard_map layout, "
                                "the per-shard page counts show the "
                                "1/P KV-capacity scaling"},
              "collectives_per_attention_layer": 1,
              "modes": rows}
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


def scenario_serve_spec(ks=(2, 4, 8), caps=(0.0, 0.5), dims="256,1024,4",
                        n_requests: int = 10, prompt_min: int = 8,
                        prompt_max: int = 48, gen_min: int = 8,
                        gen_len: int = 32, n_slots: int = 4,
                        chunk: int = 32, with_mor: bool = True,
                        out: str = "BENCH_spec.json") -> dict:
    """Self-speculative decoding (ISSUE 9): the serve-engine mixed trace
    through ``Engine(spec_k=k, draft_cap=c)`` swept over the draft
    length and the MoR draft capacity, against the non-spec engine on
    the SAME seeded trace.  Greedy identity is ASSERTED for every
    dense-mode row (speculation must not change tokens).  Two engine
    families: ``dense`` (draft == target plans — the acceptance
    ceiling and the pure round-shape cost) and calibrated ``tiled``
    (clamped draft plans; ``draft_cap`` is a traced leaf, so the sweep
    shares one compiled step per phase).

    ITL accounting: the tracer observes inter-DISPATCH latency, which
    under speculation is the round cadence (a round emits
    ``1 + acceptance*k`` tokens at once), so rows carry both the raw
    round ITL and the per-token effective ITL (round ITL / mean tokens
    per round) — the headline compares effective ITL at the
    compute-dominated d256 scale, where a verify pass's k+1 positions
    ride one dispatch."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.launch.serve import _run_engine, _trace
    from repro.models import get_model
    from repro.obs import Observability

    cfg = reduce_config(get_config("granite-3-2b")).replace(
        serve_chunk=chunk)
    if dims and dims != "none":
        d, f, L = (int(x) for x in dims.split(","))
        cfg = cfg.replace(d_model=d, d_ff=f, n_layers=L)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg, n_requests, prompt_min, prompt_max, gen_min,
                  gen_len, 0)
    max_len = prompt_max + gen_len + 2
    # prefix cache off: best-of-3 re-runs one trace (see serve-engine)
    kw = dict(n_slots=n_slots, max_len=max_len, chunk=chunk,
              prefix_cache=False)

    def run_one(label, params_r, mor, mor_mode, spec_k=0, draft_cap=0.0):
        eng, results, rep = _run_engine(
            cfg, params_r, reqs, mor=mor, mor_mode=mor_mode,
            obs=Observability(), spec_k=spec_k, draft_cap=draft_cap,
            **kw)
        itl = eng.obs.tracer.summary().get("itl") or {}
        row = {"tokens_per_s": rep["tokens_per_s"],
               "decode_tokens_per_s": rep["decode_tokens_per_s"],
               "itl_round_p50_ms": round((itl.get("p50") or 0.0) * 1e3,
                                         3),
               "itl_round_p99_ms": round((itl.get("p99") or 0.0) * 1e3,
                                         3),
               "dispatches": rep["dispatches"],
               "requests": rep["requests_finished"]}
        tokens_per_round = 1.0
        if spec_k:
            sp = rep["spec"]
            tokens_per_round = (rep["decode_tokens"]
                                / max(sp["rounds"], 1))
            row.update(
                k=spec_k, draft_cap=draft_cap,
                acceptance_rate=round(sp["acceptance_rate"], 4),
                rounds=sp["rounds"], replays=sp["replays"],
                aborts=sp["aborts"],
                tokens_drafted=sp["tokens_drafted"],
                tokens_accepted=sp["tokens_accepted"],
                tokens_per_round=round(tokens_per_round, 3))
            dm = rep.get("obs", {}).get("device_metrics", {})
            for key in ("tokens_drafted", "tokens_accepted"):
                if key in dm:
                    row[f"device_{key}"] = dm[key]
        row["itl_per_token_p50_ms"] = round(
            row["itl_round_p50_ms"] / max(tokens_per_round, 1.0), 3)
        print(f"serve_spec_{label},0,{rep['tokens_per_s']:.1f}",
              flush=True)
        return results, row

    modes = {}
    res_base, base = run_one("dense_base", params, None, "dense")
    dense = {"baseline": base, "spec": []}
    for k in ks:
        res_s, row = run_one(f"dense_k{k}", params, None, "dense",
                             spec_k=k)
        row["tokens_match_baseline"] = (res_s == res_base)
        assert row["tokens_match_baseline"], \
            f"k={k}: speculation changed greedy tokens"
        dense["spec"].append(row)
    modes["dense"] = dense
    if with_mor:
        from repro.core.deploy import calibrate_lm
        from repro.data.pipeline import synthetic_lm_batch

        def batches():
            s = 0
            while True:
                b = synthetic_lm_batch(cfg, 4, 64, seed=0, step=s)
                yield {"tokens": jnp.asarray(b["tokens"])}
                s += 1
        params_m, mor, _ = calibrate_lm(params, cfg, api.forward,
                                        batches(), 2)
        res_mb, mbase = run_one("tiled_base", params_m, mor, "tiled")
        tiled = {"baseline": mbase, "spec": []}
        for k in ks:
            for cap in caps:
                res_m, row = run_one(f"tiled_k{k}_c{cap}", params_m, mor,
                                     "tiled", spec_k=k, draft_cap=cap)
                # informational only: tile capacity couples tokens
                # within a dispatch, so K+1-wide verify under tiled
                # plans is not bit-equal to 1-wide decode (greedy
                # identity is a dense-mode guarantee)
                row["tokens_match_baseline"] = (res_m == res_mb)
                tiled["spec"].append(row)
        modes["tiled"] = tiled

    # headline: best dense-mode config meeting the acceptance bar, its
    # effective ITL against the non-spec baseline (5% wall noise slack)
    cand = [r for r in dense["spec"] if r["acceptance_rate"] >= 0.5]
    best = max(cand or dense["spec"], key=lambda r: r["tokens_per_s"])
    headline = {
        "baseline_tokens_per_s": base["tokens_per_s"],
        "baseline_itl_p50_ms": base["itl_per_token_p50_ms"],
        "best_k": best["k"], "best_draft_cap": best["draft_cap"],
        "best_tokens_per_s": best["tokens_per_s"],
        "best_itl_per_token_p50_ms": best["itl_per_token_p50_ms"],
        "best_acceptance_rate": best["acceptance_rate"],
        "speedup_vs_baseline": round(
            best["tokens_per_s"] / max(base["tokens_per_s"], 1e-9), 3),
        "meets_acceptance": best["acceptance_rate"] >= 0.5,
        "itl_no_worse": (best["itl_per_token_p50_ms"]
                         <= base["itl_per_token_p50_ms"] * 1.05),
    }
    print(f"serve_spec_best_k{best['k']},0,"
          f"{headline['speedup_vs_baseline']:.3f}", flush=True)
    print(f"serve_spec_acceptance,0,{best['acceptance_rate']:.4f}",
          flush=True)
    result = {"trace": {"arch": "granite-3-2b (reduced)", "dims": dims,
                        "n_requests": n_requests,
                        "prompt_min": prompt_min,
                        "prompt_max": prompt_max, "gen_min": gen_min,
                        "gen_len": gen_len, "n_slots": n_slots,
                        "chunk": chunk, "ks": list(ks),
                        "draft_caps": list(caps),
                        "note": "ITL is per emitting dispatch = per "
                                "round under speculation; per-token "
                                "effective ITL divides by the round's "
                                "mean emitted tokens"},
              "modes": modes,
              "headline": headline}
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


def scenario_moe_modes(modes=("dense", "exact", "tiled", "kernel"),
                       n_requests: int = 8, prompt_min: int = 4,
                       prompt_max: int = 24, gen_min: int = 4,
                       gen_len: int = 12, n_slots: int = 2, chunk: int = 8,
                       dead_frac: float = 0.5,
                       out: str = "BENCH_moe_modes.json") -> dict:
    """Expert-level MoR through the serving engine, per execution mode
    (ISSUE 3): a mixed-length trace on reduced mixtral-8x7b with
    per-(layer, expert) calibrated predictors, reporting each mode's
    expert tile-skip fraction, step time and throughput vs dense, plus
    the telemetry-calibrated per-(layer, expert) capacities.

    Random-init models have no structured ReLU sparsity (measured
    frac_tiles_live = 1.0 in BENCH_serve.json), so the calibration
    injects a trained-model-like column sparsity profile
    (``calibrate_moe(inject_dead_frac=...)``, paper Fig. 1) — the skip
    fractions measure the machinery end to end, not model quality."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.configs.base import MoRConfig
    from repro.core.deploy import calibrate_moe
    from repro.data.pipeline import synthetic_lm_batch
    from repro.launch.serve import _run_engine, _trace
    from repro.models import get_model

    cfg = reduce_config(get_config("mixtral-8x7b")).replace(
        serve_chunk=chunk,
        # narrow tiles: at reduced dims (f = 64) the default 8x128 tile
        # covers a whole expert row-block, leaving nothing to skip
        mor=MoRConfig(enabled=True, relufied=True, corr_threshold=0.5,
                      tile_m=4, tile_n=16))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)

    def batches():
        s = 0
        while True:
            b = synthetic_lm_batch(cfg, 4, 32, seed=0, step=s)
            yield {"tokens": jnp.asarray(b["tokens"])}
            s += 1

    params, mor, cal = calibrate_moe(params, cfg, api.forward, batches(), 2,
                                     cluster_experts=False,
                                     inject_dead_frac=dead_frac)
    # held-out probe batch for the predictor-driven skip measurement
    probe = {"tokens": jnp.asarray(
        synthetic_lm_batch(cfg, 4, 32, seed=1, step=999)["tokens"])}
    reqs = _trace(cfg, n_requests, prompt_min, prompt_max, gen_min,
                  gen_len, 0)
    max_len = prompt_max + gen_len + 2
    rows = {}
    dense_tps = None
    for mode in modes:
        # prefix cache off: the harness re-runs one trace best-of-3, so
        # the cache would dedup prefill and skew the tok/s accounting
        eng, results, rep = _run_engine(
            cfg, params, reqs, mor=mor if mode != "dense" else None,
            mor_mode=mode, n_slots=n_slots, max_len=max_len, chunk=chunk,
            prefix_cache=False)
        row = {
            "tokens_per_s": rep["tokens_per_s"],
            "decode_tokens_per_s": rep["decode_tokens_per_s"],
            "dispatches": rep["dispatches"],
            "step_ms": round(rep["wall_s"] / max(rep["dispatches"], 1)
                             * 1e3, 3),
        }
        if mode == "dense":
            dense_tps = rep["tokens_per_s"]
        else:
            # predictor-driven skip: measured on the training-path
            # forward, where expert buffers run at expected occupancy
            # (C = cf*T*k/E).  The serving-telemetry fractions below
            # denominate over the full serving capacity buffer (C = T,
            # pad rows force-skipped), so they also count buffer
            # under-occupancy as skip — report both, assert on the
            # predictor one (CI moe-modes-smoke).
            _, aux = api.forward(params, cfg, probe, mor=mor,
                                 mor_mode=mode)
            comp = np.asarray(aux["moe_mor_stats"]["frac_tiles_computed"])
            row["expert_tile_skip_frac"] = round(1.0 - float(comp.mean()),
                                                 4)
            scomp = np.asarray(rep["per_expert_frac_tiles_computed"])
            row["serving_expert_tile_skip_frac"] = \
                round(1.0 - float(scomp.mean()), 4)
            row["per_expert_frac_tiles_live"] = \
                rep["per_expert_frac_tiles_live"]
            caps = eng.calibrate_capacities(quantile=QUANTILE)
            row["per_expert_capacity"] = \
                np.asarray(caps["moe_mor_stats"]).round(4).tolist()
        if dense_tps:
            row["speedup_vs_dense"] = round(row["tokens_per_s"]
                                            / dense_tps, 3)
        print(f"moe_modes_{mode},0,{rep['tokens_per_s']:.1f}", flush=True)
        rows[mode] = row
    result = {"trace": {"arch": "mixtral-8x7b (reduced)",
                        "n_requests": n_requests, "prompt_min": prompt_min,
                        "prompt_max": prompt_max, "gen_min": gen_min,
                        "gen_len": gen_len, "n_slots": n_slots,
                        "chunk": chunk, "tile_m": cfg.mor.tile_m,
                        "tile_n": cfg.mor.tile_n,
                        "inject_dead_frac": dead_frac,
                        "quantile": QUANTILE},
              "calibration": cal,
              "modes": rows}
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


def scenario_paged_kernel(batch_sizes=(2, 4, 8), blocks=(8, 16, 32),
                          page: int = 8, hkv: int = 4, groups: int = 2,
                          head_dim: int = 64, reps: int = 50,
                          out: str = "BENCH_paged_kernel.json") -> dict:
    """Paged flash-decode microbench (the PR 6 tentpole kernel): one
    decode step of ``gqa_paged_flash`` against the pure-jnp gather
    fallback (``pool_view`` + ``attend_batched``) across batch sizes and
    per-slot page counts, plus a bandwidth roofline per point — the
    pool bytes a decode step must touch (k+v pages of the active
    window) over the measured wall, formatted with the same helpers the
    EXPERIMENTS.md roofline tables use (``roofline_table.fmt_s``).  Off
    TPU the kernel row runs in Pallas interpret mode and is priced for
    CORRECTNESS visibility only (``kernel_backend`` says which); the
    jnp rows and the roofline columns are the portable signal."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    try:
        from benchmarks.roofline_table import fmt_s
    except ModuleNotFoundError:        # invoked as benchmarks/run.py
        from roofline_table import fmt_s
    from repro.distributed import decode_attention as da
    from repro.kernels import paged_attention as pk
    from repro.models.layers.attention import attend_batched

    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.RandomState(0)
    rows = []
    # scoped kernel-trace frame: this benchmark reports ITS OWN dispatch
    # counts even when something else in the process (another scenario,
    # a prior engine run) already bumped the process-global counters
    trace_ctx = pk.trace_scope()
    scope_counts = trace_ctx.__enter__()
    for B in batch_sizes:
        for nb in blocks:
            n_pages = 1 + B * nb + B * nb // 2
            ring = nb * page
            key = jax.random.PRNGKey(nb * 131 + B)
            ks = jax.random.split(key, 8)
            kpool = jax.random.normal(ks[0], (n_pages, page, hkv, head_dim),
                                      jnp.float32)
            vpool = jax.random.normal(ks[1], (n_pages, page, hkv, head_dim),
                                      jnp.float32)
            perm = rng.permutation(np.arange(1, n_pages))[:B * nb]
            bt = jnp.asarray(perm.reshape(B, nb), jnp.int32)
            qpos = jnp.full((B, 1), ring - 1, jnp.int32)
            tags = jnp.arange(ring, dtype=jnp.int32).reshape(nb, page)
            ppool = jnp.full((n_pages, page), -1, jnp.int32)
            ppool = ppool.at[bt[0]].set(tags)
            for b in range(1, B):
                ppool = ppool.at[bt[b]].set(tags)
            q = jax.random.normal(ks[2], (B, 1, hkv * groups, head_dim),
                                  jnp.float32)

            def jnp_gather(q, kpool, vpool, ppool):
                gk = da.pool_view(kpool, bt, 0).reshape(B, ring, hkv,
                                                        head_dim)
                gv = da.pool_view(vpool, bt, 0).reshape(B, ring, hkv,
                                                        head_dim)
                gp = da.pool_view(ppool, bt, -1).reshape(B, ring)
                return attend_batched(q, gk, gv, qpos, gp, causal=True,
                                      window=0)

            def jnp_pool_direct(q, kpool, vpool, ppool):
                kv_pos = da.pool_positions(ppool, bt)
                return da.gqa_pool_flash(q, kpool, vpool, kv_pos, qpos,
                                         window=0)

            def kernel(q, kpool, vpool, ppool):
                return pk.gqa_paged_flash(q, kpool, vpool, ppool, bt,
                                          qpos, window=0,
                                          interpret=not on_tpu)

            def time_fn(fn, n):
                f = jax.jit(fn)
                o = f(q, kpool, vpool, ppool)
                jax.block_until_ready(o)
                t0 = time.time()
                for _ in range(n):
                    o = f(q, kpool, vpool, ppool)
                jax.block_until_ready(o)
                return (time.time() - t0) / n, o

            t_g, o_g = time_fn(jnp_gather, reps)
            t_d, o_d = time_fn(jnp_pool_direct, reps)
            t_k, o_k = time_fn(kernel, reps if on_tpu else 2)
            assert np.allclose(o_g, o_k, atol=2e-5), (B, nb)
            assert np.allclose(o_g, o_d, atol=2e-5), (B, nb)
            # roofline: a decode step must read the active window's k+v
            # pages once — anything above that is gather/copy overhead
            window_bytes = 2 * B * ring * hkv * head_dim * 4
            row = {"batch": B, "blocks_per_slot": nb, "ring": ring,
                   "window_bytes": window_bytes,
                   "jnp_gather_us": round(t_g * 1e6, 1),
                   "jnp_pool_direct_us": round(t_d * 1e6, 1),
                   "kernel_us": round(t_k * 1e6, 1),
                   "jnp_gather_gbps": round(window_bytes / t_g / 1e9, 2),
                   "jnp_pool_direct_gbps": round(window_bytes / t_d / 1e9,
                                                 2),
                   "kernel_gbps": round(window_bytes / t_k / 1e9, 2),
                   "kernel_vs_gather": round(t_g / t_k, 3)}
            rows.append(row)
            print(f"paged_kernel_B{B}_nb{nb},"
                  f"{t_k*1e6:.0f},{t_g/t_k:.4f}", flush=True)
    trace_ctx.__exit__(None, None, None)   # scope counts survive the exit
    md = ["| B | blocks | window | jnp gather | pool direct | kernel | "
          "kernel GB/s |", "|---|---|---|---|---|---|---|"]
    for r in rows:
        md.append(f"| {r['batch']} | {r['blocks_per_slot']} | "
                  f"{r['ring']} | {fmt_s(r['jnp_gather_us']/1e6)} | "
                  f"{fmt_s(r['jnp_pool_direct_us']/1e6)} | "
                  f"{fmt_s(r['kernel_us']/1e6)} | {r['kernel_gbps']} |")
    result = {"shape": {"page": page, "n_kv_heads": hkv, "groups": groups,
                        "head_dim": head_dim, "dtype": "float32"},
              "kernel_backend": ("pallas-tpu" if on_tpu
                                 else "pallas-interpret"),
              "kernel_traces": dict(scope_counts),
              "rows": rows,
              "markdown": "\n".join(md)}
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


def scenario_serve_slo(policies=("fcfs", "priority", "sjf"),
                       rate_mults=(0.5, 1.0, 2.5),
                       duration_s: float = 4.0, n_slots: int = 4,
                       chunk: int = 8, gen_max: int = 16,
                       seed: int = 0, hi_pri_frac: float = 0.25,
                       out: str = "BENCH_slo.json") -> dict:
    """SLO under open-loop load (ISSUE 8): seeded Poisson arrivals at
    0.5x/1x/2.5x the measured closed-loop capacity drive each admission
    policy over the SAME offered load (same seed => byte-identical
    arrivals), reporting p50/p99 TTFT per priority class and ITL per
    policy.  A 5% oversize-injection exercises the typed rejection path
    mid-load.  Alongside the sweep, a deterministic preemption twin
    checks that a run with page-spill preemptions is token-identical to
    its FCFS no-preemption twin."""
    import jax
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.models import get_model
    from repro.obs import Observability
    from repro.serving import Engine
    from repro.serving.loadgen import (latency_stats, poisson_trace,
                                       run_open_loop)

    cfg = reduce_config(get_config("granite-3-2b")).replace(
        serve_chunk=chunk)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    p_lo, p_hi = 6, 3 * chunk
    max_len = p_hi + gen_max + 2

    engines = {p: Engine(cfg, params, mor_mode="dense", n_slots=n_slots,
                         max_len=max_len, chunk=chunk, telemetry=False,
                         obs=Observability(), policy=p)
               for p in policies}

    # closed-loop capacity (also the compile warmup): how many requests
    # per second the engine serves when the driver never lets it idle —
    # the sweep's offered loads are multiples of this
    rng = np.random.default_rng(seed)
    warm = [(rng.integers(1, cfg.vocab_size,
                          size=rng.integers(p_lo, p_hi + 1)
                          ).astype(np.int32),
             int(rng.integers(4, gen_max + 1))) for _ in range(12)]
    cap_wall = None
    for name, eng in engines.items():
        eng.run(list(warm))                      # compile everything
        eng.reset_counters()
        t0 = time.perf_counter()
        eng.run(list(warm))
        wall = time.perf_counter() - t0
        if name == "fcfs":
            cap_wall = wall
    capacity_req_s = len(warm) / cap_wall
    print(f"serve_slo_capacity_req_s,0,{capacity_req_s:.2f}", flush=True)

    # warm the preemption path too: the first spill/restore round-trip
    # compiles its gather/scatter kernels, and without this the stall
    # lands in the tail latencies of whichever timed run first hits
    # pool pressure (or a priority preemption)
    for eng in engines.values():
        for p, _ in warm[:n_slots + 1]:
            eng.submit(p, 4)
        for _ in range(2):
            eng.step()
        victim = eng.policy.spill_victim(eng.scheduler.slots)
        if eng._can_preempt and victim is not None:
            eng._preempt(victim)
        eng.run()
        eng.reset_counters()

    runs = []
    for mult in rate_mults:
        rate = capacity_req_s * mult
        arrivals = poisson_trace(
            rate, duration_s, cfg.vocab_size, seed=seed,
            prompt_len=(p_lo, p_hi), max_new=(4, gen_max),
            hi_pri_frac=hi_pri_frac, oversize_frac=0.05,
            max_len=max_len)
        for name, eng in engines.items():
            eng.reset_counters()
            res = run_open_loop(eng, arrivals)
            spans = eng.obs.tracer.request_spans()
            ttft = latency_stats(spans, res.submitted, arrivals)
            lost = sum(
                1 for rid, idx in res.submitted.items()
                if len(eng.results.get(rid, ()))
                != arrivals[idx].max_new_tokens)
            tr = eng.obs.tracer.summary()
            row = {
                "policy": name, "offered_x": mult,
                "rate_req_s": round(rate, 3),
                "n_arrivals": len(arrivals),
                "n_submitted": res.n_submitted,
                "n_rejected": len(res.rejected),
                "requests_lost": lost,
                "preemptions": eng.counters["preemptions"],
                "restores": eng.pool.spill_events["restores"],
                "ttft": ttft, "itl": tr["itl"],
                "queue_wait": tr["queue_wait"],
                "wall_s": round(res.wall_s, 3),
            }
            runs.append(row)
            p99 = ttft.get("all", {}).get("p99", float("nan"))
            print(f"serve_slo_{name}_x{mult},0,{p99:.4f}", flush=True)

    # deterministic preemption twin: same requests, priority policy
    # (forced preemptions) vs FCFS (none) — greedy sampling makes the
    # per-request token streams scheduling-invariant, so any divergence
    # is a spill/restore bug
    twin_prompts = [rng.integers(1, cfg.vocab_size,
                                 size=rng.integers(p_lo, p_hi + 1)
                                 ).astype(np.int32)
                    for _ in range(n_slots + 4)]
    e_f, e_p = engines["fcfs"], engines.get("priority")
    rids_f = [e_f.submit(p, gen_max) for p in twin_prompts]
    e_f.run()
    pre0 = e_p.counters["preemptions"]
    rids_p = [e_p.submit(p, gen_max)
              for p in twin_prompts[:n_slots + 1]]
    for _ in range(3):
        e_p.step()
    rids_p += [e_p.submit(p, gen_max, priority=5)
               for p in twin_prompts[n_slots + 1:]]
    e_p.run()
    twin = {
        "preemptions": e_p.counters["preemptions"] - pre0,
        "identical": all(
            e_f.results[rf] == e_p.results[rp]
            for rf, rp in zip(rids_f, rids_p)),
    }
    print(f"serve_slo_twin_identical,0,{int(twin['identical'])}",
          flush=True)

    # headline: at the top offered load, does the priority policy beat
    # FCFS on high-priority p99 TTFT?
    top = max(rate_mults)
    hi = {r["policy"]: r["ttft"].get("pri5", {}).get("p99")
          for r in runs if r["offered_x"] == top}
    headline = {
        "offered_x": top,
        "fcfs_hi_p99_ttft_s": hi.get("fcfs"),
        "priority_hi_p99_ttft_s": hi.get("priority"),
        "priority_beats_fcfs": (
            hi.get("priority") is not None and hi.get("fcfs") is not None
            and hi["priority"] < hi["fcfs"]),
    }
    print(f"serve_slo_priority_beats_fcfs,0,"
          f"{int(bool(headline['priority_beats_fcfs']))}", flush=True)

    result = {
        "trace": {"arch": "granite-3-2b (reduced)", "n_slots": n_slots,
                  "chunk": chunk, "prompt_len": [p_lo, p_hi],
                  "max_new": [4, gen_max], "duration_s": duration_s,
                  "seed": seed, "hi_pri_frac": hi_pri_frac,
                  "oversize_frac": 0.05,
                  "capacity_req_s": round(capacity_req_s, 3),
                  "rate_mults": list(rate_mults),
                  "policies": list(policies)},
        "runs": runs,
        "token_identity_twin": twin,
        "headline": headline,
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


def scenario_serve_quality(n_requests: int = 8, prompt_min: int = 8,
                           prompt_max: int = 48, gen_min: int = 4,
                           gen_len: int = 16, n_slots: int = 4,
                           chunk: int = 16, shadow_rate: float = 0.25,
                           drift_threshold: float = 0.25,
                           inject_drift: bool = True,
                           inject_layer: int = 1,
                           compute_scale: bool = True,
                           out: str = "BENCH_quality.json") -> dict:
    """Predictor-quality observability (ISSUE 10): shadow-oracle
    scoring + per-layer drift detection, in four phases —

    1. PARITY: the same trace through a shadow-off and a shadow-on
       engine; the shadow pass writes only to the metrics block, so
       generated tokens must be bit-identical.
    2. CLEAN: several passes with shadow scoring on a healthy
       calibrated predictor — the drift detector must stay silent.
    3. INJECTED (``inject_drift``): one layer's calibration
       coefficients are perturbed mid-run
       (``obs.quality.inject_coefficient_drift`` via
       ``Engine.update_mor`` — no recompile) and the detector must
       flag that layer, and ONLY that layer, with a drift event in the
       Perfetto timeline.
    4. OVERHEAD (``compute_scale``): paired A/B at the d256
       compute-dominated point, shadow_rate=1/16 vs 0, interleaved
       timed passes in ONE process (same harness as serve-engine's obs
       A/B — separate-process A/B can't resolve a few percent on a
       shared CPU); acceptance budget < 5% tokens/s."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.core.deploy import calibrate_lm
    from repro.data.pipeline import synthetic_lm_batch
    from repro.launch.serve import _trace
    from repro.models import get_model
    from repro.obs import Observability, validate_chrome_trace
    from repro.obs.quality import inject_coefficient_drift
    from repro.serving import Engine

    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)

    def batches(c, a):
        s = 0
        while True:
            b = synthetic_lm_batch(c, n_slots, 64, seed=0, step=s)
            yield {"tokens": jnp.asarray(b["tokens"])}
            s += 1

    params, mor, _cal = calibrate_lm(params, cfg, api.forward,
                                     batches(cfg, api), 2)
    reqs = _trace(cfg, n_requests, prompt_min, prompt_max, gen_min,
                  gen_len, 0)
    max_len = prompt_max + gen_len + 2
    kw = dict(mor=mor, mor_mode="tiled", n_slots=n_slots,
              max_len=max_len, chunk=chunk, prefix_cache=False)

    def tok(res):
        return {int(r): [int(t) for t in np.asarray(v)]
                for r, v in res.items()}

    # 1) parity: shadow scoring must not touch the primary path's tokens
    eng_off = Engine(cfg, params, **kw)
    res_off = eng_off.run(list(reqs))
    obs = Observability()
    eng = Engine(cfg, params, obs=obs, shadow_rate=shadow_rate,
                 drift_threshold=drift_threshold, **kw)
    res_on = eng.run(list(reqs))
    parity = tok(res_off) == tok(res_on)
    print(f"serve_quality_parity,0,{int(parity)}", flush=True)

    # 2) clean passes: a healthy predictor must not trip the detector
    eng.run(list(reqs))
    rep_clean = eng.report()
    q_clean = rep_clean["quality"]
    clean = {"shadow_dispatches": q_clean["shadow_dispatches"],
             "groups": q_clean["groups"],
             "n_drifted": q_clean["drift"]["n_drifted"],
             "n_series": q_clean["drift"]["n_series"]}
    print(f"serve_quality_clean_drifted,0,{clean['n_drifted']}",
          flush=True)

    # 3) mid-run coefficient injection -> the detector must fire on the
    # perturbed layer only (two passes: the EWMA needs two drifted
    # flushes to cross an absolute threshold — by design, one noisy
    # flush can't flap the flag)
    injected = None
    if inject_drift:
        group = sorted(eng.raw_mor.keys())[0]
        eng.update_mor(inject_coefficient_drift(eng.raw_mor, group,
                                                inject_layer))
        eng.run(list(reqs))
        eng.run(list(reqs))
        rep_inj = eng.report()
        q_inj = rep_inj["quality"]
        # drift events carry the STAT-group name (e.g. "mor_stats"),
        # not the raw-mor group the injection keyed on — compare the
        # (layer, expert) coordinates, which are shared
        drifted = sorted({(e["layer"], e["expert"])
                          for e in q_inj["drift"]["drifted"]})
        trace = obs.tracer.to_chrome_trace()
        n_drift_ev = sum(1 for e in trace["traceEvents"]
                         if str(e.get("name", "")).startswith("drift "))
        injected = {
            "group": group, "layer": inject_layer,
            "shadow_dispatches": q_inj["shadow_dispatches"],
            "groups": q_inj["groups"],
            "drifted": q_inj["drift"]["drifted"],
            "fired_on_injected_only": drifted == [(inject_layer, None)],
            "trace_drift_events": n_drift_ev,
            "trace_problems": validate_chrome_trace(trace),
        }
        print(f"serve_quality_injected_fired,0,"
              f"{int(injected['fired_on_injected_only'])}", flush=True)

    # 4) shadow-overhead A/B at the compute-dominated scale
    overhead = None
    rows = {}
    if compute_scale:
        cfg2 = reduce_config(get_config("granite-3-2b")).replace(
            serve_chunk=32, d_model=256, d_ff=1024, n_layers=4)
        api2 = get_model(cfg2)
        params2 = api2.init(jax.random.PRNGKey(0), cfg2)
        params2, mor2, _ = calibrate_lm(params2, cfg2, api2.forward,
                                        batches(cfg2, api2), 2)
        reqs2 = _trace(cfg2, n_requests, prompt_min, prompt_max,
                       gen_min, gen_len, 0)
        kw2 = dict(mor=mor2, mor_mode="tiled", n_slots=n_slots,
                   max_len=max_len, chunk=32, prefix_cache=False)
        eng0 = Engine(cfg2, params2, obs=Observability(),
                      shadow_rate=0.0, **kw2)
        eng1 = Engine(cfg2, params2, obs=Observability(),
                      shadow_rate=1.0 / 16, **kw2)
        eng0.run(list(reqs2))
        eng1.run(list(reqs2))           # compile warmup, untimed
        walls = {"off": float("inf"), "on": float("inf")}
        for _ in range(5):
            for label, e in (("off", eng0), ("on", eng1)):
                e.reset_counters()
                t0 = time.time()
                e.run(list(reqs2))
                walls[label] = min(walls[label], time.time() - t0)
        rep1 = eng1.report()
        n_tok = rep1["prefill_tokens"] + rep1["decode_tokens"]
        overhead = round(1.0 - walls["off"] / walls["on"], 4)
        rows["tiled@d256-shadow"] = {
            "tokens_per_s": n_tok / walls["on"],
            "paired_off_tokens_per_s": n_tok / walls["off"],
            "shadow_rate": 1.0 / 16,
            "shadow_dispatches":
                rep1["quality"]["shadow_dispatches"],
        }
        print(f"serve_quality_overhead,0,{overhead:.4f}", flush=True)

    result = {"trace": {"n_requests": n_requests,
                        "prompt_min": prompt_min,
                        "prompt_max": prompt_max, "gen_min": gen_min,
                        "gen_len": gen_len, "n_slots": n_slots,
                        "chunk": chunk,
                        "arch": "granite-3-2b (reduced)",
                        "shadow_rate": shadow_rate,
                        "drift_threshold": drift_threshold,
                        "compute_scale": compute_scale},
              "token_parity": parity,
              "clean": clean,
              "injected": injected,
              "modes": rows}
    if overhead is not None:
        result["shadow_overhead"] = overhead
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="figures",
                    choices=("figures", "serve-engine", "moe-modes",
                             "serve-prefix", "serve-sharded",
                             "paged-kernel", "serve-slo", "serve-spec",
                             "serve-quality"))
    ap.add_argument("--archs", default=None,
                    help="serve-prefix: comma-separated arch list "
                         "(default granite-3-2b,rwkv6-3b)")
    ap.add_argument("--prefix-len", type=int, default=48)
    ap.add_argument("--modes", default=None,
                    help="default: dense,tiled,kernel (serve-engine) / "
                         "dense,exact,tiled,kernel (moe-modes)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-max", type=int, default=96)
    ap.add_argument("--gen-len", type=int, default=96)
    ap.add_argument("--no-compute-scale", action="store_true",
                    help="skip the d256 compute-dominated row (CI smoke)")
    ap.add_argument("--slo-duration", type=float, default=4.0,
                    help="serve-slo: seconds of offered load per run")
    ap.add_argument("--slo-rates", default=None,
                    help="serve-slo: comma-separated offered-load "
                         "multiples of capacity (default 0.5,1.0,2.5)")
    ap.add_argument("--policies", default=None,
                    help="serve-slo: comma-separated policy list "
                         "(default fcfs,priority,sjf)")
    ap.add_argument("--spec-ks", default=None,
                    help="serve-spec: comma-separated draft lengths "
                         "(default 2,4)")
    ap.add_argument("--spec-caps", default=None,
                    help="serve-spec: comma-separated draft_cap values "
                         "for the tiled rows (default 0.0,0.5)")
    ap.add_argument("--spec-dims", default="256,1024,4",
                    help="serve-spec: d_model,d_ff,n_layers override "
                         "('none' keeps the plain reduced config — the "
                         "CI smoke size)")
    ap.add_argument("--no-mor-draft", action="store_true",
                    help="serve-spec: skip the calibrated tiled rows "
                         "(CI smoke)")
    ap.add_argument("--no-inject-drift", action="store_true",
                    help="serve-quality: skip the mid-run coefficient "
                         "injection phase")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.scenario == "serve-quality":
        scenario_serve_quality(
            n_requests=args.requests,
            inject_drift=not args.no_inject_drift,
            compute_scale=not args.no_compute_scale,
            out=args.out or "BENCH_quality.json")
        return
    if args.scenario == "serve-spec":
        scenario_serve_spec(
            ks=tuple(int(x) for x in (args.spec_ks or "2,4,8").split(",")),
            caps=tuple(float(x) for x in
                       (args.spec_caps or "0.0,0.5").split(",")),
            dims=args.spec_dims,
            n_requests=args.requests,
            prompt_max=args.prompt_max, gen_len=args.gen_len,
            with_mor=not args.no_mor_draft,
            out=args.out or "BENCH_spec.json")
        return
    if args.scenario == "serve-slo":
        scenario_serve_slo(
            policies=tuple((args.policies
                            or "fcfs,priority,sjf").split(",")),
            rate_mults=tuple(float(x) for x in (
                args.slo_rates or "0.5,1.0,2.5").split(",")),
            duration_s=args.slo_duration,
            out=args.out or "BENCH_slo.json")
        return
    if args.scenario == "moe-modes":
        scenario_moe_modes(modes=tuple((args.modes
                                        or "dense,exact,tiled,kernel"
                                        ).split(",")),
                           n_requests=args.requests,
                           prompt_max=args.prompt_max,
                           gen_len=args.gen_len,
                           out=args.out or "BENCH_moe_modes.json")
        return
    if args.scenario == "paged-kernel":
        scenario_paged_kernel(out=args.out or "BENCH_paged_kernel.json")
        return
    if args.scenario == "serve-sharded":
        scenario_serve_sharded(n_requests=args.requests,
                               prompt_max=args.prompt_max,
                               gen_len=args.gen_len,
                               out=args.out or "BENCH_sharded.json")
        return
    if args.scenario == "serve-prefix":
        scenario_serve_prefix(archs=tuple((args.archs
                                           or "granite-3-2b,rwkv6-3b"
                                           ).split(",")),
                              n_requests=args.requests,
                              prefix_len=args.prefix_len,
                              gen_len=args.gen_len,
                              out=args.out or "BENCH_prefix.json")
        return
    if args.scenario == "serve-engine":
        scenario_serve_engine(modes=tuple((args.modes
                                           or "dense,tiled,kernel"
                                           ).split(",")),
                              n_requests=args.requests,
                              prompt_max=args.prompt_max,
                              gen_len=args.gen_len,
                              compute_scale=not args.no_compute_scale,
                              out=args.out or "BENCH_serve.json")
        return
    from benchmarks import figures
    results = []
    results.append(_run("fig1_negative_relu_input_fraction",
                        figures.fig1_negative_fraction))
    results.append(_run("fig3_relu_mac_fraction",
                        figures.fig3_mac_breakdown))
    results.append(_run("fig5_binary_pearson_mean",
                        figures.fig5_correlation))
    results.append(_run("fig8_closest_angle_mean_deg",
                        figures.fig8_angles))
    results.append(_run("fig6_binary_alone_best_savings",
                        figures.fig6_threshold_binary_alone))
    results.append(_run("fig9_hybrid_best_savings", figures.fig9_hybrid))
    results.append(_run("fig12_mispredicted_zero_rate",
                        figures.fig12_breakdown))
    results.append(_run("fig13_modeled_speedup",
                        figures.fig13_speedup_energy))

    # roofline: aggregate whatever dry-run records exist
    from benchmarks import roofline_table
    recs = roofline_table.load_records()
    if recs:
        s = roofline_table.summary(recs)
        print(f"roofline_cells_ok,{0:.0f},{s['ok']}")
        print(f"roofline_mean_train_fraction,{0:.0f},"
              f"{s['mean_roofline_fraction_train']:.4f}")

    import json
    import os
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()

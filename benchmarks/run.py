"""Benchmark harness: one entry per paper table/figure + the roofline
aggregation.  Prints ``name,us_per_call,derived`` CSV (timing = wall time
of the reproduction; derived = the figure's headline number)."""
from __future__ import annotations

import sys
import time


def _run(name, fn):
    t0 = time.time()
    derived, detail = fn()
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived:.4f}", flush=True)
    return {"name": name, "us_per_call": us, "derived": derived,
            "detail": detail}


def main() -> None:
    from benchmarks import figures
    results = []
    results.append(_run("fig1_negative_relu_input_fraction",
                        figures.fig1_negative_fraction))
    results.append(_run("fig3_relu_mac_fraction",
                        figures.fig3_mac_breakdown))
    results.append(_run("fig5_binary_pearson_mean",
                        figures.fig5_correlation))
    results.append(_run("fig8_closest_angle_mean_deg",
                        figures.fig8_angles))
    results.append(_run("fig6_binary_alone_best_savings",
                        figures.fig6_threshold_binary_alone))
    results.append(_run("fig9_hybrid_best_savings", figures.fig9_hybrid))
    results.append(_run("fig12_mispredicted_zero_rate",
                        figures.fig12_breakdown))
    results.append(_run("fig13_modeled_speedup",
                        figures.fig13_speedup_energy))

    # roofline: aggregate whatever dry-run records exist
    from benchmarks import roofline_table
    recs = roofline_table.load_records()
    if recs:
        s = roofline_table.summary(recs)
        print(f"roofline_cells_ok,{0:.0f},{s['ok']}")
        print(f"roofline_mean_train_fraction,{0:.0f},"
              f"{s['mean_roofline_fraction_train']:.4f}")

    import json
    import os
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()

"""Cross-PR benchmark trajectory: one headline row per BENCH_*.json.

Every serving-layer PR leaves a ``BENCH_<name>.json`` artifact behind
(serve-engine, moe-modes, serve-prefix, serve-sharded, paged-kernel,
serve-slo, serve-spec).  This module reads whichever exist and distills
each into one row — the subsystem, its headline number, and the
one-line context needed to read it — so EXPERIMENTS.md carries a
single table showing how the system's measured capabilities accreted
across the PR stack.  Extraction is defensive (``.get`` chains):
a missing or older-schema file yields a "(not run)" row, never a crash.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional


def _load(path: str) -> Optional[Dict]:
    if not os.path.exists(path):
        return None
    try:
        return json.load(open(path))
    except (OSError, ValueError):
        return None


def _serve(d: Dict) -> List[Dict]:
    rows = []
    tiled = d.get("modes", {}).get("tiled", {})
    if "engine_speedup_vs_static" in tiled:
        rows.append({
            "pr": "2", "subsystem": "continuous batching",
            "benchmark": "serve-engine",
            "headline": f"{tiled['engine_speedup_vs_static']:.2f}x vs "
                        "static batch",
            "detail": f"{tiled.get('tokens_per_s', 0):.0f} tok/s, "
                      "tiled MoR, mixed trace"})
    d256 = d.get("modes", {}).get("dense@d256", {})
    if "layout_cost_tokens_per_s" in d256:
        rows.append({
            "pr": "6", "subsystem": "paged KV layout",
            "benchmark": "serve-engine (d256)",
            "headline": f"paged/slotted = "
                        f"{d256['layout_cost_tokens_per_s']:.2f}x",
            "detail": ">= 1 means the page indirection is free at the "
                      "compute-bound scale"})
    if "obs_overhead" in d:
        rows.append({
            "pr": "7", "subsystem": "observability",
            "benchmark": "serve-engine (obs A/B)",
            "headline": f"{d['obs_overhead'] * 100:.1f}% tokens/s "
                        "overhead",
            "detail": "device-resident counters + span tracer vs plain "
                      "engine, paired best-of-5"})
    return rows


def _moe(d: Dict) -> List[Dict]:
    tiled = d.get("modes", {}).get("tiled", {})
    if "expert_tile_skip_frac" not in tiled:
        return []
    return [{
        "pr": "3", "subsystem": "expert-level MoR",
        "benchmark": "moe-modes",
        "headline": f"{tiled['expert_tile_skip_frac'] * 100:.0f}% expert "
                    "tiles skipped",
        "detail": "per-(layer, expert) predictors, injected column "
                  "sparsity, tiled mode"}]


def _prefix(d: Dict) -> List[Dict]:
    archs = d.get("archs", {})
    if not archs:
        return []
    best = max(archs.items(), key=lambda kv: kv[1].get("speedup", 0))
    return [{
        "pr": "4", "subsystem": "prefix caching",
        "benchmark": "serve-prefix",
        "headline": f"{best[1].get('speedup', 0):.2f}x warm vs cold "
                    f"({best[0]})",
        "detail": f"hit rate {best[1].get('hit_rate', 0):.0%}, "
                  "token-identical, shared-prompt trace"}]


def _sharded(d: Dict) -> List[Dict]:
    on = d.get("modes", {}).get("prefix_on", {})
    if not on:
        return []
    per = on.get("kv_pages_per_shard")
    single = on.get("kv_pages_single_device")
    return [{
        "pr": "5", "subsystem": "mesh-sharded pages",
        "benchmark": "serve-sharded",
        "headline": f"{single} -> {per} KV pages/device",
        "detail": "token-identical on forced host devices, one merge "
                  "collective per attention layer"}]


def _kernel(d: Dict) -> List[Dict]:
    rows = d.get("rows", [])
    if not rows:
        return []
    best = max(rows, key=lambda r: r.get("jnp_pool_direct_gbps", 0))
    return [{
        "pr": "6", "subsystem": "flash-decode kernel",
        "benchmark": "paged-kernel",
        "headline": f"{best.get('jnp_pool_direct_gbps', 0):.1f} GB/s "
                    "pool-direct decode",
        "detail": f"B={best.get('batch')}, ring={best.get('ring')}; "
                  f"kernel backend {d.get('kernel_backend', '?')}"}]


def _slo(d: Dict) -> List[Dict]:
    hl = d.get("headline", {})
    twin = d.get("token_identity_twin", {})
    if not hl:
        return []
    pri, fcfs = hl.get("priority_hi_p99_ttft_s"), hl.get("fcfs_hi_p99_ttft_s")
    head = ("priority p99 TTFT "
            f"{pri * 1e3:.0f} ms vs fcfs {fcfs * 1e3:.0f} ms"
            if pri is not None and fcfs is not None else "(partial run)")
    return [{
        "pr": "8", "subsystem": "SLO scheduling",
        "benchmark": "serve-slo",
        "headline": head,
        "detail": f"at {hl.get('offered_x', '?')}x overload; preemption "
                  f"twin identical = {twin.get('identical')}"}]


def _spec(d: Dict) -> List[Dict]:
    hl = d.get("headline", {})
    if not hl:
        return []
    return [{
        "pr": "9", "subsystem": "speculative decoding",
        "benchmark": "serve-spec",
        "headline": f"k={hl.get('best_k')}, acceptance "
                    f"{hl.get('best_acceptance_rate', 0):.0%}, "
                    f"{hl.get('speedup_vs_baseline', 0):.2f}x tokens/s",
        "detail": "self-speculative draft/verify through COW block "
                  "tables, greedy token-identical; ITL no worse = "
                  f"{hl.get('itl_no_worse')}"}]


def _quality(d: Dict) -> List[Dict]:
    inj = d.get("injected") or {}
    if not inj:
        return []
    ov = d.get("shadow_overhead")
    return [{
        "pr": "10", "subsystem": "predictor quality",
        "benchmark": "serve-quality",
        "headline": "drift fired on injected layer only = "
                    f"{inj.get('fired_on_injected_only')}",
        "detail": "shadow-oracle scoring, token parity = "
                  f"{d.get('token_parity')}, scored-dispatch overhead "
                  + ("n/a" if ov is None else f"{ov * 100:+.1f}%")
                  + " tokens/s at 1/16 sampling"}]


_EXTRACTORS = [
    ("BENCH_serve.json", _serve),
    ("BENCH_moe_modes.json", _moe),
    ("BENCH_prefix.json", _prefix),
    ("BENCH_sharded.json", _sharded),
    ("BENCH_paged_kernel.json", _kernel),
    ("BENCH_slo.json", _slo),
    ("BENCH_spec.json", _spec),
    ("BENCH_quality.json", _quality),
]


def collect(root: str = ".") -> List[Dict]:
    """One row per headline found across the BENCH artifacts in
    ``root``, ordered by PR number."""
    rows: List[Dict] = []
    for fname, extract in _EXTRACTORS:
        d = _load(os.path.join(root, fname))
        if d is None:
            continue
        rows.extend(extract(d))
    rows.sort(key=lambda r: (int(r["pr"]), r["benchmark"]))
    return rows


def markdown(rows: List[Dict]) -> str:
    md = ["| PR | subsystem | benchmark | headline | context |",
          "|---|---|---|---|---|"]
    for r in rows:
        md.append(f"| {r['pr']} | {r['subsystem']} | {r['benchmark']} | "
                  f"{r['headline']} | {r['detail']} |")
    return "\n".join(md)


def trajectory_section(root: str = ".") -> str:
    """The §Trajectory block for EXPERIMENTS.md (empty string when no
    BENCH artifact exists yet)."""
    rows = collect(root)
    if not rows:
        return ""
    return f"""\
## §Trajectory (cross-PR benchmark summary)

One headline per serving-layer PR, distilled from the BENCH_*.json
artifacts present in the repo root (regenerate any of them with
`PYTHONPATH=src python -m benchmarks.run --scenario <name>`; this table
rebuilds via `python -m benchmarks.trajectory` or
`make_experiments_md`).  Numbers are CPU-container measurements on
reduced configs — trends and invariants (token identity, overhead
bounds) are the signal, absolute tok/s is not.

{markdown(rows)}

"""


def main() -> None:
    rows = collect()
    if not rows:
        print("no BENCH_*.json artifacts found")
        return
    print(markdown(rows))


if __name__ == "__main__":
    main()

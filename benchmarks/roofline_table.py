"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline
tables (single-pod roofline, multi-pod compile proof)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ARCH_ORDER = ["qwen1.5-110b", "granite-20b", "granite-3-2b", "qwen2-7b",
              "deepseek-v2-236b", "mixtral-8x7b", "rwkv6-3b",
              "phi-3-vision-4.2b", "zamba2-7b", "hubert-xlarge"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(directory: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:
            pass
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_markdown(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | GiB/dev (bf16) | fits | t_compute | t_memory | "
        "t_collective | dominant | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    by_key = {(r["arch"], r["shape"]): r for r in recs
              if r.get("mesh") == "pod"}
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = by_key.get((a, s))
            if r is None:
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} | {s} | - | - | - | - | - | "
                             f"{r['status'][:40]} | - | - |")
                continue
            rf = r["roofline"]
            lines.append(
                f"| {a} | {s} | "
                f"{r.get('per_device_gib_bf16_corrected', '-')} | "
                f"{'Y' if r.get('fits_16gib_hbm') else 'N'} | "
                f"{fmt_s(rf['t_compute_s'])} | {fmt_s(rf['t_memory_s'])} | "
                f"{fmt_s(rf['t_collective_s'])} | {rf['dominant']} | "
                f"{rf['useful_flop_ratio']:.2f} | "
                f"{rf['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def multipod_markdown(recs: List[Dict]) -> str:
    lines = ["| arch | shape | status | compile_s | wire GB/chip | "
             "DCI GB/chip |", "|---|---|---|---|---|---|"]
    by_key = {(r["arch"], r["shape"]): r for r in recs
              if r.get("mesh") == "multipod"}
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = by_key.get((a, s))
            if r is None:
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} | {s} | {r['status'][:50]} | - | - | - |")
                continue
            cb = r["roofline"]["collective_breakdown"]
            lines.append(
                f"| {a} | {s} | ok | {r['compile_s']} | "
                f"{cb.get('total_wire_bytes', 0)/1e9:.1f} | "
                f"{cb.get('dci_bytes', 0)/1e9:.1f} |")
    return "\n".join(lines)


def summary(recs: List[Dict]) -> Dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if str(r.get("status", "")).startswith("skip")]
    err = [r for r in recs if str(r.get("status", "")).startswith("error")]
    fits = [r for r in ok if r.get("fits_16gib_hbm")]
    return {"ok": len(ok), "skip": len(skip), "error": len(err),
            "fits": len(fits),
            "mean_roofline_fraction_train": float(sum(
                r["roofline"]["roofline_fraction"] for r in ok
                if r["shape"] == "train_4k" and r["mesh"] == "pod") /
                max(1, sum(1 for r in ok if r["shape"] == "train_4k"
                           and r["mesh"] == "pod")))}


if __name__ == "__main__":
    recs = load_records()
    print(roofline_markdown(recs))
    print()
    print(multipod_markdown(recs))
    print()
    print(json.dumps(summary(recs), indent=1))

"""Shared benchmark substrate: train the paper's four DNNs at reduced
scale on the deterministic synthetic tasks, calibrate MoR, cache results.

Training here is real gradient descent (the activation statistics MoR
exploits only appear in trained networks); results are cached under
experiments/cache so the full benchmark suite re-runs in seconds.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.serialization import load_pytree, save_pytree
from repro.configs import get_config, reduce_config
from repro.configs.base import ModelConfig
from repro.data.pipeline import synthetic_frames_batch, synthetic_image_batch
from repro.models import cnn as cnn_mod
from repro.models import tds as tds_mod

CACHE = "experiments/cache"
PAPER_DNNS = ["paper-tds", "paper-cnn10", "paper-resnet18",
              "paper-darknet19"]

_TRAIN_STEPS = {"paper-tds": 150, "paper-cnn10": 200,
                "paper-resnet18": 150, "paper-darknet19": 120}
_BATCH = 32


def _sgd(params, grads, lr):
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def train_cnn(cfg: ModelConfig, steps: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = cnn_mod.init_params(key, cfg)
    state = cnn_mod.init_state(cfg)

    @jax.jit
    def step_fn(params, state, images, labels):
        def loss_fn(p):
            logits, new_state, _ = cnn_mod.forward(p, state, cfg, images,
                                                   train=True)
            lf = logits.astype(jnp.float32)
            ce = (jax.nn.logsumexp(lf, -1)
                  - jnp.take_along_axis(lf, labels[:, None], 1)[:, 0]).mean()
            acc = (logits.argmax(-1) == labels).mean()
            return ce, (new_state, acc)
        (loss, (new_state, acc)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        return _sgd(params, g, 0.05), new_state, loss, acc

    for s in range(steps):
        d = synthetic_image_batch(cfg, _BATCH, seed=seed, step=s)
        params, state, loss, acc = step_fn(params, state,
                                           jnp.asarray(d["images"]),
                                           jnp.asarray(d["labels"]))
    return params, state, float(loss), float(acc)


def train_tds(cfg: ModelConfig, steps: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = tds_mod.init_params(key, cfg)

    @jax.jit
    def step_fn(params, frames, labels):
        def loss_fn(p):
            logits, _ = tds_mod.forward(p, cfg, {"frames": frames})
            lf = logits.astype(jnp.float32)
            ce = (jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(
                lf, labels[..., None], -1)[..., 0]).mean()
            acc = (logits.argmax(-1) == labels).mean()
            return ce, acc
        (loss, acc), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return _sgd(params, g, 0.05), loss, acc

    for s in range(steps):
        d = synthetic_frames_batch(cfg, 8, 64, seed=seed, step=s)
        params, loss, acc = step_fn(params, jnp.asarray(d["frames"]),
                                    jnp.asarray(d["labels"]))
    return params, float(loss), float(acc)


def eval_accuracy(name: str, cfg, params, state, *, mor=None,
                  mor_mode="dense", n_batches=4, seed=123) -> float:
    accs = []
    for s in range(n_batches):
        if cfg.family == "cnn":
            d = synthetic_image_batch(cfg, 64, seed=seed, step=s)
            logits, _, _ = cnn_mod.forward(params, state, cfg,
                                           jnp.asarray(d["images"]),
                                           train=False, mor=mor,
                                           mor_mode=mor_mode)
            accs.append(float((logits.argmax(-1) ==
                               jnp.asarray(d["labels"])).mean()))
        else:
            d = synthetic_frames_batch(cfg, 16, 64, seed=seed, step=s)
            logits, _ = tds_mod.forward(params, cfg,
                                        {"frames": jnp.asarray(d["frames"])},
                                        mor=mor, mor_mode=mor_mode)
            accs.append(float((logits.argmax(-1) ==
                               jnp.asarray(d["labels"])).mean()))
    return float(np.mean(accs))


_MODELS: Dict[str, Tuple] = {}


def get_trained(name: str):
    """-> (cfg, params, state_or_None, train_acc).  Disk-cached."""
    if name in _MODELS:
        return _MODELS[name]
    cfg = reduce_config(get_config(name))
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, name.replace("/", "_"))
    steps = _TRAIN_STEPS[name]
    if cfg.family == "cnn":
        tmpl_p = cnn_mod.init_params(jax.random.PRNGKey(0), cfg)
        tmpl_s = cnn_mod.init_state(cfg)
        if os.path.exists(path + ".npz"):
            blob, extra = load_pytree({"p": tmpl_p, "s": tmpl_s}, path)
            out = (cfg, blob["p"], blob["s"], extra.get("acc", -1.0))
        else:
            p, s, loss, acc = train_cnn(cfg, steps)
            save_pytree({"p": p, "s": s}, path, {"acc": acc})
            out = (cfg, p, s, acc)
    else:
        tmpl_p = tds_mod.init_params(jax.random.PRNGKey(0), cfg)
        if os.path.exists(path + ".npz"):
            blob, extra = load_pytree({"p": tmpl_p}, path)
            out = (cfg, blob["p"], None, extra.get("acc", -1.0))
        else:
            p, loss, acc = train_tds(cfg, steps)
            save_pytree({"p": p}, path, {"acc": acc})
            out = (cfg, p, None, acc)
    _MODELS[name] = out
    return out


def get_taps(name: str, n_batches: int = 3, seed: int = 77) -> List[Dict]:
    """Per-ReLU-layer taps {p_bin, p_base, relu_in} accumulated as numpy."""
    cfg, params, state, _ = get_trained(name)
    all_taps: List[Dict] = []
    for s in range(n_batches):
        if cfg.family == "cnn":
            d = synthetic_image_batch(cfg, 32, seed=seed, step=s)
            _, _, aux = cnn_mod.forward(params, state, cfg,
                                        jnp.asarray(d["images"]),
                                        train=False, with_taps=True)
        else:
            d = synthetic_frames_batch(cfg, 8, 64, seed=seed, step=s)
            _, aux = tds_mod.forward(params, cfg,
                                     {"frames": jnp.asarray(d["frames"])},
                                     with_taps=True)
        taps = aux["taps"]
        if not all_taps:
            all_taps = [{k: [np.asarray(v)] for k, v in t.items()}
                        for t in taps]
        else:
            for acc, t in zip(all_taps, taps):
                for k, v in t.items():
                    acc[k].append(np.asarray(v))
    return [{k: np.concatenate(v) for k, v in t.items()} for t in all_taps]


def layer_macs(name: str) -> List[float]:
    """MACs per ReLU-tapped layer (weights the per-layer stats)."""
    cfg, params, state, _ = get_trained(name)
    if cfg.family == "cnn":
        macs = []
        hw = cfg.img_size * cfg.img_size
        from repro.models.cnn import _strides
        strides = _strides(cfg)
        for i, lp in enumerate(params["layers"]):
            hw = hw // (strides[i] ** 2)
            kh, kw, cin, cout = lp["w"].shape
            macs.append(hw * kh * kw * cin * cout)
        return macs
    macs = []
    for lp in params["layers"]:
        conv = 64 * 5 * cfg.d_model * cfg.d_model       # conv tap
        fc = 64 * cfg.d_model * cfg.d_ff                 # fc tap
        macs += [conv, fc]
    return macs

"""Generate EXPERIMENTS.md: §Paper-validation from bench_results.json,
§Dry-run + §Roofline tables from experiments/dryrun/*.json, and the
hand-written §Perf hillclimb log (PERF_LOG below, maintained by hand —
every row is a measured hypothesis->change->result iteration)."""
from __future__ import annotations

import json
import os

from benchmarks.roofline_table import (load_records, multipod_markdown,
                                       roofline_markdown, summary)

PERF_LOG = """\
## §Perf — hypothesis -> change -> measure log

Methodology: every row below is one iteration of the loop *hypothesis ->
napkin math -> change -> re-lower + re-analyse -> confirmed/refuted*.
Terms are seconds/step/chip from the trip-count-aware HLO analysis
(`launch/hlo_cost.py`) at v5e constants (197 TF/s bf16, 819 GB/s HBM,
100 GB/s ICI eff).  "frac" = MODEL_FLOPS / peak / bound-term (train) —
the roofline fraction.

### Global fixes discovered via the loop (apply to every cell)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| G1 | fp32 logits CE gathers the vocab-sharded logits (take_along_axis is a gather) | one-hot contraction CE | qwen2 train temp 35.6 -> 26.3 GiB/dev | confirmed |
| G2 | fp32 master params make FSDP gather 2x wire + f32 dots | bf16 params + fp32 master in optimizer state (MaxText-style) | AR wire 1142 -> 78 GB/chip, useful flops 0.27 -> 0.73 | confirmed |
| G3 | backward cotangents lose forward sharding through remat (`transpose(jvp())`) | custom_vjp `constrain` (pins primal AND cotangent) | killed 9.9 GB/layer full-d_ff regathers | confirmed |
| G4 | param rules mis-align on scan-stacked leading L dim (L sharded over data -> per-layer weight gathers) | right-align specs to trailing dims | deepseek train 311 -> 99 GiB/dev | confirmed |
| G5 | pinning FFN *outputs* seq-gathered would fix bwd regather | constrain y to (dp, None) | flops 2.6e14 -> 8.9e14 (recompute blowup) | **refuted** (reverted) |
| G6 | disabling sequence parallelism removes boundary AGs | --no-seq-parallel | collective 3.2 -> 2.1 s but memory 3.7 -> 25.4 s | **refuted** (SP stays on) |
| G7 | XNOR-net L1 row scaling tightens the binary rookie fit | p_bin * mean-abs(x) | Pearson 0.562 -> 0.572 | **refuted** (not worth runtime cost) |
| G8 | activation binarization zero->+1 erases post-ReLU sparsity info | activations binarize x>0 -> +1 else -1 (weights keep sign bit) | Pearson 0.25 -> 0.57 | confirmed |

### Cell A — deepseek-v2-236b x train_4k (worst baseline: frac 0.007, 311 GiB/dev)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| A1 | (T*k, d) one-hot in the MoE aux loss is ~0.5 TB of f32 | bincount load-balance loss | part of 311 -> 99 GiB (with G4) | confirmed |
| A2 | scatter-of-vectors dispatch makes GSPMD all-reduce (T*k, d) f32+u32 pairs (~16 GB/layer) | scatter int32 slot map, dispatch via gather | frac 0.007 -> 0.020; wire 22.3 -> 13.6 TB/chip | confirmed |
| A3 | (T,k,d) combine materialisation gathers full-F | per-k (T,d) combine + constrain | collective 136 -> 107 s; 14.5 GiB/dev (fits!) | confirmed |
| A4 | EP (experts over model) beats TP for 160 experts | --moe-sharding A/B | EP 107 s vs TP 256 s collective | confirmed (EP kept) |
| A5 | S x S f32 score materialisation in sdpa wastes HBM at S=4096 | chunked flash at threshold 2048 | memory 127 -> 119 s, collective 107 -> 98 s | confirmed |
| A6 | contract_tp layout (winner on dense) transfers to MoE | --param-layout A/B | 0.027 -> 0.016, 18.6 GiB | **refuted** (fsdp_tp kept) |
| A7 | GSPMD's derived schedule for dispatch/combine gathers ~14 GB/layer; an explicit shard_map "expert-slicing" MoE (tokens dp-sharded + model-replicated, experts model-sharded, ONE (T_loc,d) psum/layer) removes it | `moe_apply_a2a` (exact vs reference, 8-dev test) | frac 0.027 -> **0.052**; wire 13.6 -> 5.2 TB/chip; memory 89 -> 44 s | confirmed (now the deepseek default) |

Net: **frac 0.007 -> 0.052 (7.4x), 311 -> 15.0 GiB/dev (fits 16 GiB HBM)**.
Remaining bound: collective (FSDP weight gathers at accum 16 + MLA
activations); next lever: overlapped AG-matmul in the dense/shared paths.

### Bonus cell — rwkv6-3b x train_4k (worst roofline fraction in the final table)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| R1 | the serial 4096-step wkv scan's VJP saves the (B,H,64,64) carry per step (~21 GB/dev) | chunked scan + per-chunk remat | temp 65 -> 2 GiB/dev | confirmed |
| R2 | the per-channel-decay recurrence factorises GLA-style: y = (r e^A)(k e^-A)^T tril + carried state -> chunked matmuls feed the MXU instead of a length-S serial loop | `_wkv6_chunked` (exact vs scan to 2.7e-7; decode parity test) | frac 0.001 -> **0.0055** (5.5x), compute term 121 -> 0.5 s | confirmed |

rwkv6 remains memory-bound (f32 elementwise chains between chunk
matmuls); the natural next step is a fused Pallas wkv6 chunk kernel.

### Bonus cell — mixtral-8x7b x train_4k (8 experts on a 16-way model axis)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| M1 | the shard_map MoE extends to E < MP via "tp slicing" (every shard runs all experts on its F/MP slice; the same psum merges f-partials) | `moe_apply_a2a` mode_tp branch (exact vs reference on 8 devs, E=2/MP=4) | frac 0.038 -> **0.186** (4.9x) | confirmed on compute/collective terms |
| M2 | ...but the per-layer FSDP d-gathers of expert weights persist across the layer scan under shard_map + remat | measured 21.9 GiB/dev (accum-insensitive) | > 16 GiB HBM | memory regression — default stays "tp"; next step: pry the gathered copies out of the saved residuals or run an 8-way model sub-mesh |

### Cell B — qwen1.5-110b x train_4k (most collective-bound flagship)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| B1 | bwd-only cotangent gather pin (constrain_grad) avoids fwd cost of G5 | identity-fwd custom_vjp pin | qwen2 proxy: collective 3.4 -> 12.5 s | **refuted** (reverted) |
| B2 | custom-vjp down-matmul pinning dh/dw directly | `_down_matmul` | no change (XLA already resolved same graph) | neutral (kept for explicitness) |
| B3 | contraction-dim-over-model layout ("contract_tp", Megatron col/row parallel) beats FSDP+TP at 7-110B dense scale | param layout A/B axis | qwen2: 0.076 -> **0.172**; qwen110b: 0.154 -> **0.275** (13.9 GiB fits) | confirmed |
| B4 | dots_saveable remat trades memory for recompute-free bwd | remat A/B | frac 0.15 but 20.6 GiB (OOM) | refuted at this batch |
| B5 | grad_accum 2 halves FSDP regather amortisation loss | accum A/B | 0.182 but 21.2 GiB (OOM) | refuted at this batch |

Net: **frac 0.154 -> 0.275 (1.8x)** via the measured per-arch layout choice
(now a config field; dense archs get contract_tp, MoE-EP keeps fsdp_tp).

### Cell C — qwen2-7b x decode_32k (the paper's own scenario: weight/cache-traffic-bound decode)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| C1 | GSPMD all-gathers the sequence-sharded KV cache at every layer | shard_map distributed flash decode (local max/denom/acc + pmax/psum merge; exact, unit-tested vs oracle) | wire 16 -> 0.86 GB/step/chip (19x); HLO bytes 1.47e11 -> 4.3e10 (3.4x) | confirmed |
| C2 | DUS cache updates are in-place (charge update window, not buffer) | hlo_cost DUS aliasing model | mem_frac 0.008 -> 0.023 (accounting fidelity) | confirmed |
| C3 | MoR tile-skipping cuts the per-step FFN weight DMA by (1 - capacity) | gather_matmul static capacity (kernel validated vs oracle incl. capacity semantics) | modeled below | see below |

MoR decode saving model (C3): per chip per step the FFN weights are
0.71 GB of the 0.95 GB param read.  With live-tile capacity C the memory
term falls by 0.71 GB x (1 - C) / 819 GB/s:

| live capacity C | t_memory (s/step/chip) | vs dense |
|---|---|---|
| 1.00 (dense) | 0.0525 | - |
| 0.85 | 0.0512 | -2.5% |
| 0.50 | 0.0482 | -8.3% |
| 0.10 (OPT-class trained-ReLU sparsity) | 0.0447 | -14.8% |

Measured grounding: on the paper's own CNNs (trained, BN+ReLU) the hybrid
predictor skips 12-22% of neurons at <1% accuracy cost (Fig. 9 repro);
per-token (tile_m=1) masks on our small synthetic-task LM skip only ~2%
(Pearson 0.32 after 200 steps — brief synthetic training underestimates
the ReLU sparsity that the ReLUfication literature reports at 90%+ for
production-scale ReLU LMs).  The kernel path realises whatever sparsity
the deployed model has; the capacity knob provisions it statically.

### Three-consecutive-<5% stop rule

Cells A and B each ended after 2 consecutive sub-5% iterations (A5/A6,
B4/B5 after B3's win); cell C's C3 is provisioning-dependent and closed
the loop.  Roofline-fraction summary of the three hillclimbed cells:

| cell | baseline | final | gain |
|---|---|---|---|
| deepseek-v2-236b train_4k | 0.007 (311 GiB, OOM) | 0.052 (15.0 GiB) | 7.4x + fits |
| qwen1.5-110b train_4k | 0.154 | 0.275 | 1.8x |
| qwen2-7b decode_32k | 1.47e11 B/chip/step | 4.3e10 B (+MoR model) | 3.4x bytes |
| (bonus) rwkv6-3b train_4k | 0.001 (65 GiB) | 0.0055 (2.1 GiB) | 5.5x |
"""

PAPER_SECTION_HEADER = """\
# EXPERIMENTS

All numbers are reproducible on this container:
`PYTHONPATH=src python -m benchmarks.run` (paper figures; trains + caches
the four paper DNNs on first run), `PYTHONPATH=src python -m
repro.launch.dryrun_all` (the 80-cell dry-run grid),
`PYTHONPATH=src python -m benchmarks.make_experiments_md` (this file).

## §Paper-validation (faithful reproduction vs the paper's claims)

The paper's four DNNs (TDS/speech, CNN10, ResNet18, Darknet19) are
implemented with ReLU+BN exactly as its Fig. 2 building blocks and
trained at reduced scale on deterministic synthetic tasks (ImageNet/
Librispeech are not available offline; DESIGN.md §Risks).  The
*mechanism* statistics reproduce:

| paper claim | paper value | ours (reduced scale) | bench |
|---|---|---|---|
| computations producing negative ReLU inputs | 35-69%, mean 55% | mean {fig1:.1%} | fig1 |
| MACs in ReLU-activated (MoR-addressable) layers | "up to 46-98%" | {fig3:.1%} | fig3 |
| binary/base Pearson correlation | most neurons 0.6-0.95 | mean {fig5:.2f} | fig5 |
| closest-neighbour angles below the random-vector 80-90deg band | "majority 70-80deg, many lower" | mean {fig8:.0f}deg | fig8 |
| binary rookie alone: savings at <1% acc loss | <=12% | {fig6:.1%} | fig6 |
| hybrid: larger savings at low loss | ~18% ops avoided | {fig9:.1%} | fig9 |
| incorrectly-predicted-zero rate | 0.4-3.6% | {fig12:.2%} | fig12 |
| modeled speedup / energy | 1.2x / 16.5% | {fig13:.3f}x | fig13 |

Where ours under-shoots (Pearson, savings) the gap tracks training scale:
the paper calibrates fully-trained ImageNet/Librispeech networks; our
synthetic tasks + minutes of CPU training yield weaker self-correlation
(see §Perf G7/G8 for the calibration-quality iterations, incl. the
activation-binarization fix that took Pearson 0.25 -> 0.57).
Qualitatively every claim holds: the hybrid dominates the binary rookie
at matched accuracy, mispredicted zeros stay rare, and savings-vs-T
behaves exactly like the paper's Fig. 6/9.

"""


def serving_section(path: str = "BENCH_serve.json") -> str:
    """§Serving: the mixed-length-workload rows from the continuous-
    batching engine benchmark (benchmarks/run.py --scenario serve-engine)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    tr = data["trace"]
    rows = []
    for mode, r in data["modes"].items():
        extra = []
        if "engine_speedup_vs_static" in r:
            extra.append(f"{r['engine_speedup_vs_static']:.2f}x vs static "
                         f"batch ({r['static_batch_tokens_per_s']:.0f})")
        if "token_agreement_vs_dense" in r:
            extra.append(f"agreement {r['token_agreement_vs_dense']:.3f}")
        if "calibrated_tokens_per_s" in r:
            extra.append(f"capacity-calibrated "
                         f"{r['calibrated_tokens_per_s']:.0f} tok/s")
        rows.append(f"| {mode} | {r['tokens_per_s']:.0f} | "
                    f"{r['decode_tokens_per_s']:.0f} | "
                    f"{'; '.join(extra) or '-'} |")
    gmin = tr.get("gen_min", tr["gen_len"])
    arch = tr.get("arch", "granite-3-2b (reduced)")
    quantile = tr.get("quantile", 0.95)
    d256_note = ""
    if tr.get("compute_scale") or any("@d256" in m for m in data["modes"]):
        lc = data["modes"].get("dense@d256", {}).get(
            "layout_cost_tokens_per_s")
        lc_txt = (f" — measured layout_cost = {lc:.3f}" if lc else "")
        d256_note = f"""\
at toy dims (d=128, L=2, sub-ms dispatches) Python dispatch overhead
dominates and the two paths are near parity; the `dense@d256` rows
(d_model=256, d_ff=1024, L=4) are the smallest compute-dominated scale.
The `-slotted` row is the PR 2 contiguous layout, and `layout_cost_*`
on the `dense@d256` row is the paged/slotted throughput ratio: ≥ 1.0
means the block-table indirection is free.  Since PR 6 the paged pool
stores per-layer tuple leaves and unrolls the layer loop, so XLA's CPU
backend keeps every page scatter in-place instead of copying the pool
through the layer scan once per layer per step — the ~20% indirection
tax the old layout paid here flipped into a paged WIN{lc_txt}
(the slotted baseline still carries its own scan-copy tax).  Prefix
caching is off in THIS table so tok/s keeps meaning dispatched work
(the harness re-runs one trace best-of-3, which the cache would
dedup)."""
    else:
        d256_note = """\
at these reduced dims Python dispatch overhead dominates; run without
--no-compute-scale for the compute-dominated d256 comparison row."""
    return f"""\
## §Serving (continuous-batching engine, mixed-length workload)

`repro.serving.Engine` on a fixed mixed trace — heterogeneous on BOTH
axes: {tr['n_requests']} requests, prompts
{tr['prompt_min']}-{tr['prompt_max']} tokens and generations
{gmin}-{tr['gen_len']} tokens (log-uniform), {tr['n_slots']} slots,
chunk {tr.get('chunk', '-')}; {arch} on this CPU
container (kernel mode runs the Pallas bodies in interpret mode, so its
wall clock is a correctness datapoint, not a speed one).  Chunked
prefill mixes into decode dispatches, finished sequences are evicted
and slots recycled mid-flight, and the hot loop is fully device-
resident (sampled tokens + telemetry are fetched once at flush — the
scheduler is count-based).  Timing is best-of-3 after a compile warmup
for BOTH the engine and the static baseline.  Per-layer gather
capacities are provisioned at the q={quantile} observed tile-liveness
quantile (`per_layer_capacity` in the serve report).

| config | tok/s (total) | tok/s (decode) | notes |
|---|---|---|---|
{chr(10).join(rows)}

The static baseline pads every prompt to the trace max and convoys each
group to its longest generation, but runs one big batched-prefill
dispatch per group — {d256_note}

Reproduce: `PYTHONPATH=src python -m benchmarks.run --scenario
serve-engine` (writes BENCH_serve.json; the CI `serve-engine-smoke` job
runs it reduced-size on every push).

"""


def prefix_section(path: str = "BENCH_prefix.json") -> str:
    """§Prefix caching: shared-prompt dedup rows from the paged-pool
    benchmark (benchmarks/run.py --scenario serve-prefix)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    tr = data["trace"]
    rows = []
    for arch, r in data["archs"].items():
        mech = ("state snapshots" if r["snapshots"]
                else "shared KV pages")
        rows.append(
            f"| {arch} | {mech} | {r['hit_rate']:.2f} | "
            f"{r['warm_prefill_tokens']} / {r['cold_prefill_tokens']} | "
            f"{r['chunks_skipped']} | {r['pages_cowed']} | "
            f"{r['speedup']:.2f}x |")
    return f"""\
## §Prefix caching (paged KV pool, shared-prompt trace)

The serving cache is a paged pool (`serving.kv_pool.PagedPool`): fixed
{tr['chunk']}-token chunks write through per-slot block tables into
refcounted pages, and a hash-trie of full pages
(`serving.prefix_cache`) lets requests sharing a prompt prefix map
their leading block-table entries to the SAME physical pages
(copy-on-write on the first divergent write).  Recurrent families cache
a state snapshot at a page-aligned prompt offset instead (hybrid:
snapshot + the shared-attention pages below it).  Trace:
{tr['n_requests']} requests, every prompt = the same
{tr['prefix_len']}-token prefix + a unique {tr['suffix_min']}-{tr['suffix_max']}-token
suffix, {tr['n_slots']} slots; warm and cold runs produce IDENTICAL
tokens (asserted) — the speedup is wall clock on the same trace.

| arch | mechanism | hit rate | prefill tokens warm/cold | chunks skipped | pages COW'd | warm vs cold |
|---|---|---|---|---|---|---|
{chr(10).join(rows)}

Prefill-dispatch work drops by the hit fraction of each prompt (whole
chunks whose pages fully hit are never dispatched); at these toy dims
the residual wall clock is dispatch-overhead-bound, so the attention
row (fewer dispatches AND fewer pages written) gains more than the
ssm row (snapshot restore copies eat part of the win).

Reproduce: `PYTHONPATH=src python -m benchmarks.run --scenario
serve-prefix` (writes BENCH_prefix.json; the CI `serve-prefix-smoke`
job asserts a nonzero hit rate + skipped chunks on every push).

"""


def sharded_section(path: str = "BENCH_sharded.json") -> str:
    """§Sharded serving: mesh-sharded paged pool + distributed flash
    decode (benchmarks/run.py --scenario serve-sharded, ISSUE 5)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    tr = data["trace"]
    rows = []
    for label, r in data["modes"].items():
        hw = r["kv_pages_hiwater_per_shard"]
        rows.append(
            f"| {label.replace('_', ' ')} | "
            f"{r['paged_tokens_per_s']:.0f} | "
            f"{r['sharded_tokens_per_s']:.0f} | "
            f"{r.get('layout_cost', '-')} | "
            f"{r['kv_pages_single_device']} → {r['kv_pages_per_shard']} | "
            f"{min(hw)}-{max(hw)} everywhere | "
            f"{'identical' if r['tokens_match'] else 'DIVERGED'} |")
    return f"""\
## §Sharded serving (mesh-sharded paged KV pool, distributed flash decode)

The paged pool shards over a device mesh
(`Engine(layout="paged-sharded")`, `repro.serving.mesh`): physical
pages partition across the mesh's page axis while block tables, params
and the residual compute stay replicated, and the whole hot loop runs
as ONE `shard_map`'d step.  Each shard gathers only its
locally-resident pages through the block-table indirection, computes
partial (m, l, acc) flash statistics, and the shards combine with a
single packed all-gather per attention layer
(`distributed.collectives.flash_merge` — replacing the pmax + 2×psum
schedule).  Since PR 6 the partial stats come from the fused paged
flash kernel on TPU (`kernels.paged_attention`, `partial=True`;
§Paged-kernel — no ring materialisation, null/foreign pages are
grid-level skips) with the local ring-gather jnp path as the off-TPU
fallback, and the paged layer loop is unrolled over per-layer tuple
pool leaves, keeping every page scatter in-place (the lowered decode
step shows exactly one all-gather per layer).  The host `BlockAllocator` stays replicated but
ownership-aware: fresh pages round-robin shards most-free-first,
copy-on-write destinations stay on their source's shard, so the packed
page-edit vector splits into one shard-local row each and
`apply_cache_ops` runs unchanged inside the compiled step.  Prefix
caching, COW and eviction work unchanged on top (global page ids shard
deterministically).  Recurrent state (rwkv/mamba) shards the same way
with a single-owner psum gather per dispatch.

Measured on the serve-engine mixed trace ({tr['n_requests']} requests,
prompts {tr['prompt_min']}-{tr['prompt_max']} ×
gens {tr['gen_min']}-{tr['gen_len']}, {tr['n_slots']} slots, chunk
{tr['chunk']}, {tr['arch']}) with a {tr['n_shards']}-shard FORCED-HOST
mesh (`XLA_FLAGS=--xla_force_host_platform_device_count={tr['n_shards']}`
— the "devices" contend for one CPU, so tok/s prices the layout, it
does not claim a speedup; the win is per-device KV capacity):

| prefix cache | paged tok/s | paged-sharded tok/s | layout cost (paged/sharded) | pages/device | hiwater per shard | tokens |
|---|---|---|---|---|---|---|
{chr(10).join(rows)}

Reading `layout cost` (single-device paged / sharded tok/s): PR 6
moved BOTH sides — the single-device numerator gained ~40% from the
in-place per-layer pool leaves, while the sharded wall clock held at
PR 5 parity (its pools are 1/P the size, so the scan-copy tax it shed
was smaller, and the forced-host shards still contend for one CPU on
the fully-replicated FFN compute — the ratio's dominant term, and
ROADMAP item 1's target, not a pool-layout tax).

Acceptance checks (asserted by the benchmark and CI
`serve-sharded-smoke`): token-identical to the single-device paged
engine with the prefix cache on AND off, nonzero page high-water on
every shard (allocation balance), and exactly
{data['collectives_per_attention_layer']} collective per attention
layer per dispatch in the compiled decode step (lowered-HLO all-gather
count; no all-reduce / collective-permute).  The 5-family differential
matrix (gqa ring / absorbed MLA / rwkv state / hybrid / MoE) runs under
4 forced host devices in
`tests/test_serving.py::test_paged_sharded_engine_matrix_multidevice`.

Remaining multi-host limits: the mesh is single-process (forced host
devices or one accelerator host); params and FFN compute are fully
replicated across page shards (no TP composition on the serving mesh
yet); expert (MoE) FFNs run the replicated single-host path; and the
block-table upload is replicated to every shard rather than delta-
compressed.

Reproduce: `XLA_FLAGS=--xla_force_host_platform_device_count=4
PYTHONPATH=src python -m benchmarks.run --scenario serve-sharded`
(writes BENCH_sharded.json; CI runs it reduced on every push).

"""


def paged_kernel_section(path: str = "BENCH_paged_kernel.json") -> str:
    """§Paged-kernel: the fused paged flash-decode microbenchmark
    (benchmarks/run.py --scenario paged-kernel, PR 6)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    sh = data["shape"]
    rows = []
    for r in data["rows"]:
        rows.append(
            f"| {r['batch']} | {r['blocks_per_slot']} | {r['ring']} | "
            f"{r['jnp_gather_us']:.0f} | {r['jnp_pool_direct_us']:.0f} | "
            f"{r['kernel_us']:.0f} | {r['jnp_gather_gbps']:.2f} / "
            f"{r['jnp_pool_direct_gbps']:.2f} / {r['kernel_gbps']:.2f} |")
    backend = data["kernel_backend"]
    return f"""\
## §Paged-kernel (fused paged flash decode, PR 6)

`repro.kernels.paged_attention` fuses the paged decode attention into
ONE Pallas kernel per (slot, block) grid cell: the block table rides in
as a scalar-prefetch operand, so each grid step DMAs exactly its page's
KV rows, skips ALL compute on null pages (global id 0) and — under the
sharded pool's [lo, lo + n_local) resident window — on foreign pages,
and accumulates the online-softmax (m, l, acc) in VMEM scratch across
the block axis.  GQA and absorbed-MLA variants; `partial=True` emits
the raw flash stats for `collectives.flash_merge`, which is how the
paged-sharded engine consumes it (one merge collective per layer).
Dispatch: default ON for TPU backends, jnp gather fallback elsewhere
(`REPRO_PAGED_KERNEL=1/0` forces either).  Differential coverage:
`tests/test_paged_kernel.py` — kernel == dense oracle
(`kernels/ref.py`) over null/foreign/partially-written pages, sliding
windows and fully-masked slots; partial stats merged shard-style equal
the unsharded output; engine tokens identical kernel-vs-jnp across the
5-family matrix (+ 4-shard subprocess run with the kernel forced).

Microbenchmark (GQA decode window: hkv={sh['n_kv_heads']}, G={sh['groups']},
D={sh['head_dim']}, page={sh['page']}; backend THIS run:
**{backend}** — interpret mode serialises the page grid in Python, so
off-TPU the kernel wall clock is a correctness datapoint, not a speed
one; the jnp rows + GB/s roofline are the portable signal):

| B | blocks/slot | ring | jnp gather μs | jnp pool-direct μs | kernel μs | GB/s (gather / pool-direct / kernel) |
|---|---|---|---|---|---|---|
{chr(10).join(rows)}

Kernel dispatch counters for the run: {data['kernel_traces']} (trace
counts; the CI `paged-kernel-smoke` job asserts they are nonzero and
re-runs the engine differential in interpret mode on every push).

Reproduce: `PYTHONPATH=src python -m benchmarks.run --scenario
paged-kernel` (writes BENCH_paged_kernel.json).

"""


def observability_section(path: str = "BENCH_serve.json") -> str:
    """§Observability: obs-stack overhead + the demo run's per-layer
    skip table and latency histograms (benchmarks/run.py --scenario
    serve-engine writes both into BENCH_serve.json, ISSUE 7)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    demo = data.get("obs_demo")
    if not demo:
        return ""
    from benchmarks.figures import obs_skip_table
    from benchmarks.roofline_table import fmt_s

    overhead = data.get("obs_overhead")
    ov_txt = ("not measured in this run (needs the d256 compute-scale "
              "rows)" if overhead is None else
              f"**{overhead:+.1%}** tokens/s at the d256 "
              f"compute-dominated point (paired measurement: both "
              f"engines in one process, timed passes interleaved "
              f"off/on, best-of-5 per side; acceptance budget < 3%)")
    tr = demo.get("tracing", {})
    lat_rows = []
    for name, key in (("TTFT", "ttft"), ("ITL", "itl"),
                      ("queue wait", "queue_wait")):
        s = tr.get(key)
        if not s or not s.get("count"):
            continue
        lat_rows.append(
            f"| {name} | {s['count']} | {fmt_s(s['p50'])} | "
            f"{fmt_s(s['p90'])} | {fmt_s(s['p99'])} | "
            f"{fmt_s(s['max'])} |")
    if not lat_rows:
        # every histogram empty (Histogram.quantile -> None, summary ->
        # {"count": 0}): emit a placeholder row instead of a bodyless
        # (or zero-filled) markdown table
        lat_rows.append("| (no latency samples recorded) | 0 | — | — "
                        "| — | — |")
    dm = demo.get("device_metrics", {})
    dev_txt = ", ".join(f"{k}={dm[k]}" for k in
                        ("dispatches", "prefill_tokens", "decode_tokens",
                         "pages_touched") if k in dm)
    return f"""\
## §Observability (repro.obs: device-resident metrics + request tracing)

`repro.obs` instruments the serving stack in three layers: a metrics
registry (counters / gauges / histograms with labels; JSON + Prometheus
text export), DEVICE-RESIDENT dispatch counters — a packed int32 block
threaded through the compiled step exactly like the pool's page-edit
ops vector, accumulated on device and drained host-side only at flush
boundaries, so the hot loop gains ZERO extra device syncs — and a
span tracer (queued / prefill / decode / dispatch spans per request)
whose timeline exports as Chrome-trace JSON loadable in Perfetto or
chrome://tracing (`serve.py --metrics-json / --trace-out`).  On the
sharded layout the metrics block carries one row per shard
(replicated header fields read from row 0, shard-local page-edit
fields row-summed at drain).

Measured cost of the full stack: {ov_txt}.

Demo run (tiled mode, static `--capacity 0.5` clamp — random-init
weights predict every tile live, so the clamp is what exercises the
skip path — shared {data['trace'].get('chunk', 16) * 2}-token prompt
prefix): {demo['tokens_per_s']:.0f} tok/s,
{demo['trace_events']} timeline events, device counters
{dev_txt}.

Request-latency histograms (host-timeline approximation: TTFT = submit
→ end of the dispatch that emits the request's first token; ITL =
between emitting-dispatch ends):

| histogram | count | p50 | p90 | p99 | max |
|---|---|---|---|---|---|
{chr(10).join(lat_rows)}

Per-layer tile-skip counters (exact int32 device counts; `skip frac` =
skipped/total, `mean live frac` = fixed-point SCALE=4096 accumulation
of the per-dispatch live fraction):

{obs_skip_table(demo["metrics"])}

Reproduce: `PYTHONPATH=src python -m repro.launch.serve --reduced
--mor tiled --capacity 0.5 --shared-prefix 32 --obs --metrics-json
m.json --trace-out t.json` (any serve invocation takes the flags; the
CI `obs-smoke` job asserts nonzero predictor-skip and prefix-hit
counters and validates the Perfetto JSON on every push).

"""


def moe_section(path: str = "BENCH_moe_modes.json") -> str:
    """§MoE: expert-level MoR per-mode skip fractions from the serving
    engine benchmark (benchmarks/run.py --scenario moe-modes)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    tr = data["trace"]
    rows = []
    notes = {"dense": "predictor off, zero predictor evals",
             "exact": "neuron-granular (accuracy oracle)",
             "tiled": "jnp tile oracle",
             "kernel": "Pallas interpret on CPU (correctness datapoint; "
                       "lowering targets TPU)"}
    for mode, r in data["modes"].items():
        skip = (f"{r['expert_tile_skip_frac']:.3f}"
                if "expert_tile_skip_frac" in r else "-")
        sskip = (f"{r['serving_expert_tile_skip_frac']:.3f}"
                 if "serving_expert_tile_skip_frac" in r else "-")
        rows.append(f"| {mode} | {skip} | {sskip} | "
                    f"{r['tokens_per_s']:.0f} | "
                    f"{r['step_ms']:.2f} | {notes.get(mode, '-')} |")
    return f"""\
## §MoE (expert-level MoR: per-mode skip fractions, serving)

Expert FFNs run every MoR execution mode (exact / tiled / kernel)
through batched-expert execution plans (`MoRExecutionPlan.expert_ffn`):
one vmapped plan per MoE layer drives the fused `mor_tile_mask`
predictor and the DMA-skipping `gather_matmul` over the expert grid,
with per-(layer, expert) calibrated `cap_live` budgets from the serving
telemetry.  Differential matrix (`tests/test_moe_modes.py`): exact ==
tiled == kernel == dense under truth-proxy predictors, swept over
(E, top_k, capacity factor, tile geometry, fp32/bf16, ragged tails),
for `moe_apply` AND the EP-shard_map `moe_apply_a2a`.

Measured ({tr['arch']}, serving engine, {tr['n_requests']} requests,
prompts {tr['prompt_min']}-{tr['prompt_max']} x gens
{tr['gen_min']}-{tr['gen_len']}, {tr['n_slots']} slots, chunk
{tr['chunk']}, tiles {tr['tile_m']}x{tr['tile_n']},
q={tr['quantile']} capacities; random-init models have no structured
ReLU sparsity — measured frac_tiles_live = 1.0 — so calibration
injects a trained-model-like column-sparsity profile,
`calibrate_moe(inject_dead_frac={tr['inject_dead_frac']})`,
paper Fig. 1):

| mode | predictor tile-skip | serving tile-skip | tok/s | step ms | note |
|---|---|---|---|---|---|
{chr(10).join(rows)}

"Predictor tile-skip" is measured on the training-path forward (expert
buffers at expected occupancy, C = cf*T*k/E) — it isolates what the
injected column sparsity + predictor actually skip.  "Serving
tile-skip" is the serving-telemetry number, whose denominator is the
full lossless serving buffer (C = T): capacity-pad rows are
force-skipped (`expert_ffn` row_mask), so buffer under-occupancy counts
as skip there too — that is the right basis for capacity calibration
(budgets are fractions of the provisioned buffer) but overstates
predictor savings.  Serving-shape-aware expert capacity
(`cfg.serve_expert_capacity = 1.0`) provisions every serving dispatch
drop-free, so MoE chunked prefill equals teacher-forced logits at every
position (`test_moe_chunked_prefill_matches_teacher_forced`) — the old
by-design divergence (expert capacity scaling with each dispatch's
token count) is gone.

Reproduce: `PYTHONPATH=src python -m benchmarks.run --scenario
moe-modes` (writes BENCH_moe_modes.json; the CI `moe-modes-smoke` job
asserts the tiled/kernel skip fractions are nonzero).

"""


def slo_section(path: str = "BENCH_slo.json") -> str:
    """§SLO: open-loop tail-latency sweep per admission policy
    (benchmarks/run.py --scenario serve-slo, ISSUE 8)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    tr = data["trace"]
    rows = []
    for r in data["runs"]:
        hi = r["ttft"].get("pri5")
        hi_txt = f"{hi['p99'] * 1e3:.0f}" if hi else "-"
        rows.append(
            f"| {r['policy']} | {r['offered_x']:.1f}x | "
            f"{r['n_submitted']}/{r['n_arrivals']} | "
            f"{r['ttft']['all']['p50'] * 1e3:.1f} / "
            f"{r['ttft']['all']['p99'] * 1e3:.0f} | {hi_txt} | "
            f"{r['itl']['p50'] * 1e3:.2f} / {r['itl']['p99'] * 1e3:.2f} | "
            f"{r['preemptions']} | {r['n_rejected']} | "
            f"{r['requests_lost']} |")
    twin = data["token_identity_twin"]
    hl = data["headline"]
    return f"""\
## §SLO (admission policies + page-spill preemption, open-loop load)

A seeded open-loop Poisson generator (`serving.loadgen`) submits on a
wall-clock schedule that ignores engine backpressure — overload builds
real queues, and queue depth is what p99 TTFT measures.  Offered load
is swept as multiples of the engine's measured closed-loop capacity
({tr['capacity_req_s']:.0f} req/s on this CPU container); each (rate,
policy) cell replays the SAME seeded trace ({tr['duration_s']}s,
prompts {tr['prompt_len'][0]}-{tr['prompt_len'][1]}, gens
{tr['max_new'][0]}-{tr['max_new'][1]}, {tr['hi_pri_frac']:.0%}
high-priority, {tr['oversize_frac']:.0%} oversize injected to exercise
the typed-rejection path).  `priority` preempts lower-priority slots by
SPILLING their KV pages to host (`PagedPool.spill`/`restore`) — victims
requeue at the head and resume with zero lost tokens.

| policy | offered | submitted/arrived | TTFT all p50/p99 (ms) | TTFT pri5 p99 (ms) | ITL p50/p99 (ms) | preempt | rejected | lost |
|---|---|---|---|---|---|---|---|---|
{chr(10).join(rows)}

Token identity under preemption (deterministic twin, same prompts
greedy-sampled with and without forced spills): {twin['preemptions']}
preemptions, outputs identical = **{twin['identical']}**.  Headline at
{hl['offered_x']:.1f}x overload: high-priority p99 TTFT
{hl['priority_hi_p99_ttft_s'] * 1e3:.0f} ms under `priority` vs
{hl['fcfs_hi_p99_ttft_s'] * 1e3:.0f} ms under `fcfs`
(priority_beats_fcfs = {hl['priority_beats_fcfs']}).  `requests_lost`
counts submitted requests whose emitted token count != requested —
zero everywhere: rejection is typed and up-front
(`RequestRejected`), and preemption never drops tokens.

Reproduce: `PYTHONPATH=src python -m benchmarks.run --scenario
serve-slo` (writes BENCH_slo.json; the CI `slo-smoke` job asserts
nonzero twin preemptions with identical outputs and zero lost requests
on every push).

"""


def quality_section(path: str = "BENCH_quality.json") -> str:
    """§Predictor quality: shadow-oracle scoring + drift detection
    (benchmarks/run.py --scenario serve-quality, ISSUE 10)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    tr = data.get("trace", {})
    clean = data.get("clean") or {}
    inj = data.get("injected") or {}
    rows = []
    for label, q in (("clean", clean), ("injected", inj)):
        g = next(iter(q.get("groups", {}).values()), None)
        if g is None:
            continue
        fs, tl = g["false_skip"], max(g["truth_live"], 1)
        rows.append(
            f"| {label} | {q.get('shadow_dispatches', 0)} | "
            f"{g['shadow_tiles']} | {g['truth_live']} | "
            f"{g['false_skip']} | {g['false_keep']} | "
            f"{fs / tl:.3f} | "
            f"{q.get('n_drifted', len(q.get('drifted', [])))} |")
    drifted = ", ".join(
        f"{e['group']}[layer {e['layer']}"
        + (f", expert {e['expert']}" if e.get("expert") is not None
           else "") + f"] @ rate {e['rate']:.2f}"
        for e in inj.get("drifted", [])) or "none"
    ov = data.get("shadow_overhead")
    ov_txt = ("not measured in this run" if ov is None else
              f"**{ov:+.1%}** tokens/s at the d256 compute-dominated "
              f"point with shadow_rate=1/16 (paired interleaved "
              f"best-of-5; acceptance budget < 5%) — the scored "
              f"dispatch REPLACES the tiled primary, so the only "
              f"added work is elementwise scoring")
    return f"""\
## §Predictor quality (shadow-oracle scoring + drift detection)

`--shadow-rate 1/N` samples one dispatch in N through a scoring twin
of the active MoR execution plans: the dense-oracle pre-activations
are computed alongside the predictor's tile decisions and the exact
per-(layer, expert) false-skip / false-keep TILE counts accumulate in
the device metrics block's quality lanes (drained once per flush,
zero extra hot-loop syncs).  For tiled plans the sampled dispatch runs
in `mode="scored"` — it propagates the tile-masked activations
bitwise-identically to the tiled path, so it IS the primary dispatch
and the marginal cost is elementwise only; kernel/exact plans fall
back to a standalone `mode="shadow"` twin dispatched alongside the
primary.  Either way shadow-on is token-identical to shadow-off
(asserted below and in `tests/test_quality.py`, which also pins the
counts to a host-side numpy oracle bitwise).

Host-side, `DriftDetector` diffs the cumulative counters flush-over-
flush and runs a pluggable change detector per series (EWMA vs an
absolute false-skip budget by default, Page-Hinkley for relative mean
shifts); newly-drifted series become Perfetto timeline events and
`repro_mor_drift` gauge flips.

Trace: {tr.get('n_requests', '?')} requests, prompts \
{tr.get('prompt_min', '?')}-{tr.get('prompt_max', '?')} x gen \
{tr.get('gen_len', '?')}, shadow_rate={tr.get('shadow_rate', '?')}, \
drift_threshold={tr.get('drift_threshold', '?')}; token parity \
shadow-on == shadow-off: **{data.get('token_parity')}**.  The
"injected" phase wrecks ONE layer's calibration coefficients
(`inject_coefficient_drift` on layer {inj.get('layer', '?')}: fitted
intercept shifted hard negative, proxy assignments cleared) while the
model itself is untouched.

| phase | shadow dispatches | tiles scored | truly live | false skip | false keep | false-skip rate | series drifted |
|---|---|---|---|---|---|---|---|
{chr(10).join(rows)}

Drifted series after injection: {drifted} (fired on the injected
layer only: **{inj.get('fired_on_injected_only')}**; clean phase
drifted: {clean.get('n_drifted', '?')}; drift timeline events:
{inj.get('trace_drift_events', 0)}, trace validator problems:
{len(inj.get('trace_problems', []))}).

Shadow-scoring overhead: {ov_txt}.

Reproduce: `PYTHONPATH=src python -m benchmarks.run --scenario
serve-quality` (writes BENCH_quality.json; the CI `quality-smoke` job
asserts token parity, nonzero scored dispatches, and
injected-layer-only drift on every push).  Serving takes the same
knobs: `python -m repro.launch.serve --reduced --mor tiled --obs
--shadow-rate 0.0625 --drift-threshold 0.25 --metrics-port 9100` (GET
/metrics for Prometheus text, /metrics.json for the full snapshot).

"""


def spec_section(path: str = "BENCH_spec.json") -> str:
    """§Speculative decoding: self-speculative draft/verify sweep over
    (k, draft_cap) vs the non-spec engine (benchmarks/run.py --scenario
    serve-spec, ISSUE 9)."""
    if not os.path.exists(path):
        return ""
    data = json.load(open(path))
    tr = data["trace"]
    hl = data["headline"]
    rows = []
    for mode, md in data["modes"].items():
        b = md["baseline"]
        rows.append(f"| {mode} | baseline | - | {b['tokens_per_s']:.0f} "
                    f"| {b['itl_per_token_p50_ms']:.2f} | - | - | - |")
        for r in md["spec"]:
            rows.append(
                f"| {mode} | k={r['k']} | {r['draft_cap']} | "
                f"{r['tokens_per_s']:.0f} | "
                f"{r['itl_per_token_p50_ms']:.2f} | "
                f"{r['acceptance_rate']:.2f} | "
                f"{r['tokens_per_round']:.2f} | "
                f"{r['replays']}/{r['aborts']} |")
    return f"""\
## §Speculative decoding (self-speculative draft/verify, paged COW)

One set of weights serves both roles: the DRAFT pass runs the same
model under clamped MoR execution plans (`draft_cap` is a traced leaf
like the calibrated capacities, so sweeping it re-uses one compiled
step) and proposes up to k tokens per decoding slot autoregressively
into COW-forked pages; the VERIFY pass is one chunked-prefill-shaped
dispatch under the full-capacity target plans scoring all k+1 positions
at once.  Speculation is a block-table operation, not a cache copy —
fork records the committed position + block-table row (recurrent state
gets one backup page), rollback truncates the position and drops pages
allocated wholly past it, and recurrent families replay the accepted
tokens from the restored fork state in ONE batched dispatch.  A round
costs exactly one host sync (the per-slot emit counts).

Greedy verification is token-identical to vanilla decode BY
CONSTRUCTION (the longest draft prefix matching the target argmax plus
the target's own correction token) — asserted for every dense-mode row
below and across attention / recurrent-state / hybrid families in
`tests/test_spec.py`, including mid-speculation preemption and
prefix-cache-warm starts.  Seeded sampling follows the exact
rejection-sampling rule (emitted marginal = target distribution for
any proposal; distribution-checked in the tests).

Trace: {tr['n_requests']} requests, prompts {tr['prompt_min']}-\
{tr['prompt_max']} x gens {tr['gen_min']}-{tr['gen_len']}, \
{tr['n_slots']} slots, dims {tr['dims']} (the compute-dominated scale).
ITL is recorded per emitting dispatch = per ROUND under speculation;
the per-token column divides by the round's mean emitted tokens.

| mode | config | draft_cap | tok/s | ITL/token p50 (ms) | acceptance | tok/round | replays/aborts |
|---|---|---|---|---|---|---|---|
{chr(10).join(rows)}

Headline: best config k={hl['best_k']}, draft_cap={hl['best_draft_cap']}
reaches **{hl['best_tokens_per_s']:.0f} tok/s
({hl['speedup_vs_baseline']:.2f}x the non-spec baseline)** at acceptance
{hl['best_acceptance_rate']:.0%}; per-token ITL
**{hl['best_itl_per_token_p50_ms']:.2f} ms vs baseline
{hl['baseline_itl_p50_ms']:.2f} ms** (no worse = {hl['itl_no_worse']}).
The per-token ITL win is the robust result (one host sync per round
instead of one per token); aggregate tok/s on this CPU container is
parity-within-noise — dense spec rows sit stable across runs while the
baseline swings ~+-8% run to run, and the verify dispatch pays real
k+1-wide compute here because CPU matmuls scale near-linearly with
width where accelerator decode is weights-bandwidth-bound.
Dense-mode rows bound the round-shape cost (draft == target plans, so
acceptance is ~1 and any tok/s delta is pure dispatch accounting);
tiled rows price REAL clamped drafts, whose acceptance falls with the
cap.  On this CPU container the tiled oracle computes dead tiles and
masks them, so the draft pass is not actually cheaper — the wall-clock
upside of capacitated drafts needs the gather_matmul kernel path on
real accelerators; what these rows validate is the acceptance/identity
machinery end to end.

Reproduce: `PYTHONPATH=src python -m benchmarks.run --scenario
serve-spec --requests 10 --prompt-max 48 --gen-len 32` (writes
BENCH_spec.json; the CI `spec-smoke` job asserts nonzero acceptance
and greedy token identity on every push).

"""


def main():
    bench = {}
    if os.path.exists("experiments/bench_results.json"):
        for row in json.load(open("experiments/bench_results.json")):
            bench[row["name"]] = row["derived"]
    header = PAPER_SECTION_HEADER.format(
        fig1=bench.get("fig1_negative_relu_input_fraction", 0.51),
        fig3=bench.get("fig3_relu_mac_fraction", 0.94),
        fig5=bench.get("fig5_binary_pearson_mean", 0.57),
        fig8=bench.get("fig8_closest_angle_mean_deg", 79),
        fig6=bench.get("fig6_binary_alone_best_savings", 0.22),
        fig9=bench.get("fig9_hybrid_best_savings", 0.08),
        fig12=bench.get("fig12_mispredicted_zero_rate", 0.005),
        fig13=bench.get("fig13_modeled_speedup", 1.03),
    )
    recs = load_records()
    s = summary(recs)
    dry = f"""\
## §Dry-run (deliverable e)

Every (architecture x input-shape) cell lowers AND compiles for the
production meshes: 16x16 = 256 chips single-pod and 2x16x16 = 512 chips
multi-pod (the pod axis is pure DP with int8-compressible gradient
reduce; `repro/launch/mesh.py`).  `compiled.memory_analysis()` and
`cost_analysis()` are recorded per cell in `experiments/dryrun/*.json`.

Grid: 40 cells/mesh = 32 runnable + 8 mandated skips (encoder-only
decode, quadratic-attention long_500k — DESIGN.md §Arch-applicability).
Current records: **{s['ok']} ok, {s['skip']} skips, {s['error']} errors**;
{s['fits']} of the ok cells fit 16 GiB HBM per chip (bf16-corrected,
see hlo_cost docstring for the CPU FloatNormalization correction).

### Multi-pod (2x16x16 = 512 chips) compile proof

{multipod_markdown(recs)}

## §Roofline (single-pod 16x16, per arch x shape)

compute = HLO_FLOPs/(197 TF/s); memory = HLO_bytes/(819 GB/s);
collective = wire_bytes/(100 GB/s ICI eff, 25 GB/s DCI across pods), all
per chip with while-loop trip counts applied (launch/hlo_cost.py —
XLA's own cost_analysis counts loop bodies once; verified + unit-tested).
MODEL/HLO flops = 6*N_active*D / HLO flops (useful-compute ratio;
catches remat/redundancy waste).  roofline frac = MODEL_FLOPS / peak /
max(term) — the headline score for train cells; memory-bound decode
cells additionally report min-traffic/actual (memory_roofline_fraction).

{roofline_markdown(recs)}

Dominant-bottleneck notes (one line per arch, train_4k):
- qwen1.5-110b / granite-20b / qwen2-7b: collective-bound after layout
  opt; next lever = overlapped AG-matmul (`distributed/collectives.py`)
  in the FFN, hiding the FSDP gathers behind partial matmuls.
- deepseek-v2-236b: collective (MoE gather resolution); next lever =
  shard_map all-to-all dispatch.
- mixtral-8x7b: collective (TP expert layout; 8 experts don't divide the
  16-way axis — an 8-way model sub-axis mesh would enable EP).
- rwkv6-3b: now GLA-style chunked (5.5x, §Perf R2); remaining bound is
  the f32 elementwise chains between chunk matmuls -> fused Pallas
  wkv6 chunk kernel next.
- zamba2-7b / phi-3-vision / granite-3-2b / hubert: memory-bound; next
  lever = fusing the chunked-SSD L-matrix construction (zamba) and
  flash-chunk tuning.

"""
    from benchmarks.trajectory import trajectory_section
    with open("EXPERIMENTS.md", "w") as f:
        f.write(header + trajectory_section() + dry + serving_section()
                + prefix_section() + sharded_section()
                + paged_kernel_section() + moe_section() + slo_section()
                + observability_section() + quality_section()
                + spec_section() + PERF_LOG)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()

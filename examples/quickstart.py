"""Quickstart: the whole Mixture-of-Rookies pipeline in one minute.

Trains a tiny ReLU LM, calibrates the hybrid predictor offline (linear
regression + angle clustering), folds the tile permutation into the
weights, and decodes with MoR skipping — printing what the predictor
saved and that outputs still agree with dense decoding.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.deploy import calibrate_lm
from repro.data.pipeline import synthetic_lm_batch
from repro.launch.serve import generate
from repro.launch.steps import init_train_state, make_train_step
from repro.models import get_model
from repro.optim import OptConfig


def main():
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    opt_cfg = OptConfig(lr=1e-3, moment_dtype="float32")
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=60),
                   donate_argnums=(0, 1))

    print("== 1. train a small relufied LM ==")
    for s in range(60):
        b = synthetic_lm_batch(cfg, 8, 48, seed=0, step=s)
        params, opt_state, m = step(params, opt_state,
                                    jax.tree_util.tree_map(jnp.asarray, b))
        if s % 20 == 0:
            print(f"  step {s:3d} loss {float(m['loss']):.3f}")

    print("== 2. offline calibration (paper §3.2: regression + angles) ==")
    def batches():
        s = 1000
        while True:
            b = synthetic_lm_batch(cfg, 8, 64, seed=0, step=s)
            yield {"tokens": jnp.asarray(b["tokens"])}
            s += 1
    params, mor, report = calibrate_lm(params, cfg, api.forward, batches(), 4)
    print("  ", {k: round(v, 3) for k, v in report.items()})

    print("== 3. decode with the hybrid predictor ==")
    prompts = jnp.asarray(synthetic_lm_batch(cfg, 4, 8, seed=1,
                                             step=0)["tokens"])
    toks_mor, stats = generate(cfg, api, params, prompts, 16, mor=mor,
                               mor_mode="exact")
    toks_dense, _ = generate(cfg, api, params, prompts, 16)
    agree = float((toks_mor == toks_dense).mean())
    print(f"  token agreement MoR-exact vs dense: {agree:.3f}")
    print(f"  decode rate: {stats['decode_tokens_per_s']:.0f} tok/s")
    print("done.")


if __name__ == "__main__":
    main()

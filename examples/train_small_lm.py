"""End-to-end training driver: a few hundred steps of a small LM with
fault-tolerant checkpointing (kill it mid-run and re-launch: it resumes
from the last committed step), cosine schedule, grad clipping, and
post-training MoR calibration.

    PYTHONPATH=src python examples/train_small_lm.py [--steps 300]
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_small_lm")
    args = ap.parse_args()
    train_main([
        "--arch", "granite-3-2b", "--reduced",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "64", "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir, "--save-every", "50",
        "--log-every", "20", "--calibrate",
        "--out-json", "/tmp/repro_small_lm_report.json",
    ])


if __name__ == "__main__":
    main()

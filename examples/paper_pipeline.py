"""The paper's own pipeline on its own benchmark (TDS, speech): train,
calibrate both rookies, and print the Fig. 12-style prediction breakdown
plus modeled Fig. 13 speedup/energy.

    PYTHONPATH=src python examples/paper_pipeline.py
"""
import sys

sys.path.insert(0, ".")


def main():
    from benchmarks import figures
    from benchmarks.common import get_trained

    cfg, params, state, acc = get_trained("paper-tds")
    print(f"TDS trained (frame accuracy {acc:.3f})")

    v, detail = figures.fig12_breakdown()
    print("\nFig. 12 prediction breakdown (TDS):")
    for k, x in detail["paper-tds"].items():
        print(f"  {k:20s} {x:.4f}")
    print(f"  (paper: incorrectly-predicted-zero 0.65% for TDS; "
          f"ours {detail['paper-tds']['incorrect_zero']*100:.2f}%)")

    v, detail = figures.fig13_speedup_energy()
    print("\nFig. 13 modeled accelerator speedup/energy:")
    for name, d in detail.items():
        print(f"  {name:18s} speedup {d['speedup']:.3f}x  "
              f"energy saving {d['energy_saving']*100:.1f}%  "
              f"(ops saved {d['ops_saved']*100:.1f}%)")
    print("  (paper: 1.2x speedup, 16.5% energy on its full-scale DNNs)")


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper's deployment scenario): batched
requests against a decode cache, comparing dense vs MoR execution modes
and reporting the realised skip statistics.

    PYTHONPATH=src python examples/serve_mor.py [--arch granite-3-2b]
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    for mode in ("dense", "exact", "tiled"):
        serve_main(["--arch", args.arch, "--reduced",
                    "--batch", str(args.batch), "--prompt-len", "16",
                    "--gen-len", "32", "--mor", mode]
                   + (["--compare"] if mode != "dense" else []))


if __name__ == "__main__":
    main()

"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336
ssm_state=64; Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]

Simplifications vs. the (unverified) reference: one shared transformer
block re-applied every ``shared_attn_every`` mamba layers (the real model
alternates two shared blocks with per-invocation LoRA).  For the
``long_500k`` cell the shared attention runs with a sliding window so the
hybrid stays sub-quadratic (see DESIGN.md).
"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("zamba2-7b")
def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_head=112,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
        shared_attn_every=6,
        shared_attn_window=4096,
        activation="swiglu",
        norm="rmsnorm",
        mor=MoRConfig(enabled=True, relufied=True),
        param_layout="contract_tp",
        grad_accum=8,
    )

"""The paper's own four DNNs (§5.1): TDS (speech), ResNet18, Darknet19,
CNN10.  These are the faithful-reproduction substrate: ReLU activations
throughout, batch-norm where the paper's building blocks (Fig. 2) use it.
Trained here at reduced scale on deterministic synthetic tasks (no
ImageNet/Librispeech offline) — the *mechanism* statistics (Figs. 1,4-9,12)
are what we validate.
"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("paper-tds")
def paper_tds() -> ModelConfig:
    # Time-Depth-Separable ASR blocks (Hannun et al. 2019): CONV+ReLU then
    # FC+ReLU then FC, residual + layernorm.  Reduced-scale.
    return ModelConfig(
        name="paper-tds",
        family="tds",
        n_layers=4,
        d_model=144,              # feature channels (paper uses 10ch x 9w groups)
        d_ff=288,
        vocab_size=128,           # word-piece targets (reduced)
        activation="relu",
        norm="layernorm",
        dtype="float32",
        param_dtype="float32",
        mor=MoRConfig(enabled=True, relufied=False, corr_threshold=0.8),
    )


@register("paper-cnn10")
def paper_cnn10() -> ModelConfig:
    # "CNN10": ten conv layers, BN+ReLU (paper Fig. 2b), CIFAR-10-like task.
    return ModelConfig(
        name="paper-cnn10",
        family="cnn",
        n_layers=10,
        d_model=0,
        cnn_channels=(3, 32, 32, 64, 64, 128, 128, 128, 256, 256, 256),
        cnn_num_classes=10,
        img_size=32,
        batchnorm=True,
        residual=False,
        activation="relu",
        dtype="float32",
        param_dtype="float32",
        mor=MoRConfig(enabled=True, relufied=False),
    )


@register("paper-resnet18")
def paper_resnet18() -> ModelConfig:
    # ResNet18 building block: conv-BN-ReLU with residual (paper Fig. 2c).
    return ModelConfig(
        name="paper-resnet18",
        family="cnn",
        n_layers=18,
        d_model=0,
        cnn_channels=(3, 64, 64, 64, 64, 128, 128, 128, 128,
                      256, 256, 256, 256, 512, 512, 512, 512),
        cnn_num_classes=10,
        img_size=32,
        batchnorm=True,
        residual=True,
        activation="relu",
        dtype="float32",
        param_dtype="float32",
        mor=MoRConfig(enabled=True, relufied=False),
    )


@register("paper-darknet19")
def paper_darknet19() -> ModelConfig:
    # Darknet19 (Redmon & Farhadi): conv-BN-ReLU stacks, no residual.
    return ModelConfig(
        name="paper-darknet19",
        family="cnn",
        n_layers=19,
        d_model=0,
        cnn_channels=(3, 32, 64, 128, 64, 128, 256, 128, 256,
                      512, 256, 512, 256, 512, 1024, 512, 1024, 512, 1024),
        cnn_num_classes=10,
        img_size=32,
        batchnorm=True,
        residual=False,
        activation="relu",
        dtype="float32",
        param_dtype="float32",
        mor=MoRConfig(enabled=True, relufied=False),
    )

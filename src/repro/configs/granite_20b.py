"""granite-20b [dense] — 52L d_model=6144 48H (GQA kv=1 / MQA) d_ff=24576
vocab=49152, llama-arch, code.  [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("granite-20b")
def granite_20b() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_head=128,
        d_ff=24576,
        vocab_size=49152,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        mor=MoRConfig(enabled=True, relufied=True),
        grad_accum=8,
    )

"""Configuration system: model configs, shape specs, registry.

Every assigned architecture gets a ``ModelConfig`` built from the exact
published hyper-parameters.  ``reduce_config`` produces a tiny same-family
variant for CPU smoke tests.  ``input_specs`` produces ShapeDtypeStruct
stand-ins (never allocates device memory) for the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# MoR (Mixture-of-Rookies) feature config
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MoRConfig:
    """Config for the paper's hybrid ReLU-output predictor.

    ``enabled`` turns the predictor on for ReLU-family FFN/conv layers.
    ``relufied`` swaps a non-sign-thresholdable activation (SiLU/GELU) for
    ReLU so the predictor is exact (see DESIGN.md §Arch-applicability).
    """

    enabled: bool = False
    relufied: bool = False           # swap SwiGLU/GELU gate for ReLU
    corr_threshold: float = 0.8      # paper's T: enable binary rookie if c > T
    max_cluster_angle: float = 90.0  # degrees; only cluster below this angle
    tile_n: int = 128                # TPU lane width: output-column tile
    tile_m: int = 8                  # sublane rows grouped per mask decision
    capacity: float = 1.0            # static live-tile budget (fraction) for
                                     # gather_matmul; 1.0 = no compaction
    calib_batches: int = 8           # offline calibration batches


# --------------------------------------------------------------------------
# Model config
# --------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "vlm", "hybrid", "audio", "cnn", "tds")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    qkv_bias: bool = False
    activation: str = "swiglu"      # swiglu | relu_glu | relu | relu2 | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    causal: bool = True             # False for encoder-only (hubert)

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    first_k_dense: int = 0          # leading dense layers before MoE starts
    capacity_factor: float = 1.25
    expert_sharding: str = "tp"     # "ep" (expert dim over model) | "tp"

    # --- MLA (deepseek-v2) ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- sliding-window attention ---
    sliding_window: int = 0         # 0 = full attention

    # --- SSM (mamba2 / rwkv6) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    rwkv_head_size: int = 64

    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0      # apply shared attention block every k layers
    shared_attn_window: int = 0     # 0 = full; >0 = sliding window for long ctx

    # --- modality frontends (stubs per assignment) ---
    frontend: str = "none"          # none | vision_stub | audio_stub
    frontend_tokens: int = 0        # patches / frames supplied by the stub

    # --- CNN-family (paper DNNs) ---
    cnn_channels: Tuple[int, ...] = ()
    cnn_num_classes: int = 0
    img_size: int = 0
    batchnorm: bool = False
    residual: bool = False

    # --- distribution (per-arch measured choices; see EXPERIMENTS.md §Perf) ---
    param_layout: str = "fsdp_tp"   # "contract_tp" | "fsdp_tp"
    flash_threshold: int = 4096     # kv length above which attention chunks

    # --- serving (repro.serving continuous-batching engine) ---
    serve_chunk: int = 32           # chunked-prefill chunk length; also the
                                    # kv ring-buffer margin above the window
    serve_page: int = 8             # paged KV pool: tokens per physical page
    # (the unit of allocation, refcounting and prefix sharing; prefix
    # caching only matches full pages, and state snapshots are taken at
    # page-aligned chunk boundaries, so serve_chunk % serve_page == 0 is
    # the useful regime)
    serve_expert_capacity: float = 1.0
    # serving-shape-aware MoE expert capacity: serving dispatches (the
    # token_mask path) provision each expert for C = this * T tokens of
    # the dispatch itself.  1.0 is lossless (a token claims at most one
    # slot per expert), so chunked prefill matches teacher-forced logits
    # exactly; 0 restores the training-style cf*T*k/E budget.

    # --- numerics / training ---
    dtype: str = "bfloat16"
    # params live in bf16 (compute copy); the fp32 master lives in the
    # optimizer state — halves FSDP all-gather traffic vs fp32 params
    param_dtype: str = "bfloat16"
    remat: str = "nothing_saveable"  # none | dots_saveable | nothing_saveable
    grad_accum: int = 1

    # --- the paper's feature ---
    mor: MoRConfig = field(default_factory=MoRConfig)

    # ---- derived helpers ----
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Shape grid (assigned input-shape set)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def param_count(cfg: ModelConfig) -> Tuple[int, int]:
    """(total, active) parameter counts, analytic.  Used for 6*N*D."""
    d, L = cfg.d_model, cfg.n_layers
    if cfg.family == "cnn":
        total = sum(cfg.cnn_channels[i] * cfg.cnn_channels[i + 1] * 9
                    for i in range(len(cfg.cnn_channels) - 1))
        return total, total
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    per_layer_attn = 0
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.mla:
            q = (d * cfg.q_lora_rank
                 + cfg.q_lora_rank * cfg.n_heads
                 * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim))
            kv = (d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                  + cfg.kv_lora_rank * cfg.n_heads
                  * (cfg.qk_nope_head_dim + cfg.v_head_dim))
            o = cfg.n_heads * cfg.v_head_dim * d
            per_layer_attn = q + kv + o
        else:
            hd = cfg.head_dim
            per_layer_attn = (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                              + cfg.n_heads * hd * d)
    n_ffn_mults = 3 if cfg.activation in ("swiglu", "relu_glu") else 2
    dense_ffn = n_ffn_mults * d * cfg.d_ff
    if cfg.family == "moe":
        e_ff = cfg.moe_d_ff or cfg.d_ff
        moe_ffn = cfg.n_experts * n_ffn_mults * d * e_ff
        shared = cfg.n_shared_experts * n_ffn_mults * d * e_ff
        act_ffn = (cfg.top_k + cfg.n_shared_experts) * n_ffn_mults * d * e_ff
        n_moe = L - cfg.first_k_dense
        total = emb + L * per_layer_attn + cfg.first_k_dense * dense_ffn \
            + n_moe * (moe_ffn + shared + cfg.n_experts * d)
        active = emb + L * per_layer_attn + cfg.first_k_dense * dense_ffn \
            + n_moe * (act_ffn + cfg.n_experts * d)
        return int(total), int(active)
    if cfg.family == "ssm" and cfg.ssm_state and not cfg.n_heads:
        d_in = cfg.ssm_expand * d
        per_layer = (d * (2 * d_in + 2 * cfg.ssm_state) + d_in * d) + 2 * d * cfg.d_ff
        total = emb + L * per_layer
        return int(total), int(total)
    if cfg.family == "ssm":  # rwkv6
        per_layer = 6 * d * d + 2 * d * cfg.d_ff  # r,k,v,g,o,w + channel mix
        total = emb + L * per_layer
        return int(total), int(total)
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        mamba = (d * (2 * d_in + 2 * cfg.ssm_state) + d_in * d)
        n_shared = L // max(cfg.shared_attn_every, 1)
        hd = cfg.head_dim
        shared_blk = (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                      + cfg.n_heads * hd * d + 3 * d * cfg.d_ff)
        total = emb + L * mamba + shared_blk  # shared params counted once
        active = emb + L * mamba + n_shared * shared_blk
        return int(total), int(active)
    total = emb + L * (per_layer_attn + dense_ffn)
    return int(total), int(total)


# --------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins (no allocation)
# --------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model *data* inputs for one step as ShapeDtypeStructs.

    train    -> {tokens, labels [, frontend embeddings]}
    prefill  -> {tokens [, frontend embeddings]}
    decode   -> {tokens (B,1)} (cache specs come from models.cache_specs)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.family == "cnn":
        x = sds((B, cfg.img_size, cfg.img_size, 3), jnp.float32)
        if shape.kind == "train":
            return {"images": x, "labels": sds((B,), i32)}
        return {"images": x}
    out: Dict[str, Any] = {}
    if shape.kind == "decode":
        out["tokens"] = sds((B, 1), i32)
        return out
    if cfg.frontend == "vision_stub":
        n_txt = max(S - cfg.frontend_tokens, 8)
        out["tokens"] = sds((B, n_txt), i32)
        out["patch_embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    elif cfg.frontend == "audio_stub":
        out["frames"] = sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        out["tokens"] = sds((B, S), i32)
    if shape.kind == "train":
        out["labels"] = sds((B, S), i32)
    return out


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # late import so `configs.<arch>` modules self-register
        from repro import configs as _pkg  # noqa: F401
        _pkg.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    from repro import configs as _pkg
    _pkg.load_all()
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# Smoke-test reduction: same family, tiny dims
# --------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    kw: Dict[str, Any] = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        d_ff=256,
        vocab_size=min(cfg.vocab_size, 512) if cfg.vocab_size else 0,
        remat="none",
        grad_accum=1,
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4) or 1, d_head=32)
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2),
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  moe_d_ff=64, first_k_dense=min(cfg.first_k_dense, 1))
    if cfg.mla:
        kw.update(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16, d_head=24)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_chunk=16)
    if cfg.family == "hybrid":
        kw.update(n_layers=5, shared_attn_every=2,
                  shared_attn_window=min(cfg.shared_attn_window, 16)
                  if cfg.shared_attn_window else 0)
    if cfg.family == "ssm" and cfg.rwkv_head_size:
        kw.update(rwkv_head_size=16)
    if cfg.frontend_tokens:
        kw.update(frontend_tokens=16)
    if cfg.family == "cnn":
        kw = dict(n_layers=cfg.n_layers, d_model=16, img_size=32,
                  cnn_channels=tuple(min(c, 16) for c in cfg.cnn_channels),
                  dtype="float32", remat="none")
    if cfg.family == "tds":
        kw = dict(n_layers=2, d_model=64, d_ff=128, vocab_size=64,
                  dtype="float32", remat="none")
    kw.setdefault("serve_chunk", 8)
    return cfg.replace(**kw)

"""Config registry: one module per assigned architecture + the paper's DNNs."""
import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoRConfig, ShapeSpec, SHAPES, get_config, list_archs,
    reduce_config, input_specs, param_count, register,
)

_MODULES = [
    "qwen1_5_110b", "granite_20b", "granite_3_2b", "qwen2_7b",
    "deepseek_v2_236b", "mixtral_8x7b", "rwkv6_3b", "phi_3_vision_4_2b",
    "zamba2_7b", "hubert_xlarge",
    "paper_dnns",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True

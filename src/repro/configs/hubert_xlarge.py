"""hubert-xlarge [audio] — 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504, encoder-only (same arch as wav2vec2).  [arXiv:2106.07447;
unverified]

The convolutional waveform frontend is a STUB per the assignment:
``input_specs`` supplies pre-computed frame embeddings.  Encoder-only:
decode shapes are skipped.  The FFN uses ReLU here (speech domain, the
paper's own domain) so MoR applies natively.
"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("hubert-xlarge")
def hubert_xlarge() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_head=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        activation="relu",
        norm="layernorm",
        frontend="audio_stub",
        mor=MoRConfig(enabled=True, relufied=False),  # native ReLU FFN
        param_layout="contract_tp",
        grad_accum=2,
    )

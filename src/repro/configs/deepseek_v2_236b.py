"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("deepseek-v2-236b")
def deepseek_v2_236b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,          # MLA: heads share the compressed kv
        d_ff=12288,              # dense first layer inter size
        moe_d_ff=1536,
        vocab_size=102400,
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        first_k_dense=1,
        expert_sharding="ep_shmap",  # shard_map expert slicing (§Perf A7)
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        # --- MLA ---
        mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        mor=MoRConfig(enabled=True, relufied=True),
        flash_threshold=2048,
        grad_accum=16,
    )

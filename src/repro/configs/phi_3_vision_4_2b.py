"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064, phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("phi-3-vision-4.2b")
def phi_3_vision_4_2b() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_head=96,
        d_ff=8192,
        vocab_size=32064,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        frontend="vision_stub",
        frontend_tokens=1024,    # pre-computed CLIP patch embeddings
        mor=MoRConfig(enabled=True, relufied=True),
        param_layout="contract_tp",
        grad_accum=2,
    )

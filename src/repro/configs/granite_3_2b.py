"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155.  [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("granite-3-2b")
def granite_3_2b() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_head=64,
        d_ff=8192,
        vocab_size=49155,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        tie_embeddings=True,
        mor=MoRConfig(enabled=True, relufied=True),
        param_layout="contract_tp",
        grad_accum=4,
    )

"""rwkv6-3b (Finch) [ssm] — 32L d_model=2560 (attn-free) d_ff=8960
vocab=65536, data-dependent decay.  [arXiv:2404.05892; hf]

RWKV channel-mix uses ReLU^2: zero iff pre-activation <= 0, so the
Mixture-of-Rookies predictor applies *natively* (no relufication).
"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("rwkv6-3b")
def rwkv6_3b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=0,               # attention-free
        d_ff=8960,
        vocab_size=65536,
        rwkv_head_size=64,
        activation="relu2",
        norm="layernorm",
        mor=MoRConfig(enabled=True, relufied=False),  # native ReLU^2
        grad_accum=4,
    )

"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, SWA(4096).  [arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, MoRConfig, register


@register("mixtral-8x7b")
def mixtral_8x7b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        moe_d_ff=14336,
        vocab_size=32000,
        n_experts=8,
        top_k=2,
        expert_sharding="tp",    # 8 experts < 16-way model axis -> TP inside
        sliding_window=4096,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        mor=MoRConfig(enabled=True, relufied=True),
        param_layout="contract_tp",
        grad_accum=8,
    )

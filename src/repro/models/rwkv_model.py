"""RWKV6 model assembly (attention-free; family 'ssm')."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import decode_attention as da
from repro.distributed.sharding_rules import constrain
from repro.models.layers.common import embed_init, dense_init, split_keys
from repro.models.layers.norms import norm_init, apply_norm
from repro.models.layers import rwkv


def _layer_init(key, cfg: ModelConfig) -> Dict:
    ks = split_keys(key, 2)
    return {"ln1": norm_init(cfg.norm, cfg.d_model),
            "tm": rwkv.timemix_init(ks[0], cfg),
            "ln2": norm_init(cfg.norm, cfg.d_model),
            "cm": rwkv.chanmix_init(ks[1], cfg)}


def init_params(key, cfg: ModelConfig) -> Dict:
    ks = split_keys(key, 4)
    keys = jnp.stack(split_keys(ks[0], cfg.n_layers))
    return {
        "embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model,
                            jnp.dtype(cfg.param_dtype)),
        "in_norm": norm_init(cfg.norm, cfg.d_model),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(keys),
        "final_norm": norm_init(cfg.norm, cfg.d_model),
        "lm_head": dense_init(ks[2], cfg.d_model, cfg.vocab_size,
                              jnp.dtype(cfg.param_dtype)),
    }


def forward(params: Dict, cfg: ModelConfig, batch: Dict, *,
            mor: Optional[Dict] = None, mor_mode: str = "dense",
            with_taps: bool = False) -> Tuple[jnp.ndarray, Dict]:
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    x = apply_norm(cfg.norm, params["in_norm"], x)
    x = constrain(x, "residual")

    def body(carry, xs):
        lp = xs["lp"]
        h = apply_norm(cfg.norm, lp["ln1"], carry)
        carry = carry + rwkv.timemix_forward(lp["tm"], cfg, h)
        h2 = apply_norm(cfg.norm, lp["ln2"], carry)
        h2_prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        f, stats = rwkv.chanmix_forward(lp["cm"], cfg, h2, h2_prev,
                                        mor=xs.get("mor"), mor_mode=mor_mode)
        carry = constrain(carry + f, "residual")
        ys: Dict[str, Any] = {}
        if stats:
            ys["mor_stats"] = stats
        if with_taps:
            from repro.core.predictor import binary_preact
            xk = h2 + (h2_prev - h2) * lp["cm"]["mu"][0].astype(h2.dtype)
            x2 = xk.reshape(-1, xk.shape[-1])
            w = lp["cm"]["w_up"]
            ys["taps"] = {"p_bin": binary_preact(x2, w),
                          "p_base": (x2 @ w.astype(x2.dtype)
                                     ).astype(jnp.float32)}
        return carry, ys

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, ys = jax.lax.scan(body, x, {"lp": params["layers"],
                                   **({"mor": mor["layers"]} if mor else {})})
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = x @ params["lm_head"].astype(x.dtype)
    return constrain(logits, "logits"), ys


def cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    H = cfg.d_model // cfg.rwkv_head_size
    hd = cfg.rwkv_head_size
    L = cfg.n_layers
    d = cfg.d_model
    return {
        "pos": jnp.zeros((), jnp.int32),
        "tm_shift": jnp.zeros((L, batch, d), dtype),
        "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        "cm_shift": jnp.zeros((L, batch, d), dtype),
    }


def prefill_chunk(params: Dict, cfg: ModelConfig, tokens, cache: Dict, *,
                  n_valid, mor: Optional[Dict] = None,
                  mor_mode: str = "dense") -> Tuple[jnp.ndarray, Dict, Dict]:
    """tokens: (B, C) -> (logits (B, C, V) f32, cache, aux).

    The serving chunk step for RWKV: each slot consumes its next
    ``n_valid[b]`` tokens, carrying the wkv state, the time-mix token
    shift, and the channel-mix token shift across chunk boundaries —
    the recurrent-family replacement for the old scanned-decode prefill
    fallback (one compiled (B, C) dispatch per chunk instead of P
    single-token steps).

    A cache carrying a top-level ``state_table`` is the PAGED layout
    (``serving.kv_pool.PagedPool``): state leaves are (L, n_state_pages,
    ...) pools and each slot's row is reached through the (B,) table —
    the chunk gathers its slots' pages, runs the carry, and scatters the
    new state back through the same indirection (which is what lets
    prefix-cache state snapshots live in the same pool).  Under a page-
    shard context the pools are mesh-sharded: the gather/scatter go
    through ``decode_attention.state_take``/``state_put`` — a single-
    owner psum gather per leaf per dispatch, owner-local scatter."""
    dt = jnp.dtype(cfg.dtype)
    B, C = tokens.shape
    state_table = cache.get("state_table")
    if state_table is not None:
        gathered = {k: da.state_take(cache[k], state_table)
                    for k in ("tm_shift", "wkv", "cm_shift")}
    else:
        gathered = {k: cache[k] for k in ("tm_shift", "wkv", "cm_shift")}
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_valid[:, None]
    vm = valid[..., None]
    nv = n_valid
    last = jnp.clip(nv - 1, 0)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = apply_norm(cfg.norm, params["in_norm"], x)
    x = jnp.where(vm, x, 0.0).astype(dt)

    def body(carry, xs):
        lp = xs["lp"]
        h = apply_norm(cfg.norm, lp["ln1"], carry)
        y, tm_new, wkv_new = rwkv.timemix_chunk(
            lp["tm"], cfg, h, xs["tm_shift"].astype(dt), xs["wkv"], valid)
        carry = carry + jnp.where(vm, y, 0.0).astype(dt)
        h2 = apply_norm(cfg.norm, lp["ln2"], carry)
        h2_prev = jnp.concatenate(
            [xs["cm_shift"].astype(dt)[:, None], h2[:, :-1]], 1)
        f, stats = rwkv.chanmix_forward(lp["cm"], cfg, h2, h2_prev,
                                        mor=xs.get("mor"), mor_mode=mor_mode)
        carry = carry + jnp.where(vm, f, 0.0).astype(dt)
        h2_last = jnp.take_along_axis(h2, last[:, None, None], axis=1)[:, 0]
        cm_new = jnp.where((nv > 0)[:, None], h2_last,
                           xs["cm_shift"].astype(dt))
        ys = {"tm_shift": tm_new.astype(xs["tm_shift"].dtype),
              "wkv": wkv_new,
              "cm_shift": cm_new.astype(xs["cm_shift"].dtype)}
        if stats:
            ys["mor_stats"] = stats
        return carry, ys

    xs = {"lp": params["layers"], **gathered}
    if mor is not None:
        xs["mor"] = mor["layers"]
    x, new = jax.lax.scan(body, x, xs)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x @ params["lm_head"].astype(dt)).astype(jnp.float32)
    aux = {}
    if "mor_stats" in new:
        aux["mor_stats"] = new.pop("mor_stats")
    if state_table is not None:
        new = {k: da.state_put(cache[k], state_table, v)
               for k, v in new.items()}
        new["state_table"] = state_table
    new_cache = {"pos": cache["pos"] + n_valid, **new}
    return logits, new_cache, aux


def decode_step(params: Dict, cfg: ModelConfig, tokens, cache: Dict, *,
                mor: Optional[Dict] = None, mor_mode: str = "dense",
                ) -> Tuple[jnp.ndarray, Dict]:
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(dt)  # (B, d)
    x = apply_norm(cfg.norm, params["in_norm"], x)

    def body(carry, xs):
        lp = xs["lp"]
        h = apply_norm(cfg.norm, lp["ln1"], carry)
        y, tm_state = rwkv.timemix_decode(
            lp["tm"], cfg, h, {"shift": xs["tm_shift"], "wkv": xs["wkv"]})
        carry = carry + y
        h2 = apply_norm(cfg.norm, lp["ln2"], carry)
        f, _ = rwkv.chanmix_forward(lp["cm"], cfg, h2,
                                    xs["cm_shift"].astype(dt),
                                    mor=xs.get("mor"), mor_mode=mor_mode)
        carry = carry + f
        return carry, {"tm_shift": tm_state["shift"], "wkv": tm_state["wkv"],
                       "cm_shift": h2}

    xs = {"lp": params["layers"], "tm_shift": cache["tm_shift"],
          "wkv": cache["wkv"], "cm_shift": cache["cm_shift"]}
    if mor is not None:
        xs["mor"] = mor["layers"]
    x, new_states = jax.lax.scan(body, x, xs)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = x @ params["lm_head"].astype(dt)
    return logits, {"pos": cache["pos"] + 1, **new_states}

"""The paper's CNN benchmarks: CNN10 / Darknet19 (conv-BN-ReLU stacks,
Fig. 2b) and ResNet18 (conv-BN-ReLU + residual, Fig. 2c).

Functional batch-norm: train mode uses batch statistics and returns
updated running stats; eval mode uses running stats — which is exactly
what MoR's BN folding consumes (scale = gamma/sigma, bias = beta -
mu*gamma/sigma, paper §3.2.1).

A conv output *channel* is a 'neuron' whose weight vector is the
flattened (kh*kw*cin) filter; the binary rookie is the conv of sign
tensors — same math as the FC case.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.common import split_keys

_BN_MOMENTUM = 0.9


def _conv_init(key, cin, cout, k=3):
    scale = (k * k * cin) ** -0.5
    return jax.random.normal(key, (k, k, cin, cout), jnp.float32) * scale


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn_apply(p, s, x, train: bool):
    if train:
        mu = x.mean((0, 1, 2))
        var = x.var((0, 1, 2))
        new_s = {"mu": _BN_MOMENTUM * s["mu"] + (1 - _BN_MOMENTUM) * mu,
                 "var": _BN_MOMENTUM * s["var"] + (1 - _BN_MOMENTUM) * var}
    else:
        mu, var = s["mu"], s["var"]
        new_s = s
    inv = jax.lax.rsqrt(var + 1e-5)
    return (x - mu) * inv * p["gamma"] + p["beta"], new_s


def bn_fold(p, s) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (scale, bias) s.t. relu_input = preact * scale + bias."""
    inv = jax.lax.rsqrt(s["var"] + 1e-5)
    return p["gamma"] * inv, p["beta"] - s["mu"] * p["gamma"] * inv


def _strides(cfg: ModelConfig) -> List[int]:
    """Downsample (stride 2) whenever channel count grows."""
    ch = cfg.cnn_channels
    return [2 if ch[i + 1] > ch[i] and i > 0 else 1
            for i in range(len(ch) - 1)]


def init_params(key, cfg: ModelConfig) -> Dict:
    ch = cfg.cnn_channels
    n = len(ch) - 1
    ks = split_keys(key, n + 1)
    layers = []
    for i in range(n):
        p: Dict[str, Any] = {"w": _conv_init(ks[i], ch[i], ch[i + 1])}
        if cfg.batchnorm:
            p["bn"] = {"gamma": jnp.ones((ch[i + 1],), jnp.float32),
                       "beta": jnp.zeros((ch[i + 1],), jnp.float32)}
        layers.append(p)
    head = jax.random.normal(ks[n], (ch[-1], cfg.cnn_num_classes),
                             jnp.float32) * ch[-1] ** -0.5
    return {"layers": layers, "head": head}


def init_state(cfg: ModelConfig) -> Dict:
    ch = cfg.cnn_channels
    return {"bn": [{"mu": jnp.zeros((c,), jnp.float32),
                    "var": jnp.ones((c,), jnp.float32)}
                   for c in ch[1:]]}


def forward(params: Dict, state: Dict, cfg: ModelConfig, images, *,
            train: bool = False, with_taps: bool = False,
            mor: Optional[List] = None, mor_mode: str = "dense",
            ) -> Tuple[jnp.ndarray, Dict, Dict]:
    """-> (logits, new_state, aux).  aux['taps'][i] = calibration taps for
    conv layer i; aux['mor_stats'] aggregated skip stats."""
    x = images
    strides = _strides(cfg)
    new_bn = []
    taps: List[Dict] = []
    mstats: List[Dict] = []
    shortcut = None
    for i, lp in enumerate(params["layers"]):
        pre = _conv(x, lp["w"], strides[i])
        res_in = None
        if cfg.residual and i % 2 == 1 and shortcut is not None \
                and shortcut.shape == pre.shape:
            res_in = shortcut
        if cfg.batchnorm:
            pre_bn, s_new = _bn_apply(lp["bn"], state["bn"][i], pre, train)
            new_bn.append(s_new)
        else:
            pre_bn = pre
            new_bn.append(state["bn"][i])
        relu_in = pre_bn + (res_in if res_in is not None else 0.0)

        if with_taps:
            from repro.core.predictor import binarize
            wb = binarize(lp["w"]).astype(x.dtype)
            p_bin = _conv(jnp.where(x > 0, 1.0, -1.0).astype(x.dtype),
                          wb, strides[i])
            taps.append({
                "p_bin": p_bin.reshape(-1, p_bin.shape[-1]),
                "p_base": pre.reshape(-1, pre.shape[-1]).astype(jnp.float32),
                "relu_in": relu_in.reshape(-1, pre.shape[-1]
                                           ).astype(jnp.float32),
            })

        if mor is not None and mor_mode != "dense" and mor[i] is not None:
            from repro.core.executor import as_plan
            # conv-as-matmul view for the predictor: flatten spatial dims
            plan = as_plan(mor[i], mode=mor_mode, tile_m=cfg.mor.tile_m,
                           tile_n=cfg.mor.tile_n)
            m = plan.mor
            B, H, W, C = pre.shape
            pre_flat = pre.reshape(-1, C)
            res_flat = (res_in.reshape(-1, C) if res_in is not None else None)
            # ONE predictor pass on the *true* preacts (conv already
            # computed — conv layers always evaluate exact-style)
            computed = plan.predict(
                _im2col(x, lp["w"].shape[0], strides[i]),
                _wmat(lp["w"])[:, m["perm"]],
                preact_full=pre_flat[:, m["perm"]],
                residual=None if res_flat is None else res_flat[:, m["perm"]],
            ).computed
            relu_flat = relu_in.reshape(-1, C)[:, m["perm"]]
            y = jnp.where(computed, jax.nn.relu(relu_flat), 0.0)
            inv = m["inv_perm"]
            x = y[:, inv].reshape(B, H, W, C)
            mstats.append({"frac_computed":
                           computed.mean(dtype=jnp.float32)})
        else:
            x = jax.nn.relu(relu_in)
        if cfg.residual and i % 2 == 0:
            shortcut = x
    pooled = x.mean((1, 2))
    logits = pooled @ params["head"]
    aux: Dict[str, Any] = {}
    if with_taps:
        aux["taps"] = taps
    if mstats:
        aux["mor_stats"] = mstats
    return logits, {"bn": new_bn}, aux


def _wmat(w) -> jnp.ndarray:
    """(kh,kw,cin,cout) -> (kh*kw*cin, cout) neuron weight matrix."""
    return w.reshape(-1, w.shape[-1])


def _im2col(x, k: int, stride: int) -> jnp.ndarray:
    """NHWC -> (B*H'*W', k*k*C) patches matching SAME conv."""
    B, H, W, C = x.shape
    pad = (k - 1) // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    Ho = (H + stride - 1) // stride
    Wo = (W + stride - 1) // stride
    patches = []
    for di in range(k):
        for dj in range(k):
            patches.append(
                jax.lax.slice(xp, (0, di, dj, 0),
                              (B, di + H, dj + W, C),
                              (1, stride, stride, 1)))
    cols = jnp.concatenate(patches, axis=-1)   # (B,Ho,Wo,k*k*C)
    return cols.reshape(B * Ho * Wo, k * k * C)


def layer_weight_matrices(params: Dict) -> List[jnp.ndarray]:
    """Per-conv-layer (K, N) matrices for clustering/calibration."""
    return [_wmat(lp["w"]) for lp in params["layers"]]

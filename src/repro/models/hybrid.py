"""Zamba2-style hybrid: Mamba2 backbone + one shared attention+MLP block
re-applied every ``shared_attn_every`` layers (see configs/zamba2_7b.py for
documented simplifications).  81 = 13 segments x 6 mamba layers + 3 tail.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import decode_attention as da
from repro.distributed.sharding_rules import constrain
from repro.models.layers import attention as attn
from repro.models.layers.common import embed_init, dense_init, split_keys
from repro.models.layers.mlp import mlp_init, mlp_apply, mlp_taps
from repro.models.layers.norms import norm_init, apply_norm
from repro.models.layers.ssm import (
    mamba2_init, mamba2_forward, mamba2_cache_init, mamba2_chunk,
    mamba2_decode,
)


def _seg_counts(cfg: ModelConfig) -> Tuple[int, int, int]:
    every = cfg.shared_attn_every
    n_seg = cfg.n_layers // every
    tail = cfg.n_layers - n_seg * every
    return n_seg, every, tail


def _mamba_layer_init(key, cfg: ModelConfig) -> Dict:
    return {"ln": norm_init(cfg.norm, cfg.d_model),
            "mamba": mamba2_init(key, cfg)}


def init_params(key, cfg: ModelConfig) -> Dict:
    ks = split_keys(key, 6)
    n_seg, every, tail = _seg_counts(cfg)
    seg_keys = jnp.stack(split_keys(ks[0], n_seg * every))
    params: Dict[str, Any] = {
        "embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model,
                            jnp.dtype(cfg.param_dtype)),
        "mamba_layers": jax.vmap(lambda k: _mamba_layer_init(k, cfg))(seg_keys),
        "shared": {
            "ln1": norm_init(cfg.norm, cfg.d_model),
            "attn": attn.gqa_init(ks[2], cfg),
            "ln2": norm_init(cfg.norm, cfg.d_model),
            "mlp": mlp_init(ks[3], cfg),
        },
        "final_norm": norm_init(cfg.norm, cfg.d_model),
        "lm_head": dense_init(ks[4], cfg.d_model, cfg.vocab_size,
                              jnp.dtype(cfg.param_dtype)),
    }
    if tail:
        tail_keys = jnp.stack(split_keys(ks[5], tail))
        params["tail_layers"] = jax.vmap(
            lambda k: _mamba_layer_init(k, cfg))(tail_keys)
    return params


def _mamba_block(lp, cfg, x):
    h = apply_norm(cfg.norm, lp["ln"], x)
    return constrain(x + mamba2_forward(lp["mamba"], cfg, h), "residual")


def _shared_block(sp, cfg, x, positions, mor, mor_mode, with_taps=False):
    h = apply_norm(cfg.norm, sp["ln1"], x)
    swa_cfg = cfg.replace(sliding_window=cfg.shared_attn_window)
    a = attn.gqa_forward(sp["attn"], swa_cfg, h, positions)
    x = constrain(x + a, "residual")
    h2 = apply_norm(cfg.norm, sp["ln2"], x)
    f, stats = mlp_apply(sp["mlp"], cfg, h2, mor=mor, mor_mode=mor_mode)
    taps = mlp_taps(sp["mlp"], cfg, h2) if with_taps else None
    return constrain(x + f, "residual"), stats, taps


def forward(params: Dict, cfg: ModelConfig, batch: Dict, *,
            mor: Optional[Dict] = None, mor_mode: str = "dense",
            with_taps: bool = False) -> Tuple[jnp.ndarray, Dict]:
    dt = jnp.dtype(cfg.dtype)
    n_seg, every, tail = _seg_counts(cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = constrain(x, "residual")

    # reshape the 78 stacked mamba layers into (13, 6, ...) segments
    seg_params = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, every, *a.shape[1:]),
        params["mamba_layers"])
    shared_mor = None if mor is None else mor.get("shared")

    def seg_body(carry, seg_lp):
        def inner(c, lp):
            return _mamba_block(lp, cfg, c), None
        if cfg.remat != "none":
            inner = jax.checkpoint(
                inner, policy=jax.checkpoint_policies.nothing_saveable)
        c, _ = jax.lax.scan(inner, carry, seg_lp)
        c, stats, taps = _shared_block(params["shared"], cfg, c, positions,
                                       shared_mor, mor_mode, with_taps)
        return c, ((stats, taps) if with_taps else stats)

    x, ys = jax.lax.scan(seg_body, x, seg_params)
    taps = None
    if with_taps:
        stats, taps = ys
    else:
        stats = ys
    if tail:
        def inner(c, lp):
            return _mamba_block(lp, cfg, c), None
        x, _ = jax.lax.scan(inner, x, params["tail_layers"])
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = x @ params["lm_head"].astype(dt)
    aux = {"mor_stats": stats} if stats else {}
    if taps is not None:
        # one shared FFN observed at every segment boundary: the taps
        # come back (n_seg, B*S, N)-stacked; the calibrator folds the
        # segment axis into the batch (core.deploy.calibrate_hybrid)
        aux["taps"] = taps
    return constrain(logits, "logits"), aux


def cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    n_seg, every, tail = _seg_counts(cfg)
    m1 = mamba2_cache_init(cfg, batch, dtype)
    swa_cfg = cfg.replace(sliding_window=cfg.shared_attn_window)
    a1 = attn.gqa_cache_init(swa_cfg, batch, max_len, dtype)

    def stack(c, n):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c)

    cache = {"pos": jnp.zeros((), jnp.int32),
             "mamba": stack(m1, n_seg * every),
             "shared_attn": stack(a1, n_seg)}
    if tail:
        cache["tail"] = stack(m1, tail)
    return cache


def prefill_chunk(params: Dict, cfg: ModelConfig, tokens, cache: Dict, *,
                  n_valid, mor: Optional[Dict] = None,
                  mor_mode: str = "dense") -> Tuple[jnp.ndarray, Dict, Dict]:
    """tokens: (B, C) -> (logits (B, C, V) f32, cache, aux).

    The serving chunk step for the hybrid family: mamba layers carry
    their SSD + conv state across chunks (``mamba2_chunk``), the shared
    attention block scatters into its per-slot sliding-window ring
    (``gqa_chunk``).  Replaces the old scanned-decode prefill fallback.

    A cache carrying top-level ``state_table`` / ``block_table`` is the
    PAGED layout (``serving.kv_pool.PagedPool``): mamba state rows are
    gathered/scattered through the (B,) state table, and the shared
    attention ring reads/writes its kv pages through the (B, n_blocks)
    block table.  Under a page-shard context both pools are mesh-
    sharded: state rows go through the single-owner
    ``decode_attention.state_take``/``state_put`` indirection and the
    shared-attention ring runs the distributed flash decode inside
    ``gqa_chunk``."""
    dt = jnp.dtype(cfg.dtype)
    n_seg, every, tail = _seg_counts(cfg)
    B, C = tokens.shape
    pos = cache["pos"]
    state_table = cache.get("state_table")
    block_table = cache.get("block_table")
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_valid[:, None]
    vm = valid[..., None]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = jnp.where(vm, x, 0.0).astype(dt)
    x = constrain(x, "residual")
    swa_cfg = cfg.replace(sliding_window=cfg.shared_attn_window)
    shared_mor = None if mor is None else mor.get("shared")

    def gather_state(node):
        if state_table is None:
            return node
        return jax.tree_util.tree_map(
            lambda a: da.state_take(a, state_table), node)

    def scatter_state(full, new):
        if state_table is None:
            return new
        return jax.tree_util.tree_map(
            lambda f, n: da.state_put(f, state_table, n), full, new)

    seg_params = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, every, *a.shape[1:]),
        params["mamba_layers"])
    seg_caches = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, every, *a.shape[1:]),
        gather_state(cache["mamba"]))

    def mamba_inner(c, inner_xs):
        lp, mc = inner_xs
        h = apply_norm(cfg.norm, lp["ln"], c)
        y, mc_new = mamba2_chunk(lp["mamba"], cfg, h, mc, valid)
        return c + jnp.where(vm, y, 0.0).astype(dt), mc_new

    def seg_body(carry, xs):
        c, mamba_new = jax.lax.scan(mamba_inner, carry, (xs["lp"], xs["mc"]))
        h = apply_norm(cfg.norm, params["shared"]["ln1"], c)
        a, ac_new = attn.gqa_chunk(params["shared"]["attn"], swa_cfg, h,
                                   xs["ac"], pos, valid,
                                   block_table=block_table)
        c = c + jnp.where(vm, a, 0.0).astype(dt)
        h2 = apply_norm(cfg.norm, params["shared"]["ln2"], c)
        f, stats = mlp_apply(params["shared"]["mlp"], cfg, h2,
                             mor=shared_mor, mor_mode=mor_mode)
        c = c + jnp.where(vm, f, 0.0).astype(dt)
        ys = {"mamba": mamba_new, "attn": ac_new}
        if stats:
            ys["mor_stats"] = stats
        return c, ys

    shared = cache["shared_attn"]
    if isinstance(shared.get("k"), tuple):
        # paged pool with per-layer tuple leaves: unroll the segment
        # loop so the shared-attention page-pool scatters stay in-place
        # (scan would copy the full pool leaf once per segment)
        attn_new: Dict[str, list] = {k: [] for k in shared}
        mamba_news, stats_all = [], []
        for s in range(n_seg):
            xs_s = {"lp": jax.tree_util.tree_map(lambda a: a[s], seg_params),
                    "mc": jax.tree_util.tree_map(lambda a: a[s], seg_caches),
                    "ac": {k: v[s] for k, v in shared.items()}}
            x, ys = seg_body(x, xs_s)
            for k in attn_new:
                attn_new[k].append(ys["attn"][k])
            mamba_news.append(ys["mamba"])
            if "mor_stats" in ys:
                stats_all.append(ys["mor_stats"])
        new = {"mamba": jax.tree_util.tree_map(
                   lambda *a: jnp.stack(a), *mamba_news),
               "attn": {k: tuple(v) for k, v in attn_new.items()}}
        if stats_all:
            new["mor_stats"] = jax.tree_util.tree_map(
                lambda *a: jnp.stack(a), *stats_all)
    else:
        x, new = jax.lax.scan(seg_body, x,
                              {"lp": seg_params, "mc": seg_caches,
                               "ac": cache["shared_attn"]})
    new_cache: Dict[str, Any] = {
        "pos": pos + n_valid,
        "mamba": scatter_state(cache["mamba"], jax.tree_util.tree_map(
            lambda a: a.reshape(n_seg * every, *a.shape[2:]), new["mamba"])),
        "shared_attn": new["attn"],
    }
    if state_table is not None:
        new_cache["state_table"] = state_table
    if block_table is not None:
        new_cache["block_table"] = block_table
    if tail:
        x, tail_new = jax.lax.scan(mamba_inner, x,
                                   (params["tail_layers"],
                                    gather_state(cache["tail"])))
        new_cache["tail"] = scatter_state(cache["tail"], tail_new)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = (x @ params["lm_head"].astype(dt)).astype(jnp.float32)
    aux = {"mor_stats": new["mor_stats"]} if "mor_stats" in new else {}
    return logits, new_cache, aux


def decode_step(params: Dict, cfg: ModelConfig, tokens, cache: Dict, *,
                mor: Optional[Dict] = None, mor_mode: str = "dense",
                ) -> Tuple[jnp.ndarray, Dict]:
    dt = jnp.dtype(cfg.dtype)
    n_seg, every, tail = _seg_counts(cfg)
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)  # (B,1,d)
    swa_cfg = cfg.replace(sliding_window=cfg.shared_attn_window)
    shared_mor = None if mor is None else mor.get("shared")

    seg_params = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, every, *a.shape[1:]),
        params["mamba_layers"])
    seg_caches = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, every, *a.shape[1:]), cache["mamba"])

    def seg_body(carry, xs):
        def inner(c, inner_xs):
            lp, mc = inner_xs
            h = apply_norm(cfg.norm, lp["ln"], c)
            y, mc_new = mamba2_decode(lp["mamba"], cfg, h, mc)
            return c + y, mc_new
        c, mamba_new = jax.lax.scan(inner, carry, (xs["lp"], xs["mc"]))
        h = apply_norm(cfg.norm, params["shared"]["ln1"], c)
        a, ac_new = attn.gqa_decode(params["shared"]["attn"], swa_cfg, h,
                                    xs["ac"], pos)
        c = c + a
        h2 = apply_norm(cfg.norm, params["shared"]["ln2"], c)
        f, _ = mlp_apply(params["shared"]["mlp"], cfg, h2, mor=shared_mor,
                         mor_mode=mor_mode)
        return c + f, {"mamba": mamba_new, "attn": ac_new}

    x, new = jax.lax.scan(seg_body, x,
                          {"lp": seg_params, "mc": seg_caches,
                           "ac": cache["shared_attn"]})
    new_cache = {
        "pos": pos + 1,
        "mamba": jax.tree_util.tree_map(
            lambda a: a.reshape(n_seg * every, *a.shape[2:]), new["mamba"]),
        "shared_attn": new["attn"],
    }
    if tail:
        def inner(c, inner_xs):
            lp, mc = inner_xs
            h = apply_norm(cfg.norm, lp["ln"], c)
            y, mc_new = mamba2_decode(lp["mamba"], cfg, h, mc)
            return c + y, mc_new
        x, tail_new = jax.lax.scan(inner, x,
                                   (params["tail_layers"], cache["tail"]))
        new_cache["tail"] = tail_new
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = x[:, 0, :] @ params["lm_head"].astype(dt)
    return logits, new_cache

"""Model API dispatch: every family exposes
  init(key, cfg) -> params
  forward(params, cfg, batch, **kw) -> (logits, aux)
  cache_init(cfg, batch, max_len, dtype) -> cache      (decoder families)
  decode_step(params, cfg, tokens, cache, **kw) -> (logits, cache)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ModelAPI:
    init: Callable
    forward: Callable
    cache_init: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    has_decode: bool = True
    # batched prefill: (params, cfg, tokens (B,S), cache, *, mor, mor_mode)
    # -> (last-position logits, cache).  Families without one run chunked
    # prefill instead (see launch.steps.make_prefill_step).
    prefill: Optional[Callable] = None
    # serving chunk step: (params, cfg, tokens (B,C), cache, *, n_valid
    # (B,), mor, mor_mode) -> (logits (B,C,V), cache, aux) on the slot-
    # pool cache layout (repro.serving.kv_pool): per-slot positions,
    # validity-masked cache writes.  The continuous-batching engine's
    # single compiled dispatch (prefill chunks AND decode steps).
    prefill_chunk: Optional[Callable] = None


def get_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models import transformer as t
        return ModelAPI(t.init_params, t.forward, t.cache_init, t.decode_step,
                        prefill=t.prefill, prefill_chunk=t.prefill_chunk)
    if fam == "audio":
        from repro.models import transformer as t
        return ModelAPI(t.init_params, t.forward, None, None,
                        has_decode=False)
    if fam == "ssm":
        from repro.models import rwkv_model as r
        return ModelAPI(r.init_params, r.forward, r.cache_init, r.decode_step,
                        prefill_chunk=r.prefill_chunk)
    if fam == "hybrid":
        from repro.models import hybrid as h
        return ModelAPI(h.init_params, h.forward, h.cache_init, h.decode_step,
                        prefill_chunk=h.prefill_chunk)
    if fam == "cnn":
        from repro.models import cnn
        return ModelAPI(cnn.init_params,
                        cnn.forward, None, None, has_decode=False)
    if fam == "tds":
        from repro.models import tds
        return ModelAPI(tds.init_params, tds.forward, None, None,
                        has_decode=False)
    raise ValueError(f"unknown family {fam!r}")


def supports_long_context(cfg: ModelConfig) -> bool:
    """Sub-quadratic decode: SSM/hybrid state or bounded (SWA) KV."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    return cfg.sliding_window > 0


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct tree of the params — no allocation."""
    api = get_model(cfg)
    return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    api = get_model(cfg)
    assert api.cache_init is not None
    return jax.eval_shape(
        lambda: api.cache_init(cfg, batch, max_len, jnp.dtype(cfg.dtype)))

"""Decoder LM / encoder assembly for the dense, moe, vlm and audio
families.  Layers are scan-stacked (leading L dim) so an 80-layer 110B
model lowers to a single-layer HLO body — essential for dry-run compile
times at 512 devices.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding_rules import constrain
from repro.models.layers import attention as attn
from repro.models.layers.common import embed_init, dense_init, split_keys
from repro.models.layers.mlp import mlp_init, mlp_apply, mlp_taps
from repro.models.layers.moe import moe_init, moe_apply, moe_taps
from repro.models.layers.norms import norm_init, apply_norm


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    pol = {"dots_saveable": jax.checkpoint_policies.dots_saveable,
           "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
           }[cfg.remat]
    return jax.checkpoint(fn, policy=pol)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig):
    return attn.mla_init(key, cfg) if cfg.mla else attn.gqa_init(key, cfg)


def _layer_init(key, cfg: ModelConfig, kind: str) -> Dict:
    ks = split_keys(key, 2)
    p = {"ln1": norm_init(cfg.norm, cfg.d_model),
         "attn": _attn_init(ks[0], cfg),
         "ln2": norm_init(cfg.norm, cfg.d_model)}
    if kind == "moe":
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    ks = split_keys(key, 5)
    L = cfg.n_layers
    params: Dict[str, Any] = {}
    if cfg.vocab_size:
        params["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                     jnp.dtype(cfg.param_dtype))
    if cfg.frontend == "audio_stub":
        params["in_norm"] = norm_init(cfg.norm, cfg.d_model)

    if cfg.family == "moe":
        kd = cfg.first_k_dense
        if kd:
            keys = jnp.stack(split_keys(ks[1], kd))
            params["dense_layers"] = jax.vmap(
                lambda k: _layer_init(k, cfg, "dense"))(keys)
        keys = jnp.stack(split_keys(ks[2], L - kd))
        params["moe_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, "moe"))(keys)
    else:
        keys = jnp.stack(split_keys(ks[1], L))
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, "dense"))(keys)

    params["final_norm"] = norm_init(cfg.norm, cfg.d_model)
    if cfg.vocab_size and not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], cfg.d_model, cfg.vocab_size,
                                       jnp.dtype(cfg.param_dtype))
    return params


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _block_apply(lp: Dict, cfg: ModelConfig, x, positions, kind: str,
                 mor_layer, mor_mode: str, with_taps: bool):
    h = constrain(apply_norm(cfg.norm, lp["ln1"], x), "attn_in")
    if cfg.mla:
        a = attn.mla_forward(lp["attn"], cfg, h, positions)
    else:
        a = attn.gqa_forward(lp["attn"], cfg, h, positions)
    x = constrain(x + a, "residual")
    h2 = apply_norm(cfg.norm, lp["ln2"], x)
    ys: Dict[str, Any] = {}
    if kind == "moe":
        f, aux = moe_apply(lp["moe"], cfg, h2, mor=mor_layer,
                           mor_mode=mor_mode)
        ys["lb_loss"] = aux["lb_loss"]
        if "mor_stats" in aux:
            # (E,)-shaped; the layer scan stacks these to (L, E)
            ys["moe_mor_stats"] = aux["mor_stats"]
        if with_taps:
            ys["taps"] = moe_taps(lp["moe"], cfg, h2)
    else:
        f, stats = mlp_apply(lp["mlp"], cfg, h2, mor=mor_layer,
                             mor_mode=mor_mode)
        if stats:
            ys["mor_stats"] = stats
        if with_taps:
            ys["taps"] = mlp_taps(lp["mlp"], cfg, h2)
    x = constrain(x + f, "residual")
    return x, ys


def _embed_inputs(params: Dict, cfg: ModelConfig, batch: Dict):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_stub":
        x = apply_norm(cfg.norm, params["in_norm"],
                       batch["frames"].astype(dt))
        return x
    tok_emb = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(dt), tok_emb], 1)
    else:
        x = tok_emb
    return x


def forward(params: Dict, cfg: ModelConfig, batch: Dict, *,
            mor: Optional[Dict] = None, mor_mode: str = "dense",
            with_taps: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """-> (logits (B, S, V), aux)."""
    x = _embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = constrain(x, "residual")

    def run_stack(x, stacked, kind, mor_stack):
        def body(carry, xs):
            lp = xs["lp"]
            ml = xs.get("mor", None)
            return _block_apply(lp, cfg, carry, positions, kind, ml,
                                mor_mode, with_taps)
        body = _remat(body, cfg)
        xs = {"lp": stacked}
        if mor_stack is not None:
            xs["mor"] = mor_stack
        return jax.lax.scan(body, x, xs)

    aux: Dict[str, Any] = {}
    if cfg.family == "moe":
        if cfg.first_k_dense:
            x, ys = run_stack(x, params["dense_layers"], "dense",
                              None if mor is None else mor.get("dense_layers"))
            aux.update({f"dense_{k}": v for k, v in ys.items()})
        x, ys = run_stack(x, params["moe_layers"], "moe",
                          None if mor is None else mor.get("moe_layers"))
        aux.update(ys)
    else:
        x, ys = run_stack(x, params["layers"], "dense",
                          None if mor is None else mor.get("layers"))
        aux.update(ys)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    if not cfg.vocab_size:
        return x, aux
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    logits = constrain(logits, "logits")
    return logits, aux


# --------------------------------------------------------------------------
# batched prefill: whole prompt in ONE step, writing the decode cache
# --------------------------------------------------------------------------

def _block_prefill(lp, cfg: ModelConfig, x, c, kind, mor_layer, mor_mode):
    # same sharding constraints as _block_apply: prefill is the large-S
    # serving dispatch, exactly where GSPMD needs the layout hints
    h = constrain(apply_norm(cfg.norm, lp["ln1"], x), "attn_in")
    if cfg.mla:
        a, c_new = attn.mla_prefill(lp["attn"], cfg, h, c)
    else:
        a, c_new = attn.gqa_prefill(lp["attn"], cfg, h, c)
    x = constrain(x + a, "residual")
    h2 = apply_norm(cfg.norm, lp["ln2"], x)
    if kind == "moe":
        f, _ = moe_apply(lp["moe"], cfg, h2, mor=mor_layer, mor_mode=mor_mode)
    else:
        f, _ = mlp_apply(lp["mlp"], cfg, h2, mor=mor_layer, mor_mode=mor_mode)
    return constrain(x + f, "residual"), c_new


def prefill(params: Dict, cfg: ModelConfig, tokens, cache: Dict, *,
            mor: Optional[Dict] = None, mor_mode: str = "dense",
            ) -> Tuple[jnp.ndarray, Dict]:
    """tokens: (B, S) prompt -> (last-position logits (B, V), cache).

    One compiled step consumes the entire prompt: forward-style causal
    attention over the batch while every layer writes its S kv rows into
    the decode cache in one dynamic-update (vs. S Python-dispatched
    decode steps).  The MoR predictor runs once per layer over all S
    positions, so serving throughput reflects the predictor's benefit
    rather than dispatch overhead.  Requires a fresh cache (pos == 0)
    and S <= the KV ring-buffer length."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = constrain(x, "residual")

    def run_stack(x, stacked, caches, kind, mor_stack):
        def body(carry, xs):
            y, c_new = _block_prefill(xs["lp"], cfg, carry, xs["c"], kind,
                                      xs.get("mor"), mor_mode)
            return y, c_new
        xs = {"lp": stacked, "c": caches}
        if mor_stack is not None:
            xs["mor"] = mor_stack
        return jax.lax.scan(body, x, xs)

    new_cache: Dict[str, Any] = {"pos": cache["pos"] + S}
    if cfg.family == "moe":
        if cfg.first_k_dense:
            x, nc = run_stack(x, params["dense_layers"],
                              cache["dense_layers"], "dense",
                              None if mor is None else mor.get("dense_layers"))
            new_cache["dense_layers"] = nc
        x, nc = run_stack(x, params["moe_layers"], cache["moe_layers"],
                          "moe", None if mor is None else mor.get("moe_layers"))
        new_cache["moe_layers"] = nc
    else:
        x, nc = run_stack(x, params["layers"], cache["layers"], "dense",
                          None if mor is None else mor.get("layers"))
        new_cache["layers"] = nc

    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x[:, -1, :] @ head.astype(x.dtype)
    return logits, new_cache


# --------------------------------------------------------------------------
# chunked prefill: C tokens per slot at per-slot positions (serving pool)
# --------------------------------------------------------------------------

def _block_chunk(lp, cfg: ModelConfig, x, c, pos, valid, kind, mor_layer,
                 mor_mode, block_table=None):
    vm = valid[..., None]
    h = apply_norm(cfg.norm, lp["ln1"], x)
    if cfg.mla:
        a, c_new = attn.mla_chunk(lp["attn"], cfg, h, c, pos, valid,
                                  block_table=block_table)
    else:
        a, c_new = attn.gqa_chunk(lp["attn"], cfg, h, c, pos, valid,
                                  block_table=block_table)
    x = x + jnp.where(vm, a, 0.0).astype(x.dtype)
    h2 = apply_norm(cfg.norm, lp["ln2"], x)
    ys: Dict[str, Any] = {}
    if kind == "moe":
        # invalid rows must not claim expert capacity (slot isolation)
        f, aux_m = moe_apply(lp["moe"], cfg, h2, mor=mor_layer,
                             mor_mode=mor_mode, token_mask=valid)
        if "mor_stats" in aux_m:
            ys["moe_mor_stats"] = aux_m["mor_stats"]
    else:
        f, stats = mlp_apply(lp["mlp"], cfg, h2, mor=mor_layer,
                             mor_mode=mor_mode)
        if stats:
            ys["mor_stats"] = stats
    x = x + jnp.where(vm, f, 0.0).astype(x.dtype)
    return x, c_new, ys


def prefill_chunk(params: Dict, cfg: ModelConfig, tokens, cache: Dict, *,
                  n_valid, mor: Optional[Dict] = None,
                  mor_mode: str = "dense") -> Tuple[jnp.ndarray, Dict, Dict]:
    """tokens: (B, C) -> (logits (B, C, V) f32, cache, aux).

    The serving engine's ONE compiled step: every slot consumes its next
    ``n_valid[b]`` tokens (0 for idle slots, 1 for decoding slots, up to
    C for prompt chunks) starting at its own ``cache["pos"][b]``.  The
    invalid tail of each row is masked out of the residual stream and
    dropped from the cache writes, so idle slots are untouched; chaining
    chunks reproduces the teacher-forced forward exactly (incl. prompts
    longer than the sliding-window ring, given the kv_pool's chunk-margin
    ring).  aux["mor_stats"] carries the per-layer (L-stacked) realised
    skip statistics that feed ``serving.telemetry``.

    A cache carrying a top-level ``block_table`` is the PAGED layout
    (``serving.kv_pool.PagedPool``): every layer reads/writes its kv
    pages through the shared (B, n_blocks) table instead of slot rows.
    The mesh-sharded layout (``Engine(layout="paged-sharded")``) reuses
    this exact step under ``shard_map`` — the table stays replicated
    while the page pools split over the mesh's page axis, and each
    layer's attention becomes a distributed flash decode (one merge
    collective per layer; see ``distributed.decode_attention``)."""
    B, C = tokens.shape
    pos = cache["pos"]
    block_table = cache.get("block_table")
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_valid[:, None]
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = jnp.where(valid[..., None], x, 0.0).astype(x.dtype)
    x = constrain(x, "residual")

    def run_stack(x, stacked, caches, kind, mor_stack):
        if isinstance(caches, dict) and \
                any(isinstance(v, tuple) for v in caches.values()):
            # paged pools carry per-layer TUPLE leaves: unroll the layer
            # loop in python so every pool scatter updates its own
            # donated buffer in place — threading the pools through
            # lax.scan copies each full leaf once per layer on CPU,
            # which charges the whole pool (not the attended window) to
            # every dispatch
            L = len(next(v for v in caches.values()
                         if isinstance(v, tuple)))
            new_c = {k: [] for k in caches}
            ys_all = []
            y = x
            for l in range(L):
                lp = jax.tree_util.tree_map(lambda a: a[l], stacked)
                ml = (None if mor_stack is None else
                      jax.tree_util.tree_map(lambda a: a[l], mor_stack))
                cl = {k: v[l] for k, v in caches.items()}
                y, c_new, ys = _block_chunk(lp, cfg, y, cl, pos, valid,
                                            kind, ml, mor_mode,
                                            block_table=block_table)
                for k in new_c:
                    new_c[k].append(c_new[k])
                ys_all.append(ys)
            caches_new = {k: tuple(v) for k, v in new_c.items()}
            ys = (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys_all)
                  if ys_all[0] else {})
            return y, caches_new, ys

        def body(carry, xs):
            y, c_new, ys = _block_chunk(xs["lp"], cfg, carry, xs["c"], pos,
                                        valid, kind, xs.get("mor"), mor_mode,
                                        block_table=block_table)
            return y, {"c": c_new, **ys}
        xs = {"lp": stacked, "c": caches}
        if mor_stack is not None:
            xs["mor"] = mor_stack
        y, out = jax.lax.scan(body, x, xs)
        ys = {k: v for k, v in out.items() if k != "c"}
        return y, out["c"], ys

    new_cache: Dict[str, Any] = {"pos": pos + n_valid}
    if block_table is not None:
        new_cache["block_table"] = block_table
    aux: Dict[str, Any] = {}
    if cfg.family == "moe":
        if cfg.first_k_dense:
            x, nc, ys = run_stack(
                x, params["dense_layers"], cache["dense_layers"], "dense",
                None if mor is None else mor.get("dense_layers"))
            new_cache["dense_layers"] = nc
            aux.update({f"dense_{k}": v for k, v in ys.items()})
        x, nc, ys = run_stack(x, params["moe_layers"], cache["moe_layers"],
                              "moe",
                              None if mor is None else mor.get("moe_layers"))
        new_cache["moe_layers"] = nc
        aux.update(ys)
    else:
        x, nc, ys = run_stack(x, params["layers"], cache["layers"], "dense",
                              None if mor is None else mor.get("layers"))
        new_cache["layers"] = nc
        aux.update(ys)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache, aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    if cfg.mla:
        return attn.mla_cache_init(cfg, batch, max_len, dtype)
    return attn.gqa_cache_init(cfg, batch, max_len, dtype)


def _stack_cache(c, n: int):
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c)


def cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    c1 = _layer_cache(cfg, batch, max_len, dtype)
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "moe":
        if cfg.first_k_dense:
            cache["dense_layers"] = _stack_cache(c1, cfg.first_k_dense)
        cache["moe_layers"] = _stack_cache(c1, cfg.n_layers - cfg.first_k_dense)
    else:
        cache["layers"] = _stack_cache(c1, cfg.n_layers)
    return cache


def _block_decode(lp, cfg: ModelConfig, x, c, pos, kind, mor_layer, mor_mode):
    h = apply_norm(cfg.norm, lp["ln1"], x)
    if cfg.mla:
        a, c_new = attn.mla_decode(lp["attn"], cfg, h, c, pos)
    else:
        a, c_new = attn.gqa_decode(lp["attn"], cfg, h, c, pos)
    x = x + a
    h2 = apply_norm(cfg.norm, lp["ln2"], x)
    if kind == "moe":
        f, _ = moe_apply(lp["moe"], cfg, h2, mor=mor_layer, mor_mode=mor_mode)
    else:
        f, _ = mlp_apply(lp["mlp"], cfg, h2, mor=mor_layer, mor_mode=mor_mode)
    return x + f, c_new


def decode_step(params: Dict, cfg: ModelConfig, tokens, cache: Dict, *,
                mor: Optional[Dict] = None, mor_mode: str = "dense",
                ) -> Tuple[jnp.ndarray, Dict]:
    """tokens: (B, 1) -> (logits (B, V), new cache)."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = constrain(x, "residual_decode")

    def run_stack(x, stacked, caches, kind, mor_stack):
        def body(carry, xs):
            y, c_new = _block_decode(xs["lp"], cfg, carry, xs["c"], pos,
                                     kind, xs.get("mor"), mor_mode)
            return y, c_new
        xs = {"lp": stacked, "c": caches}
        if mor_stack is not None:
            xs["mor"] = mor_stack
        return jax.lax.scan(body, x, xs)

    new_cache: Dict[str, Any] = {"pos": pos + 1}
    if cfg.family == "moe":
        if cfg.first_k_dense:
            x, nc = run_stack(x, params["dense_layers"],
                              cache["dense_layers"], "dense",
                              None if mor is None else mor.get("dense_layers"))
            new_cache["dense_layers"] = nc
        x, nc = run_stack(x, params["moe_layers"], cache["moe_layers"],
                          "moe", None if mor is None else mor.get("moe_layers"))
        new_cache["moe_layers"] = nc
    else:
        x, nc = run_stack(x, params["layers"], cache["layers"], "dense",
                          None if mor is None else mor.get("layers"))
        new_cache["layers"] = nc

    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x[:, 0, :] @ head.astype(x.dtype))
    return logits, new_cache

"""RMSNorm / LayerNorm (fp32 statistics, cast back to input dtype)."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def norm_init(kind: str, d: int):
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def apply_norm(kind: str, params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jnp.reciprocal(jnp.sqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps))
        y = y * params["scale"]
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
        y = y * params["scale"] + params["bias"]
    return y.astype(x.dtype)

"""Rotary position embeddings (on-the-fly, no precomputed tables so the
same code path serves 4k training and 500k decode without giant buffers)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: (..., S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                      # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv   # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)

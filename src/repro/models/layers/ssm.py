"""Mamba2 (SSD) layer: chunked state-space dual form for training/prefill
(O(S * chunk) with an inter-chunk scan) and O(1) recurrent decode.

Follows the Mamba2 structure: in_proj -> [z | xBC | dt], causal depthwise
conv on xBC, per-head scalar decay a_t = exp(-softplus(dt + bias) *
exp(A_log)), SSD attention-like intra-chunk term + carried state, gated
RMSNorm, out_proj.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.common import dense_init, split_keys

_D_CONV = 4


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return d_in, H, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_init(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_in, H, P, N = _dims(cfg)
    pd = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 3)
    conv_ch = d_in + 2 * N
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * N + H, pd),
        "conv_w": (jax.random.normal(ks[1], (_D_CONV, conv_ch), jnp.float32)
                   * 0.1).astype(pd),
        "conv_b": jnp.zeros((conv_ch,), pd),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], d_in, d, pd),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_in, H, P, N = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N:]
    return z, xBC, dt


def _gated_norm(y, z, scale, eps=1e-6):
    g = y * jax.nn.silu(z.astype(jnp.float32))
    r = jnp.reciprocal(jnp.sqrt(jnp.mean(g * g, -1, keepdims=True) + eps))
    return g * r * scale


def _ssd(xbar, Bc, Cc, la, S0):
    """Chunked SSD core: xbar (B, nc, Q, H, P); Bc/Cc (B, nc, Q, N); la
    (B, nc, Q, H) log-decays; S0 (B, H, N, P) initial state.  Returns
    (y (B, nc, Q, H, P), S_last) — S_last is the state after the final
    position, so chaining calls is exact (serving's chunked prefill)."""
    B, nc, Q, H, P = xbar.shape
    cum = jnp.cumsum(la, axis=2)                         # (B,nc,Q,H)

    # --- intra-chunk (quadratic within chunk) ---
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)       # shared across heads
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xbar)

    # --- inter-chunk state carry ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,nc,Q,H)
    S_local = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_to_end, xbar)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def carry_fn(S_prev, inp):
        S_loc, cdec = inp
        S_new = S_prev * cdec[..., None, None] + S_loc
        return S_new, S_prev

    S_last, S_prevs = jax.lax.scan(
        carry_fn, S0,
        (S_local.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         Cc, jnp.exp(cum), S_prevs)
    return y_intra + y_inter, S_last


def mamba2_forward(params: Dict, cfg: ModelConfig, x) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d).  S must be a multiple of ssm_chunk or
    smaller than it (it is padded internally)."""
    B, S, d = x.shape
    d_in, H, P, N = _dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    dt_ = x.dtype

    zxbcdt = x @ params["in_proj"].astype(dt_)
    z, xBC, dtd = _split_proj(zxbcdt, cfg)
    # causal depthwise conv over time
    xp = jnp.pad(xBC, ((0, 0), (_D_CONV - 1, 0), (0, 0)))
    conv = sum(xp[:, i:i + S, :] * params["conv_w"][i].astype(dt_)
               for i in range(_D_CONV)) + params["conv_b"].astype(dt_)
    conv = jax.nn.silu(conv.astype(jnp.float32))
    xs = conv[..., :d_in].reshape(B, S, H, P)
    B_ = conv[..., d_in:d_in + N]
    C_ = conv[..., d_in + N:]

    dt_soft = jax.nn.softplus(dtd.astype(jnp.float32) + params["dt_bias"])
    loga = -dt_soft * jnp.exp(params["A_log"])           # (B,S,H) <= 0
    xbar = xs.astype(jnp.float32) * dt_soft[..., None]   # dt-scaled input

    pad = (-S) % Q
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // Q
    y, _ = _ssd(xbar.reshape(B, nc, Q, H, P),
                B_.reshape(B, nc, Q, N).astype(jnp.float32),
                C_.reshape(B, nc, Q, N).astype(jnp.float32),
                loga.reshape(B, nc, Q, H),
                jnp.zeros((B, H, N, P), jnp.float32))

    y = y.reshape(B, S + pad, H, P)[:, :S]
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = _gated_norm(y.reshape(B, S, d_in), z, params["norm_scale"])
    return (y.astype(dt_) @ params["out_proj"].astype(dt_))


def mamba2_cache_init(cfg: ModelConfig, batch: int, dtype):
    d_in, H, P, N = _dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "conv": jnp.zeros((batch, _D_CONV - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba2_chunk(params: Dict, cfg: ModelConfig, x, cache, valid) -> Tuple:
    """State-carrying chunk: x (B, C, d) continues from ``cache`` ({conv
    (B, 3, ch), ssm (B, H, N, P)}); ``valid`` (B, C) marks the real-token
    prefix of each row.  Invalid positions contribute nothing to the SSD
    state (xbar -> 0, log-decay -> 0) and the conv history advances by
    exactly the valid count, so chaining chunks equals one long forward.
    -> (y (B, C, d), new_cache)."""
    B, C, d = x.shape
    d_in, H, P, N = _dims(cfg)
    dt_ = x.dtype
    zxbcdt = x @ params["in_proj"].astype(dt_)
    z, xBC, dtd = _split_proj(zxbcdt, cfg)
    hist = jnp.concatenate([cache["conv"].astype(dt_), xBC], 1)  # (B,C+3,ch)
    conv = sum(hist[:, i:i + C, :] * params["conv_w"][i].astype(dt_)
               for i in range(_D_CONV)) + params["conv_b"].astype(dt_)
    conv = jax.nn.silu(conv.astype(jnp.float32))
    xs = conv[..., :d_in].reshape(B, C, H, P)
    B_ = conv[..., d_in:d_in + N].astype(jnp.float32)
    C_ = conv[..., d_in + N:].astype(jnp.float32)

    dt_soft = jax.nn.softplus(dtd.astype(jnp.float32) + params["dt_bias"])
    loga = -dt_soft * jnp.exp(params["A_log"])
    xbar = xs * dt_soft[..., None]
    xbar = jnp.where(valid[:, :, None, None], xbar, 0.0)
    loga = jnp.where(valid[:, :, None], loga, 0.0)

    Q = min(cfg.ssm_chunk, C)
    pad = (-C) % Q
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
    nc = (C + pad) // Q
    y, S_last = _ssd(xbar.reshape(B, nc, Q, H, P), B_.reshape(B, nc, Q, N),
                     C_.reshape(B, nc, Q, N), loga.reshape(B, nc, Q, H),
                     cache["ssm"])
    y = y.reshape(B, C + pad, H, P)[:, :C]
    y = y + params["D"][None, None, :, None] * xs
    y = _gated_norm(y.reshape(B, C, d_in), z, params["norm_scale"])
    out = y.astype(dt_) @ params["out_proj"].astype(dt_)
    # conv history = the last (_D_CONV - 1) VALID inputs: rows
    # [n_valid, n_valid + 3) of the (history ++ chunk) concatenation
    nv = valid.sum(1)
    idx = nv[:, None] + jnp.arange(_D_CONV - 1, dtype=jnp.int32)[None, :]
    conv_new = jnp.take_along_axis(hist, idx[:, :, None], axis=1)
    return out, {"conv": conv_new.astype(cache["conv"].dtype),
                 "ssm": S_last}


def mamba2_decode(params: Dict, cfg: ModelConfig, x, cache) -> Tuple:
    """x: (B, 1, d) single step."""
    B = x.shape[0]
    d_in, H, P, N = _dims(cfg)
    dt_ = x.dtype
    zxbcdt = x[:, 0] @ params["in_proj"].astype(dt_)
    z, xBC, dtd = _split_proj(zxbcdt, cfg)
    hist = jnp.concatenate([cache["conv"], xBC[:, None, :]], 1)  # (B,4,ch)
    conv = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32)) \
        + params["conv_b"].astype(jnp.float32)
    conv = jax.nn.silu(conv)
    xs = conv[:, :d_in].reshape(B, H, P)
    B_ = conv[:, d_in:d_in + N]
    C_ = conv[:, d_in + N:]
    dt_soft = jax.nn.softplus(dtd.astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(-dt_soft * jnp.exp(params["A_log"]))     # (B,H)
    xbar = xs * dt_soft[..., None]
    S_new = cache["ssm"] * a[..., None, None] \
        + jnp.einsum("bn,bhp->bhnp", B_, xbar)
    y = jnp.einsum("bn,bhnp->bhp", C_, S_new) \
        + params["D"][None, :, None] * xs
    y = _gated_norm(y.reshape(B, d_in), z, params["norm_scale"])
    out = (y.astype(dt_) @ params["out_proj"].astype(dt_))[:, None, :]
    return out, {"conv": hist[:, 1:], "ssm": S_new}

"""Mixture-of-Experts FFN: top-k routing with static capacity, sort-based
dispatch (no O(T*E*C) one-hot tensors — scales to 1M-token global batches),
shared experts (DeepSeek-V2 style), load-balancing auxiliary loss.

Expert weights are (E, d, f) so they shard as EP (expert dim over "model")
or TP (f over "model") per ``cfg.expert_sharding``.

Expert-level MoR runs every execution mode (exact / tiled / kernel)
through one batched-expert plan per layer (``executor.expert_ffn``):
per-(layer, expert) predictors, per-expert calibrated capacity clamps,
and (E,)-shaped skip stats in aux["mor_stats"] for the serving
telemetry.  Serving dispatches (the ``token_mask`` path) provision
expert capacity from the dispatch shape (``cfg.serve_expert_capacity``)
so chunked prefill never drops a valid token.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.common import activation_fn, dense_init, is_glu, split_keys
from repro.models.layers.mlp import effective_activation, mlp_init, mlp_apply


def moe_init(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    pd = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 6)
    glu = is_glu(effective_activation(cfg))

    def ew(k, a, b):
        return (jax.random.normal(k, (E, a, b), jnp.float32) * a ** -0.5
                ).astype(pd)

    p = {"router": dense_init(ks[0], d, E, pd, scale=0.02),
         "w_up": ew(ks[1], d, f),
         "w_down": ew(ks[2], f, d)}
    if glu:
        p["w_gate"] = ew(ks[3], d, f)
    if cfg.n_shared_experts:
        shared_cfg = cfg.replace(d_ff=cfg.n_shared_experts * f)
        p["shared"] = mlp_init(ks[4], shared_cfg,
                               d_ff=cfg.n_shared_experts * f)
    return p


def _dispatch_indices(top_idx: jnp.ndarray, E: int, C: int):
    """top_idx: (T, k) expert choice per token-slot.  Returns, per flat
    (token,k) pair, the expert buffer slot it lands in (or E*C if dropped),
    using a stable sort so earlier tokens win capacity — matches standard
    GShard/Switch semantics."""
    T, k = top_idx.shape
    flat = top_idx.reshape(-1)                          # (T*k,)
    order = jnp.argsort(flat, stable=True)              # group by expert
    sorted_e = flat[order]
    counts = jnp.bincount(flat, length=E)               # tokens per expert
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k) - starts[sorted_e]     # rank within expert
    # sentinel pairs (expert id >= E: masked tokens) must land EXACTLY on
    # the E*C drop slot — without the explicit check their rank offset
    # (computed against the clamped starts[E-1]) leaks past E*C and the
    # combine gather only behaves by virtue of jax's clamp semantics
    keep = (pos_in_e < C) & (sorted_e < E)
    slot_sorted = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
    slot = jnp.zeros((T * k,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))
    return slot.reshape(T, k)


def moe_apply_a2a(params: Dict, cfg: ModelConfig, x, *,
                  mor=None, mor_mode: str = "dense") -> Tuple:
    """Expert-parallel MoE in shard_map ("expert slicing"): tokens are
    dp-sharded and REPLICATED over the model axis (which SP layouts give
    us anyway at the FFN boundary); experts are model-sharded.  Each
    model shard routes the same local tokens, keeps only its own
    experts' buffers, runs the expert FFN locally, and one psum over
    'model' sums the disjoint expert contributions.

    Total comms per layer = ONE (T_loc, d) psum — no dispatch gathers,
    no all_to_all, no (T*k, d) materialisation (the §Perf A-cell lever;
    GSPMD's derived schedule for the same math moved ~14 GB/layer).
    Router compute is replicated across the model axis (negligible).
    Capacity is static: C_loc = cf * T_loc * k / E."""
    from repro.distributed.sharding_rules import _TLS
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return None
    mesh, _ = ctx
    if "model" not in mesh.axis_names:
        return None
    MP = mesh.shape["model"]
    E, k = cfg.n_experts, cfg.top_k
    # E >= MP and divisible: experts sharded over model ("ep slicing").
    # E < MP (mixtral: 8 over 16): every shard runs ALL experts on its
    # F/MP slice ("tp slicing") — the same single psum combines either
    # the disjoint expert outputs or the f-slice partials.
    mode_tp = E % MP != 0
    f = cfg.moe_d_ff or cfg.d_ff
    if mode_tp and f % MP != 0:
        return None
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    if T % (dp * MP) != 0:
        return None
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    T_loc = T // dp
    C_loc = max(int(cfg.capacity_factor * T_loc * k / E), 1)
    E_loc = E if mode_tp else E // MP
    dt = x.dtype
    glu = "w_gate" in params
    act_name = effective_activation(cfg)
    act = activation_fn(act_name)
    from repro.core.executor import MoRExecutionPlan, as_expert_plan
    em = mor.get("experts") if isinstance(mor, dict) else None
    eplan = as_expert_plan(em, mode=mor_mode, tile_m=cfg.mor.tile_m,
                           tile_n=cfg.mor.tile_n,
                           capacity_frac=cfg.mor.capacity)
    # expert-level MoR rides the EP ("expert slicing") layout only: each
    # shard holds its experts' FULL f dim, so the per-column predictor
    # tables and proxy gathers stay local.  TP slicing splits every
    # expert's columns across shards (proxies may live elsewhere) — the
    # expert FFN stays dense there (ROADMAP: a2a-path limit).
    use_mor = (eplan.active and not mode_tp
               and act_name in ("relu", "relu2", "relu_glu"))
    base_act = "relu" if act_name == "relu_glu" else act_name

    def body(xl, router, w_up, w_gate, w_down, em_loc, cap_loc):
        # xl: (T_loc/MP?, ...) — tokens are sharded over dp ONLY, so with
        # in_spec P(dp_spec) each model shard holds the same T_loc tokens;
        # router logits are computed redundantly (cheap) and each model
        # shard extracts its own experts' buffers (no dispatch comms at
        # all — "expert slicing" beats all_to_all when tokens are
        # replicated over the model axis, which SP decode/train gives us).
        logits = (xl @ router).astype(jnp.float32)       # (T_loc, E)
        probs = jax.nn.softmax(logits, -1)
        top_p, top_idx = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        slot = _dispatch_indices(top_idx, E, C_loc)      # (T_loc, k)
        e0 = 0 if mode_tp else jax.lax.axis_index("model") * E_loc
        # local slot ids for the experts this shard owns
        loc = slot - e0 * C_loc
        mine = (loc >= 0) & (loc < E_loc * C_loc)
        loc = jnp.where(mine, loc, E_loc * C_loc)
        smap = jnp.full((E_loc * C_loc + 1,), T_loc, jnp.int32)
        smap = smap.at[loc.reshape(-1)].set(
            jnp.broadcast_to(jnp.arange(T_loc, dtype=jnp.int32)[:, None],
                             (T_loc, k)).reshape(-1), mode="drop")
        xpad = jnp.concatenate([xl, jnp.zeros((1, d), dt)], 0)
        eb = jnp.take(xpad, smap[:E_loc * C_loc], 0).reshape(E_loc, C_loc, d)
        if use_mor:
            # per-expert MoR plans over this shard's experts: same static
            # config as the attached plan, leaves sliced by the shard_map
            # in_spec.  Buffer rows past an expert's routed count hold
            # the zero pad row — force-skipped via row_mask.
            counts = jnp.bincount(top_idx.reshape(-1), length=E)
            cnt_loc = jax.lax.dynamic_slice(
                counts, (jnp.asarray(e0, jnp.int32),), (E_loc,))
            row_valid = (jnp.arange(C_loc, dtype=jnp.int32)[None, :]
                         < jnp.minimum(cnt_loc, C_loc)[:, None])
            plan = MoRExecutionPlan(em_loc, mode=eplan.mode,
                                    tile_m=eplan.tile_m,
                                    tile_n=eplan.tile_n,
                                    capacity_frac=eplan.capacity_frac,
                                    cap_live=cap_loc if has_cap else None)
            # per-expert stats stay shard-local (telemetry calibrates on
            # the serving path; this is the training/forward layout)
            out_e, _ = plan.expert_ffn(
                eb, w_up, w_down, activation=base_act,
                w_gate=w_gate if glu else None, row_mask=row_valid)
            out_e = out_e.astype(dt)                     # (E_loc, C_loc, d)
        else:
            up = jnp.einsum("ecd,edf->ecf", eb, w_up)
            if glu:
                h = (act(jnp.einsum("ecd,edf->ecf", eb, w_gate))
                     * up).astype(dt)
            else:
                h = act(up).astype(dt)
            out_e = jnp.einsum("ecf,efd->ecd", h, w_down)  # (E_loc, C_loc, d)
        out_flat = jnp.concatenate(
            [out_e.reshape(E_loc * C_loc, d), jnp.zeros((1, d), dt)], 0)
        # combine: each shard contributes only its experts' outputs
        # (an F/R partial when R > 1); psum over model sums the disjoint
        # expert contributions AND the f-slice partials.
        y = jnp.zeros((T_loc, d), dt)
        for kk in range(k):
            part = jnp.take(out_flat, jnp.where(mine[:, kk], loc[:, kk],
                                                E_loc * C_loc), 0)
            y = y + part * top_p[:, kk:kk + 1].astype(dt)
        y = jax.lax.psum(y, "model")
        # load-balance loss (identical on every shard)
        fr = (jnp.zeros((E,), jnp.float32)
              .at[top_idx.reshape(-1)].add(1.0, mode="drop") / (T_loc * k))
        lb = E * jnp.sum(fr * probs.mean(0))
        return y, lb

    gate = params.get("w_gate")
    if mode_tp:
        up_spec = P(None, None, "model")
        down_spec = P(None, "model", None)
    else:
        up_spec = down_spec = P("model")
    # expert MoR leaves ride in expert-sliced ((E, ...) over "model"),
    # mirroring the EP weight layout; a scalar dummy otherwise.  The
    # calibrated per-expert cap_live budget (an authoritative part of an
    # attached plan) slices the same way.
    em_arg = eplan.mor if use_mor else jnp.zeros((), dt)
    em_spec = P("model") if use_mor else P()
    has_cap = use_mor and eplan.cap_live is not None
    cap_arg = (jnp.broadcast_to(jnp.asarray(eplan.cap_live, jnp.float32),
                                (E,))
               if has_cap else jnp.zeros((), dt))
    cap_spec = P("model") if has_cap else P()
    y, lb = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec), P(), up_spec,
                  up_spec if glu else P(), down_spec, em_spec, cap_spec),
        out_specs=(P(dp_spec), P()),
        check_rep=False,
    )(xf, params["router"].astype(dt), params["w_up"].astype(dt),
      gate.astype(dt) if glu else jnp.zeros((), dt),
      params["w_down"].astype(dt), em_arg, cap_arg)
    aux = {"lb_loss": lb, "router_entropy": jnp.zeros((), jnp.float32)}
    return y.reshape(*lead, d), aux


def moe_apply(params: Dict, cfg: ModelConfig, x, *,
              mor=None, mor_mode: str = "dense",
              token_mask=None) -> Tuple[jnp.ndarray, Dict]:
    """x: (..., d) -> (y, aux).  aux carries the load-balance loss.

    ``token_mask`` (broadcastable to x's leading dims) marks REAL tokens:
    masked-out rows are excluded from routing entirely (their expert id
    is set to the out-of-range sentinel E, so they never claim capacity
    slots).  The serving engine's chunk steps pass their validity mask —
    without it, a co-scheduled slot's padding rows would flood an
    expert's capacity buffer and displace real tokens (capacity is
    assigned by token index, earlier wins)."""
    if cfg.expert_sharding == "ep_shmap" and token_mask is None:
        out = moe_apply_a2a(params, cfg, x, mor=mor, mor_mode=mor_mode)
        if out is not None:
            y, aux = out
            if cfg.n_shared_experts:
                ys, _ = mlp_apply(params["shared"], cfg,
                                  x.reshape(-1, x.shape[-1]),
                                  mor=mor, mor_mode=mor_mode)
                y = y + ys.reshape(y.shape)
            return y, aux
    dt = x.dtype
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    f = cfg.moe_d_ff or cfg.d_ff
    if token_mask is not None and cfg.serve_expert_capacity > 0:
        # serving-shape-aware capacity (ROADMAP item): a serving chunk
        # dispatch provisions each expert for the dispatch shape itself.
        # Every token claims at most ONE slot per expert (top-k indices
        # are distinct), so C = serve_expert_capacity * T with the
        # default factor 1.0 can NEVER drop a valid token — chunked
        # prefill computes the exact (drop-free) MoE and matches the
        # teacher-forced logits instead of diverging by design whenever
        # an expert oversubscribed a small dispatch's cf*T*k/E budget.
        C = max(int(math.ceil(cfg.serve_expert_capacity * T)), 1)
    else:
        C = max(int(cfg.capacity_factor * T * k / E), 1)
    act_name = effective_activation(cfg)
    act = activation_fn(act_name)
    glu = "w_gate" in params

    logits = (xf @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    if token_mask is not None:
        tm = jnp.broadcast_to(token_mask, lead).reshape(-1)
        # sentinel expert E: sorts last, drops from bincount/capacity,
        # and lands every masked (token, k) pair on the zero row
        top_idx = jnp.where(tm[:, None], top_idx, E)

    slot = _dispatch_indices(top_idx, E, C)             # (T, k)
    # dispatch = GATHER, not scatter-of-vectors: scattering (T*k, d) rows
    # into the expert buffer made GSPMD all-reduce a (T*k, d) f32 + u32
    # pair per layer (~16 GB/layer at 1M tokens).  Scatter only the int32
    # token ids into the slot map, then gather d-vectors.
    tok_src = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                               (T, k)).reshape(-1)
    slot_map = jnp.full((E * C + 1,), T, jnp.int32)
    slot_map = slot_map.at[slot.reshape(-1)].set(tok_src, mode="drop")
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), dt)], 0)
    eb = jnp.take(xf_pad, slot_map[:E * C], axis=0).reshape(E, C, d)
    from repro.distributed.sharding_rules import constrain
    eb = constrain(eb, "expert_buf")
    h_kind = ("expert_hidden_ep" if cfg.expert_sharding == "ep"
              else "expert_hidden_tp")

    # per-expert FFN.  Expert-level MoR (tentpole, ISSUE 3): the stacked
    # expert MoRLayers run through ONE batched-expert execution plan —
    # the attached plan's own mode/tiling/cap_live is authoritative,
    # a bare stacked layer follows the caller's mor_mode exactly like
    # dense FFNs do (so "dense" skips ALL predictor work).  The router
    # itself already acts as the coarse zero predictor for the
    # (E - top_k) unrouted experts.
    from repro.core.executor import as_expert_plan
    em = mor.get("experts") if isinstance(mor, dict) else None
    eplan = as_expert_plan(em, mode=mor_mode, tile_m=cfg.mor.tile_m,
                           tile_n=cfg.mor.tile_n,
                           capacity_frac=cfg.mor.capacity)
    mor_stats = None
    if eplan.active and act_name in ("relu", "relu2", "relu_glu"):
        base_act = "relu" if act_name == "relu_glu" else act_name
        # buffer rows past an expert's routed count replicate xf_pad's
        # zero row; mark them dead so they never hold tiles live (their
        # outputs are never gathered back) and the per-(layer, expert)
        # liveness telemetry reflects real tokens only
        counts = jnp.bincount(top_idx.reshape(-1), length=E)
        row_valid = (jnp.arange(C, dtype=jnp.int32)[None, :]
                     < jnp.minimum(counts, C)[:, None])
        out_e, mor_stats = eplan.expert_ffn(
            eb, params["w_up"].astype(dt), params["w_down"].astype(dt),
            activation=base_act,
            w_gate=params["w_gate"].astype(dt) if glu else None,
            row_mask=row_valid)
        # anchor the expert outputs like the buffer inputs (the (E, C, f)
        # hidden-layout hint stays on the dense path only — the MoR
        # hidden lives inside the vmapped plan)
        out_e = constrain(out_e.astype(dt), "expert_buf")
    else:
        # dense path (einsum over the expert dim — shardable EP or TP)
        up = jnp.einsum("ecd,edf->ecf", eb, params["w_up"].astype(dt))
        if glu:
            g_pre = jnp.einsum("ecd,edf->ecf", eb,
                               params["w_gate"].astype(dt))
            h = (act(g_pre) * up).astype(dt)
        else:
            h = act(up).astype(dt)
        h = constrain(h, h_kind)
        out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))
    out_flat = jnp.concatenate(
        [out_e.reshape(E * C, d), jnp.zeros((1, d), dt)], 0)

    # combine: gather each (token,k)'s result back, weight by router prob.
    # One (T, d) gather per routed expert k (unrolled, k is 2..6) keeps
    # the intermediate at (T, d) instead of materialising (T, k, d).
    y = jnp.zeros((T, d), dt)
    for kk in range(k):
        part = jnp.take(out_flat, slot[:, kk], axis=0)
        part = constrain(part, "ffn_in_2d")
        y = y + part * top_p[:, kk:kk + 1].astype(dt)

    if cfg.n_shared_experts:
        ys, _ = mlp_apply(params["shared"], cfg, xf, mor=mor,
                          mor_mode=mor_mode)
        y = y + ys

    # Switch-style load-balance aux loss.  bincount, NOT one_hot: a
    # (T, k, E) one-hot at 1M tokens x 160 experts is ~0.5 TB of f32.
    frac_routed = (jnp.zeros((E,), jnp.float32)
                   .at[top_idx.reshape(-1)].add(1.0, mode="drop")
                   / (T * k))
    mean_prob = probs.mean(0)
    aux = {"lb_loss": E * jnp.sum(frac_routed * mean_prob),
           "router_entropy": -jnp.mean(
               jnp.sum(probs * jnp.log(probs + 1e-9), -1))}
    if mor_stats is not None:
        # (E,)-shaped realised skip fractions per expert — stacked over
        # layers by the model scan into the per-(layer, expert) stats
        # the serving telemetry bins ("moe_mor_stats")
        aux["mor_stats"] = mor_stats
    return y.reshape(*lead, d), aux


def moe_taps(params: Dict, cfg: ModelConfig, x) -> Dict:
    """Calibration taps for the expert FFNs: per-expert (p_bin, p_base)
    of the gate (or up) pre-activation over ALL tokens.  Taps are
    routing-independent — expert dispatch merely subsamples the token
    distribution the fitted line models, so fitting on the full stream
    gives every expert the same estimator with more samples."""
    from repro.core.predictor import binary_preact
    x2 = x.reshape(-1, x.shape[-1])
    w = params.get("w_gate", params["w_up"])            # (E, d, f)
    p_base = jnp.einsum("td,edf->etf", x2.astype(jnp.float32),
                        w.astype(jnp.float32))
    p_bin = jax.vmap(lambda we: binary_preact(x2, we))(w)
    return {"p_bin": p_bin, "p_base": p_base}

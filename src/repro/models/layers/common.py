"""Shared initialisation + activation helpers (pure-pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def activation_fn(name: str):
    if name == "relu":
        return jax.nn.relu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    if name in ("silu", "swiglu"):
        return jax.nn.silu
    if name == "relu_glu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name!r}")


def is_glu(name: str) -> bool:
    return name in ("swiglu", "relu_glu")


def split_keys(key, n: int):
    return list(jax.random.split(key, n))

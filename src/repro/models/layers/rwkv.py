"""RWKV6 "Finch" layers: time-mix with data-dependent decay (the defining
Finch feature, via a LoRA on w) and channel-mix with ReLU^2 — the latter is
a *native* Mixture-of-Rookies target (zero iff pre-activation <= 0).

Train/prefill uses a lax.scan over time (state is O(H * hd^2) per layer);
decode is a single recurrence step.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.common import dense_init, split_keys

_W_LORA = 64


def _heads(cfg: ModelConfig):
    hd = cfg.rwkv_head_size
    return cfg.d_model // hd, hd


def timemix_init(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H, hd = _heads(cfg)
    pd = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 8)
    return {
        "mu": jnp.full((5, d), 0.5, jnp.float32),        # r,k,v,g,w lerps
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wA": dense_init(ks[0], d, _W_LORA, pd, scale=0.01),
        "wB": dense_init(ks[1], _W_LORA, d, pd, scale=0.01),
        "Wr": dense_init(ks[2], d, d, pd),
        "Wk": dense_init(ks[3], d, d, pd),
        "Wv": dense_init(ks[4], d, d, pd),
        "Wg": dense_init(ks[5], d, d, pd),
        "Wo": dense_init(ks[6], d, d, pd),
        "u": (jax.random.normal(ks[7], (H, hd), jnp.float32) * 0.1),
        "ln_scale": jnp.ones((d,), jnp.float32),
    }


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _timemix_inputs(params, cfg, x, x_prev):
    """x, x_prev: (..., d) current and token-shifted activations."""
    B = x.shape[:-1]
    d = x.shape[-1]
    H, hd = _heads(cfg)
    dt = x.dtype
    mu = params["mu"].astype(dt)
    xr, xk, xv, xg, xw = (_mix(x, x_prev, mu[i]) for i in range(5))
    r = (xr @ params["Wr"].astype(dt)).reshape(*B, H, hd)
    k = (xk @ params["Wk"].astype(dt)).reshape(*B, H, hd)
    v = (xv @ params["Wv"].astype(dt)).reshape(*B, H, hd)
    g = jax.nn.silu((xg @ params["Wg"].astype(dt)).astype(jnp.float32))
    # Finch data-dependent decay: w = exp(-exp(w0 + tanh(xw A) B))
    dd = jnp.tanh(xw @ params["wA"].astype(dt)) @ params["wB"].astype(dt)
    w = jnp.exp(-jnp.exp(params["w0"] + dd.astype(jnp.float32)))
    return r, k, v, g, w.reshape(*B, H, hd)


def _group_norm(y, scale, eps=1e-6):
    """per-head rmsnorm then flatten; y: (..., H, hd)."""
    r = jnp.reciprocal(jnp.sqrt(jnp.mean(y * y, -1, keepdims=True) + eps))
    out = (y * r).reshape(*y.shape[:-2], -1)
    return out * scale


def _wkv6_chunked(r, k, v, w, u, chunk: int = 8, initial_state=None,
                  return_state: bool = False):
    """GLA-style chunked-parallel wkv6 (exact, tested vs the scan).

    With per-channel decay w_t and A_t = sum_{i<=t} log w_i, the intra-
    chunk contribution factorises:
        y_t = sum_{j<t} (r_t * e^{A_t - A_j - log w_j ... }) . k_j v_j
            = (r_t * e^{A_t}) @ (k_j * e^{-A_j})^T  (strictly-lower mask)
    so the O(S) recurrence becomes O(S/C) chunk scans + per-chunk
    matmuls that feed the MXU — the serial-scan wkv was the worst cell
    in the roofline table (train frac 0.001).  Stabilised by taking the
    cumsum relative to each chunk start.  Decay convention matches the
    scan: state used at t contains kv_j scaled by prod_{i in (j, t)} w_i,
    and the current token contributes via the bonus u.

    r,k,v,w: (B, S, H, hd); returns (B, S, H, hd) float32."""
    B, S, H, hd = r.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))  # noqa
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    nc = (S + pad) // C
    rc = r.reshape(B, nc, C, H, hd).astype(jnp.float32)
    kc = k.reshape(B, nc, C, H, hd).astype(jnp.float32)
    vc = v.reshape(B, nc, C, H, hd).astype(jnp.float32)
    # clamp: |per-chunk cumulated log-decay| <= C*10 = 80 < log(f32_max),
    # so the factored exponentials never overflow.  Exact for w >=
    # exp(-10) ~ 4.5e-5; stronger decays saturate (their true
    # contribution is < e^-10 of the signal).
    logw = jnp.log(jnp.clip(w.reshape(B, nc, C, H, hd).astype(jnp.float32),
                            jnp.exp(-10.0), 1.0))
    # A[t] = sum of log w over chunk positions < t ("decay applied after
    # use": state at t holds kv_j decayed by w_{j+1..t-1}... matching the
    # scan where S is updated with w_t AFTER producing y_t)
    A = jnp.cumsum(logw, axis=2) - logw              # exclusive cumsum
    r_sc = rc * jnp.exp(A)
    k_sc = kc * jnp.exp(-A - logw)                   # e^{-A_j - log w_j}
    scores = jnp.einsum("bcthk,bcjhk->bchtj", r_sc, k_sc)
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)     # strictly lower
    y_intra = jnp.einsum("bchtj,bcjhv->bcthv",
                         jnp.where(tri[None, None, None], scores, 0.0), vc)
    # current-token bonus
    y_intra = y_intra + jnp.einsum("bcthk,bcthk,bcthv->bcthv",
                                   rc, kc * u[None, None, None], vc)
    # inter-chunk: carry S (B,H,hd,hd) across chunks
    decay_end = jnp.exp(A[:, :, -1] + logw[:, :, -1])      # full-chunk decay
    S_local = jnp.einsum("bcjhk,bcjhv->bchkv",
                         kc * jnp.exp(A[:, :, -1:] + logw[:, :, -1:]
                                      - A - logw), vc)

    def carry(Sst, inp):
        S_loc, dec = inp
        S_new = Sst * dec[..., None] + S_loc
        return S_new, Sst

    S0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if initial_state is None
          else initial_state)
    S_last, S_prevs = jax.lax.scan(
        carry, S0, (S_local.transpose(1, 0, 2, 3, 4),
                    decay_end.transpose(1, 0, 2, 3)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)             # (B,nc,H,hd,hd)
    y_inter = jnp.einsum("bcthk,bchkv->bcthv", r_sc, S_prevs)
    y = (y_intra + y_inter).reshape(B, nc * C, H, hd)[:, :S]
    if return_state:
        return y, S_last
    return y


def timemix_forward(params: Dict, cfg: ModelConfig, x, *,
                    chunked: bool = True) -> jnp.ndarray:
    """x: (B, S, d)."""
    B, S, d = x.shape
    H, hd = _heads(cfg)
    dt = x.dtype
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _timemix_inputs(params, cfg, x, x_prev)
    u = params["u"]

    if chunked:
        y = _wkv6_chunked(r, k, v, w, u)
        y = _group_norm(y, params["ln_scale"]) * g
        return y.astype(dt) @ params["Wo"].astype(dt)

    def step(S_state, inp):
        rt, kt, vt, wt = inp                       # (B,H,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32),
                        vt.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32),
                       S_state + u[None, :, :, None] * kv)
        S_state = wt.astype(jnp.float32)[..., None] * S_state + kv
        return S_state, y

    # chunked scan with rematerialisation: a flat S-step scan's VJP saves
    # the (B,H,hd,hd) carry EVERY step (S=4096 -> ~340 GB global); the
    # chunked form saves one carry per chunk and recomputes within.
    CH = 256
    pad = (-S) % CH
    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    if pad:
        xs = tuple(jnp.pad(a, ((0, pad), (0, 0), (0, 0), (0, 0)))
                   for a in xs)
    nc = (S + pad) // CH
    xs_c = tuple(a.reshape(nc, CH, *a.shape[1:]) for a in xs)

    @jax.checkpoint
    def chunk_step(S_state, chunk):
        return jax.lax.scan(step, S_state, chunk)

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, S0, xs_c)
    ys = ys.reshape(nc * CH, B, H, hd)[:S]
    y = ys.transpose(1, 0, 2, 3)                   # (B,S,H,hd)
    y = _group_norm(y, params["ln_scale"]) * g
    return y.astype(dt) @ params["Wo"].astype(dt)


def timemix_chunk(params: Dict, cfg: ModelConfig, x, shift0, wkv0,
                  valid) -> Tuple:
    """State-carrying chunk: x (B, C, d) continues from ``shift0`` (B, d)
    token-shift state and ``wkv0`` (B, H, hd, hd) wkv state; ``valid``
    (B, C) marks real tokens (the valid prefix of each row — serving's
    chunked prefill contract).  Invalid positions are identity updates on
    the state (k -> 0, w -> 1), so the returned state equals the state
    after exactly the valid tokens.  -> (y (B, C, d), shift_new, wkv_new)."""
    dt = x.dtype
    x_prev = jnp.concatenate([shift0[:, None, :].astype(dt), x[:, :-1]], 1)
    r, k, v, g, w = _timemix_inputs(params, cfg, x, x_prev)
    vm = valid[:, :, None, None]
    k = jnp.where(vm, k, 0.0).astype(k.dtype)
    w = jnp.where(vm, w, 1.0)
    y, S_last = _wkv6_chunked(r, k, v, w, params["u"],
                              initial_state=wkv0, return_state=True)
    y = _group_norm(y, params["ln_scale"]) * g
    y = y.astype(dt) @ params["Wo"].astype(dt)
    nv = valid.sum(1)
    last = jnp.clip(nv - 1, 0)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    shift_new = jnp.where((nv > 0)[:, None], x_last, shift0.astype(dt))
    return y, shift_new, S_last


def timemix_decode(params: Dict, cfg: ModelConfig, x, state) -> Tuple:
    """x: (B, d); state: {"shift": (B, d), "wkv": (B,H,hd,hd)}."""
    dt = x.dtype
    r, k, v, g, w = _timemix_inputs(params, cfg, x, state["shift"].astype(dt))
    u = params["u"]
    kv = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32),
                    v.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                   state["wkv"] + u[None, :, :, None] * kv)
    wkv = w.astype(jnp.float32)[..., None] * state["wkv"] + kv
    y = _group_norm(y, params["ln_scale"]) * g
    out = y.astype(dt) @ params["Wo"].astype(dt)
    return out, {"shift": x, "wkv": wkv}


# --- channel mix (ReLU^2 -> native MoR target) -----------------------------

def chanmix_init(key, cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 3)
    return {
        "mu": jnp.full((2, d), 0.5, jnp.float32),   # k, r lerps
        "w_up": dense_init(ks[0], d, f, pd),
        "w_down": dense_init(ks[1], f, d, pd),
        "Wr": dense_init(ks[2], d, d, pd),
    }


def chanmix_forward(params: Dict, cfg: ModelConfig, x, x_prev, *,
                    mor=None, mor_mode: str = "dense") -> Tuple:
    """x, x_prev: (..., d).  ReLU^2 channel mix with MoR hook."""
    dt = x.dtype
    mu = params["mu"].astype(dt)
    xk = _mix(x, x_prev, mu[0])
    xr = _mix(x, x_prev, mu[1])
    gate = jax.nn.sigmoid((xr @ params["Wr"].astype(dt)).astype(jnp.float32))
    stats: Dict = {}
    from repro.core.executor import as_plan
    plan = as_plan(mor, mode=mor_mode, tile_m=cfg.mor.tile_m,
                   tile_n=cfg.mor.tile_n, capacity_frac=cfg.mor.capacity)
    if plan.active:
        lead = xk.shape[:-1]
        h, stats = plan.relu_matmul(
            xk.reshape(-1, xk.shape[-1]), params["w_up"].astype(dt),
            activation="relu2")
        h = h.reshape(*lead, -1)
    else:
        h = jnp.square(jax.nn.relu(xk @ params["w_up"].astype(dt)))
    y = gate.astype(dt) * (h.astype(dt) @ params["w_down"].astype(dt))
    return y, stats

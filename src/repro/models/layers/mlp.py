"""FFN layers with the Mixture-of-Rookies hook.

``mlp_apply`` runs the standard dense math during training; at inference,
when a calibrated ``MoRLayer`` is supplied and the activation is
ReLU-family, it routes through ``repro.core.masked_ffn``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

import jax

from repro.configs.base import ModelConfig
from repro.models.layers.common import activation_fn, dense_init, is_glu, split_keys


@jax.custom_vjp
def _down_matmul(h, w):
    """TP down-projection with a hand-pinned backward.

    GSPMD's derived backward for `dh = dy @ w^T` under a sequence-
    parallel residual all-gathers the FULL-d_ff hidden grad per layer
    (measured: 9.9 GB/layer f32 on qwen2-7b).  The custom vjp computes
    dh/dw with their shardings pinned to the forward layout.  Composes
    with jax.checkpoint: under remat the residuals are recomputed, not
    saved."""
    return h @ w


def _dm_fwd(h, w):
    return h @ w, (h, w)


def _dm_bwd(res, dy):
    from repro.distributed.sharding_rules import constrain
    h, w = res
    dy = dy.astype(h.dtype)
    dh = constrain(dy @ w.T, "ffn_hidden_2d")
    dw = constrain(h.T @ dy, "w_down_grad")
    return dh.astype(h.dtype), dw.astype(w.dtype)


_down_matmul.defvjp(_dm_fwd, _dm_bwd)


def effective_activation(cfg: ModelConfig) -> str:
    """swiglu + relufied -> relu_glu; gelu + relufied -> relu."""
    act = cfg.activation
    if cfg.mor.relufied:
        if act == "swiglu":
            return "relu_glu"
        if act in ("gelu", "silu"):
            return "relu"
    return act


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    act = effective_activation(cfg)
    if is_glu(act):
        ks = split_keys(key, 3)
        return {"w_gate": dense_init(ks[0], d, f, pd),
                "w_up": dense_init(ks[1], d, f, pd),
                "w_down": dense_init(ks[2], f, d, pd)}
    ks = split_keys(key, 2)
    return {"w_up": dense_init(ks[0], d, f, pd),
            "w_down": dense_init(ks[1], f, d, pd)}


def mlp_apply(params: Dict, cfg: ModelConfig, x, *,
              mor=None, mor_mode: str = "dense",
              ) -> Tuple[jnp.ndarray, Dict]:
    """x: (..., d).  Returns (y, mor_stats)."""
    act_name = effective_activation(cfg)
    dt = x.dtype
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    stats: Dict = {}

    from repro.core.executor import as_plan
    plan = as_plan(mor, mode=mor_mode, tile_m=cfg.mor.tile_m,
                   tile_n=cfg.mor.tile_n, capacity_frac=cfg.mor.capacity)
    use_mor = plan.active and act_name in ("relu", "relu2", "relu_glu")
    if use_mor:
        base = "relu" if act_name == "relu_glu" else act_name
        y, stats = plan.ffn(
            x2,
            params["w_up"].astype(dt),
            params["w_down"].astype(dt),
            activation=base,
            w_gate=params.get("w_gate", None) if is_glu(act_name) else None,
        )
        return y.reshape(*lead, -1).astype(dt), stats

    from repro.distributed.sharding_rules import constrain
    x2 = constrain(x2, "ffn_in_2d")
    fn = activation_fn(act_name)
    if is_glu(act_name):
        h = fn(x2 @ params["w_gate"].astype(dt)) * (x2 @ params["w_up"].astype(dt))
    else:
        h = fn(x2 @ params["w_up"].astype(dt))
    h = constrain(h.astype(dt), "ffn_hidden_2d")
    y = _down_matmul(h, params["w_down"].astype(dt))
    return y.reshape(*lead, -1), stats


def mlp_taps(params: Dict, cfg: ModelConfig, x) -> Dict:
    """Calibration taps: (p_bin, p_base) for the ReLU pre-activation of
    this FFN (gate matmul for GLU, up matmul otherwise)."""
    from repro.core.predictor import binary_preact
    dt = x.dtype
    x2 = x.reshape(-1, x.shape[-1])
    w = params["w_gate"] if "w_gate" in params else params["w_up"]
    p_base = (x2 @ w.astype(dt)).astype(jnp.float32)
    p_bin = binary_preact(x2, w)
    return {"p_bin": p_bin, "p_base": p_base}

"""Attention: GQA/MQA (full + sliding-window), MLA (DeepSeek-V2), with
flash-style chunked softmax for long sequences and ring-buffer /
absorbed-latent decode caches.

All einsums accumulate softmax statistics in fp32; activations stay in the
model compute dtype.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import decode_attention as da
from repro.kernels import paged_attention as pk
from repro.models.layers.common import dense_init, split_keys
from repro.models.layers.norms import norm_init, apply_norm
from repro.models.layers.rope import apply_rope

NEG_INF = da.NEG_INF      # one mask floor across paged/sharded layouts
_FLASH_THRESHOLD = 4096   # use chunked attention above this many kv positions
_CHUNK = 1024


def set_flash_threshold(n: int) -> None:
    """Perf knob (dry-run --flash-threshold): kv length above which the
    chunked-softmax path replaces the S x S materialising sdpa."""
    global _FLASH_THRESHOLD
    _FLASH_THRESHOLD = n


# ===========================================================================
# GQA / MQA
# ===========================================================================

def gqa_init(key, cfg: ModelConfig) -> Dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pd = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, pd),
        "wk": dense_init(ks[1], d, hkv * hd, pd),
        "wv": dense_init(ks[2], d, hkv * hd, pd),
        "wo": dense_init(ks[3], h * hd, d, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), pd)
        p["bk"] = jnp.zeros((hkv * hd,), pd)
        p["bv"] = jnp.zeros((hkv * hd,), pd)
    return p


def _qkv(params, cfg: ModelConfig, x):
    B, S, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    return (q.reshape(B, S, h, hd), k.reshape(B, S, hkv, hd),
            v.reshape(B, S, hkv, hd))


def _mask_bias(q_pos, kv_pos, causal: bool, window: int):
    """(Sq, Skv) additive bias from position vectors — the unbatched
    face of ``decode_attention.position_ok``, so the teacher-forced,
    slotted, paged and sharded paths all share ONE mask predicate."""
    ok = da.position_ok(q_pos[:, None], kv_pos[None, :], causal, window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """q:(B,Sq,H,D) k:(B,Skv,Hkv,D) v:(B,Skv,Hkv,Dv) bias:(Sq,Skv)."""
    B, Sq, H, D = q.shape
    Hkv, Dv = k.shape[2], v.shape[-1]
    G = H // Hkv
    qf = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf, k,
                   preferred_element_type=jnp.float32)
    s = s * (D ** -0.5) + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, Dv)


def _flash(q, k, v, q_pos, kv_pos, causal: bool, window: int):
    """Chunked-softmax attention: scan over kv chunks with running
    (max, denom, acc) — bounds temp memory to one (Sq, CHUNK) tile."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    C = min(_CHUNK, Skv)
    n_chunks = (Skv + C - 1) // C
    pad = n_chunks * C - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    kc = k.reshape(B, n_chunks, C, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, C, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, C)
    qf = (q.reshape(B, Sq, Hkv, G, D) * (D ** -0.5)).astype(q.dtype)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s = jnp.einsum("bqkgd,btkd->bkgqt", qf, kb,
                       preferred_element_type=jnp.float32)
        s = s + _mask_bias(q_pos, pb, causal, window)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqt,btkd->bkgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def _banded(q, k, v, q_pos, kv_pos, window: int):
    """Sliding-window attention that only touches in-window kv chunks:
    for q-chunk i, dynamic-slice kv rows [i*C - W, i*C + C).  Sub-quadratic
    in sequence length (O(S * (W + C)))."""
    B, S, H, D = q.shape
    C = min(_CHUNK, S)
    n_chunks = S // C if S % C == 0 else None
    assert n_chunks is not None, "banded path expects seq % chunk == 0"
    W = window
    span = W + C

    kp = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))
    pp = jnp.pad(kv_pos, (W, 0), constant_values=-1)

    def one_chunk(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * C, C, axis=1)
        qpi = jax.lax.dynamic_slice_in_dim(q_pos, i * C, C)
        ki = jax.lax.dynamic_slice_in_dim(kp, i * C, span, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vp, i * C, span, axis=1)
        ppi = jax.lax.dynamic_slice_in_dim(pp, i * C, span)
        bias = _mask_bias(qpi, ppi, True, W)
        return _sdpa(qi, ki, vi, bias)

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, v.shape[-1])


def attend(q, k, v, q_pos, kv_pos, *, causal: bool, window: int = 0):
    Skv = k.shape[1]
    if window and q.shape[1] == k.shape[1] and q.shape[1] % min(_CHUNK, q.shape[1]) == 0 \
            and q.shape[1] > window:
        return _banded(q, k, v, q_pos, kv_pos, window)
    if Skv > _FLASH_THRESHOLD:
        return _flash(q, k, v, q_pos, kv_pos, causal, window)
    bias = _mask_bias(q_pos, kv_pos, causal, window)
    return _sdpa(q, k, v, bias)


def attend_batched(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                   window: int = 0):
    """Attention with PER-BATCH-ROW positions: q_pos (B, Sq), kv_pos
    (B, Skv).  This is the continuous-batching slot-pool case — every
    slot sits at its own position, so the additive bias carries a batch
    dim instead of being shared.  kv entries tagged -1 are masked.
    The mask itself lives in ``decode_attention.batched_bias`` so the
    sharded partial-flash path shares the exact same semantics."""
    bias = da.batched_bias(q_pos, kv_pos, causal, window)
    return _sdpa(q, k, v, bias[:, None, None])


def gqa_forward(params, cfg: ModelConfig, x, positions):
    q, k, v = _qkv(params, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    pos1 = positions[0] if positions.ndim == 2 else positions
    o = attend(q, k, v, pos1, pos1, causal=cfg.causal,
               window=cfg.sliding_window)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ params["wo"].astype(x.dtype)


# --- decode cache -----------------------------------------------------------

def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    W = cfg.sliding_window or max_len
    L = min(W, max_len)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, L, hkv, hd), dtype),
        "v": jnp.zeros((batch, L, hkv, hd), dtype),
        "pos": jnp.full((L,), -1, jnp.int32),
    }


def _tp_flash_decode(q, k, v, kv_pos, pos, window: int):
    """Decode attention over a sequence-sharded KV cache: each 'model'
    shard computes local flash statistics (max, denom, acc) over its
    S/P slice; one pmax + two psums merge the softmax exactly.  Replaces
    GSPMD's derived strategy, which all-gathered the sharded KV
    (measured 16 GB/step on qwen2-7b decode_32k)."""
    from repro.distributed.sharding_rules import _TLS
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return None
    mesh, _ = ctx
    if "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        return None
    P_ = mesh.shape["model"]
    B, Skv = k.shape[0], k.shape[1]
    if Skv % P_ != 0:
        return None
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    b_spec = (dp_axes if len(dp_axes) > 1 else dp_axes[0]) \
        if (dp_axes and B % dp == 0) else None

    def body(qb, kb, vb, pb):
        D = qb.shape[-1]
        s = jnp.einsum("bqkgd,btkd->bkgqt", qb, kb,
                       preferred_element_type=jnp.float32) * (D ** -0.5)
        s = s + _mask_bias(jnp.full((1,), pos, jnp.int32), pb[0],
                           True, window)
        m_loc = s.max(-1)
        m_glob = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m_glob[..., None])
        l = jax.lax.psum(p.sum(-1), "model")
        acc = jax.lax.psum(
            jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vb.dtype),
                       vb).astype(jnp.float32), "model")
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qb.dtype)

    Hkv = k.shape[2]
    G = q.shape[2] // Hkv
    qf = q.reshape(B, 1, Hkv, G, q.shape[-1])
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(b_spec), P(b_spec, "model"), P(b_spec, "model"),
                  P(None, "model")),
        out_specs=P(b_spec), check_rep=False,
    )(qf, k, v, kv_pos[None, :])
    return out.reshape(B, 1, q.shape[2], q.shape[-1])


def gqa_decode(params, cfg: ModelConfig, x, cache, pos):
    """x: (B, 1, d); pos: scalar int32 current position."""
    B = x.shape[0]
    q, k, v = _qkv(params, cfg, x)
    pvec = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, pvec, cfg.rope_theta)
    k = apply_rope(k, pvec, cfg.rope_theta)
    L = cache["k"].shape[1]
    slot = pos % L
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    cp = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)
    o = _tp_flash_decode(q, ck, cv, cp, pos, cfg.sliding_window)
    if o is None:
        o = attend(q, ck, cv, jnp.full((1,), pos, jnp.int32), cp,
                   causal=True, window=cfg.sliding_window)
    y = o.reshape(B, 1, -1) @ params["wo"].astype(x.dtype)
    return y, {"k": ck, "v": cv, "pos": cp}


def gqa_prefill(params, cfg: ModelConfig, x, cache):
    """Batched prefill: consume the whole (B, S, d) prompt in one step,
    attending within the prompt (forward-style causal attention) while
    writing all S kv rows into the FRESH decode cache at once.  Replaces
    S single-token decode dispatches with one compiled step.

    Assumes the cache is empty (pos == 0) and S fits the ring buffer
    (S <= cache length); ``launch.steps.make_prefill_step`` falls back to
    a scanned decode when that doesn't hold."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, cfg, x)
    pvec = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q = apply_rope(q, pvec, cfg.rope_theta)
    k = apply_rope(k, pvec, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
    tags = jnp.arange(S, dtype=jnp.int32)
    if cache["pos"].ndim == 2:      # slot-pool layout: per-slot (B, Lr) tags
        cp = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.broadcast_to(tags[None], (B, S)), (0, 0))
    else:
        cp = jax.lax.dynamic_update_slice_in_dim(cache["pos"], tags, 0,
                                                 axis=0)
    pos1 = jnp.arange(S, dtype=jnp.int32)
    o = attend(q, k, v, pos1, pos1, causal=True, window=cfg.sliding_window)
    y = o.reshape(B, S, -1) @ params["wo"].astype(x.dtype)
    return y, {"k": ck, "v": cv, "pos": cp}


def gqa_chunk(params, cfg: ModelConfig, x, cache, pos, valid,
              block_table=None):
    """Slot-pool chunk step: consume x (B, C, d) starting at PER-SLOT
    positions ``pos`` (B,), with ``valid`` (B, C) marking real tokens
    (a slot mid-prompt has a full row; an idle or decoding slot has
    n_valid 0 or 1).  Invalid tokens are dropped from the ring-buffer
    write (out-of-range scatter index), so an idle slot's cache is
    bit-identical before and after the dispatch.

    With ``block_table`` (B, n_blocks) the cache is the PAGED layout
    ({k/v (n_pages, page, hkv, hd), pos (n_pages, page)}): ring row
    ``r = qpos % (n_blocks * page)`` lives at physical page
    ``block_table[b, r // page]``, offset ``r % page``.  Reads gather
    the slot's ring view through the table (null-page rows carry -1
    position tags and mask out); writes scatter through the same
    indirection — the pool guarantees every written page is exclusively
    owned (copy-on-write happens host-side before dispatch).

    Under a page-shard context (``distributed.decode_attention``, the
    engine's ``paged-sharded`` layout) the pool arrays are the LOCAL
    page range of a mesh-sharded pool: writes drop pages another shard
    owns, reads gather only locally-resident pages, and attention
    becomes a distributed flash decode — partial (m, l, acc) statistics
    per shard merged with one collective per layer.

    The ring must have ≥ chunk-length slack above the attention window
    (``serving.kv_pool`` allocates window + serve_chunk) so that the
    oldest in-window entries are not overwritten by the chunk itself."""
    B, C, _ = x.shape
    q, k, v = _qkv(params, cfg, x)
    qpos = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    q = apply_rope(q, qpos, cfg.rope_theta)
    k = apply_rope(k, qpos, cfg.rope_theta)
    if block_table is not None:
        page = cache["k"].shape[1]
        ring = block_table.shape[1] * page
        r = qpos % ring
        blk, off = r // page, r % page
        pidx = jnp.take_along_axis(block_table, blk, axis=1)
        ck = da.pool_set(cache["k"], pidx, off, k, valid)
        cv = da.pool_set(cache["v"], pidx, off, v, valid)
        cp = da.pool_set(cache["pos"], pidx, off, qpos, valid)
        if da.shard_info() is not None:
            o = da.gqa_paged_attend(q, ck, cv, cp, block_table, qpos,
                                    window=cfg.sliding_window)
        elif pk.enabled():
            # fused paged flash decode: attend straight off the block
            # table — no materialised ring view, null pages skipped at
            # the grid level
            o = pk.gqa_paged_flash(q, ck, cv, cp, block_table, qpos,
                                   window=cfg.sliding_window)
        else:
            hkv, hd = k.shape[2], k.shape[3]
            gk = da.pool_view(ck, block_table, 0).reshape(B, ring, hkv, hd)
            gv = da.pool_view(cv, block_table, 0).reshape(B, ring, hkv, hd)
            gp = da.pool_view(cp, block_table, -1).reshape(B, ring)
            o = attend_batched(q, gk, gv, qpos, gp, causal=True,
                               window=cfg.sliding_window)
        y = o.reshape(B, C, -1) @ params["wo"].astype(x.dtype)
        return y, {"k": ck, "v": cv, "pos": cp}
    Lr = cache["k"].shape[1]
    slot = jnp.where(valid, qpos % Lr, Lr)          # Lr is OOB -> dropped
    bidx = jnp.arange(B)[:, None]
    ck = cache["k"].at[bidx, slot].set(k, mode="drop")
    cv = cache["v"].at[bidx, slot].set(v, mode="drop")
    cp = cache["pos"].at[bidx, slot].set(qpos, mode="drop")
    o = attend_batched(q, ck, cv, qpos, cp, causal=True,
                       window=cfg.sliding_window)
    y = o.reshape(B, C, -1) @ params["wo"].astype(x.dtype)
    return y, {"k": ck, "v": cv, "pos": cp}


# ===========================================================================
# MLA (DeepSeek-V2): low-rank joint kv compression + decoupled RoPE head
# ===========================================================================

def mla_init(key, cfg: ModelConfig) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    pd = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, qr, pd),
        "q_norm": norm_init(cfg.norm, qr),
        "wq_b": dense_init(ks[1], qr, h * (nd + rd), pd),
        "wkv_a": dense_init(ks[2], d, kr + rd, pd),
        "kv_norm": norm_init(cfg.norm, kr),
        "wk_b": dense_init(ks[3], kr, h * nd, pd),
        "wv_b": dense_init(ks[4], kr, h * vd, pd),
        "wo": dense_init(ks[5], h * vd, d, pd),
    }


def _mla_q(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    h = cfg.n_heads
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    dt = x.dtype
    cq = apply_norm(cfg.norm, params["q_norm"], x @ params["wq_a"].astype(dt))
    q = (cq @ params["wq_b"].astype(dt)).reshape(B, S, h, nd + rd)
    q_nope, q_pe = q[..., :nd], q[..., nd:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _mla_kv_compress(params, cfg: ModelConfig, x, positions):
    kr, rd = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dt = x.dtype
    kv = x @ params["wkv_a"].astype(dt)
    c_kv = apply_norm(cfg.norm, params["kv_norm"], kv[..., :kr])
    k_pe = apply_rope(kv[..., kr:][:, :, None, :], positions,
                      cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_pe


def mla_forward(params, cfg: ModelConfig, x, positions):
    """Prefill/train path: expand the latent kv to per-head k/v."""
    B, S, _ = x.shape
    h = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank
    dt = x.dtype
    q_nope, q_pe = _mla_q(params, cfg, x, positions)
    c_kv, k_pe = _mla_kv_compress(params, cfg, x, positions)
    k_nope = (c_kv @ params["wk_b"].astype(dt)).reshape(B, S, h, nd)
    v = (c_kv @ params["wv_b"].astype(dt)).reshape(B, S, h, vd)
    # pack rope dims into k/q so we can reuse the shared attend()
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, h, rd))], -1)
    q_full = jnp.concatenate([q_nope, q_pe], -1)
    pos1 = positions[0] if positions.ndim == 2 else positions
    o = attend(q_full, k_full, v, pos1, pos1, causal=True, window=0)
    return o.reshape(B, S, h * vd) @ params["wo"].astype(dt)


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_prefill(params, cfg: ModelConfig, x, cache):
    """Batched MLA prefill: run the expanded (forward-style) attention
    over the whole prompt while writing the latent kv cache rows [0, S)
    in one shot.  Assumes a fresh cache (pos == 0)."""
    B, S, _ = x.shape
    h = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = x.dtype
    pvec = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q_nope, q_pe = _mla_q(params, cfg, x, pvec)
    c_kv, k_pe = _mla_kv_compress(params, cfg, x, pvec)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, 0, axis=1)
    cp = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe, 0, axis=1)
    k_nope = (c_kv @ params["wk_b"].astype(dt)).reshape(B, S, h, nd)
    v = (c_kv @ params["wv_b"].astype(dt)).reshape(B, S, h, vd)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, h, rd))], -1)
    q_full = jnp.concatenate([q_nope, q_pe], -1)
    pos1 = jnp.arange(S, dtype=jnp.int32)
    o = attend(q_full, k_full, v, pos1, pos1, causal=True, window=0)
    y = o.reshape(B, S, h * vd) @ params["wo"].astype(dt)
    new_cache = {"c_kv": ck, "k_pe": cp}
    if "pos" in cache:              # slot-pool layout carries kv pos tags
        new_cache["pos"] = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.broadcast_to(pos1[None], (B, S)), (0, 0))
    return y, new_cache


def mla_chunk(params, cfg: ModelConfig, x, cache, pos, valid,
              block_table=None):
    """Slot-pool chunk step for MLA (absorbed latent attention): x
    (B, C, d) at per-slot positions ``pos`` (B,); ``valid`` (B, C) gates
    the cache scatter.  The cache carries per-slot position tags
    (``cache["pos"]``, (B, max_len), -1 = empty) so each slot only
    attends to its own written prefix.

    With ``block_table`` (B, n_blocks) the latent cache is the PAGED
    layout ({c_kv (n_pages, page, kr), k_pe, pos (n_pages, page)}):
    absolute position p lives at page ``block_table[b, p // page]``,
    offset ``p % page`` (no ring — MLA caches the full max_len).  Under
    a page-shard context the pools are the local range of a mesh-
    sharded pool and the absorbed attention runs as a distributed flash
    decode in latent space (partial stats per shard, one collective
    merge, W_uv absorbed after the merge)."""
    B, C, _ = x.shape
    h, nd, vd = cfg.n_heads, cfg.qk_nope_head_dim, cfg.v_head_dim
    kr, rd = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dt = x.dtype
    qpos = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    q_nope, q_pe = _mla_q(params, cfg, x, qpos)          # (B,C,h,nd/rd)
    c_kv_t, k_pe_t = _mla_kv_compress(params, cfg, x, qpos)
    wk_b = params["wk_b"].astype(dt).reshape(kr, h, nd)
    wv_b = params["wv_b"].astype(dt).reshape(kr, h, vd)
    q_lat = jnp.einsum("bchd,khd->bchk", q_nope, wk_b)   # absorb W_uk
    if block_table is not None:
        page = cache["c_kv"].shape[1]
        blk, off = qpos // page, qpos % page
        pidx = jnp.take_along_axis(block_table, blk, axis=1)
        ck_pool = da.pool_set(cache["c_kv"], pidx, off, c_kv_t, valid)
        cpe_pool = da.pool_set(cache["k_pe"], pidx, off, k_pe_t, valid)
        cp_pool = da.pool_set(cache["pos"], pidx, off, qpos, valid)
        new_cache = {"c_kv": ck_pool, "k_pe": cpe_pool, "pos": cp_pool}
        if da.shard_info() is not None:
            o_lat = da.mla_paged_attend(q_lat, q_pe, ck_pool, cpe_pool,
                                        cp_pool, block_table, qpos,
                                        scale=(nd + rd) ** -0.5)
            o = jnp.einsum("bchk,khv->bchv", o_lat, wv_b)  # absorb W_uv
            y = o.reshape(B, C, h * vd) @ params["wo"].astype(dt)
            return y, new_cache
        if pk.enabled():
            o_lat = pk.mla_paged_flash(q_lat, q_pe, ck_pool, cpe_pool,
                                       cp_pool, block_table, qpos,
                                       scale=(nd + rd) ** -0.5)
            o = jnp.einsum("bchk,khv->bchv", o_lat, wv_b)  # absorb W_uv
            y = o.reshape(B, C, h * vd) @ params["wo"].astype(dt)
            return y, new_cache
        ring = block_table.shape[1] * page
        ck = da.pool_view(ck_pool, block_table, 0).reshape(B, ring, kr)
        cpe = da.pool_view(cpe_pool, block_table, 0).reshape(B, ring, rd)
        cp = da.pool_view(cp_pool, block_table, -1).reshape(B, ring)
    else:
        ML = cache["c_kv"].shape[1]
        idx = jnp.where(valid, qpos, ML)                 # ML is OOB -> drop
        bidx = jnp.arange(B)[:, None]
        ck = cache["c_kv"].at[bidx, idx].set(c_kv_t, mode="drop")
        cpe = cache["k_pe"].at[bidx, idx].set(k_pe_t, mode="drop")
        cp = cache["pos"].at[bidx, idx].set(qpos, mode="drop")
        new_cache = {"c_kv": ck, "k_pe": cpe, "pos": cp}
    s = (jnp.einsum("bchk,btk->bhct", q_lat, ck,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bchr,btr->bhct", q_pe, cpe,
                      preferred_element_type=jnp.float32))
    s = s * ((nd + rd) ** -0.5)
    ok = da.position_ok(qpos[:, None, :, None], cp[:, None, None, :], True, 0)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhct,btk->bchk", p, ck)
    o = jnp.einsum("bchk,khv->bchv", o_lat, wv_b)        # absorb W_uv
    y = o.reshape(B, C, h * vd) @ params["wo"].astype(dt)
    return y, new_cache


def mla_decode(params, cfg: ModelConfig, x, cache, pos):
    """Absorbed decode: attention runs in the rank-512 latent space; the
    per-head k/v are never materialised (cache is (S, kv_lora+rope))."""
    B = x.shape[0]
    h, nd, vd = cfg.n_heads, cfg.qk_nope_head_dim, cfg.v_head_dim
    kr, rd = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dt = x.dtype
    pvec = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_pe = _mla_q(params, cfg, x, pvec)      # (B,1,h,nd/rd)
    c_kv_t, k_pe_t = _mla_kv_compress(params, cfg, x, pvec)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv_t, pos, axis=1)
    cp = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe_t, pos, axis=1)
    wk_b = params["wk_b"].astype(dt).reshape(kr, h, nd)
    wv_b = params["wv_b"].astype(dt).reshape(kr, h, vd)
    q_lat = jnp.einsum("bohd,khd->bhk", q_nope, wk_b)        # absorb W_uk
    s = (jnp.einsum("bhk,btk->bht", q_lat, ck,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bohr,btr->bht", q_pe, cp,
                      preferred_element_type=jnp.float32))
    s = s * ((nd + rd) ** -0.5)
    t_idx = jnp.arange(ck.shape[1])
    s = jnp.where(t_idx[None, None, :] <= pos, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    o_lat = jnp.einsum("bht,btk->bhk", p, ck)
    o = jnp.einsum("bhk,khv->bhv", o_lat, wv_b)              # absorb W_uv
    y = o.reshape(B, 1, h * vd) @ params["wo"].astype(dt)
    return y, {"c_kv": ck, "k_pe": cp}

"""Time-Depth-Separable ASR network (paper §3.1 Fig. 2a, Hannun et al.):
per block, a 1-D conv over time with ReLU + residual + layernorm, then a
two-layer FC bottleneck with ReLU + residual + layernorm.  Both ReLU
pre-activations are MoR targets (the paper's primary benchmark: TDS gives
46% of MACs in ReLU-activated CONV+FC layers, Fig. 3).

Inputs are pre-processed audio frames (B, T, d) — the paper's pipeline
also consumes filterbank features; synthetic frames suffice to exercise
the mechanism.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.common import dense_init, split_keys
from repro.models.layers.norms import layernorm_init, apply_norm

_KERNEL = 5


def init_params(key, cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, cfg.n_layers + 2)
    layers = []
    for i in range(cfg.n_layers):
        lk = split_keys(ks[i], 3)
        layers.append({
            "conv_w": (jax.random.normal(lk[0], (_KERNEL, d, d), jnp.float32)
                       * (_KERNEL * d) ** -0.5),
            "conv_b": jnp.zeros((d,), jnp.float32),
            "ln1": layernorm_init(d),
            "fc1": dense_init(lk[1], d, f),
            "fc1_b": jnp.zeros((f,), jnp.float32),
            "fc2": dense_init(lk[2], f, d),
            "ln2": layernorm_init(d),
        })
    return {"layers": layers,
            "head": dense_init(ks[-1], d, cfg.vocab_size)}


def _conv1d(x, w, b):
    """x: (B,T,d), w: (K,d,d) causal-padded conv over time."""
    B, T, d = x.shape
    xp = jnp.pad(x, ((0, 0), (_KERNEL - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + T, :] @ w[i] for i in range(_KERNEL)) + b
    return out


def forward(params: Dict, cfg: ModelConfig, batch: Dict, *,
            with_taps: bool = False, mor: Optional[List] = None,
            mor_mode: str = "dense") -> Tuple[jnp.ndarray, Dict]:
    x = batch["frames"]
    taps: List[Dict] = []
    mstats: List[Dict] = []
    for i, lp in enumerate(params["layers"]):
        # --- conv sub-block ---
        pre = _conv1d(x, lp["conv_w"], lp["conv_b"])
        if with_taps:
            from repro.core.predictor import binarize
            xs = jnp.where(x > 0, 1.0, -1.0)
            wb = binarize(lp["conv_w"]).astype(x.dtype)
            p_bin = _conv1d(xs, wb, jnp.zeros_like(lp["conv_b"]))
            taps.append({"p_bin": p_bin.reshape(-1, pre.shape[-1]),
                         "p_base": pre.reshape(-1, pre.shape[-1]),
                         "relu_in": pre.reshape(-1, pre.shape[-1])})
        x = apply_norm("layernorm", lp["ln1"], x + jax.nn.relu(pre))
        # --- FC sub-block ---
        if mor is not None and mor_mode != "dense" and mor[i] is not None:
            from repro.core.executor import as_plan
            plan = as_plan(mor[i], mode=mor_mode, tile_m=cfg.mor.tile_m,
                           tile_n=cfg.mor.tile_n,
                           capacity_frac=cfg.mor.capacity)
            m = plan.mor
            x2 = x.reshape(-1, x.shape[-1])
            h, st = plan.relu_matmul(x2, lp["fc1"][:, m["perm"]],
                                     activation="relu")
            mstats.append(st)
            fc = (h @ lp["fc2"][m["perm"], :]).reshape(x.shape)
        else:
            pre_fc = x @ lp["fc1"] + lp["fc1_b"]
            if with_taps:
                from repro.core.predictor import binary_preact
                x2 = x.reshape(-1, x.shape[-1])
                taps.append({
                    "p_bin": binary_preact(x2, lp["fc1"]),
                    "p_base": (x2 @ lp["fc1"]).astype(jnp.float32),
                    "relu_in": pre_fc.reshape(-1, pre_fc.shape[-1]),
                })
            fc = jax.nn.relu(pre_fc) @ lp["fc2"]
        x = apply_norm("layernorm", lp["ln2"], x + fc)
    logits = x @ params["head"]
    aux: Dict[str, Any] = {}
    if with_taps:
        aux["taps"] = taps
    if mstats:
        aux["mor_stats"] = mstats
    return logits, aux


def layer_weight_matrices(params: Dict) -> List[jnp.ndarray]:
    """(K,N) weight matrices of the FC1 ReLU layers (MoR targets)."""
    return [lp["fc1"] for lp in params["layers"]]

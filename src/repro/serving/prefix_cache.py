"""Prefix cache: a hash-trie of full KV pages plus recurrent-state
snapshots, keyed by token prefixes.

Two kinds of reusable artifacts come out of serving a prompt:

  * **KV pages** (attention families): a physical page holding the kv
    rows for positions ``[i*page, (i+1)*page)`` is valid for ANY later
    request whose first ``(i+1)*page`` tokens are identical — the keys
    are RoPE'd at absolute positions, which match by construction.  The
    trie stores one entry per *full* page, keyed by the entire token
    prefix up to that page boundary (so a lookup walks parent-to-child:
    a page only matches if everything before it matched too — the trie
    property, realised as a dict of prefix keys).  Sliding-window
    prompts longer than their ring publish at the LAST PRE-WRAP page
    boundary (``PagedPool.maybe_publish_prewrap``) — by prefill's end
    the ring has wrapped and its pages hold the tail, not the prefix.
    Entries store GLOBAL page ids, so the trie works unchanged over the
    mesh-sharded pool (ids partition deterministically across shards).
  * **State snapshots** (ssm / hybrid families): recurrent state at a
    page-aligned prompt offset, keyed by the exact token prefix it
    summarises.  A hybrid snapshot also records the KV page ids of the
    shared-attention ring below that offset, so a hit restores both.

This module is pure host-side bookkeeping: it stores *page ids* and
*snapshot page ids*, never device arrays.  Refcount changes are the
caller's job (``kv_pool.PagedPool`` retains a page per trie entry that
lists it and drops it on eviction), which keeps this class trivially
testable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PrefixCache", "PageEntry", "SnapEntry"]


@dataclass
class PageEntry:
    """One full KV page: positions [depth*page, (depth+1)*page)."""
    page: int                    # physical page id in the paged pool
    depth: int                   # page index within the prompt
    last_used: int = 0


@dataclass
class SnapEntry:
    """Recurrent state at ``n_tokens`` (page-aligned), plus the KV pages
    of the shared-attention ring below it (empty for pure-ssm)."""
    n_tokens: int
    spage: int                   # physical state-page id
    kv_pages: List[int] = field(default_factory=list)
    last_used: int = 0


class PrefixCache:
    def __init__(self, page: int):
        assert page >= 1
        self.page = page
        self.pages: Dict[bytes, PageEntry] = {}
        self.snaps: Dict[bytes, SnapEntry] = {}
        self._clock = 0

    # -- keys --------------------------------------------------------------
    def _key(self, prompt: np.ndarray, n: int) -> bytes:
        return np.ascontiguousarray(prompt[:n], np.int32).tobytes()

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- KV page chain (attention families) --------------------------------
    def match_pages(self, prompt: np.ndarray, limit: int) -> List[int]:
        """Longest chain of cached full pages covering a prefix of
        ``prompt``; never spans past ``limit`` tokens (callers pass
        ``len(prompt) - 1`` so at least one token is recomputed).
        Returns the physical page ids, parent-to-child."""
        out: List[int] = []
        n_full = min(limit, len(prompt)) // self.page
        for i in range(n_full):
            e = self.pages.get(self._key(prompt, (i + 1) * self.page))
            if e is None:
                break
            e.last_used = self._tick()
            out.append(e.page)
        return out

    def insert_pages(self, prompt: np.ndarray, n_tokens: int,
                     get_page: Callable[[int], int]) -> List[int]:
        """Publish the full pages of ``prompt[:n_tokens]``.  Existing
        entries (same key) are kept — the first publisher wins, later
        identical prompts just reuse it.  Returns the page ids of the
        entries NEWLY inserted (the caller must retain a ref on each)."""
        new: List[int] = []
        for i in range(n_tokens // self.page):
            key = self._key(prompt, (i + 1) * self.page)
            if key in self.pages:
                continue
            pg = int(get_page(i))
            self.pages[key] = PageEntry(pg, i, self._tick())
            new.append(pg)
        return new

    def evict_lru_page(self, evictable=None) -> Optional[int]:
        """Drop the least-recently-used DEEPEST page entry (children
        before parents, so match chains never dangle mid-walk for long)
        among those whose page id satisfies ``evictable`` (the pool
        passes "dropping the trie ref actually frees the page" — an
        entry still shared into live slots is kept: evicting it would
        reclaim nothing and just forfeit future hits).  Returns the
        physical page id (caller drops the trie's ref)."""
        keys = [k for k in self.pages
                if evictable is None or evictable(self.pages[k].page)]
        if not keys:
            return None
        key = min(keys, key=lambda k: (-self.pages[k].depth,
                                       self.pages[k].last_used))
        return self.pages.pop(key).page

    # -- state snapshots (ssm / hybrid families) ---------------------------
    def match_state(self, prompt: np.ndarray, limit: int
                    ) -> Optional[SnapEntry]:
        """Longest snapshot whose key prefix-matches ``prompt`` with
        n_tokens <= limit."""
        best: Optional[SnapEntry] = None
        for n in sorted({e.n_tokens for e in self.snaps.values()},
                        reverse=True):
            if n > limit or n > len(prompt):
                continue
            e = self.snaps.get(self._key(prompt, n))
            if e is not None:
                e.last_used = self._tick()
                best = e
                break
        return best

    def has_state(self, prompt: np.ndarray, n_tokens: int) -> bool:
        return self._key(prompt, n_tokens) in self.snaps

    def insert_state(self, prompt: np.ndarray, n_tokens: int, spage: int,
                     kv_pages: List[int]) -> SnapEntry:
        key = self._key(prompt, n_tokens)
        assert key not in self.snaps, "snapshot key already published"
        e = SnapEntry(n_tokens, spage, list(kv_pages), self._tick())
        self.snaps[key] = e
        return e

    def evict_lru_snap(self, evictable=None) -> Optional[SnapEntry]:
        """Drop the LRU snapshot among those satisfying ``evictable``
        (the pool excludes snapshots pinned mid-restore and, when
        hunting kv pages, snapshots whose pages would not free); caller
        frees its state page and drops the refs on its kv_pages."""
        keys = [k for k in self.snaps
                if evictable is None or evictable(self.snaps[k])]
        if not keys:
            return None
        key = min(keys, key=lambda k: self.snaps[k].last_used)
        return self.snaps.pop(key)

    # -- introspection ------------------------------------------------------
    @property
    def n_entries(self) -> Tuple[int, int]:
        return len(self.pages), len(self.snaps)

    def page_refs(self) -> Dict[int, int]:
        """KV-pool refcounts the trie is responsible for, per page id:
        one per page entry plus one per snapshot that lists the page in
        its shared-attention ring.  Feed into
        ``BlockAllocator.check(external_refs=...)`` to audit that every
        non-table ref is accounted for (no leak, no over-release)."""
        refs: Dict[int, int] = {}
        for e in self.pages.values():
            refs[e.page] = refs.get(e.page, 0) + 1
        for s in self.snaps.values():
            for pg in s.kv_pages:
                refs[pg] = refs.get(pg, 0) + 1
        return refs

    def state_refs(self) -> Dict[int, int]:
        """State-pool refcounts the trie holds (one per snapshot)."""
        refs: Dict[int, int] = {}
        for s in self.snaps.values():
            refs[s.spage] = refs.get(s.spage, 0) + 1
        return refs

    def stats(self) -> Dict[str, int]:
        """Trie introspection for the obs registry: entry counts, how
        deep the cached chains go, and the token span they cover."""
        max_depth = max((e.depth + 1 for e in self.pages.values()),
                        default=0)
        return {
            "trie_pages": len(self.pages),
            "trie_snapshots": len(self.snaps),
            "max_chain_pages": max_depth,
            "tokens_covered": len(self.pages) * self.page,
            "snap_tokens_covered": sum(e.n_tokens
                                       for e in self.snaps.values()),
        }

"""Continuous-batching scheduler: the policy half of the engine.

Requests with heterogeneous prompt/generation lengths share a fixed pool
of ``n_slots`` cache slots.  Prompts are consumed in fixed-size chunks;
a dispatch is MIXED — every prefilling slot contributes its next chunk
while every decoding slot contributes its one pending token in the same
(B, C) batch — so ongoing generations never stall behind a long prompt
(chunked prefill interleaved with decode at token granularity).  When
all remaining work is decode, dispatches shrink to (B, 1).  Finished
sequences are evicted immediately and their slot is recycled for the
next waiting request mid-flight.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Tuple

import numpy as np

FREE, PREFILL, DECODE = "free", "prefill", "decode"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int = 16


@dataclass
class _Slot:
    state: str = FREE
    req: Optional[Request] = None
    offset: int = 0                     # prompt tokens already prefilled
    n_generated: int = 0                # tokens emitted so far

    # NOTE: the scheduler never sees token VALUES — admission, chunking
    # and eviction are all count-based (greedy sampling to a fixed
    # max_new_tokens), so the engine can keep the generated-token stream
    # on device and fetch it once at the end instead of syncing the
    # accelerator pipeline on every dispatch.


class Scheduler:
    def __init__(self, n_slots: int, chunk: int):
        assert n_slots >= 1 and chunk >= 1
        self.n_slots = n_slots
        self.chunk = chunk
        self.slots = [_Slot() for _ in range(n_slots)]
        self.waiting: Deque[Request] = deque()
        # prefix-cache accounting (admission-time hits shrink a
        # request's remaining prefill; see ``admit``)
        self.chunks_skipped = 0
        self.tokens_skipped = 0
        # per-kind dispatch accounting (obs registry export; the engine
        # resets these alongside its own counters)
        self.dispatch_kinds = {"mixed": 0, "decode": 0}

    # -- admission ---------------------------------------------------------
    def add(self, req: Request) -> None:
        self.waiting.append(req)

    def admit(self, match=None) -> List[int]:
        """Move waiting requests into free slots; returns the admitted
        slot indices (their cache rows must be reset before dispatch).

        ``match(slot, req) -> n_cached`` is the prefix-cache hook (the
        paged engine binds it to ``PagedPool.admit``): the request's
        first ``n_cached`` prompt tokens are already in the cache, so
        prefill starts at that offset — whole chunks whose pages fully
        hit are never dispatched."""
        newly = []
        for s, slot in enumerate(self.slots):
            if not self.waiting:
                break
            if slot.state is FREE:
                req = self.waiting.popleft()
                off = 0
                if match is not None:
                    off = int(match(s, req))
                    assert 0 <= off < len(req.prompt)
                self.slots[s] = _Slot(state=PREFILL, req=req, offset=off)
                if off:
                    cold = -(-len(req.prompt) // self.chunk)
                    warm = -(-(len(req.prompt) - off) // self.chunk)
                    self.chunks_skipped += cold - warm
                    self.tokens_skipped += off
                newly.append(s)
        return newly

    # -- dispatch construction --------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s.state is not FREE
                                         for s in self.slots)

    def next_dispatch(self) -> Optional[str]:
        kind = None
        if any(s.state is PREFILL for s in self.slots):
            kind = "mixed"
        elif any(s.state is DECODE for s in self.slots):
            kind = "decode"
        if kind is not None:
            self.dispatch_kinds[kind] += 1
        return kind

    def build_batch(self, kind: str
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               List[Tuple[int, int]],
                               List[Tuple[int, int]],
                               List[Tuple[int, int, int]]]:
        """-> (tokens (B, C), n_valid (B,), use_pending (B,), emits,
        finishing, prefilling).

        ``tokens`` carries each prefilling slot's next prompt chunk;
        slots flagged in ``use_pending`` feed their device-resident last
        sampled token instead (the engine splices it in without a host
        round-trip).  ``emits`` lists (slot, rid) pairs that will emit a
        generated token from THIS dispatch (decoding slots, and prefill
        slots whose prompt completes here).  ``finishing`` lists (slot,
        offset) pairs whose PROMPT completes this dispatch — the paged
        engine snapshots recurrent state at ``offset`` before
        dispatching (prefix cache for ssm/hybrid families).
        ``prefilling`` lists every (slot, offset, take) consuming prompt
        tokens this dispatch — the paged engine's pre-wrap publish hook
        (windowed prompts longer than their ring publish their prefix
        pages BEFORE the ring wraps over them)."""
        C = self.chunk if kind == "mixed" else 1
        tokens = np.zeros((self.n_slots, C), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        use_pending = np.zeros((self.n_slots,), bool)
        emits: List[Tuple[int, int]] = []
        finishing: List[Tuple[int, int]] = []
        prefilling: List[Tuple[int, int, int]] = []
        for s, slot in enumerate(self.slots):
            if slot.state is PREFILL:
                take = min(C, len(slot.req.prompt) - slot.offset)
                tokens[s, :take] = slot.req.prompt[slot.offset:
                                                   slot.offset + take]
                n_valid[s] = take
                prefilling.append((s, slot.offset, take))
                if slot.offset + take >= len(slot.req.prompt):
                    emits.append((s, slot.req.rid))
                    finishing.append((s, slot.offset))
            elif slot.state is DECODE:
                use_pending[s] = True
                n_valid[s] = 1
                emits.append((s, slot.req.rid))
        return tokens, n_valid, use_pending, emits, finishing, prefilling

    # -- result ingestion --------------------------------------------------
    def feed(self, n_valid: np.ndarray
             ) -> Tuple[List[Tuple[int, Request]],
                        List[Tuple[int, Request]]]:
        """Advance slot states after a dispatch (count-based: the token
        values stay on device — see _Slot note).  Returns
        ``(finished, entering_decode)`` as (slot, request) pairs:
        finished requests' slots are freed for recycling; slots entering
        decode just completed their prompt (the paged engine publishes
        their full prompt pages into the prefix trie here — AFTER the
        dispatch that wrote them)."""
        finished = []
        entering = []
        for s, slot in enumerate(self.slots):
            nv = int(n_valid[s])
            if nv == 0:
                continue
            if slot.state is PREFILL:
                slot.offset += nv
                if slot.offset >= len(slot.req.prompt):
                    slot.state = DECODE
                    slot.n_generated = 1
                    entering.append((s, slot.req))
            elif slot.state is DECODE:
                slot.n_generated += 1
            if slot.state is DECODE and \
                    slot.n_generated >= slot.req.max_new_tokens:
                finished.append((s, slot.req))
                self.slots[s] = _Slot()
        return finished, entering

"""Continuous-batching scheduler: the policy half of the engine.

Requests with heterogeneous prompt/generation lengths share a fixed pool
of ``n_slots`` cache slots.  Prompts are consumed in fixed-size chunks;
a dispatch is MIXED — every prefilling slot contributes its next chunk
while every decoding slot contributes its one pending token in the same
(B, C) batch — so ongoing generations never stall behind a long prompt
(chunked prefill interleaved with decode at token granularity).  When
all remaining work is decode, dispatches shrink to (B, 1).  Finished
sequences are evicted immediately and their slot is recycled for the
next waiting request mid-flight.

Admission order, the decode-vs-prefill token budget, and preemption
victims are delegated to a pluggable ``policy.Policy`` (FCFS baseline /
priority classes / shortest-remaining-prefill).  ``preempt(slot)``
requeues a RUNNING request at its exact progress (offset + generated
count); the engine pairs it with ``PagedPool.spill``/``restore`` so a
preempted request never loses a token.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.policy import FCFSPolicy, Policy

FREE, PREFILL, DECODE = "free", "prefill", "decode"


class RequestRejected(ValueError):
    """A request the engine can never serve (oversize prompt, empty
    prompt, nonpositive max_new_tokens) — raised by ``Engine.submit``
    at validation time, BEFORE the request enters the queue, so
    arrival-driven load survives bad requests (the bare ``assert`` it
    replaces vanished under ``python -O`` and killed the engine)."""

    def __init__(self, reason: str, msg: str):
        super().__init__(msg)
        self.reason = reason


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int = 16
    priority: int = 0                   # higher admits first (policy)


@dataclass
class PendingEntry:
    """One waiting-queue item: a fresh request, or a preempted one
    carrying its exact resume point (prompt offset + generated count —
    the engine restores its spilled pages and pending token)."""
    req: Request
    offset: int = 0
    n_generated: int = 0
    resume: bool = False
    seq: int = 0                        # arrival order (stable ties)


@dataclass
class _Slot:
    state: str = FREE
    req: Optional[Request] = None
    offset: int = 0                     # prompt tokens already prefilled
    n_generated: int = 0                # tokens emitted so far
    seq: int = 0                        # arrival seq of the occupant

    # NOTE: the scheduler never sees token VALUES — admission, chunking
    # and eviction are all count-based (greedy sampling to a fixed
    # max_new_tokens), so the engine can keep the generated-token stream
    # on device and fetch it once at the end instead of syncing the
    # accelerator pipeline on every dispatch.


class Scheduler:
    def __init__(self, n_slots: int, chunk: int,
                 policy: Optional[Policy] = None):
        assert n_slots >= 1 and chunk >= 1
        self.n_slots = n_slots
        self.chunk = chunk
        self.policy = policy or FCFSPolicy()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.waiting: List[PendingEntry] = []
        self._seq = 0
        # set by ``admit`` when the placement callback deferred (pool
        # exhausted) — the engine may spill a victim and retry
        self.deferred = False
        # prefix-cache accounting (admission-time hits shrink a
        # request's remaining prefill; see ``admit``)
        self.chunks_skipped = 0
        self.tokens_skipped = 0
        # per-kind dispatch accounting (obs registry export; the engine
        # resets these alongside its own counters).  draft/verify/replay
        # are the speculative-decode round's dispatches (engine.spec).
        self.dispatch_kinds = {"mixed": 0, "decode": 0,
                               "draft": 0, "verify": 0, "replay": 0}

    # -- admission ---------------------------------------------------------
    def add(self, req: Request) -> None:
        self.waiting.append(PendingEntry(req, seq=self._seq))
        self._seq += 1

    def admit(self, place=None) -> List[int]:
        """Move waiting requests (policy order) into free slots; returns
        the admitted slot indices (their cache rows must be reset before
        dispatch).

        ``place(slot, entry) -> offset`` is the engine's placement hook:
        for fresh requests the paged engine binds it to
        ``PagedPool.admit`` (prefix-cache hits shrink the remaining
        prefill — whole chunks whose pages fully hit are never
        dispatched); for preempted resumes it restores the spilled
        pages and returns the entry's own offset.  Returning ``None``
        defers admission (pool exhausted): the entry stays at the head
        of the queue, ``self.deferred`` is set, and admission stops."""
        self.policy.order(self.waiting)
        newly: List[int] = []
        self.deferred = False
        for s, slot in enumerate(self.slots):
            if not self.waiting:
                break
            if slot.state is not FREE:
                continue
            entry = self.waiting[0]
            off = entry.offset if place is None else place(s, entry)
            if off is None:
                self.deferred = True
                break
            off = int(off)
            self.waiting.pop(0)
            P = len(entry.req.prompt)
            if entry.resume:
                assert 0 <= off <= P
            else:
                assert 0 <= off < P
            state = DECODE if off >= P else PREFILL
            self.slots[s] = _Slot(state=state, req=entry.req,
                                  offset=min(off, P),
                                  n_generated=entry.n_generated,
                                  seq=entry.seq)
            if off and not entry.resume:
                cold = -(-P // self.chunk)
                warm = -(-(P - off) // self.chunk)
                self.chunks_skipped += cold - warm
                self.tokens_skipped += off
            newly.append(s)
        return newly

    def preempt(self, slot: int) -> Request:
        """Evict a RUNNING request from ``slot`` and requeue it at its
        exact progress (front of the queue; the policy re-sorts at the
        next admit).  The engine spills the slot's pages first — the
        resume entry carries only counts, never token values."""
        sl = self.slots[slot]
        assert sl.state is not FREE and sl.req is not None
        self.waiting.insert(0, PendingEntry(
            sl.req, offset=sl.offset, n_generated=sl.n_generated,
            resume=True, seq=sl.seq))
        self.slots[slot] = _Slot()
        return sl.req

    # -- dispatch construction --------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s.state is not FREE
                                         for s in self.slots)

    def peek_kind(self) -> Optional[str]:
        if any(s.state is PREFILL for s in self.slots):
            return "mixed"
        if any(s.state is DECODE for s in self.slots):
            return "decode"
        return None

    def next_dispatch(self) -> Optional[str]:
        kind = self.peek_kind()
        if kind is not None:
            self.dispatch_kinds[kind] += 1
        return kind

    def build_batch(self, kind: str
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               List[Tuple[int, int]],
                               List[Tuple[int, int]],
                               List[Tuple[int, int, int]]]:
        """-> (tokens (B, C), n_valid (B,), use_pending (B,), emits,
        finishing, prefilling).

        ``tokens`` carries each prefilling slot's next prompt chunk;
        slots flagged in ``use_pending`` feed their device-resident last
        sampled token instead (the engine splices it in without a host
        round-trip).  ``emits`` lists (slot, rid) pairs that will emit a
        generated token from THIS dispatch (decoding slots, and prefill
        slots whose prompt completes here).  ``finishing`` lists (slot,
        offset) pairs whose PROMPT completes this dispatch — the paged
        engine snapshots recurrent state at ``offset`` before
        dispatching (prefix cache for ssm/hybrid families).
        ``prefilling`` lists every (slot, offset, take) consuming prompt
        tokens this dispatch — the paged engine's pre-wrap publish hook
        (windowed prompts longer than their ring publish their prefix
        pages BEFORE the ring wraps over them).

        The policy's ``prefill_budget`` > 0 caps the TOTAL prompt
        tokens a mixed dispatch consumes (decode-vs-prefill knob):
        prefill slots past the budget contribute nothing this dispatch
        (n_valid 0 — ``feed`` skips them), so decode riders keep their
        cadence while prompts stream through in sub-chunk slices.  The
        first prefilling slot always gets at least one token, so
        prefill can never starve outright."""
        C = self.chunk if kind == "mixed" else 1
        budget = self.policy.prefill_budget
        left = budget if (kind == "mixed" and budget > 0) else None
        tokens = np.zeros((self.n_slots, C), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        use_pending = np.zeros((self.n_slots,), bool)
        emits: List[Tuple[int, int]] = []
        finishing: List[Tuple[int, int]] = []
        prefilling: List[Tuple[int, int, int]] = []
        for s, slot in enumerate(self.slots):
            if slot.state is PREFILL:
                take = min(C, len(slot.req.prompt) - slot.offset)
                if left is not None:
                    take = min(take, left if prefilling else max(left, 1))
                    if take <= 0:
                        continue
                    left -= take
                tokens[s, :take] = slot.req.prompt[slot.offset:
                                                   slot.offset + take]
                n_valid[s] = take
                prefilling.append((s, slot.offset, take))
                if slot.offset + take >= len(slot.req.prompt):
                    emits.append((s, slot.req.rid))
                    finishing.append((s, slot.offset))
            elif slot.state is DECODE:
                use_pending[s] = True
                n_valid[s] = 1
                emits.append((s, slot.req.rid))
        return tokens, n_valid, use_pending, emits, finishing, prefilling

    def decode_remaining(self, slot: int) -> int:
        """Tokens this slot may still emit (max_new_tokens minus those
        already generated); 0 for non-DECODE slots.  The speculative
        decoder caps its per-slot draft length with this so a round can
        never overshoot a request's budget."""
        sl = self.slots[slot]
        if sl.state is not DECODE or sl.req is None:
            return 0
        return max(0, sl.req.max_new_tokens - sl.n_generated)

    # -- result ingestion --------------------------------------------------
    def feed(self, n_valid: np.ndarray
             ) -> Tuple[List[Tuple[int, Request]],
                        List[Tuple[int, Request]]]:
        """Advance slot states after a dispatch (count-based: the token
        values stay on device — see _Slot note).  Returns
        ``(finished, entering_decode)`` as (slot, request) pairs:
        finished requests' slots are freed for recycling; slots entering
        decode just completed their prompt (the paged engine publishes
        their full prompt pages into the prefix trie here — AFTER the
        dispatch that wrote them)."""
        finished = []
        entering = []
        for s, slot in enumerate(self.slots):
            nv = int(n_valid[s])
            if nv == 0:
                continue
            if slot.state is PREFILL:
                slot.offset += nv
                if slot.offset >= len(slot.req.prompt):
                    slot.state = DECODE
                    slot.n_generated = 1
                    entering.append((s, slot.req))
            elif slot.state is DECODE:
                slot.n_generated += 1
            if slot.state is DECODE and \
                    slot.n_generated >= slot.req.max_new_tokens:
                finished.append((s, slot.req))
                self.slots[s] = _Slot()
        return finished, entering

    def feed_counts(self, counts) -> List[Tuple[int, Request]]:
        """Advance DECODE slots by a per-slot emitted-token COUNT (the
        speculative verify emits 1..k+1 tokens per round, vs ``feed``'s
        one-per-dispatch).  Still count-based — token values never reach
        the scheduler.  Returns finished (slot, request) pairs; their
        slots are freed for recycling."""
        finished = []
        for s, slot in enumerate(self.slots):
            n = int(counts[s])
            if n == 0 or slot.state is not DECODE:
                continue
            slot.n_generated += n
            assert slot.n_generated <= slot.req.max_new_tokens, \
                (s, slot.n_generated, slot.req.max_new_tokens)
            if slot.n_generated >= slot.req.max_new_tokens:
                finished.append((s, slot.req))
                self.slots[s] = _Slot()
        return finished

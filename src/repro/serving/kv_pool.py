"""Slot-pool cache: the serving engine's per-slot decode-cache layout.

``models.*.cache_init`` builds UNIFORM-batch caches: one scalar clock
(``cache["pos"]``) and, for attention families, one shared (L,) ring of
kv position tags — fine when every sequence in the batch advances in
lockstep, wrong for continuous batching where each slot sits at its own
position.  ``init`` upgrades that layout in place:

  * top-level ``pos``: scalar -> (n_slots,) per-slot positions;
  * attention ring tags: (stack, L) -> (stack, n_slots, L);
  * MLA latent caches gain per-slot (stack, n_slots, max_len) tags
    (the uniform layout masks by the scalar clock instead);
  * sliding-window rings are allocated with a ``serve_chunk`` margin
    above the window so a prefill chunk never overwrites kv rows still
    inside another in-chunk token's window.

Every stacked leaf keeps the batch dim at axis 1 (axis 0 = layer stack)
and the top-level ``pos`` at axis 0 — ``reset_slots`` relies on exactly
this invariant to recycle evicted slots in one masked select.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model


def ring_cfg(cfg: ModelConfig, chunk: int) -> ModelConfig:
    """Config used ONLY for cache allocation: window + chunk ring slack."""
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=cfg.sliding_window + chunk)
    if cfg.family == "hybrid" and cfg.shared_attn_window:
        cfg = cfg.replace(shared_attn_window=cfg.shared_attn_window + chunk)
    return cfg


def _upgrade(node, n_slots: int):
    if not isinstance(node, dict):
        return node
    if "k" in node and "pos" in node:           # attention ring cache
        out = dict(node)
        lead, L = node["pos"].shape[:-1], node["pos"].shape[-1]
        out["pos"] = jnp.full(lead + (n_slots, L), -1, jnp.int32)
        return out
    if "c_kv" in node:                          # MLA latent cache
        out = dict(node)
        out["pos"] = jnp.full(node["c_kv"].shape[:-1], -1, jnp.int32)
        return out
    return {k: _upgrade(v, n_slots) for k, v in node.items()}


def init(cfg: ModelConfig, n_slots: int, max_len: int,
         chunk: int = 0, dtype=None) -> Dict:
    """Allocate the slot-pool cache for ``n_slots`` sequences of up to
    ``max_len`` positions, consumable by the ``prefill_chunk`` steps."""
    chunk = chunk or cfg.serve_chunk
    api = get_model(cfg)
    assert api.cache_init is not None, f"{cfg.name} has no decode cache"
    cache = api.cache_init(ring_cfg(cfg, chunk), n_slots, max_len,
                           dtype or cfg.jdtype)
    out = {k: _upgrade(v, n_slots) for k, v in cache.items()}
    out["pos"] = jnp.zeros((n_slots,), jnp.int32)
    return out


def reset_slots(cache: Dict, slots) -> Dict:
    """Recycle cache slots: zero state / -1 kv tags / 0 position for every
    slot where ``slots`` (n_slots,) bool is True, leaving the rest
    untouched.  jit-safe (one select per leaf)."""
    slots = jnp.asarray(slots)

    def leaf(key, a):
        fill = -1 if key == "pos" else 0
        m = slots.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, jnp.full((), fill, a.dtype), a)

    def walk(node):
        out = {}
        for k, v in node.items():
            out[k] = walk(v) if isinstance(v, dict) else leaf(k, v)
        return out

    out = {k: (walk(v) if isinstance(v, dict) else leaf(k, v))
           for k, v in cache.items() if k != "pos"}
    out["pos"] = jnp.where(slots, 0, cache["pos"])
    return out

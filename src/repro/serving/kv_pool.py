"""Serving cache layouts: the legacy contiguous slot pool and the paged
pool (fixed-size pages + block tables + refcounts + copy-on-write).

**Slot pool** (``init`` / ``reset_slots``): every slot owns a contiguous
cache row — the layout PR 2 shipped, kept as the differential baseline
(``Engine(layout="slotted")`` and the tests' paged-vs-slotted matrix).

**Paged pool** (``PagedPool``): physical storage is a pool of fixed-size
pages and every token-indexed leaf is read/written through a per-slot
*block table* indirection:

  * attention kv rings and MLA latent caches become
    ``(stack, n_pages, page, ...)`` pools; a slot's logical ring row
    ``r`` lives at ``(block_table[slot, r // page], r % page)``.  Page 0
    is a reserved null page (position tags -1, always masked) so
    unallocated block-table entries read as empty.
  * recurrent state (rwkv shift/wkv, mamba conv/ssd) becomes a
    ``(L, n_state_pages, ...)`` pool indexed by a one-entry-per-slot
    ``state_table`` — the same indirection with block count 1, which is
    what lets state snapshots live in the same pool as live slots.

The allocator half (``BlockAllocator``) is pure host-side numpy — free
list, refcounts, block tables — so its invariants (no page leaked, no
page double-owned, copy-on-write never mutates a shared page) are
property-testable without a device.  ``PagedPool`` drives it, packs the
resulting device edits (page-tag resets, page copies, table uploads)
into ONE int32 vector per dirty dispatch (``drain``) that the engine
applies INSIDE its compiled step (``apply_cache_ops`` — clean
dispatches skip it entirely), and implements prefix caching on top:
published full pages / state snapshots are refcounted by a
``prefix_cache.PrefixCache`` and shared into new slots' tables at
admission; the first divergent write to a shared page triggers
copy-on-write (the scheduler is count-based, so the engine knows every
page a dispatch will write BEFORE dispatching it).

Every stacked leaf keeps the page/batch dim at axis 1 (axis 0 = layer
stack) and the top-level ``pos`` at axis 0 — both layouts rely on
exactly this invariant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serving.prefix_cache import PrefixCache


class PoolExhausted(RuntimeError):
    """A paged pool ran out of pages (after prefix-cache eviction).
    Recoverable under preemption: the engine spills a victim slot to
    the host pool and retries — admission paths raise it with the pool
    FULLY rolled back (no leaked refs, no half-attached slot)."""


def ring_cfg(cfg: ModelConfig, chunk: int) -> ModelConfig:
    """Config used ONLY for cache allocation: window + chunk ring slack."""
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=cfg.sliding_window + chunk)
    if cfg.family == "hybrid" and cfg.shared_attn_window:
        cfg = cfg.replace(shared_attn_window=cfg.shared_attn_window + chunk)
    return cfg


# ==========================================================================
# slot-pool layout (contiguous per-slot rows) — the PR 2 baseline
# ==========================================================================

def _upgrade(node, n_slots: int):
    if not isinstance(node, dict):
        return node
    if "k" in node and "pos" in node:           # attention ring cache
        out = dict(node)
        lead, L = node["pos"].shape[:-1], node["pos"].shape[-1]
        out["pos"] = jnp.full(lead + (n_slots, L), -1, jnp.int32)
        return out
    if "c_kv" in node:                          # MLA latent cache
        out = dict(node)
        out["pos"] = jnp.full(node["c_kv"].shape[:-1], -1, jnp.int32)
        return out
    return {k: _upgrade(v, n_slots) for k, v in node.items()}


def init(cfg: ModelConfig, n_slots: int, max_len: int,
         chunk: int = 0, dtype=None) -> Dict:
    """Allocate the slot-pool cache for ``n_slots`` sequences of up to
    ``max_len`` positions, consumable by the ``prefill_chunk`` steps."""
    chunk = chunk or cfg.serve_chunk
    api = get_model(cfg)
    assert api.cache_init is not None, f"{cfg.name} has no decode cache"
    cache = api.cache_init(ring_cfg(cfg, chunk), n_slots, max_len,
                           dtype or cfg.jdtype)
    out = {k: _upgrade(v, n_slots) for k, v in cache.items()}
    out["pos"] = jnp.zeros((n_slots,), jnp.int32)
    return out


def reset_slots(cache: Dict, slots) -> Dict:
    """Recycle cache slots: zero state / -1 kv tags / 0 position for every
    slot where ``slots`` (n_slots,) bool is True, leaving the rest
    untouched.  jit-safe (one select per leaf)."""
    slots = jnp.asarray(slots)

    def leaf(key, a):
        fill = -1 if key == "pos" else 0
        m = slots.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, jnp.full((), fill, a.dtype), a)

    def walk(node):
        out = {}
        for k, v in node.items():
            out[k] = walk(v) if isinstance(v, dict) else leaf(k, v)
        return out

    out = {k: (walk(v) if isinstance(v, dict) else leaf(k, v))
           for k, v in cache.items() if k != "pos"}
    out["pos"] = jnp.where(slots, 0, cache["pos"])
    return out


# ==========================================================================
# paged layout: tree walkers
# ==========================================================================

_TABLE_KEYS = ("pos", "block_table", "state_table")


def _is_kv_node(node) -> bool:
    return isinstance(node, dict) and (
        ("k" in node and "pos" in node) or "c_kv" in node)


def map_kv_nodes(tree, fn):
    """Apply ``fn`` to every token-indexed cache node (attention ring /
    MLA latent dicts), leaving everything else untouched."""
    if _is_kv_node(tree):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: map_kv_nodes(v, fn) for k, v in tree.items()}
    return tree


def map_state_leaves(tree, fn):
    """Apply ``fn`` to every recurrent-state leaf (any array leaf NOT
    inside a token-indexed node)."""
    if _is_kv_node(tree):
        return tree
    if isinstance(tree, dict):
        return {k: map_state_leaves(v, fn) for k, v in tree.items()}
    return fn(tree)


def _pool_dims(cache) -> Tuple[int, int]:
    """-> (n_pages, n_state_pages) read off the paged cache's leaves
    (0 when the model has no leaves of that kind)."""
    n_pages = n_spages = 0

    def kv(node):
        nonlocal n_pages
        tag = node["pos"]
        if isinstance(tag, tuple):               # per-layer pool leaves
            tag = tag[0]
        n_pages = tag.shape[-2]
        return node

    def stl(a):
        nonlocal n_spages
        n_spages = a.shape[1]
        return a

    for k, v in cache.items():
        if k in _TABLE_KEYS:
            continue
        map_kv_nodes(v, kv)
        map_state_leaves(v, stl)
    return n_pages, n_spages


def apply_cache_ops(cache: Dict, ops, kv_copy_max: int,
                    st_copy_max: int) -> Dict:
    """Apply one batch of host-planned pool edits to the device cache —
    page-tag resets for freshly allocated pages, page copies (COW /
    state snapshot+restore), and table/pos uploads.  Pure and jit-safe;
    the engine fuses it into the compiled dispatch step, so a dirty
    dispatch costs ONE extra host->device transfer (``ops`` is a single
    packed int32 vector, laid out by ``PagedPool._build_ops``) and a
    clean dispatch skips the whole thing (``ops=None`` selects a
    separately-compiled step without it)."""
    has_kv = "block_table" in cache
    has_state = "state_table" in cache
    n_slots = cache["pos"].shape[0]
    n_pages, n_spages = _pool_dims(cache)

    def take(n):
        nonlocal i
        sl = ops[i:i + n]            # static offsets: plain slices
        i += n
        return sl

    i = 0
    out = {"pos": take(n_slots)}
    if has_kv:
        n_blocks = cache["block_table"].shape[1]
        out["block_table"] = take(n_slots * n_blocks).reshape(n_slots,
                                                              n_blocks)
    if has_state:
        out["state_table"] = take(n_slots)
    kv_reset = take(n_pages).astype(bool) if has_kv else None
    kv_src = take(kv_copy_max) if has_kv else None
    kv_dst = take(kv_copy_max) if has_kv else None
    s_reset = take(n_spages).astype(bool) if has_state else None
    s_src = take(st_copy_max) if has_state else None
    s_dst = take(st_copy_max) if has_state else None

    def kv(node):
        # leaves are either layer-stacked ((L, n_pages, page, ...)) or —
        # the serving pool's in-place layout — a per-layer TUPLE of
        # (n_pages, page, ...) arrays (tuple leaves keep every scatter
        # aliasable to its own donated buffer; a stacked leaf threaded
        # through the layer scan gets copied wholesale each iteration)
        node = dict(node)

        def reset(tag):
            m = kv_reset.reshape((-1,) + (1,) * (tag.ndim - 1))
            return jnp.where(m, jnp.full((), -1, tag.dtype), tag)

        def copy(a):
            # pads carry an out-of-bounds index and are dropped (the
            # clamped OOB gather on the src side feeds a dropped write)
            return a.at[kv_dst].set(a[kv_src], mode="drop")

        per_layer = isinstance(node["pos"], tuple)
        if per_layer:
            node["pos"] = tuple(reset(t) for t in node["pos"])
        else:
            tag = node["pos"]
            m = kv_reset.reshape((1, -1) + (1,) * (tag.ndim - 2))
            node["pos"] = jnp.where(m, jnp.full((), -1, tag.dtype), tag)
        if kv_copy_max == 0:         # copy-free round ({0, max} buckets)
            return node
        for key, a in node.items():
            if per_layer:
                node[key] = tuple(copy(x) for x in a)
            else:
                node[key] = a.at[:, kv_dst].set(a[:, kv_src], mode="drop")
        return node

    def stl(a):
        m = s_reset.reshape((1, -1) + (1,) * (a.ndim - 2))
        a = jnp.where(m, jnp.zeros((), a.dtype), a)
        # sequential: a restore may read a snapshot taken earlier in
        # the same batch (pads are OOB and dropped)
        for j in range(st_copy_max):
            a = a.at[:, s_dst[j]].set(a[:, s_src[j]], mode="drop")
        return a

    for k, v in cache.items():
        if k in _TABLE_KEYS:
            continue
        if has_kv:
            v = map_kv_nodes(v, kv)
        if has_state:
            v = map_state_leaves(v, stl)
        out[k] = v
    return out


def ops_counts(cache: Dict, ops, kv_copy_max: int,
               st_copy_max: int) -> Dict:
    """Count the page edits an ops vector will perform — same static
    ``take`` walk as ``apply_cache_ops``, reduced to four int32 scalars
    for the obs device-metrics block.  Jit-safe; copy pads carry an
    out-of-bounds destination (== local page count), so valid copies
    are the in-bounds destinations.  Under ``shard_map`` this sees the
    shard's own ops row against the shard-local page count, making the
    counts shard-local (the metrics block sums rows at read)."""
    has_kv = "block_table" in cache
    has_state = "state_table" in cache
    n_slots = cache["pos"].shape[0]
    n_pages, n_spages = _pool_dims(cache)

    def take(n):
        nonlocal i
        sl = ops[i:i + n]
        i += n
        return sl

    i = n_slots                                  # skip pos upload
    if has_kv:
        i += n_slots * cache["block_table"].shape[1]
    if has_state:
        i += n_slots
    zero = jnp.zeros((), jnp.int32)
    out = {"kv_page_resets": zero, "kv_page_copies": zero,
           "state_page_resets": zero, "state_page_copies": zero}
    if has_kv:
        kv_reset = take(n_pages)
        take(kv_copy_max)                        # kv_src
        kv_dst = take(kv_copy_max)
        out["kv_page_resets"] = kv_reset.astype(bool).sum(
            dtype=jnp.int32)
        if kv_copy_max:
            out["kv_page_copies"] = (kv_dst < n_pages).sum(
                dtype=jnp.int32)
    if has_state:
        s_reset = take(n_spages)
        take(st_copy_max)                        # s_src
        s_dst = take(st_copy_max)
        out["state_page_resets"] = s_reset.astype(bool).sum(
            dtype=jnp.int32)
        if st_copy_max:
            out["state_page_copies"] = (s_dst < n_spages).sum(
                dtype=jnp.int32)
    return out


def _scan_structure(cache) -> Tuple[bool, bool, int]:
    """-> (has_kv, has_state, kv ring length in rows)."""
    has_kv, has_state, ring = False, False, 0

    def kv(node):
        nonlocal has_kv, ring
        has_kv = True
        rows = (node["k"].shape[-3] if "k" in node
                else node["c_kv"].shape[-2])
        ring = max(ring, rows)
        return node

    def st(leaf):
        nonlocal has_state
        has_state = True
        return leaf

    for k, v in cache.items():
        if k in _TABLE_KEYS:
            continue
        map_kv_nodes(v, kv)
        map_state_leaves(v, st)
    return has_kv, has_state, ring


# ==========================================================================
# BlockAllocator: host-side page accounting (property-tested)
# ==========================================================================

class BlockAllocator:
    """Free list + refcounts + per-slot block tables for one page pool.

    Page ids are ints in ``[1, n_pages)``; id 0 is the reserved null
    page (reads of it are masked by -1 position tags) and is never
    allocated.  A page's refcount equals the number of holders: block
    table entries pointing at it plus external retains (prefix-cache
    entries).  ``ref == 1`` with a single table entry means the slot
    owns the page exclusively and may write it in place; ``write_plan``
    enforces that, allocating fresh pages for null entries and
    copy-on-writing shared ones.

    With ``n_shards > 1`` the pool is MESH-SHARDED (ISSUE 5): page ids
    stay global but the id space is partitioned into ``n_shards``
    contiguous ranges of ``pages_per_shard`` — shard ``s`` physically
    holds ids ``[s*pps, (s+1)*pps)`` — and the allocator becomes
    ownership-aware: a page pins to the shard that holds it for its
    whole lifetime, fresh allocations round-robin the shards (most-free
    first) to balance occupancy, and copy-on-write destinations are
    allocated on the SOURCE page's shard so every device page copy is
    shard-local (the packed ops vector splits cleanly per shard, no
    cross-device traffic in ``apply_cache_ops``)."""

    def __init__(self, n_pages: int, n_slots: int, n_blocks: int,
                 n_shards: int = 1):
        assert n_pages >= 2 and n_slots >= 1 and n_blocks >= 1
        assert n_shards >= 1 and n_pages % n_shards == 0, \
            "page count must divide evenly over the mesh shards"
        self.n_pages = n_pages
        self.n_shards = n_shards
        self.pages_per_shard = n_pages // n_shards
        self.table = np.zeros((n_slots, n_blocks), np.int32)
        self.ref = np.zeros((n_pages,), np.int64)
        self.ref[0] = 1                          # null page, pinned
        # per-shard LIFO free lists (shard 0 excludes the null page);
        # n_shards == 1 degenerates to the historical single list
        pps = self.pages_per_shard
        self._free: List[List[int]] = [
            list(range((s + 1) * pps - 1, max(1, s * pps) - 1, -1))
            for s in range(n_shards)]
        self._rr = 0                             # round-robin tiebreak
        # occupancy accounting per shard (current / high-water) — the
        # shard-balance invariants and serve report read these
        self.in_use = np.zeros((n_shards,), np.int64)
        self.hiwater = np.zeros((n_shards,), np.int64)
        # cumulative alloc/free event counts (obs registry export)
        self.events = {"alloc": 0, "free": 0}

    @property
    def free(self) -> List[int]:
        """All free page ids (flattened across shards)."""
        return [p for fl in self._free for p in fl]

    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    # -- primitive ops -----------------------------------------------------
    def alloc(self, prefer: Optional[int] = None) -> Optional[int]:
        """Allocate a page.  ``prefer`` pins the allocation to one shard
        (COW destinations must live on their source's shard); without it
        shards are round-robined most-free-first to balance occupancy."""
        if prefer is not None:
            if not self._free[prefer]:
                return None
            p = self._free[prefer].pop()
        else:
            s = min(range(self.n_shards),
                    key=lambda i: (-len(self._free[i]),
                                   (i - self._rr) % self.n_shards))
            if not self._free[s]:
                return None
            self._rr = (s + 1) % self.n_shards
            p = self._free[s].pop()
        assert self.ref[p] == 0, "free list held a referenced page"
        self.ref[p] = 1
        sh = self.shard_of(p)
        self.in_use[sh] += 1
        self.hiwater[sh] = max(self.hiwater[sh], self.in_use[sh])
        self.events["alloc"] += 1
        return p

    def retain(self, page: int) -> None:
        assert page != 0 and self.ref[page] > 0, "retain of unowned page"
        self.ref[page] += 1

    def unalloc(self, page: int) -> None:
        """Return a just-allocated (sole-ref) page to the free list."""
        assert self.ref[page] == 1, "unalloc of a shared page"
        self.ref[page] = 0
        self._free[self.shard_of(page)].append(page)
        self.in_use[self.shard_of(page)] -= 1
        self.events["free"] += 1

    def drop(self, page: int) -> bool:
        """Drop one reference; returns True if the page was freed."""
        assert page != 0 and self.ref[page] > 0, "drop of unowned page"
        self.ref[page] -= 1
        if self.ref[page] == 0:
            self._free[self.shard_of(page)].append(page)
            self.in_use[self.shard_of(page)] -= 1
            self.events["free"] += 1
            return True
        return False

    # -- table ops ---------------------------------------------------------
    def share(self, slot: int, block: int, page: int) -> None:
        """Point a (null) block-table entry at an existing page."""
        assert self.table[slot, block] == 0, "share over an owned block"
        self.retain(page)
        self.table[slot, block] = page

    def write_plan(self, slot: int, blocks: Sequence[int], alloc=None,
                   on_copy=None) -> Tuple[List[int], List[Tuple[int, int]]]:
        """Make every listed block exclusively owned by ``slot`` before a
        dispatch writes it.  Returns ``(fresh, copies)``: ``fresh`` pages
        were allocated for null entries (device must reset their position
        tags), ``copies`` are (src, dst) copy-on-write pairs (dst is a
        fresh page; src keeps its remaining holders and is NEVER written
        — the COW invariant).  ``on_copy(src, dst)`` fires the moment a
        pair is created — BEFORE any later block's alloc — so the caller
        can pin src against eviction by that very alloc.

        Sharded pools allocate the COW destination on the SOURCE page's
        shard (``alloc(prefer=...)``) so the device copy is shard-local."""
        alloc = alloc or self.alloc
        fresh: List[int] = []
        copies: List[Tuple[int, int]] = []
        for b in blocks:
            cur = int(self.table[slot, b])
            if cur != 0 and self.ref[cur] == 1:
                continue                          # already exclusive
            prefer = (self.shard_of(cur)
                      if cur != 0 and self.n_shards > 1 else None)
            new = alloc(prefer=prefer)
            if new is None:
                raise PoolExhausted("paged KV pool exhausted")
            if cur == 0:
                fresh.append(new)
            else:
                copies.append((cur, new))
                if on_copy is not None:
                    on_copy(cur, new)
                self.drop(cur)                    # ref > 1: never frees
            self.table[slot, b] = new
        return fresh, copies

    def release_slot(self, slot: int) -> List[int]:
        """Drop the slot's references; returns the pages actually freed."""
        freed = []
        for b in np.nonzero(self.table[slot])[0]:
            p = int(self.table[slot, b])
            if self.drop(p):
                freed.append(p)
        self.table[slot, :] = 0
        return freed

    # -- invariants (asserted by the property tests) -----------------------
    def check(self, external_refs: Optional[Dict[int, int]] = None) -> None:
        """No page leaked, no page double-owned: every non-null page is
        either on the free list (ref 0) or referenced, with its refcount
        equal to its holder count (table occurrences + external refs)."""
        free = set(self.free)
        assert len(free) == len(self.free), "free list has duplicates"
        assert 0 not in free and self.ref[0] == 1
        for s, fl in enumerate(self._free):
            assert all(self.shard_of(p) == s for p in fl), \
                f"shard {s} free list holds a foreign page"
        counts = np.bincount(self.table.reshape(-1),
                             minlength=self.n_pages).astype(np.int64)
        counts[0] = 1
        for p, n in (external_refs or {}).items():
            counts[p] += n
        for p in range(1, self.n_pages):
            if p in free:
                assert self.ref[p] == 0, f"page {p} free but referenced"
                assert counts[p] == 0, f"page {p} free but held"
            else:
                assert self.ref[p] == counts[p], \
                    f"page {p}: ref {self.ref[p]} != holders {counts[p]}"
        # occupancy accounting consistent with the refcounts
        owned = np.zeros((self.n_shards,), np.int64)
        for p in range(1, self.n_pages):
            if self.ref[p] > 0:
                owned[self.shard_of(p)] += 1
        assert np.array_equal(owned, self.in_use), \
            f"per-shard in_use {self.in_use} != owned {owned}"


# ==========================================================================
# spill records: host-side page images for preempted slots
# ==========================================================================

# deterministic kv-node leaf order shared by the spill gather and the
# restore scatter (k and v share shape+dtype, so a stable walk order —
# not just stable shapes — is what keeps the flat host lists aligned)
_KV_KEYS = ("k", "v", "c_kv", "k_pe", "pos")


@dataclass
class SpecFork:
    """Host-side restore point for one slot's speculative round: the
    committed position, the block-table row at fork time (tells
    rollback which pages the round allocated fresh), and — for
    recurrent-state families — a backup state page holding the
    pre-round state (a page COPY, because draft/verify dispatches
    advance the live state page in place; KV pages need no backup at
    all — stale future rows self-mask on the causal position check, so
    KV rollback is pure position truncation + fresh-page drop)."""
    slot: int
    pos: int
    kv_row: Optional[np.ndarray] = None
    st_backup: int = 0


@dataclass
class SpillRecord:
    """Host-side image of a preempted slot — everything ``restore``
    needs to resume the request in ANY slot later: the slot's position,
    its last sampled token (the engine splices it back into its
    device-resident pending vector), the content of its exclusively
    owned pages (copied to host), and the ids of its SHARED pages
    (prefix-trie / multi-slot pages are retained by reference instead
    of copied — the trie stays consistent and restore just points the
    new block table back at them)."""
    rid: int = -1
    pos: int = 0
    last_token: int = 0
    kv_kept: List[Tuple[int, int]] = field(default_factory=list)
    kv_blocks: List[int] = field(default_factory=list)
    kv_host: List[np.ndarray] = field(default_factory=list)
    st_host: List[np.ndarray] = field(default_factory=list)
    nbytes: int = 0


def _scatter_spill(cache: Dict, kv_ids, kv_host, st_page, st_host) -> Dict:
    """Upload a spill record's host page images into freshly allocated
    pool pages (jit-safe, cache donated — the restore path's one device
    call).  ``kv_host``/``st_host`` are flat tuples in the same walk
    order ``PagedPool._gather_kv_pages``/``_gather_state`` produced;
    empty tuples skip that half entirely (static pytree structure)."""
    kv_it = iter(kv_host)

    def kv(node):
        node = dict(node)
        for key in _KV_KEYS:
            if key not in node:
                continue
            v = node[key]
            if isinstance(v, tuple):
                node[key] = tuple(a.at[kv_ids].set(next(kv_it))
                                  for a in v)
            else:
                node[key] = v.at[:, kv_ids].set(next(kv_it))
        return node

    st_it = iter(st_host)

    def stl(a):
        return a.at[:, st_page].set(next(st_it))

    out = {}
    for k, v in cache.items():
        if k in _TABLE_KEYS:
            out[k] = v
            continue
        if len(kv_host):
            v = map_kv_nodes(v, kv)
        if len(st_host):
            v = map_state_leaves(v, stl)
        out[k] = v
    return out


# ==========================================================================
# PagedPool: device pool + prefix caching on top of the allocator
# ==========================================================================

class PagedPool:
    """The paged serving cache: builds the device pytree, owns the host
    allocators and the prefix cache, and turns host-side decisions into
    ONE packed ops vector per dirty dispatch (``drain``) that the engine
    fuses into its compiled step — clean dispatches upload nothing and
    run a separately-compiled step without the apply.

    The device cache is NOT stored here — ``build()`` returns it and
    every mutating method takes and returns it (the engine owns the
    single live copy because the dispatch step donates it).

    With ``n_shards > 1`` (and the serving page ``mesh``) the pools are
    MESH-SHARDED: every ``(stack, n_pages, ...)`` leaf is partitioned on
    its page axis across the mesh's page dimension, the allocators
    become ownership-aware (see ``BlockAllocator``), and ``_build_ops``
    emits one packed ops ROW per shard — resets and copies routed to the
    shard that physically holds the pages, with shard-LOCAL indices — so
    ``apply_cache_ops`` runs unchanged inside ``shard_map``."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int, *,
                 chunk: int = 0, page: int = 0, dtype=None,
                 spare_pages: Optional[int] = None,
                 snap_slots: Optional[int] = None,
                 prefix_cache: bool = True, n_shards: int = 1,
                 mesh=None):
        chunk = chunk or cfg.serve_chunk
        page = page or cfg.serve_page
        assert page >= 1
        assert n_shards >= 1
        self.cfg, self.n_slots, self.max_len = cfg, n_slots, max_len
        self.chunk, self.page = chunk, page
        self.n_shards, self.mesh = n_shards, mesh
        api = get_model(cfg)
        assert api.cache_init is not None, f"{cfg.name} has no decode cache"
        proto = api.cache_init(ring_cfg(cfg, chunk), 1, max_len,
                               dtype or cfg.jdtype)
        self.has_kv, self.has_state, rows = _scan_structure(proto)
        self._proto = proto
        # ring length rounded up to a page multiple: position p maps to
        # ring row p % ring, block r // page, offset r % page
        self.n_blocks = max(1, -(-rows // page)) if self.has_kv else 0
        self.ring = self.n_blocks * page
        if self.has_kv:
            spare = (n_slots * self.n_blocks if spare_pages is None
                     else spare_pages)
            n_pages = 1 + n_slots * self.n_blocks + spare
            n_pages += (-n_pages) % n_shards     # even split per shard
            self.n_pages = n_pages
            self.kv = BlockAllocator(self.n_pages, n_slots, self.n_blocks,
                                     n_shards)
        else:
            self.n_pages, self.kv = 0, None
        if self.has_state:
            n_snap = (n_slots if (snap_slots is None and prefix_cache)
                      else (snap_slots or 0))
            # one live page per slot + one spare per slot (admission
            # cycles to a fresh page before the old one is dropped) +
            # the snapshot budget; page 0 reserved as null for symmetry
            n_spages = 1 + 2 * n_slots + n_snap
            n_spages += (-n_spages) % n_shards
            self.n_spages = n_spages
            self.st = BlockAllocator(self.n_spages, n_slots, 1, n_shards)
            for s in range(n_slots):
                self.st.table[s, 0] = self.st.alloc()
        else:
            self.n_spages, self.st = 0, None
        self.prefix = PrefixCache(page) if prefix_cache else None
        self.pos = np.zeros((n_slots,), np.int64)
        self.counters = {
            "prefix_queries": 0, "prefix_hits": 0, "tokens_reused": 0,
            "pages_shared": 0, "pages_published": 0, "pages_cowed": 0,
            "pages_evicted": 0, "snapshots": 0, "snap_restores": 0,
        }
        # pending device ops, applied by the next flush
        self._kv_reset: set = set()
        self._kv_copies: List[Tuple[int, int]] = []
        self._st_reset: set = set()
        self._st_copies: List[Tuple[int, int]] = []
        self._dirty = False
        # pages held alive BY REFERENCE for spilled (preempted) requests
        # — shared pages are not copied to host, their SpillRecord just
        # retains them ({page: n_holds}; see ``spill``/``restore``)
        self._spill_kv: Dict[int, int] = {}
        self.spill_events = {"spills": 0, "restores": 0,
                             "spilled_bytes": 0}
        self._scatter = (jax.jit(_scatter_spill, donate_argnums=(0,))
                         if mesh is None else None)
        self.kv_copy_max = max(1, n_slots * (chunk // page + 2))
        # restores + snapshots per dispatch rarely exceed the slot
        # count; bursts overflow into extra pre-step apply rounds
        self.st_copy_max = max(1, n_slots)
        # per-dispatch copy pad widths of the LAST ``_build_ops`` round
        # ({0, copy_max} buckets) — the engine passes them into its
        # fused step as static args
        self.last_pads: Tuple[int, int] = (self.kv_copy_max,
                                           self.st_copy_max)
        assert n_shards == 1 or mesh is not None, \
            "sharded pool needs the page mesh"
        if mesh is None:
            self._apply = jax.jit(
                lambda cache, ops, pads: apply_cache_ops(cache, ops,
                                                         *pads),
                static_argnums=(2,), donate_argnums=(0,))
        else:
            # mesh present (even 1-shard): ops come as per-shard rows,
            # so the standalone apply must be the shard_map one —
            # built by build() (needs the cache's partition specs)
            self._apply = None

    # -- device cache ------------------------------------------------------
    def build(self) -> Dict:
        """Allocate the paged device cache (all pools zeroed, position
        tags -1, block tables null, state table at each slot's page)."""
        n_pages, page, n_spages = self.n_pages, self.page, self.n_spages

        def kv(node):
            # per-LAYER tuple leaves, one (n_pages, page, ...) array per
            # stack entry: the layer loop unrolls over tuple elements so
            # each page-pool scatter aliases its own donated buffer
            # in-place (a single stacked leaf threaded through lax.scan
            # is copied wholesale every layer on CPU backends)
            out = {}
            stack = None
            for key in ("k", "v", "c_kv", "k_pe"):
                if key in node:
                    a = node[key]
                    lead, feat = a.shape[:-3], a.shape[-1:]
                    if key in ("k", "v"):
                        feat = a.shape[-2:]
                        lead = a.shape[:-4]
                    assert len(lead) == 1, f"kv node {key}: lead {lead}"
                    stack = lead[0]
                    out[key] = tuple(
                        jnp.zeros((n_pages, page) + feat, a.dtype)
                        for _ in range(stack))
            out["pos"] = tuple(jnp.full((n_pages, page), -1, jnp.int32)
                               for _ in range(stack))
            return out

        def st(a):
            return jnp.zeros(a.shape[:1] + (n_spages,) + a.shape[2:],
                             a.dtype)

        cache: Dict = {}
        for k, v in self._proto.items():
            if k in _TABLE_KEYS:
                continue
            v = map_kv_nodes(v, kv)
            v = map_state_leaves(v, st)
            cache[k] = v
        cache["pos"] = jnp.zeros((self.n_slots,), jnp.int32)
        if self.has_kv:
            cache["block_table"] = jnp.zeros((self.n_slots, self.n_blocks),
                                             jnp.int32)
        if self.has_state:
            cache["state_table"] = jnp.asarray(self.st.table[:, 0],
                                               jnp.int32)
        if self.mesh is not None:
            # place the pools page-sharded on the mesh and compile the
            # standalone (overflow-round) apply as a shard_map step
            # (mesh-keyed, like _build_ops' per-shard rows — a 1-shard
            # mesh still takes this path)
            from repro.serving.mesh import (cache_partition_specs,
                                            shard_cache, sharded_apply)
            specs = cache_partition_specs(cache)
            cache = shard_cache(cache, self.mesh, specs)
            self._apply = sharded_apply(self.mesh, specs)
        return cache

    def _take_copies(self, pending: List[Tuple[int, int]], alloc,
                     budget: int):
        """Pop up to ``budget`` pending copies PER SHARD (routed by the
        src page's shard — COW/snapshot destinations are allocated on
        the same shard, asserted), dropping each emitted pair's
        pending-src pin.  Returns (src, dst) local-index arrays shaped
        (n_shards, budget); pads are the OOB sentinel ``pages_per_shard``
        — dropped by ``apply_cache_ops``'s scatter.  A (0, 0) self-copy
        pad would COLLIDE with a real copy whose destination is local
        page 0 (on shards >= 1 that is an allocatable page, unlike the
        global null page), and a duplicate-index scatter may let the
        stale pad win over the real copy."""
        P_, pps = alloc.n_shards, alloc.pages_per_shard
        src = np.full((P_, budget), pps, np.int32)
        dst = np.full((P_, budget), pps, np.int32)
        fill = [0] * P_
        rest: List[Tuple[int, int]] = []
        for s, d in pending:
            sh = alloc.shard_of(s)
            assert alloc.shard_of(d) == sh, \
                "page copy crosses shards (allocator ownership bug)"
            if fill[sh] < budget:
                src[sh, fill[sh]] = s - sh * pps
                dst[sh, fill[sh]] = d - sh * pps
                fill[sh] += 1
                alloc.drop(s)            # release the pending-src pin
            else:
                rest.append((s, d))
        pending[:] = rest
        return src, dst

    def _take_resets(self, reset: set, alloc) -> np.ndarray:
        """Pending page-tag resets as (n_shards, pages_per_shard) rows
        of shard-local flags; clears the set."""
        P_, pps = alloc.n_shards, alloc.pages_per_shard
        out = np.zeros((P_, pps), np.int32)
        for p in reset:
            out[alloc.shard_of(p), p % pps] = 1
        reset.clear()
        return out

    def _build_ops(self):
        """Materialise ONE round of pending edits as a packed int32
        vector (layout mirrored by ``apply_cache_ops``) — one
        host->device transfer per dirty dispatch.  Sharded pools emit
        one ROW per shard, (n_shards, row_len): the replicated sections
        (pos, block/state tables, global ids) are duplicated into every
        row while resets and copies carry shard-LOCAL page indices, so
        each shard applies exactly its own edits inside shard_map."""
        P_ = self.n_shards
        base = [np.asarray(self.pos, np.int32)]
        if self.has_kv:
            base.append(self.kv.table.reshape(-1).astype(np.int32))
        if self.has_state:
            base.append(self.st.table[:, 0].astype(np.int32))
        # copy pads bucket to {0, copy_max}: the common dirty dispatch
        # (fresh page allocated — resets + table upload, NO copies)
        # would otherwise gather-and-drop copy_max pages per pool leaf
        # inside the fused step, a pure ineffectual-work tax.  The pad
        # widths ride to ``apply_cache_ops`` as static args
        # (``last_pads``), so each bucket is its own executable.
        kv_parts = st_parts = None
        kv_pad = self.kv_copy_max if self._kv_copies else 0
        st_pad = self.st_copy_max if self._st_copies else 0
        if self.has_kv:
            reset = self._take_resets(self._kv_reset, self.kv)
            src, dst = self._take_copies(self._kv_copies, self.kv, kv_pad)
            kv_parts = (reset, src, dst)
        if self.has_state:
            reset = self._take_resets(self._st_reset, self.st)
            src, dst = self._take_copies(self._st_copies, self.st, st_pad)
            st_parts = (reset, src, dst)
        self.last_pads = (kv_pad if self.has_kv else 0,
                          st_pad if self.has_state else 0)
        rows = []
        for s in range(P_):
            parts = list(base)
            if kv_parts is not None:
                parts += [p[s] for p in kv_parts]
            if st_parts is not None:
                parts += [p[s] for p in st_parts]
            rows.append(np.concatenate(parts))
        if self.mesh is None:
            return jnp.asarray(rows[0])      # single-device: flat vector
        return jnp.asarray(np.stack(rows))   # sharded step: one row/shard

    def drain(self, cache: Dict) -> Tuple[Dict, Optional[jnp.ndarray]]:
        """-> (cache, ops): the pending edits as ONE packed vector for
        the engine to fuse into its compiled step, or None when clean
        (the engine's clean-step executable skips the apply entirely).
        Overflow rounds (more COW/snapshot copies than the pad width —
        rare) are applied to the cache directly."""
        if not self._dirty:
            return cache, None
        ops = self._build_ops()
        while self._kv_copies or self._st_copies:
            cache = self._apply(cache, ops, self.last_pads)
            ops = self._build_ops()
        self._dirty = False
        return cache, ops

    def flush(self, cache: Dict) -> Dict:
        """Apply all pending edits now (standalone jitted call — the
        engine prefers ``drain`` + its fused step).  No-op when clean."""
        cache, ops = self.drain(cache)
        if ops is not None:
            cache = self._apply(cache, ops, self.last_pads)
        return cache

    # -- pending page copies: the src is PINNED (one extra ref) from
    # queueing until ``_build_ops`` emits the pair, so no interleaved
    # eviction/free can recycle it and reset/zero it ahead of the copy
    def _push_kv_copy(self, src: int, dst: int) -> None:
        self.kv.retain(src)
        self._kv_copies.append((src, dst))
        self._kv_reset.add(dst)
        self._dirty = True

    def _push_st_copy(self, src: int, dst: int) -> None:
        self.st.retain(src)
        self._st_copies.append((src, dst))
        self._dirty = True

    # -- allocation with prefix-cache eviction -----------------------------
    # ``prefer`` pins the allocation (and, when eviction is needed to
    # satisfy it, the eviction hunt) to one mesh shard: COW and
    # snapshot-restore destinations must live on their source's shard
    # ``reset=False`` (spill restore) allocates a page whose CONTENT is
    # about to be uploaded from host — queuing the usual tag reset would
    # wipe that upload at the next dispatch, so the pending reset (if a
    # rolled-back admission left one behind on this id) is discarded
    def _kv_alloc(self, prefer: Optional[int] = None,
                  reset: bool = True) -> Optional[int]:
        p = self.kv.alloc(prefer=prefer)
        while p is None and self.prefix is not None:
            # evict only entries whose page actually frees (an entry
            # still shared into a live slot reclaims nothing — keep it
            # for future hits); same for snapshots via their kv pages
            pg = self.prefix.evict_lru_page(
                lambda q: self.kv.ref[q] == 1 and
                (prefer is None or self.kv.shard_of(q) == prefer))
            if pg is not None:
                self.kv.drop(pg)
                self.counters["pages_evicted"] += 1
            else:
                e = self.prefix.evict_lru_snap(
                    lambda s: any(
                        self.kv.ref[q] == 1 and
                        (prefer is None or self.kv.shard_of(q) == prefer)
                        for q in s.kv_pages))
                if e is None:
                    break
                self._drop_snap(e)
            p = self.kv.alloc(prefer=prefer)
        if p is not None:
            if reset:
                self._kv_reset.add(p)
            else:
                self._kv_reset.discard(p)
            self._dirty = True
        return p

    def _st_alloc(self, prefer: Optional[int] = None,
                  reset: bool = True) -> Optional[int]:
        p = self.st.alloc(prefer=prefer)
        while p is None and self.prefix is not None:
            # a pinned snapshot (mid-restore this step) has spage ref
            # > 1 and is excluded; everything else frees its state page
            e = self.prefix.evict_lru_snap(
                lambda s: self.st.ref[s.spage] == 1 and
                (prefer is None or self.st.shard_of(s.spage) == prefer))
            if e is None:
                break
            self._drop_snap(e)
            p = self.st.alloc(prefer=prefer)
        if p is not None:
            if reset:
                self._st_reset.add(p)
            else:
                self._st_reset.discard(p)
            self._dirty = True
        return p

    def _drop_snap(self, e) -> None:
        if self.st.drop(e.spage):
            self.counters["pages_evicted"] += 1
        for pg in e.kv_pages:
            if self.kv.drop(pg):
                self.counters["pages_evicted"] += 1

    # -- engine lifecycle ---------------------------------------------------
    def admit(self, slot: int, prompt: np.ndarray) -> int:
        """Attach a fresh request to ``slot``: match the prompt against
        the prefix cache, share hit pages / restore the hit snapshot,
        cycle the slot onto a fresh state page, and reset its position.
        Returns the number of leading tokens whose prefill is skipped
        (always < len(prompt): the last token is recomputed to produce
        the first sampled logit)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n_cached = 0
        shared_pages: List[int] = []
        snap = None
        if self.prefix is not None:
            self.counters["prefix_queries"] += 1
            limit = len(prompt) - 1
            if self.has_state:
                snap = self.prefix.match_state(prompt, limit)
                if snap is not None:
                    n_cached = snap.n_tokens
                    shared_pages = snap.kv_pages
            elif self.has_kv:
                shared_pages = self.prefix.match_pages(prompt, limit)
                n_cached = len(shared_pages) * self.page
            if n_cached:
                self.counters["prefix_hits"] += 1
                self.counters["tokens_reused"] += n_cached
                self.counters["pages_shared"] += len(shared_pages)
        for i, pg in enumerate(shared_pages):
            self.kv.share(slot, i, pg)
        if self.has_state:
            if snap is not None:
                # pin the matched snapshot across the alloc below: its
                # eviction would free (and possibly recycle) the very
                # page the restore copy is about to read
                self.st.retain(snap.spage)
            # a restore copies snapshot -> fresh page: the fresh page
            # must live on the snapshot's shard (shard-local copy)
            prefer = (self.st.shard_of(snap.spage)
                      if snap is not None and self.n_shards > 1 else None)
            new = self._st_alloc(prefer=prefer)
            if new is None:
                # ROLL BACK before surfacing the failure: the shared
                # prefix pages were already retained into the slot's
                # table and the snapshot pinned — leaving them leaks
                # refcounts and half-attaches the slot (the old
                # RuntimeError path did exactly that).  After rollback
                # the failure is deferrable: the scheduler keeps the
                # request queued and the engine may spill a victim.
                if snap is not None:
                    self.st.drop(snap.spage)     # release the admit pin
                for i in range(len(shared_pages)):
                    pg = int(self.kv.table[slot, i])
                    self.kv.table[slot, i] = 0
                    self.kv.drop(pg)
                raise PoolExhausted("paged state pool exhausted")
            old = int(self.st.table[slot, 0])
            if old:
                self.st.drop(old)
            self.st.table[slot, 0] = new
            if snap is not None:
                self._push_st_copy(snap.spage, new)
                self.st.drop(snap.spage)         # release the admit pin
                self.counters["snap_restores"] += 1
        self.pos[slot] = n_cached
        self._dirty = True
        return n_cached

    def plan_writes(self, n_valid: np.ndarray) -> None:
        """Pre-dispatch (host only): make every page this dispatch will
        write exclusively owned — fresh alloc for null blocks,
        copy-on-write for shared ones."""
        if not self.has_kv:
            return
        for s, nv in enumerate(np.asarray(n_valid)):
            if nv <= 0:
                continue
            p0 = int(self.pos[s])
            blocks = sorted({(p % self.ring) // self.page
                             for p in range(p0, p0 + int(nv))})
            fresh, copies = self.kv.write_plan(s, blocks,
                                               alloc=self._kv_alloc,
                                               on_copy=self._push_kv_copy)
            self.counters["pages_cowed"] += len(copies)
            if fresh:
                self._dirty = True

    def prepare(self, cache: Dict, n_valid: np.ndarray) -> Dict:
        """plan_writes + standalone flush (the engine instead drains the
        ops into its fused compiled step)."""
        self.plan_writes(n_valid)
        return self.flush(cache)

    def advance(self, n_valid: np.ndarray) -> None:
        self.pos += np.asarray(n_valid, np.int64)

    def active_blocks(self, n_valid: np.ndarray) -> Optional[int]:
        """Block-table width this dispatch actually NEEDS (host-side,
        count-based — no device sync): every position any slot has
        written or will write this step lies below
        ``max(pos + n_valid)``, so block-table columns past
        ``ceil(need / page)`` hold only null pages — ineffectual rows
        the attend would gather, mask and softmax for nothing.  The
        engine slices the table to this width inside its compiled step.

        Safety: a width ``W < n_blocks`` changes the ring modulus to
        ``W * page``, which is only sound while no slot has wrapped —
        ``pos + n_valid`` is clamped to ``ring`` so any wrap (windowed
        rings) forces the full width.  The result is bucketed to the
        next multiple of 4 blocks (capped at ``n_blocks``) — coarse
        enough that the engine compiles O(n_blocks / 4) step variants,
        fine enough that the attend width tracks the longest live
        sequence instead of snapping to the full ring."""
        if not self.has_kv:
            return None
        need = int(np.minimum(self.pos + np.asarray(n_valid, np.int64),
                              self.ring).max(initial=0))
        w = max(1, -(-need // self.page))
        return min(-(-w // 4) * 4, self.n_blocks)

    def maybe_snapshot(self, slot: int, prompt: np.ndarray,
                       offset: int) -> None:
        """Called just before the dispatch that finishes ``slot``'s
        prompt: snapshot the recurrent state at ``offset`` (page-aligned
        chunk boundary) keyed by ``prompt[:offset]``, retaining the
        shared-attention pages below it for hybrid models."""
        if self.prefix is None or not self.has_state:
            return
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if offset <= 0 or offset % self.page or offset > len(prompt) - 1:
            return
        if self.has_kv and offset > self.ring:
            return                       # ring wrapped: pages incomplete
        if self.prefix.has_state(prompt, offset):
            return
        cur = int(self.st.table[slot, 0])
        spage = self._st_alloc(
            prefer=self.st.shard_of(cur) if self.n_shards > 1 else None)
        if spage is None:
            return                       # snapshot budget exhausted
        self._push_st_copy(cur, spage)
        kv_pages: List[int] = []
        if self.has_kv:
            kv_pages = [int(self.kv.table[slot, i])
                        for i in range(offset // self.page)]
            for pg in kv_pages:
                self.kv.retain(pg)
        self.prefix.insert_state(prompt, offset, spage, kv_pages)
        self.counters["snapshots"] += 1
        self._dirty = True

    def publish(self, slot: int, prompt: np.ndarray) -> None:
        """Called when ``slot`` finishes prefill (attention families):
        publish the full pages of its prompt into the prefix trie.
        Prompts longer than the sliding-window ring have wrapped by now
        (pages hold the TAIL positions, not the prefix) — those were
        already published at the last pre-wrap page boundary by
        ``maybe_publish_prewrap``, so nothing is lost here."""
        if self.prefix is None or not self.has_kv or self.has_state:
            return
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) > self.ring:
            return                       # ring wrapped: prewrap published
        n_full = (len(prompt) // self.page) * self.page
        new = self.prefix.insert_pages(
            prompt, n_full, lambda i: self.kv.table[slot, i])
        for pg in new:
            self.kv.retain(pg)
        self.counters["pages_published"] += len(new)

    def maybe_publish_prewrap(self, slot: int, prompt: np.ndarray,
                              offset: int, take: int) -> None:
        """Close the windowed-prompt prefix-cache gap (ROADMAP): a
        sliding-window prompt longer than its ring used to publish
        NOTHING — by the time prefill ends the ring has wrapped and the
        pages hold the tail, not the prefix.  Called pre-dispatch for
        every prefilling slot about to consume ``take`` tokens at
        ``offset``: on the dispatch that first writes past the ring,
        publish a state-snapshot-style entry at the LAST PRE-WRAP page
        boundary — full pages [0, offset) for attention families, the
        recurrent-state snapshot at ``offset`` (page-aligned) for
        ssm/hybrid — while the prefix is still intact."""
        if self.prefix is None or not self.has_kv:
            return
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) <= self.ring:
            return                       # no wrap: publish() covers it
        if not (offset <= self.ring < offset + take):
            return                       # not the wrap-crossing dispatch
        if self.has_state:               # hybrid: snapshot-style entry
            self.maybe_snapshot(slot, prompt, offset)
            return
        n_full = (min(offset, len(prompt) - 1) // self.page) * self.page
        if n_full <= 0:
            return
        new = self.prefix.insert_pages(
            prompt, n_full, lambda i: self.kv.table[slot, i])
        for pg in new:
            self.kv.retain(pg)
        self.counters["pages_published"] += len(new)

    def release(self, slot: int) -> None:
        """Evict a finished request: drop its page refs (pages still
        pinned by the prefix cache survive for future hits)."""
        if self.has_kv:
            self.kv.release_slot(slot)
        self.pos[slot] = 0
        self._dirty = True

    # -- preemption: spill / restore ---------------------------------------
    def _gather_kv_pages(self, cache: Dict, ids: List[int]
                         ) -> List[np.ndarray]:
        """Device -> host copy of kv-pool pages ``ids`` as a flat list
        in the fixed ``_KV_KEYS`` walk order (``_scatter_spill`` replays
        the identical walk on restore)."""
        out: List[np.ndarray] = []
        idx = np.asarray(ids, np.int32)

        def kv(node):
            # walk order must match _scatter_spill exactly — k and v
            # share shape+dtype so a swap would corrupt silently
            for key in _KV_KEYS:
                if key not in node:
                    continue
                v = node[key]
                if isinstance(v, tuple):
                    out.extend(np.asarray(a[idx]) for a in v)
                else:
                    out.append(np.asarray(v[:, idx]))
            return node

        for k, v in cache.items():
            if k in _TABLE_KEYS:
                continue
            map_kv_nodes(v, kv)
        return out

    def _gather_state(self, cache: Dict, spage: int) -> List[np.ndarray]:
        out: List[np.ndarray] = []

        def stl(a):
            out.append(np.asarray(a[:, spage]))
            return a

        for k, v in cache.items():
            if k in _TABLE_KEYS:
                continue
            map_state_leaves(v, stl)
        return out

    def spill(self, slot: int, cache: Dict
              ) -> Tuple[Dict, SpillRecord]:
        """Preempt ``slot``: move its cache content off the device pool
        so the pages can serve other requests, returning a record
        ``restore`` can replay into ANY free slot later.

        Shared pages (prefix trie / other slots hold refs) are NOT
        copied — the record retains them by reference, so a restore is
        free for the prefix-hit part of the sequence and the trie stays
        consistent throughout.  Exclusive pages and the slot's recurrent
        state are copied to host; pending COW/snapshot edits are flushed
        FIRST so the copies read post-edit content.  The slot keeps its
        state page attached (every live slot always owns one — admission
        cycles it), only the CONTENT moves."""
        assert self.mesh is None, \
            "spill/restore is single-device (layout='paged')"
        cache = self.flush(cache)
        rec = SpillRecord(pos=int(self.pos[slot]))
        if self.has_kv:
            copied: List[Tuple[int, int]] = []
            for b in np.nonzero(self.kv.table[slot])[0]:
                pg = int(self.kv.table[slot, b])
                if self.kv.ref[pg] > 1:
                    self.kv.retain(pg)
                    self._spill_kv[pg] = self._spill_kv.get(pg, 0) + 1
                    rec.kv_kept.append((int(b), pg))
                else:
                    copied.append((int(b), pg))
            if copied:
                rec.kv_blocks = [b for b, _ in copied]
                rec.kv_host = self._gather_kv_pages(
                    cache, [pg for _, pg in copied])
            self.kv.release_slot(slot)
        if self.has_state:
            rec.st_host = self._gather_state(
                cache, int(self.st.table[slot, 0]))
        self.pos[slot] = 0
        self._dirty = True
        rec.nbytes = int(sum(a.nbytes for a in rec.kv_host + rec.st_host))
        self.spill_events["spills"] += 1
        self.spill_events["spilled_bytes"] += rec.nbytes
        return cache, rec

    def restore(self, slot: int, rec: SpillRecord, cache: Dict) -> Dict:
        """Re-admit a spilled request into (free) ``slot``: allocate
        fresh pages for the copied content, re-attach the
        retained-by-reference shared pages, upload the host images in
        one jitted donated scatter, and restore the position.  All
        allocation happens BEFORE any table mutation — on exhaustion the
        fresh pages are returned and ``PoolExhausted`` surfaces with the
        pool unchanged (the engine can spill another victim and retry)."""
        assert self.mesh is None
        fresh: List[int] = []
        for _ in rec.kv_blocks:
            p = self._kv_alloc(reset=False)
            if p is None:
                for q in fresh:
                    self.kv.unalloc(q)
                raise PoolExhausted("paged KV pool exhausted (restore)")
            fresh.append(p)
        st_new = 0
        if self.has_state:
            st_new = self._st_alloc(reset=False)
            if st_new is None:
                for q in fresh:
                    self.kv.unalloc(q)
                raise PoolExhausted(
                    "paged state pool exhausted (restore)")
        if self.has_kv:
            assert not self.kv.table[slot].any(), "restore into live slot"
            for b, pg in rec.kv_kept:
                # the spill hold becomes the table's ref — no net change
                self.kv.table[slot, b] = pg
                n = self._spill_kv[pg] - 1
                if n:
                    self._spill_kv[pg] = n
                else:
                    del self._spill_kv[pg]
            for b, p in zip(rec.kv_blocks, fresh):
                self.kv.table[slot, b] = p
        if self.has_state:
            old = int(self.st.table[slot, 0])
            self.st.table[slot, 0] = st_new
            if old:
                self.st.drop(old)
        self.pos[slot] = rec.pos
        self._dirty = True
        if rec.kv_host or rec.st_host:
            cache = self._scatter(
                cache, jnp.asarray(fresh or [0], jnp.int32),
                tuple(rec.kv_host), st_new, tuple(rec.st_host))
        self.spill_events["restores"] += 1
        return cache

    # -- speculative decoding: fork / rollback ------------------------------
    # A speculative round is a block-table operation, not a cache copy:
    # fork records the committed position + the slot's block-table row
    # and backs up the recurrent state to a spare page; rollback drops
    # the pages the round allocated past the accepted prefix and
    # truncates the position.  KV content never moves — draft rows past
    # the committed position carry tags > any future query position and
    # self-mask on the causal check, and the next dispatch's
    # write-before-attend overwrites the committed frontier row.

    def spec_fork(self, slot: int) -> SpecFork:
        """Host-side restore point for ``slot`` before a speculative
        round.  Raises ``PoolExhausted`` when no spare state page is
        available for the backup (the caller falls back to vanilla
        decode for this step)."""
        rec = SpecFork(slot=slot, pos=int(self.pos[slot]))
        if self.has_kv:
            rec.kv_row = self.kv.table[slot].copy()
        if self.has_state:
            backup = self._st_alloc(reset=False)
            if backup is None:
                raise PoolExhausted(
                    "paged state pool exhausted (spec fork)")
            rec.st_backup = backup
            # content copy rides the next drain, BEFORE the first draft
            # dispatch advances the live page in place
            self._push_st_copy(int(self.st.table[slot, 0]), backup)
        return rec

    def spec_set_pos(self, slot: int, pos: int) -> None:
        """Host-side position override (pre-verify reset to the fork
        point / post-verify truncation to the accepted prefix); dirties
        the pool so the next drain re-uploads the position row."""
        self.pos[slot] = int(pos)
        self._dirty = True

    def spec_restore_state(self, rec: SpecFork) -> None:
        """Queue backup -> live state-page copy (the live page holds
        draft-advanced or over-verified state; the backup holds the
        state at the fork point)."""
        if rec.st_backup:
            self._push_st_copy(rec.st_backup,
                               int(self.st.table[rec.slot, 0]))

    def spec_rollback_pages(self, rec: SpecFork, committed_pos: int
                            ) -> int:
        """Drop blocks the round allocated FRESH entirely past the
        accepted prefix (null in the fork row, live now, first position
        >= committed).  COW'd blocks are kept — their shared source was
        already released by write_plan, and their stale draft rows
        self-mask.  Fresh blocks only exist pre-wrap, where block ``b``
        covers positions ``[b*page, (b+1)*page)`` exactly, so the
        position test is well defined.  Returns the drop count."""
        if not self.has_kv:
            return 0
        dropped = 0
        for b in range(self.n_blocks):
            pg = int(self.kv.table[rec.slot, b])
            if pg and rec.kv_row[b] == 0 and \
                    b * self.page >= committed_pos:
                self.kv.drop(pg)
                self.kv.table[rec.slot, b] = 0
                dropped += 1
        if dropped:
            self._dirty = True
        return dropped

    def spec_drop_backup(self, rec: SpecFork) -> None:
        """Release the state backup page.  Safe while a restore copy is
        still queued: ``_push_st_copy`` pinned the source until
        ``_build_ops`` emits the pair."""
        if rec.st_backup:
            self.st.drop(rec.st_backup)
            rec.st_backup = 0

    def spec_abort(self, rec: SpecFork) -> None:
        """Unwind a round that died mid-flight (pool exhausted during a
        draft/verify plan): truncate to the fork point, drop any pages
        the partial round allocated, restore the state backup.  Handles
        ``write_plan``'s partial mutation on raise — the fork-row diff
        covers exactly the blocks it touched."""
        self.spec_rollback_pages(rec, rec.pos)
        if rec.st_backup:
            self.spec_restore_state(rec)
            self.spec_drop_backup(rec)
        self.spec_set_pos(rec.slot, rec.pos)

    def external_refs(self, table: str = "kv") -> Dict[int, int]:
        """Refcount holders OUTSIDE the block tables — prefix-trie
        retains, pending-copy source pins, and spilled requests' kept
        pages — keyed by page id, in the shape
        ``BlockAllocator.check`` expects (invariant audits in tests)."""
        refs: Dict[int, int] = {}

        def add(p: int, n: int = 1) -> None:
            if p:
                refs[p] = refs.get(p, 0) + n

        if table == "kv":
            if self.prefix is not None:
                for p, n in self.prefix.page_refs().items():
                    add(p, n)
            for s, _ in self._kv_copies:
                add(s)
            for p, n in self._spill_kv.items():
                add(p, n)
        else:
            if self.prefix is not None:
                for p, n in self.prefix.state_refs().items():
                    add(p, n)
            for s, _ in self._st_copies:
                add(s)
        return refs

    # -- reporting ----------------------------------------------------------
    def alloc_events(self) -> Dict:
        """Cumulative allocator alloc/free event counts per table."""
        out: Dict = {}
        if self.has_kv:
            out["kv_alloc"] = self.kv.events["alloc"]
            out["kv_free"] = self.kv.events["free"]
        if self.has_state:
            out["state_alloc"] = self.st.events["alloc"]
            out["state_free"] = self.st.events["free"]
        return out

    def reset_event_counters(self) -> None:
        """Zero the cumulative event counters (prefix counters + alloc
        events); occupancy/hiwater accounting is left intact."""
        for k in self.counters:
            self.counters[k] = 0
        for k in self.spill_events:
            self.spill_events[k] = 0
        for al in (self.kv, self.st):
            if al is not None:
                al.events = {"alloc": 0, "free": 0}

    def shard_report(self) -> Dict:
        """Per-shard page occupancy: current in-use and high-water marks
        (the null page on shard 0 is excluded by the allocator's
        accounting — it is pinned, never allocated)."""
        rep: Dict = {"n_shards": self.n_shards}
        if self.has_kv:
            rep["kv_pages_per_shard"] = self.kv.pages_per_shard
            rep["kv_pages_in_use_per_shard"] = self.kv.in_use.tolist()
            rep["kv_pages_hiwater_per_shard"] = self.kv.hiwater.tolist()
        if self.has_state:
            rep["state_pages_per_shard"] = self.st.pages_per_shard
            rep["state_pages_in_use_per_shard"] = self.st.in_use.tolist()
            rep["state_pages_hiwater_per_shard"] = self.st.hiwater.tolist()
        return rep

    def report(self) -> Dict:
        rep = {
            "page": self.page, "n_blocks": self.n_blocks,
            "ring": self.ring, "n_pages": self.n_pages,
            "n_state_pages": self.n_spages,
            "prefix_caching": self.prefix is not None,
        }
        if self.has_kv:
            rep["pages_in_use"] = int(np.sum(self.kv.ref > 0) - 1)
        if any(self.spill_events.values()):
            rep.update({f"spill_{k}": v
                        for k, v in self.spill_events.items()})
        if self.n_shards > 1:
            rep["sharding"] = self.shard_report()
        if self.prefix is not None:
            q = max(self.counters["prefix_queries"], 1)
            n_pages, n_snaps = self.prefix.n_entries
            rep.update(self.counters,
                       hit_rate=self.counters["prefix_hits"] / q,
                       trie_pages=n_pages, trie_snapshots=n_snaps)
        return rep

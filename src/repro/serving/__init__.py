"""repro.serving — continuous-batching MoR serving engine.

The paper's deployment target is an inference accelerator serving real
traffic; this package embeds the MoR predictor in a serving loop that
*measures and exploits* the sparsity it predicts:

  kv_pool    — slot-pool cache layout (per-slot positions, per-slot kv
               position tags, window + chunk ring margin) + slot recycle.
  scheduler  — continuous-batching policy: admit requests with
               heterogeneous prompt/gen lengths into a fixed slot pool,
               chunk prompts, mix prefill chunks and decode steps in one
               dispatch, evict finished sequences mid-flight.
  engine     — the driver: one compiled chunk step per dispatch shape,
               request queue -> token streams + a serving report.
  telemetry  — per-layer tile-liveness histograms + predictor hit/miss
               counters accumulated during serving; feeds
               ``calibrate_capacity`` (liveness-quantile provisioning of
               each layer's gather_matmul capacity).
"""
from repro.serving.engine import Engine, Request
from repro.serving.telemetry import ServingTelemetry, calibrate_capacity

__all__ = ["Engine", "Request", "ServingTelemetry", "calibrate_capacity"]

"""repro.serving — continuous-batching MoR serving engine.

The paper's deployment target is an inference accelerator serving real
traffic; this package embeds the MoR predictor in a serving loop that
*measures and exploits* the sparsity it predicts:

  kv_pool      — cache layouts: the paged pool (``PagedPool``: fixed-size
                 pages, free list + refcounts (``BlockAllocator``),
                 per-slot block tables, copy-on-write) and the legacy
                 contiguous slot pool kept as the differential baseline.
  prefix_cache — hash-trie of full KV pages + recurrent-state snapshots
                 keyed by token prefixes; requests sharing a prompt
                 prefix map their leading block-table entries to the
                 same physical pages and skip the hit prefill chunks.
  scheduler    — continuous-batching policy: admit requests with
                 heterogeneous prompt/gen lengths into a fixed slot pool
                 (prefix-matched at admission), chunk prompts, mix
                 prefill chunks and decode steps in one dispatch, evict
                 finished sequences mid-flight.
  engine       — the driver: one compiled chunk step per dispatch shape,
                 request queue -> token streams + a serving report;
                 greedy or temperature/top-k sampling; per-request token
                 stream callbacks / iterator (flush-time, no extra
                 device syncs).
  mesh         — the mesh-sharded paged layout
                 (``Engine(layout="paged-sharded")``): page pools
                 partitioned over a mesh axis, block tables replicated,
                 the hot loop one shard_map'd step with a distributed
                 flash decode (one merge collective per attention
                 layer via ``distributed.collectives.flash_merge``).
  policy       — pluggable admission/preemption policies (FCFS /
                 priority classes / shortest-remaining-prefill + the
                 decode-vs-prefill token-budget knob); the engine pairs
                 ``PriorityPolicy`` victims with page-spill preemption
                 (``PagedPool.spill``/``restore``) so high-priority
                 arrivals take slots without anyone losing tokens.
  loadgen      — seeded open-loop (Poisson) arrival generator driving
                 ``Engine.submit`` in real time for SLO benchmarks
                 (p50/p99 TTFT + ITL per policy under offered load).
  telemetry    — per-layer tile-liveness histograms + predictor hit/miss
                 counters + prefix-cache counters accumulated during
                 serving; feeds ``calibrate_capacity`` (liveness-quantile
                 provisioning of each layer's gather_matmul capacity).
"""
from repro.serving.engine import Engine, Request, RequestRejected
from repro.serving.policy import (FCFSPolicy, Policy, PriorityPolicy,
                                  ShortestPrefillPolicy, get_policy)
from repro.serving.telemetry import ServingTelemetry, calibrate_capacity

__all__ = ["Engine", "Request", "RequestRejected", "Policy",
           "FCFSPolicy", "PriorityPolicy", "ShortestPrefillPolicy",
           "get_policy", "ServingTelemetry", "calibrate_capacity"]

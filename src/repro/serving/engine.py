"""The continuous-batching serving engine.

One compiled chunk step per dispatch shape ((B, chunk) mixed and (B, 1)
decode-only) drives the whole request stream: the scheduler packs each
dispatch, the paged kv pool allocates/copy-on-writes the pages the
dispatch will touch (host-side, count-based — no device sync), the
telemetry accumulates per-layer tile-liveness from every dispatch's MoR
stats, and ``calibrate_capacities`` turns that into per-layer
gather_matmul capacity fractions (attached to the execution plans as a
traced leaf — updating them does NOT recompile the step).

Cache layouts: ``layout="paged"`` (default) runs on
``kv_pool.PagedPool`` — block-table indirection, refcounted pages, and
prefix caching (requests sharing a prompt prefix map their leading
block-table entries to the same physical pages; fully-hit prefill
chunks are never dispatched).  ``layout="paged-sharded"`` is the same
pool MESH-SHARDED over a page axis (``serving.mesh``): physical pages
partitioned across the mesh devices, block tables replicated, the hot
loop one shard_map'd step with a distributed flash decode (one merge
collective per attention layer) — multi-device KV capacity as a config
flag.  ``layout="slotted"`` is the PR 2 contiguous layout, kept as the
differential baseline.

Sampling: greedy argmax by default; ``temperature`` > 0 enables
temperature sampling (optionally top-k truncated), seeded and
device-resident like the greedy path.

Streaming: ``submit(..., on_token=cb)`` registers a per-request token
callback and ``stream()`` wraps one request as a generator.  Callbacks
fire at token FLUSH time (tokens already land host-side there), so the
default path keeps its zero extra device syncs; ``run(stream_interval=
N)`` opts into flushing every N dispatches for incremental delivery.
"""
from __future__ import annotations

import contextlib
import time
from functools import partial
from typing import Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serving import kv_pool
from repro.serving.policy import Policy, get_policy
from repro.serving.scheduler import (FREE, Request, RequestRejected,
                                     Scheduler)
from repro.serving.spec import SpecDecoder, sample_step
from repro.serving.telemetry import (STAT_KEYS, ServingTelemetry,
                                     calibrate_capacity, export_telemetry,
                                     mor_group_map)

__all__ = ["Engine", "Request", "RequestRejected"]


class Engine:
    """Continuous-batching serving engine over a fixed slot pool.

    ``mor`` is the RAW calibrated MoR pytree ({layer group -> stacked
    MoRLayer}) as produced by ``deploy.calibrate_lm`` /
    ``deploy.calibrate_moe``; the engine attaches per-layer execution
    plans itself so that capacity calibration can re-attach them with
    per-layer budgets (per-(layer, expert) for the MoE expert group,
    whose stats arrive (L, E)-shaped via aux["moe_mor_stats"])."""

    def __init__(self, cfg: ModelConfig, params, *, mor: Optional[Dict] = None,
                 mor_mode: str = "dense", n_slots: int = 8,
                 max_len: int = 256, chunk: int = 0,
                 capacities: Optional[Dict] = None, telemetry: bool = True,
                 layout: str = "paged", page: int = 0,
                 prefix_cache: bool = True,
                 spare_pages: Optional[int] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, mesh=None, obs=None,
                 policy=None, spec_k: int = 0, draft_cap: float = 0.0,
                 spec_draft_temperature: Optional[float] = None,
                 shadow_rate: float = 0.0, drift_threshold: float = 0.25,
                 drift_detector: str = "ewma"):
        api = get_model(cfg)
        assert api.prefill_chunk is not None, \
            f"{cfg.name} ({cfg.family}) has no serving chunk step"
        assert layout in ("paged", "paged-sharded", "slotted")
        self.cfg = cfg
        self.api = api
        self.params = params
        self.mor_mode = mor_mode
        self.raw_mor = mor if mor_mode != "dense" else None
        self.chunk = chunk or cfg.serve_chunk
        self.n_slots = n_slots
        self.max_len = max_len
        self.mor = self._attach(capacities)
        self.capacities = capacities
        self.layout = layout
        self.mesh = None
        if layout in ("paged", "paged-sharded"):
            n_shards = 1
            if layout == "paged-sharded":
                if mesh is None:
                    from repro.launch.mesh import make_page_mesh
                    mesh = make_page_mesh()
                from repro.distributed.sharding_rules import PAGE_AXIS
                self.mesh = mesh
                n_shards = mesh.shape[PAGE_AXIS]
            self.pool: Optional[kv_pool.PagedPool] = kv_pool.PagedPool(
                cfg, n_slots, max_len, chunk=self.chunk, page=page,
                spare_pages=spare_pages, prefix_cache=prefix_cache,
                n_shards=n_shards, mesh=self.mesh)
            self.cache = self.pool.build()
            self._reset = None
        else:
            self.pool = None
            self.cache = kv_pool.init(cfg, n_slots, max_len, self.chunk)
            self._reset = jax.jit(kv_pool.reset_slots, donate_argnums=(0,))
        # scheduling policy (SLO layer): a Policy instance or a name
        # ("fcfs" / "priority" / "sjf") — see repro.serving.policy
        if isinstance(policy, str):
            policy = get_policy(policy)
        self.scheduler = Scheduler(n_slots, self.chunk, policy=policy)
        self.policy: Policy = self.scheduler.policy
        # preemption spills pages through host copies of the
        # single-device pool leaves — gated off for the sharded layout
        # (its pages live mesh-distributed) and the slotted baseline
        self._can_preempt = (layout == "paged")
        # spilled (preempted) requests' host-side page images, by rid;
        # re-admission restores them into whatever slot frees up
        self._spilled: Dict[int, kv_pool.SpillRecord] = {}
        self.telemetry = ServingTelemetry() if telemetry else None
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._base_key = jax.random.PRNGKey(sample_seed)
        # observability (repro.obs.Observability): metrics registry +
        # request tracer + packed device-resident metrics block.  The
        # block's layout is fixed NOW from the step's aux stat shapes
        # (jax.eval_shape — no compile) so the jit signature is stable.
        self.obs = obs
        self._tr = obs.tracer if obs is not None else None
        self._mspec = self._mblock = None
        if obs is not None and obs.device_metrics:
            from repro.obs.device import DeviceMetricsSpec
            self._mspec = DeviceMetricsSpec(self._probe_stat_shapes())
            n_rows = self.pool.n_shards if self.pool is not None else 1
            self._mblock = self._mspec.init(n_rows)
        body = partial(self._step_impl, cfg, api, mor_mode,
                       self.temperature, self.top_k, self._mspec)
        if layout == "paged-sharded":
            from repro.serving.mesh import make_sharded_step
            self._step = make_sharded_step(body, self.mesh, self.cache)
        else:
            # n_active (arg 10) is the static block-table width
            # (bucketed multiples of four) and copy_pads (arg 11) the
            # static {0, max} copy-pad widths — a handful of
            # executables total.  The metrics block (arg 9) is donated
            # like the cache: it round-trips through every dispatch.
            self._step = jax.jit(body, donate_argnums=(2, 9),
                                 static_argnums=(10, 11))
        # shadow-oracle predictor scoring (obs.quality): every Nth
        # vanilla dispatch is scored against the dense oracle and the
        # exact per-(layer, expert) false-skip / false-keep counts land
        # in the device metrics block.  TWO execution strategies,
        # picked per plan mode:
        #
        # - tiled plans: the cheap IN-STEP twin (mode="scored") — the
        #   sampled dispatch itself runs the scoring forward, whose
        #   activations are bitwise identical to the tiled path (tiled
        #   mode evaluates the dense matmul and selects), so it
        #   REPLACES the primary dispatch and the only extra cost is
        #   the elementwise truth arithmetic;
        # - kernel / exact plans: a standalone dense twin
        #   (mode="shadow") dispatched alongside the primary — those
        #   modes cannot guarantee bitwise identity (gather_matmul may
        #   reassociate the accumulation; exact is neuron-granular), so
        #   the primary's tokens stay authoritative and the twin's only
        #   output is the updated metrics block.
        #
        # Either way shadow-on is token-identical to shadow-off, and
        # shadow_rate=0 never builds or calls any twin, so the default
        # path's device-sync count is untouched.  Speculative rounds
        # bypass step()'s vanilla dispatch, so sampling covers vanilla
        # dispatches only.
        self.shadow_rate = float(shadow_rate)
        self._shadow_every: Optional[int] = None
        self._shadow_step = None
        self._shadow_mor = None
        self.drift = None
        if self.shadow_rate > 0.0:
            assert self.raw_mor is not None, \
                "shadow_rate needs a calibrated MoR tree " \
                "(mor_mode != 'dense')"
            assert self._mspec is not None, \
                "shadow_rate needs Observability(device_metrics=True)"
            from repro.core.executor import map_plans
            from repro.obs.quality import DriftDetector
            self._shadow_every = max(1, int(round(1.0 / self.shadow_rate)))
            modes = set()
            map_plans(self.mor, lambda p: (
                modes.add(p.mode) if p.active else None, p)[1])
            twin = bool(modes - {"tiled"})
            self._shadow_as = (
                (lambda p: p.as_shadow()) if twin else
                (lambda p: p.as_scored() if p.mode == "tiled" else p))
            self._shadow_mor = map_plans(self.mor, self._shadow_as)
            if twin:
                sbody = partial(self._shadow_impl, cfg, api, mor_mode,
                                self._mspec)
                if layout == "paged-sharded":
                    from repro.serving.mesh import make_sharded_shadow_step
                    self._shadow_step = make_sharded_shadow_step(
                        sbody, self.mesh, self.cache)
                else:
                    self._shadow_step = jax.jit(sbody, donate_argnums=(8,),
                                                static_argnums=(9, 10))
            self.drift = DriftDetector(threshold=drift_threshold,
                                       detector=drift_detector)
        # self-speculative decoding: MoR-capacitated draft passes
        # verified through the paged-COW block tables (serving.spec).
        # Gated to the single-device paged layout — rounds use
        # spill-style host-side fork/rollback and their own jitted
        # phase bodies, not the sharded step's fixed out_specs.
        self.spec: Optional[SpecDecoder] = None
        if spec_k > 0:
            assert layout == "paged", \
                "speculative decoding requires layout='paged'"
            # draft rows past the committed position must stay outside
            # any window: the pool sizes windowed rings with `chunk`
            # slack, which bounds how far a round may write ahead
            assert spec_k <= self.chunk, \
                f"spec_k={spec_k} must be <= chunk={self.chunk}"
            self.spec = SpecDecoder(
                self, spec_k=spec_k, draft_cap=draft_cap,
                draft_temperature=spec_draft_temperature)
        self._stream_cbs: Dict[int, Callable[[int, int], None]] = {}
        self._stream_done: set = set()
        self._next_rid = 0
        self._aux_log: List[Dict] = []
        # device-resident hot loop: each slot's last sampled token lives
        # in ``_pending`` and each dispatch's (emits, nxt) pair in
        # ``_tok_log`` — token values are fetched to host ONCE at flush,
        # so the dispatch loop never blocks on the accelerator pipeline
        # (completion is count-based; see scheduler._Slot)
        self._pending = jnp.zeros((n_slots,), jnp.int32)
        self._tok_log: List = []
        self.results: Dict[int, List[int]] = {}
        self.counters = {"prefill_tokens": 0, "decode_tokens": 0,
                         "dispatches": 0, "wall_s": 0.0,
                         "preemptions": 0, "requests_rejected": 0}
        # rejection reasons -> counts (mirrored into the obs registry)
        self.rejections: Dict[str, int] = {}
        # last host-side read of the device metrics block (set by
        # _flush_obs; surfaced in report()["obs"])
        self._last_device_metrics: Optional[Dict] = None

    def _flush_tokens(self) -> None:
        if self._tok_log:
            # ONE host transfer for the whole log.  Entries are either
            # (emits, nxt (B,)) vanilla dispatches or
            # (emits, tokens (B, K+1), counts (B,)) speculative rounds
            # (counts already host-side — it was the round's one sync)
            fetched = jax.device_get([e[1] for e in self._tok_log])
            for entry, toks in zip(self._tok_log, fetched):
                emits = entry[0]
                counts = entry[2] if len(entry) > 2 else None
                toks = np.asarray(toks)
                for s, rid in emits:
                    if counts is None:
                        vals = (int(toks[s]),)
                    else:
                        vals = tuple(int(t)
                                     for t in toks[s, :int(counts[s])])
                    cb = self._stream_cbs.get(rid)
                    res = self.results.setdefault(rid, [])
                    for t in vals:
                        res.append(t)
                        if cb is not None:
                            cb(rid, t)
            self._tok_log.clear()
        # a flush drains every pending dispatch, so finished requests'
        # callbacks have now delivered their last token — drop them
        # (long-lived engines would otherwise leak one closure per
        # streamed request)
        for rid in self._stream_done:
            self._stream_cbs.pop(rid, None)
        self._stream_done.clear()

    def _flush_telemetry(self) -> None:
        if self.telemetry is not None:
            for aux in self._aux_log:
                self.telemetry.update(aux)
            if self.pool is not None and self.pool.prefix is not None:
                self.telemetry.update_prefix(self._prefix_counters())
            if self.pool is not None and self.pool.n_shards > 1:
                self.telemetry.update_sharding(self.pool.shard_report())
        self._aux_log.clear()

    def _flush_obs(self) -> None:
        """Mirror every counter source into the obs registry: the
        device metrics block (ONE host transfer), the pool's host-side
        accounting, kernel traces, and the telemetry summary.  Runs at
        flush boundaries only — never on the dispatch hot path.  All
        series are labeled by layout and written idempotently, so
        repeated flushes (and several engines sharing one registry)
        overwrite their own series instead of double-reporting."""
        if self.obs is None:
            return
        reg = self.obs.registry
        lay = self.layout
        if self._mblock is not None:
            dm = self._mspec.read(self._mblock)
            self._last_device_metrics = dm
            reg.counter("repro_engine_dispatches_total",
                        "compiled-step dispatches (device-counted)",
                        ("layout",)).set(dm["dispatches"], layout=lay)
            ctok = reg.counter(
                "repro_engine_tokens_total",
                "tokens processed by the compiled step",
                ("layout", "phase"))
            ctok.set(dm["prefill_tokens"], layout=lay, phase="prefill")
            ctok.set(dm["decode_tokens"], layout=lay, phase="decode")
            reg.counter(
                "repro_engine_pages_touched_total",
                "live (slot, block) table entries visible to the paged "
                "attends, summed over dispatches",
                ("layout",)).set(dm["pages_touched"], layout=lay)
            reg.counter(
                "repro_spec_tokens_drafted_total",
                "draft tokens proposed by speculative rounds "
                "(device-counted)",
                ("layout",)).set(dm["tokens_drafted"], layout=lay)
            reg.counter(
                "repro_spec_tokens_accepted_total",
                "draft tokens the target verify accepted "
                "(device-counted)",
                ("layout",)).set(dm["tokens_accepted"], layout=lay)
            cpe = reg.counter(
                "repro_pool_page_events_total",
                "device page edits applied by the fused cache-ops step",
                ("layout", "table", "event"))
            for table in ("kv", "state"):
                cpe.set(dm[f"{table}_page_resets"], layout=lay,
                        table=table, event="reset")
                cpe.set(dm[f"{table}_page_copies"], layout=lay,
                        table=table, event="copy")
            ct = reg.counter(
                "repro_mor_tiles_total",
                "predictor tile-grid size, summed over dispatches",
                ("layout", "group", "layer", "expert"))
            cs = reg.counter(
                "repro_mor_tiles_skipped_total",
                "tiles the predictor skipped, summed over dispatches",
                ("layout", "group", "layer", "expert"))
            gl = reg.gauge(
                "repro_mor_frac_tiles_live",
                "mean live-tile fraction (device fixed-point)",
                ("layout", "group", "layer", "expert"))
            for g, d in dm["groups"].items():
                for idx in np.ndindex(d["tiles_total"].shape):
                    lab = {"layout": lay, "group": g, "layer": idx[0],
                           "expert": idx[1] if len(idx) > 1 else ""}
                    ct.set(int(d["tiles_total"][idx]), **lab)
                    cs.set(int(d["tiles_skipped"][idx]), **lab)
                    gl.set(float(d["mean_frac_tiles_live"][idx]), **lab)
            if self._shadow_every is not None:
                # predictor-quality mirrors + drift detection over the
                # freshly drained shadow-oracle counters (obs.quality)
                reg.counter(
                    "repro_engine_shadow_dispatches_total",
                    "dispatches scored by the shadow-oracle twin",
                    ("layout",)).set(dm["shadow_dispatches"], layout=lay)
                cfs = reg.counter(
                    "repro_mor_false_skip_total",
                    "tiles the predictor skipped that the dense oracle "
                    "says were live (shadow-sampled)",
                    ("layout", "group", "layer", "expert"))
                cfk = reg.counter(
                    "repro_mor_false_keep_total",
                    "tiles the predictor kept that the dense oracle "
                    "says were dead (shadow-sampled)",
                    ("layout", "group", "layer", "expert"))
                gfs = reg.gauge(
                    "repro_mor_false_skip_rate",
                    "false skips over truly-live tiles, last flush "
                    "window (drift-detector input)",
                    ("layout", "group", "layer", "expert"))
                gsa = reg.gauge(
                    "repro_mor_shadow_sign_agree",
                    "mean predictor/oracle sign-agreement rate per "
                    "shadow dispatch",
                    ("layout", "group", "layer", "expert"))
                gse = reg.gauge(
                    "repro_mor_shadow_err",
                    "mean relative output-error norm of the MoR-masked "
                    "activation vs dense, per shadow dispatch",
                    ("layout", "group", "layer", "expert"))
                gdr = reg.gauge(
                    "repro_mor_drift",
                    "1 while the drift detector flags this series",
                    ("layout", "group", "layer", "expert"))
                for ev in self.drift.update(dm):
                    if self._tr is not None:
                        self._tr.on_drift(ev["group"], ev["layer"],
                                          ev["expert"], ev["rate"])
                dst = self.drift.state()
                for g, d in dm["groups"].items():
                    drifted = dst.get(g, {}).get("drifted")
                    for idx in np.ndindex(d["false_skip"].shape):
                        lab = {"layout": lay, "group": g,
                               "layer": idx[0],
                               "expert": idx[1] if len(idx) > 1 else ""}
                        cfs.set(int(d["false_skip"][idx]), **lab)
                        cfk.set(int(d["false_keep"][idx]), **lab)
                        gfs.set(float(d["false_skip_rate"][idx]), **lab)
                        gsa.set(float(d["mean_sign_agree"][idx]), **lab)
                        gse.set(float(d["mean_shadow_err"][idx]), **lab)
                        gdr.set(1.0 if drifted is not None
                                and bool(drifted[idx]) else 0.0, **lab)
        csd = reg.counter("repro_scheduler_dispatches_total",
                          "dispatches built, by kind",
                          ("layout", "kind"))
        for kind, v in self.scheduler.dispatch_kinds.items():
            csd.set(v, layout=lay, kind=kind)
        crj = reg.counter("repro_requests_rejected_total",
                          "requests rejected at submit validation",
                          ("layout", "reason"))
        for reason, v in self.rejections.items():
            crj.set(v, layout=lay, reason=reason)
        if self.pool is not None:
            cpre = reg.counter(
                "repro_preemptions_total",
                "slot preemptions: page spills to host and restores",
                ("layout", "event"))
            for k, v in self.pool.spill_events.items():
                cpre.set(v, layout=lay, event=k)
        if self.pool is not None:
            cal = reg.counter(
                "repro_pool_alloc_events_total",
                "host allocator page alloc/free events",
                ("layout", "table", "event"))
            for k, v in self.pool.alloc_events().items():
                table, event = k.split("_")
                cal.set(v, layout=lay, table=table, event=event)
            sh = self.pool.shard_report()
            giu = reg.gauge("repro_pool_pages_in_use",
                            "pages currently allocated, per shard",
                            ("layout", "table", "shard"))
            ghw = reg.gauge("repro_pool_pages_hiwater",
                            "page-occupancy high-water mark, per shard",
                            ("layout", "table", "shard"))
            for table in ("kv", "state"):
                key = f"{table}_pages_in_use_per_shard"
                if key not in sh:
                    continue
                for s, v in enumerate(sh[key]):
                    giu.set(v, layout=lay, table=table, shard=s)
                for s, v in enumerate(
                        sh[f"{table}_pages_hiwater_per_shard"]):
                    ghw.set(v, layout=lay, table=table, shard=s)
            if self.pool.prefix is not None:
                pc = self._prefix_counters()
                cpr = reg.counter("repro_prefix_events_total",
                                  "prefix-cache event counters",
                                  ("layout", "event"))
                for k, v in pc.items():
                    if k == "hit_rate":
                        continue
                    cpr.set(v, layout=lay, event=k)
                reg.gauge("repro_prefix_hit_rate",
                          "prefix-cache hit rate since last reset",
                          ("layout",)).set(pc["hit_rate"], layout=lay)
                gtr = reg.gauge("repro_prefix_trie",
                                "prefix-trie occupancy",
                                ("layout", "stat"))
                for k, v in self.pool.prefix.stats().items():
                    gtr.set(v, layout=lay, stat=k)
        from repro.kernels import paged_attention as pk
        ckt = reg.counter("repro_kernel_traces_total",
                          "paged-attention kernel traces (innermost "
                          "scope)", ("kind",))
        for kind, v in pk.kernel_traces().items():
            ckt.set(v, kind=kind)
        if self.telemetry is not None:
            export_telemetry(reg, self.telemetry, layout=lay,
                             capacities=self.capacities)

    def _probe_stat_shapes(self) -> Dict[str, tuple]:
        """Shapes of the step's per-layer MoR stat leaves, via
        ``jax.eval_shape`` on the UNJITTED step body (abstract cache —
        nothing compiles, nothing runs).  Fixes the device metrics
        block's layout before the first dispatch."""
        sds = lambda a: jax.ShapeDtypeStruct(jnp.shape(a),
                                             jnp.result_type(a))
        cache_abs = jax.tree_util.tree_map(sds, self.cache)
        body = partial(self._step_impl, self.cfg, self.api, self.mor_mode,
                       self.temperature, self.top_k, None)
        out = jax.eval_shape(
            body, self.params, self.mor, cache_abs,
            jax.ShapeDtypeStruct((self.n_slots, self.chunk), jnp.int32),
            jax.ShapeDtypeStruct((self.n_slots,), jnp.int32),
            jax.ShapeDtypeStruct((self.n_slots,), jnp.bool_),
            jax.ShapeDtypeStruct((self.n_slots,), jnp.int32),
            self._base_key, None)
        aux = out[3]
        return {k: tuple(aux[k]["frac_tiles_live"].shape)
                for k in STAT_KEYS if aux.get(k)}

    # -- plan attachment ---------------------------------------------------
    def _attach(self, capacities: Optional[Dict]):
        if self.raw_mor is None:
            return None
        from repro.core.deploy import attach_plans
        caps = None
        if capacities is not None:
            gmap = mor_group_map(self.cfg)
            caps = {gmap.get(k, k): v for k, v in capacities.items()}
        return attach_plans(self.raw_mor, self.cfg, self.mor_mode,
                            capacities=caps)

    @staticmethod
    def _step_impl(cfg, api, mor_mode, temperature, top_k, mspec,
                   params, mor, cache, tokens, n_valid, use_pending,
                   pending, key, ops, metrics=None, n_active=None,
                   copy_pads=(0, 0)):
        # obs page-edit counts mirror the ops walk against the pre-edit
        # cache (same static slices apply_cache_ops uses) — entirely on
        # device, rides in the metrics block
        mcounts = {}
        if metrics is not None and ops is not None:
            mcounts = kv_pool.ops_counts(cache, ops, *copy_pads)
        # paged layout: fuse the pool's pending page edits (resets, COW
        # copies, table uploads — one packed int32 vector) into THIS
        # compiled step; clean steps pass ops=None and jit caches a
        # second executable without the apply at all, so the steady
        # decode loop pays nothing for the allocator
        if ops is not None:
            cache = kv_pool.apply_cache_ops(cache, ops, *copy_pads)
        # active-block-width: slice the (post-ops) block table down to
        # the width this dispatch needs (``PagedPool.active_blocks``) —
        # the attends then never touch the provably-null tail columns.
        # The table itself is only ever edited host-side (via ops), so
        # the full table is restored verbatim in the returned cache.
        full_bt = None
        if n_active is not None and "block_table" in cache and \
                n_active < cache["block_table"].shape[1]:
            full_bt = cache["block_table"]
            cache = dict(cache, block_table=full_bt[:, :n_active])
        # pages this dispatch's attends can touch: live entries in the
        # active slots' (sliced) block tables
        bt_active = cache.get("block_table")
        # splice each decoding slot's device-resident last token into
        # column 0 (inside jit: no extra op dispatches on the hot loop)
        tokens = tokens.at[:, 0].set(
            jnp.where(use_pending, pending, tokens[:, 0]))
        # attached plans carry their own mode; mor_mode covers bare layers
        logits, cache, aux = api.prefill_chunk(
            params, cfg, tokens, cache, n_valid=n_valid, mor=mor,
            mor_mode=mor_mode)
        if full_bt is not None:
            cache = dict(cache, block_table=full_bt)
        last = jnp.clip(n_valid - 1, 0)
        lg = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
        # shared sampling head (serving.spec) — the speculative verify
        # uses the same function per position, which is what makes
        # greedy speculation token-identical to this vanilla step
        nxt, _ = sample_step(lg, temperature=temperature, top_k=top_k,
                             key=key)
        new_pending = jnp.where(n_valid > 0, nxt, pending)
        if metrics is not None:
            # all operands already live on device — the block update
            # fuses into this executable, no extra dispatch or sync
            dec = jnp.where(use_pending, n_valid, 0).sum(dtype=jnp.int32)
            scalars = dict(mcounts, dispatches=1,
                           decode_tokens=dec,
                           prefill_tokens=n_valid.sum(
                               dtype=jnp.int32) - dec)
            # an in-step scored dispatch (mode="scored" plans) carries
            # shadow_* quality leaves in its aux; it IS the primary
            # dispatch, so base lanes count once and the quality lanes
            # ride the same delta
            if any(isinstance(st, dict) and "shadow_false_skip" in st
                   for st in aux.values()):
                scalars["shadow_dispatches"] = 1
            if bt_active is not None:
                scalars["pages_touched"] = (
                    (bt_active > 0) & (n_valid > 0)[:, None]).sum(
                        dtype=jnp.int32)
            metrics = mspec.accumulate(metrics, scalars, aux)
        return nxt, new_pending, cache, aux, metrics

    @staticmethod
    def _shadow_impl(cfg, api, mor_mode, mspec, params, mor, cache,
                     tokens, n_valid, use_pending, pending, ops, metrics,
                     n_active=None, copy_pads=(0, 0)):
        """The dense-oracle twin of ``_step_impl``: reconstruct exactly
        the cache state the primary dispatch will see (the same pending
        page edits, the same active-block slice, the same pending-token
        splice), run the forward through mode="shadow" plans — dense
        math, with the predictor SCORED against the dense truth — and
        fold the shadow_* stat leaves into the metrics block.  Nothing
        else escapes: the cache copy is discarded (NOT donated — the
        primary step consumes the real one right after) and no tokens
        are sampled, so the primary path stays authoritative."""
        if ops is not None:
            cache = kv_pool.apply_cache_ops(cache, ops, *copy_pads)
        if n_active is not None and "block_table" in cache and \
                n_active < cache["block_table"].shape[1]:
            cache = dict(cache, block_table=cache["block_table"][:, :n_active])
        tokens = tokens.at[:, 0].set(
            jnp.where(use_pending, pending, tokens[:, 0]))
        _, _, aux = api.prefill_chunk(params, cfg, tokens, cache,
                                      n_valid=n_valid, mor=mor,
                                      mor_mode=mor_mode)
        # keep ONLY the shadow_* quality leaves: the primary dispatch
        # already accumulated this batch's base tile lanes, and the
        # quality lanes are what the shadow pass exists to fill
        qaux = {}
        for g, st in (aux or {}).items():
            if isinstance(st, dict):
                sh = {k: v for k, v in st.items()
                      if k.startswith("shadow_")}
                if sh:
                    qaux[g] = sh
        return mspec.accumulate(metrics, {"shadow_dispatches": 1}, qaux)

    # -- request API -------------------------------------------------------
    def _reject(self, reason: str, msg: str) -> None:
        self.counters["requests_rejected"] += 1
        self.rejections[reason] = self.rejections.get(reason, 0) + 1
        raise RequestRejected(reason, msg)

    def submit(self, prompt, max_new_tokens: int = 16,
               on_token: Optional[Callable[[int, int], None]] = None,
               priority: int = 0) -> int:
        """Queue a request; returns its rid.  ``on_token(rid, token)``
        is the detokenizing-stream hook: invoked for each generated
        token IN ORDER when the engine flushes its device-resident token
        log (end of ``run`` by default, every ``stream_interval``
        dispatches when opted in) — streaming adds no device syncs.
        ``priority`` feeds the scheduling policy (higher admits first;
        under ``PriorityPolicy`` it may preempt lower classes).

        Unservable requests raise ``RequestRejected`` (and count into
        ``requests_rejected``) BEFORE touching the queue — arrival-
        driven load records the rejection and keeps serving, where the
        old bare ``assert`` vanished under ``python -O`` and took the
        whole engine down."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            self._reject("empty_prompt", "prompt must have >= 1 token")
        if max_new_tokens < 1:
            # max_new_tokens=0 used to slip through and STILL emit one
            # token (prompt completion always samples) — reject upfront
            self._reject("nonpositive_max_new_tokens",
                         f"max_new_tokens={max_new_tokens} must be >= 1")
        if prompt.size + max_new_tokens + 1 > self.max_len:
            self._reject("oversize",
                         f"prompt {prompt.size} + max_new "
                         f"{max_new_tokens} exceeds max_len "
                         f"{self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        if on_token is not None:
            self._stream_cbs[rid] = on_token
        if self._tr is not None:
            self._tr.on_submit(rid)
        self.scheduler.add(Request(rid, prompt, max_new_tokens,
                                   priority=priority))
        return rid

    # -- preemption --------------------------------------------------------
    def _preempt(self, slot: int) -> None:
        """Spill ``slot``'s pages to host and requeue its request at its
        exact progress.  The slot's device-resident pending token (its
        last sample — about to be consumed when decode resumes) rides in
        the spill record; ``_place`` splices it back on restore."""
        req = self.scheduler.slots[slot].req
        self.cache, rec = self.pool.spill(slot, self.cache)
        rec.rid = req.rid
        rec.last_token = int(jax.device_get(self._pending[slot]))
        self._spilled[req.rid] = rec
        self.scheduler.preempt(slot)
        self.counters["preemptions"] += 1
        if self._tr is not None:
            self._tr.on_preempt(req.rid, slot)

    def _place(self, slot: int, entry) -> Optional[int]:
        """Scheduler admission callback: attach ``entry`` to ``slot``
        through the paged pool — prefix-cache admission for fresh
        requests, spill-record restore for preempted resumes.  Returns
        the prompt offset to start from, or None to DEFER the admission
        (pool exhausted — the engine may spill a victim and retry)."""
        if entry.resume:
            rec = self._spilled[entry.req.rid]
            try:
                self.cache = self.pool.restore(slot, rec, self.cache)
            except kv_pool.PoolExhausted:
                return None
            del self._spilled[entry.req.rid]
            self._pending = self._pending.at[slot].set(rec.last_token)
            if self._tr is not None:
                self._tr.on_restore(entry.req.rid, slot)
            return entry.offset
        try:
            return self.pool.admit(slot, entry.req.prompt)
        except kv_pool.PoolExhausted:
            return None

    def step(self) -> List[int]:
        """One scheduler iteration: admit (preempting victims when the
        policy or pool pressure demands it), dispatch, ingest.  Returns
        the rids that finished this step."""
        t0 = time.perf_counter()
        sched = self.scheduler
        # policy-driven preemption: when no slot is free, the policy may
        # evict a running victim so the top waiting request (after its
        # ordering) gets served now — slots are the scarce resource
        if self._can_preempt and sched.waiting and \
                not any(s.state is FREE for s in sched.slots):
            self.policy.order(sched.waiting)
            victim = self.policy.select_victim(sched.slots,
                                               sched.waiting[0])
            if victim is not None:
                self._preempt(victim)
        place = self._place if self.pool is not None else None
        admitted = sched.admit(place)
        if self.pool is not None:
            # admission deferred on pool pressure: spill victims (their
            # exclusive pages move to host) and retry — bounded, never
            # touching slots admitted THIS step
            for _ in range(self.n_slots):
                if not sched.deferred or not self._can_preempt:
                    break
                victim = self.policy.spill_victim(sched.slots,
                                                  exclude=admitted)
                if victim is None:
                    break
                self._preempt(victim)
                admitted += sched.admit(place)
        if admitted and self.pool is None:
            mask = np.zeros((self.n_slots,), bool)
            mask[admitted] = True
            self.cache = self._reset(self.cache, jnp.asarray(mask))
        kind = sched.peek_kind()
        if kind is None:
            if sched.waiting:
                # nothing runs AND nothing can be admitted: without a
                # victim to spill this can never make progress
                raise kv_pool.PoolExhausted(
                    "no waiting request can be admitted and nothing is "
                    "running — pool exhausted with no preemption victim")
            return []
        # decode-only dispatches upgrade to a speculative round (draft
        # k tokens cheap, verify them in one target pass) — atomic
        # inside this step, so preemption above always sees committed
        # state.  ready() backs off one step after a pool-pressure
        # abort so the vanilla path's spill machinery can run.
        if self.spec is not None and kind == "decode" and \
                self.spec.ready():
            return self.spec.round(t0, admitted)
        tokens, n_valid, use_pending, emits, finishing, prefilling = \
            sched.build_batch(kind)
        ops = None
        if self.pool is not None:
            # pre-dispatch: snapshot recurrent state of slots whose
            # prompt finishes here (the state at ``offset`` is what the
            # previous dispatches left in the pool), publish the prefix
            # of windowed prompts about to wrap their ring (their pages
            # are still intact NOW — after this dispatch they aren't),
            # then allocate / copy-on-write every page this dispatch
            # will touch; the resulting device edits ride into the
            # fused step as ``ops``.  Pool exhaustion mid-plan spills a
            # victim and REBUILDS the batch (the victim may have been in
            # it); the hooks are idempotent and ``plan_writes`` resumes
            # past blocks already made exclusive, so retrying is safe.
            for _ in range(self.n_slots + 1):
                for s, off in finishing:
                    self.pool.maybe_snapshot(
                        s, sched.slots[s].req.prompt, off)
                for s, off, take in prefilling:
                    self.pool.maybe_publish_prewrap(
                        s, sched.slots[s].req.prompt, off, take)
                try:
                    self.pool.plan_writes(n_valid)
                    break
                except kv_pool.PoolExhausted:
                    victim = (self.policy.spill_victim(sched.slots,
                                                       exclude=admitted)
                              if self._can_preempt else None)
                    if victim is None:
                        raise
                    self._preempt(victim)
                    kind = sched.peek_kind()
                    if kind is None:        # spilled the whole batch
                        return []
                    (tokens, n_valid, use_pending, emits, finishing,
                     prefilling) = sched.build_batch(kind)
            else:
                raise kv_pool.PoolExhausted(
                    "dispatch cannot fit even after spilling victims")
            self.cache, ops = self.pool.drain(self.cache)
        sched.dispatch_kinds[kind] += 1
        # decode riders in a mixed dispatch: counted at BUILD time (feed()
        # below flips prefill->decode / frees finished slots)
        ndec = int(use_pending.sum()) if kind == "mixed" else 0
        key = jax.random.fold_in(self._base_key, self.counters["dispatches"]) \
            if self.temperature > 0.0 else self._base_key
        n_active = (self.pool.active_blocks(n_valid)
                    if self.pool is not None else None)
        copy_pads = (self.pool.last_pads
                     if self.pool is not None and ops is not None else (0, 0))
        # tracer span bookkeeping (rid lookups must happen BEFORE feed()
        # below frees finished slots); None when tracing is off
        if self._tr is not None:
            slots = self.scheduler.slots
            tr_t0 = self._tr.now()
            tr_admitted = [(s, slots[s].req.rid) for s in admitted]
            tr_prefilling = [(s, slots[s].req.rid, off, take)
                             for s, off, take in prefilling]
            ann = self._tr.annotation(kind)
        else:
            ann = contextlib.nullcontext()
        # shadow-oracle sampling: every Nth vanilla dispatch is scored.
        # Tiled plans swap the scored twin INTO the primary dispatch
        # (bitwise-identical activations, no extra forward); kernel /
        # exact plans run the standalone dense twin first — BEFORE the
        # primary step, which donates the cache and metrics block the
        # twin reads — and keep the primary's tokens authoritative.
        sampled = (self._shadow_every is not None and
                   self.counters["dispatches"] % self._shadow_every == 0)
        if sampled and self._shadow_step is not None:
            self._mblock = self._shadow_step(
                self.params, self._shadow_mor, self.cache,
                jnp.asarray(tokens), jnp.asarray(n_valid),
                jnp.asarray(use_pending), self._pending, ops,
                self._mblock, n_active, copy_pads)
        mor_step = (self._shadow_mor
                    if sampled and self._shadow_step is None else self.mor)
        with ann:
            nxt, self._pending, self.cache, aux, self._mblock = self._step(
                self.params, mor_step, self.cache, jnp.asarray(tokens),
                jnp.asarray(n_valid), jnp.asarray(use_pending),
                self._pending, key, ops, self._mblock, n_active, copy_pads)
        if self.pool is not None:
            self.pool.advance(n_valid)
        if emits:
            self._tok_log.append((emits, nxt))
        if self.telemetry is not None and aux:
            # buffer the (device) stat arrays; host conversion happens
            # lazily in _flush_telemetry so the dispatch loop never syncs
            # on telemetry
            self._aux_log.append(aux)
        finished, entering = self.scheduler.feed(n_valid)
        for _, req in finished:
            if req.rid in self._stream_cbs:
                self._stream_done.add(req.rid)
        if self.pool is not None:
            # publish AFTER the dispatch that wrote the prompt's last
            # pages; release AFTER publish so a request finishing in the
            # same step still shares its pages
            for s, req in entering:
                self.pool.publish(s, req.prompt)
            for s, _ in finished:
                self.pool.release(s)
        self.counters["dispatches"] += 1
        nv_total = int(n_valid.sum())
        if kind == "decode":
            self.counters["decode_tokens"] += nv_total
        else:
            # decode slots riding in a mixed dispatch contribute 1 each
            self.counters["decode_tokens"] += ndec
            self.counters["prefill_tokens"] += nv_total - ndec
        self.counters["wall_s"] += time.perf_counter() - t0
        if self._tr is not None:
            self._tr.on_dispatch(
                kind, tr_t0, self._tr.now(), admitted=tr_admitted,
                prefilling=tr_prefilling, emits=emits,
                finished=[req.rid for _, req in finished],
                queue_depth=len(self.scheduler.waiting),
                n_active=int(np.count_nonzero(n_valid)))
        return [req.rid for _, req in finished]

    def reset_counters(self) -> None:
        """Zero the throughput AND prefix-cache counters (e.g. between a
        compile-warmup pass and a timed pass) — so a report's hit rate /
        skipped chunks describe the same pass as its token counts.  The
        cache CONTENTS survive: only the accounting resets.  With
        observability on, the device metrics block and the tracer reset
        with them (registry mirrors follow at the next flush)."""
        self.counters = {"prefill_tokens": 0, "decode_tokens": 0,
                         "dispatches": 0, "wall_s": 0.0,
                         "preemptions": 0, "requests_rejected": 0}
        self.rejections = {}
        self.scheduler.chunks_skipped = 0
        self.scheduler.tokens_skipped = 0
        self.scheduler.dispatch_kinds = {"mixed": 0, "decode": 0,
                                         "draft": 0, "verify": 0,
                                         "replay": 0}
        if self.spec is not None:
            self.spec.reset()
        if self.pool is not None:
            self.pool.reset_event_counters()
        if self._mblock is not None:
            n_rows = self.pool.n_shards if self.pool is not None else 1
            self._mblock = self._mspec.init(n_rows)
        if self.drift is not None:
            # the cumulative source counters just zeroed; detector
            # state (EWMA / PH accumulators, raised flags) survives
            self.drift.rebase()
        if self._tr is not None:
            self._tr.reset()

    def drain(self) -> None:
        """Flush boundary without draining the queue: deliver the token
        log to host (+ stream callbacks) and push telemetry/obs mirrors.
        Open-loop drivers stepping the engine themselves call this once
        the arrival stream ends (``run`` does it implicitly)."""
        self._flush_tokens()
        self._flush_telemetry()
        self._flush_obs()

    def run(self, requests=None,
            stream_interval: int = 0) -> Dict[int, List[int]]:
        """Drive the queue (plus optional (prompt, max_new) pairs) to
        completion; returns {rid: generated tokens} for the requests
        submitted via THIS call (all-time results stay in
        ``self.results``).  ``stream_interval`` > 0 flushes the token
        log (firing ``on_token`` stream callbacks) every that many
        dispatches instead of only at the end — the opt-in trade of
        periodic device syncs for incremental delivery."""
        first_rid = self._next_rid
        if requests:
            for prompt, max_new in requests:
                self.submit(prompt, max_new)
        while self.scheduler.has_work:
            self.step()
            if stream_interval > 0 and \
                    self.counters["dispatches"] % stream_interval == 0:
                self._flush_tokens()
        self._flush_tokens()
        self._flush_telemetry()
        self._flush_obs()
        if requests:
            return {rid: toks for rid, toks in self.results.items()
                    if rid >= first_rid}
        return dict(self.results)

    def stream(self, prompt, max_new_tokens: int = 16,
               interval: int = 1) -> Iterator[int]:
        """Detokenizing-stream iterator for ONE request: submit it NOW
        and return a generator yielding its tokens as they reach the
        host (the token log flushes every ``interval`` dispatches —
        already-host-side values, no extra per-token syncs).  Other
        queued requests keep being served by the same dispatches."""
        got: List[int] = []
        self.submit(prompt, max_new_tokens,
                    on_token=lambda _rid, tok: got.append(tok))

        def gen() -> Iterator[int]:
            served = 0
            while self.scheduler.has_work:
                self.step()
                if self.counters["dispatches"] % max(interval, 1) == 0:
                    self._flush_tokens()
                while served < len(got):
                    yield got[served]
                    served += 1
            self._flush_tokens()
            self._flush_telemetry()
            self._flush_obs()
            while served < len(got):
                yield got[served]
                served += 1

        return gen()

    # -- telemetry-driven capacity calibration -----------------------------
    def calibrate_capacities(self, quantile: float = 0.95,
                             floor: float = 0.05) -> Dict[str, np.ndarray]:
        """Set per-layer gather_matmul capacities from the accumulated
        tile-liveness histograms and re-attach the execution plans.
        Returns the chosen {stat group -> capacity fractions}, (L,) for
        dense stacks and (L, E) for the MoE expert group."""
        assert self.telemetry is not None and self.raw_mor is not None
        self._flush_telemetry()
        caps = calibrate_capacity(self.telemetry, quantile=quantile,
                                  floor=floor)
        self.capacities = caps
        self.mor = self._attach(caps)
        if self._shadow_mor is not None:
            # the shadow twin mirrors the active plans' capacity clip
            from repro.core.executor import map_plans
            self._shadow_mor = map_plans(self.mor, self._shadow_as)
        if self.spec is not None:
            # the draft tree wraps the (re-attached) target plans
            self.spec.refresh()
        return caps

    def update_mor(self, raw_mor: Dict) -> None:
        """Swap the calibrated MoR tree in place — the online-recalib
        hook (ROADMAP item 4) and the benchmark's drift-injection knob.
        Coefficients are traced leaves of the attached plans, so the
        compiled step does NOT recompile; the shadow twin and the
        speculative draft tree re-wrap the fresh plans."""
        assert self.raw_mor is not None, \
            "engine was built without a MoR tree"
        self.raw_mor = raw_mor
        self.mor = self._attach(self.capacities)
        if self._shadow_mor is not None:
            from repro.core.executor import map_plans
            self._shadow_mor = map_plans(self.mor, self._shadow_as)
        if self.spec is not None:
            self.spec.refresh()

    def _prefix_counters(self) -> Dict:
        """Prefix-cache counters merged across the pool (pages, hits)
        and the scheduler (chunks whose dispatch was skipped)."""
        pc = self.pool.report()
        return {
            "hit_rate": pc.get("hit_rate", 0.0),
            "prefix_queries": pc.get("prefix_queries", 0),
            "prefix_hits": pc.get("prefix_hits", 0),
            "tokens_reused": pc.get("tokens_reused", 0),
            "pages_shared": pc.get("pages_shared", 0),
            "pages_published": pc.get("pages_published", 0),
            "pages_cowed": pc.get("pages_cowed", 0),
            "pages_evicted": pc.get("pages_evicted", 0),
            "snapshots": pc.get("snapshots", 0),
            "snap_restores": pc.get("snap_restores", 0),
            "chunks_skipped": self.scheduler.chunks_skipped,
            "tokens_skipped": self.scheduler.tokens_skipped,
        }

    def report(self) -> Dict:
        self._flush_tokens()
        c = dict(self.counters)
        # counters["wall_s"] is HOST dispatch time (the device-resident
        # loop never blocks per step) — an upper bound on throughput.
        # serve._run_engine overrides the rates with a blocking
        # end-to-end wall clock; prefer those for published numbers.
        wall = max(c["wall_s"], 1e-9)
        rep = {
            "n_slots": self.n_slots, "chunk": self.chunk,
            "mor_mode": self.mor_mode, "layout": self.layout,
            "requests_finished": len(self.results),
            "tokens_per_s": (c["decode_tokens"] + c["prefill_tokens"]) / wall,
            "decode_tokens_per_s": c["decode_tokens"] / wall,
            **c,
        }
        if self.temperature > 0.0:
            rep["sampling"] = {"temperature": self.temperature,
                               "top_k": self.top_k}
        if self.spec is not None:
            rep["spec"] = self.spec.report()
        if self.pool is not None:
            rep["page"] = self.pool.page
            if self.pool.n_shards > 1:
                rep["sharding"] = self.pool.shard_report()
            if self.pool.prefix is not None:
                rep["prefix_cache"] = self._prefix_counters()
        if self.telemetry is not None:
            self._flush_telemetry()
            rep["telemetry"] = self.telemetry.summary()
        if self.capacities is not None:
            rep["per_layer_capacity"] = {
                k: np.asarray(v).tolist() for k, v in self.capacities.items()}
        if self.obs is not None:
            self._flush_obs()
            obs_rep: Dict = {}
            if self._mspec is not None and self._mblock is not None:
                obs_rep["device_metrics"] = self._mspec.read_json(
                    self._mblock)
            if self._tr is not None:
                obs_rep["tracing"] = self._tr.summary()
            rep["obs"] = obs_rep
        if self._shadow_every is not None:
            q: Dict = {"shadow_rate": self.shadow_rate,
                       "shadow_every": self._shadow_every}
            dm = self._last_device_metrics
            if dm is not None:
                q["shadow_dispatches"] = dm["shadow_dispatches"]
                q["groups"] = {
                    g: {"shadow_tiles": int(d["shadow_tiles"].sum()),
                        "false_skip": int(d["false_skip"].sum()),
                        "false_keep": int(d["false_keep"].sum()),
                        "truth_live": int(d["truth_live"].sum()),
                        "false_skip_rate": np.round(
                            d["false_skip_rate"], 6).tolist(),
                        "false_keep_rate": np.round(
                            d["false_keep_rate"], 6).tolist(),
                        "mean_sign_agree": np.round(
                            d["mean_sign_agree"], 6).tolist(),
                        "mean_shadow_err": np.round(
                            d["mean_shadow_err"], 6).tolist()}
                    for g, d in dm["groups"].items()}
            if self.drift is not None:
                q["drift"] = self.drift.summary()
            rep["quality"] = q
        return rep

"""Admission / preemption policies for the serving scheduler.

The scheduler stays count-based (it never sees token values) — a
``Policy`` only reorders the waiting queue, caps how many prefill
tokens a mixed dispatch may consume, and picks preemption victims.
Everything it reads (priorities, offsets, generated counts) is host
bookkeeping, so policies plug in without touching the compiled step.

Three built-ins:

  * ``FCFSPolicy`` — arrival order, never preempts (the PR 2 baseline
    behaviour, now explicit).
  * ``PriorityPolicy`` — higher ``Request.priority`` admits first, and
    a waiting request may PREEMPT a strictly-lower-priority running
    slot (the engine spills the victim's pages to host and requeues it
    at its exact progress — no tokens lost).
  * ``ShortestPrefillPolicy`` — shortest-remaining-prefill first (SJF
    on the work the slot pool actually serializes); preempted resumes
    (zero remaining prefill) naturally sort to the front.

All three share the decode-vs-prefill knob: ``prefill_budget`` > 0
caps the prompt tokens one MIXED dispatch may consume, so decode
riders keep their inter-token latency while long prompts stream
through in sub-chunk slices (0 = unlimited).

All three also share ``spill_victim`` — the pool-pressure fallback the
engine consults when a dispatch or admission exhausts the paged pool:
lowest priority first, then the most remaining work (it blocks a slot
longest), then the latest arrival.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

__all__ = ["Policy", "FCFSPolicy", "PriorityPolicy",
           "ShortestPrefillPolicy", "get_policy"]


def _remaining(slot_or_entry) -> int:
    """Tokens of work left: unconsumed prompt + ungenerated tokens."""
    req = slot_or_entry.req
    return (max(0, len(req.prompt) - slot_or_entry.offset)
            + max(0, req.max_new_tokens - slot_or_entry.n_generated))


class Policy:
    """Base policy: FCFS ordering, no priority preemption, shared
    pool-pressure victim selection.  Subclass hooks:

      ``order(waiting)``          — stable in-place sort of the waiting
                                    queue (entries carry .req/.offset/
                                    .n_generated/.seq).
      ``select_victim(slots, e)`` — running slot to preempt so waiting
                                    entry ``e`` can be admitted, or
                                    None (no voluntary preemption).
      ``spill_victim(slots, exclude)`` — running slot to spill when the
                                    paged pool is exhausted, or None.
    """

    name = "fcfs"

    def __init__(self, prefill_budget: int = 0):
        assert prefill_budget >= 0
        self.prefill_budget = int(prefill_budget)

    def order(self, waiting: List) -> None:
        pass                                 # arrival order (stable)

    def select_victim(self, slots: Sequence, entry) -> Optional[int]:
        return None

    def spill_victim(self, slots: Sequence,
                     exclude: Sequence[int] = ()) -> Optional[int]:
        cand = [s for s, sl in enumerate(slots)
                if sl.req is not None and s not in set(exclude)]
        if not cand:
            return None
        # lowest priority, then most remaining work, then latest seq
        return max(cand, key=lambda s: (-slots[s].req.priority,
                                        _remaining(slots[s]),
                                        slots[s].seq))


class FCFSPolicy(Policy):
    name = "fcfs"


class PriorityPolicy(Policy):
    """Strict priority classes: the waiting queue sorts by descending
    ``Request.priority`` (arrival order within a class), and a waiting
    request preempts the lowest-priority running slot whose priority is
    STRICTLY below its own — equal priorities never preempt each other,
    so there is no ping-pong."""

    name = "priority"

    def order(self, waiting: List) -> None:
        waiting.sort(key=lambda e: (-e.req.priority, e.seq))

    def select_victim(self, slots: Sequence, entry) -> Optional[int]:
        cand = [s for s, sl in enumerate(slots)
                if sl.req is not None
                and sl.req.priority < entry.req.priority]
        if not cand:
            return None
        return max(cand, key=lambda s: (-slots[s].req.priority,
                                        _remaining(slots[s]),
                                        slots[s].seq))


class ShortestPrefillPolicy(Policy):
    """Shortest-remaining-prefill first.  Preempted resumes have zero
    remaining prefill and sort to the front — a spilled request gets
    its slot back before new long prompts cut in."""

    name = "sjf"

    def order(self, waiting: List) -> None:
        waiting.sort(key=lambda e: (max(0, len(e.req.prompt) - e.offset),
                                    e.seq))


_POLICIES = {p.name: p for p in (FCFSPolicy, PriorityPolicy,
                                 ShortestPrefillPolicy)}


def get_policy(name: str, prefill_budget: int = 0) -> Policy:
    assert name in _POLICIES, \
        f"unknown policy {name!r} (have {sorted(_POLICIES)})"
    return _POLICIES[name](prefill_budget=prefill_budget)

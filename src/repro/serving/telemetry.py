"""Serving telemetry: per-layer tile-liveness histograms + predictor
hit/miss counters, and the liveness-quantile capacity calibration.

Every engine dispatch returns ``aux`` whose ``*mor_stats`` entries are
per-layer (L-stacked) realised skip statistics from the ONE predictor
pass each layer ran (``core.executor``).  The telemetry bins each
layer's live-tile fraction into a fixed histogram; ``calibrate_capacity``
then reads a quantile of that distribution per layer — the observed
demand — and provisions each layer's ``gather_matmul`` capacity from it
instead of the static global ``cfg.mor.capacity`` (ROADMAP open item).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig

# aux keys that carry per-layer MoR stats, in the order groups appear.
# "moe_mor_stats" is (L, E)-shaped — per-(layer, expert) realised skip
# fractions from the batched-expert plans; the histograms flatten it to
# L*E rows and ``calibrate_capacity`` hands the budgets back in the
# original shape.
STAT_KEYS = ("mor_stats", "dense_mor_stats", "moe_mor_stats")


def mor_group_map(cfg: ModelConfig) -> Dict[str, str]:
    """aux stat-group key -> mor-pytree layer-group key."""
    if cfg.family == "hybrid":
        return {"mor_stats": "shared"}
    if cfg.family == "moe":
        return {"dense_mor_stats": "dense_layers",
                "moe_mor_stats": "moe_layers"}
    return {"mor_stats": "layers"}


class ServingTelemetry:
    """Host-side accumulator over chunk-step aux dicts.

    Per stat group (usually one): a (L, n_bins) histogram of the
    live-tile fraction per dispatch, running means of the computed /
    live / mispredicted fractions, and dispatch counters."""

    def __init__(self, n_bins: int = 32):
        self.n_bins = n_bins
        self.hist: Dict[str, np.ndarray] = {}
        self.sums: Dict[str, Dict[str, np.ndarray]] = {}
        # original per-dispatch stat shape per group ((L,) for dense
        # stacks, (L, E) for expert stats) — quantiles/capacities are
        # computed on the flattened rows and reported in this shape
        self.shapes: Dict[str, tuple] = {}
        self.n_updates = 0
        # prefix-cache counters (pages shared, chunks skipped, hit rate)
        # pushed by the engine alongside the MoR stats; surfaced in
        # summary() so they land in the serve report next to
        # per_layer_capacity
        self.prefix: Optional[Dict] = None
        # mesh-sharded pool occupancy (per-shard pages in use / high
        # water), pushed by the paged-sharded engine — the serve-sharded
        # smoke asserts every shard carried pages
        self.sharding: Optional[Dict] = None

    def update(self, aux: Dict) -> None:
        seen = False
        for key in STAT_KEYS:
            stats = aux.get(key)
            if not stats:
                continue
            seen = True
            live = np.asarray(stats["frac_tiles_live"], np.float64)
            self.shapes.setdefault(key, live.shape)
            live = live.reshape(-1)
            L = live.shape[0]
            if key not in self.hist:
                self.hist[key] = np.zeros((L, self.n_bins), np.int64)
                self.sums[key] = {
                    "frac_computed": np.zeros(L),
                    "frac_tiles_live": np.zeros(L),
                    "frac_tiles_computed": np.zeros(L),
                    "frac_mispredicted_zero": np.zeros(L),
                }
            bins = np.clip((live * self.n_bins).astype(np.int64), 0,
                           self.n_bins - 1)
            self.hist[key][np.arange(L), bins] += 1
            for name, acc in self.sums[key].items():
                if name in stats:
                    acc += np.asarray(stats[name], np.float64).reshape(-1)
        if seen:
            self.n_updates += 1

    def liveness_quantile(self, q: float) -> Dict[str, np.ndarray]:
        """Per layer, the smallest bin upper edge whose cumulative mass
        reaches ``q`` — the live-tile fraction demanded by a q-fraction
        of observed dispatches."""
        out = {}
        for key, h in self.hist.items():
            cdf = np.cumsum(h, axis=1) / np.maximum(h.sum(1, keepdims=True),
                                                    1)
            idx = np.argmax(cdf >= q, axis=1)
            out[key] = ((idx + 1) / self.n_bins).reshape(
                self.shapes.get(key, idx.shape))
        return out

    def update_prefix(self, counters: Dict) -> None:
        """Record the latest prefix-cache counters (cumulative values —
        the engine recomputes them from the pool at each flush)."""
        self.prefix = dict(counters)

    def update_sharding(self, counters: Dict) -> None:
        """Record the latest per-shard page occupancy (the sharded
        engine recomputes it from its allocators at each flush)."""
        self.sharding = dict(counters)

    def summary(self) -> Dict:
        out: Dict = {"n_dispatches": self.n_updates}
        if self.prefix is not None:
            out["prefix_cache"] = dict(self.prefix)
        if self.sharding is not None:
            out["sharding"] = dict(self.sharding)
        for key, sums in self.sums.items():
            n = max(self.n_updates, 1)
            shape = self.shapes.get(key)
            out[key] = {name: (acc / n).reshape(shape
                                                if shape else acc.shape
                                                ).tolist()
                        for name, acc in sums.items()}
        return out


def export_telemetry(registry, tel: ServingTelemetry, *, layout: str,
                     capacities: Optional[Dict] = None) -> None:
    """Mirror a ``ServingTelemetry`` summary (and optionally the
    calibrated per-layer capacities) into an obs
    ``MetricsRegistry``.

    Every series carries a ``layout`` label and is written with
    idempotent ``set``: re-exporting the same layout overwrites its own
    series.  This is also the capacity double-report fix — the
    pre-registry summary path appended a ``per_layer_capacity`` block
    per engine report, so a process running both a slotted and a paged
    engine surfaced the same group's capacity twice with no way to tell
    the rows apart; keying by ``(layout, group, layer, expert)`` gives
    each engine its own series and makes repeats overwrite instead of
    accumulate."""
    g_frac = registry.gauge(
        "repro_telemetry_frac",
        "mean realised MoR fractions per layer (serving dispatches)",
        ("layout", "group", "stat", "layer", "expert"))
    g_disp = registry.gauge(
        "repro_telemetry_dispatches",
        "dispatches accumulated into the telemetry histograms",
        ("layout",))
    g_disp.set(tel.n_updates, layout=layout)

    def cells(arr, shape):
        a = np.asarray(arr, np.float64).reshape(shape)
        if a.ndim == 0:
            # scalar capacity spec (serve --capacity): one all-layers cell
            yield "", "", float(a)
        elif a.ndim == 1:
            for li in range(a.shape[0]):
                yield li, "", float(a[li])
        else:
            for li in range(a.shape[0]):
                for e in range(a.shape[1]):
                    yield li, e, float(a[li, e])

    n = max(tel.n_updates, 1)
    for key, sums in tel.sums.items():
        shape = tel.shapes.get(key)
        for name, acc in sums.items():
            for li, e, v in cells(acc / n, shape):
                g_frac.set(v, layout=layout, group=key, stat=name,
                           layer=li, expert=e)
    if capacities:
        g_cap = registry.gauge(
            "repro_telemetry_capacity",
            "calibrated per-layer gather_matmul capacity fraction",
            ("layout", "group", "layer", "expert"))
        for key, arr in capacities.items():
            a = np.asarray(arr)
            for li, e, v in cells(a, a.shape):
                g_cap.set(v, layout=layout, group=key, layer=li,
                          expert=e)


def calibrate_capacity(tel: ServingTelemetry, *, quantile: float = 0.95,
                       floor: float = 0.05,
                       headroom: float = 0.0) -> Dict[str, np.ndarray]:
    """Liveness-quantile capacity calibration: per layer (and per expert
    for the MoE group), provision the gather_matmul capacity at the
    ``quantile`` of the observed live-tile fraction (+ optional
    headroom), floored so a layer is never starved.  Returns {mor stat
    group -> capacity fractions in (0, 1], shaped like the group's
    per-dispatch stats ((L,) dense, (L, E) experts)}."""
    assert tel.n_updates > 0, "calibrate_capacity needs serving telemetry"
    caps = {}
    for key, q in tel.liveness_quantile(quantile).items():
        caps[key] = np.clip(q + headroom, floor, 1.0)
    return caps

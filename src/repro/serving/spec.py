"""Self-speculative decoding: MoR-capacitated draft passes verified
through paged-COW block tables.

One set of weights serves both roles.  The DRAFT pass is the same
model under clamped execution plans (``MoRExecutionPlan.as_draft`` +
``attach_draft_caps``: ``draft_cap`` is a traced leaf like
``cap_live``, so sweeping it never recompiles) — the rookie-heavy
cheap configuration proposes up to ``k`` tokens per decoding slot
autoregressively.  The VERIFY pass is one chunked-prefill-shaped
dispatch under the full-capacity target plans scoring all ``k+1``
positions at once; the standard accept/reject rule keeps the longest
target-consistent prefix plus one correction/bonus token, so GREEDY
output is token-identical to vanilla decode by construction and
SEEDED sampling follows the exact rejection-sampling rule (the
emitted marginal equals the target distribution for ANY draft
proposal).

Speculation is a block-table operation, not a cache copy:

- fork: ``PagedPool.spec_fork`` records the committed position and
  block-table row, and backs recurrent state up to a spare page (the
  only content copy; KV needs none).
- draft writes land in COW-forked / freshly-allocated pages exactly
  like any other dispatch (``plan_writes``).
- rollback: truncate the position to the accepted prefix and drop
  pages the round allocated wholly past it.  Stale draft rows beyond
  the committed position carry tags greater than any future query
  position and self-mask on the shared causal check
  (``decode_attention.position_ok``); the committed frontier row is
  overwritten by the next dispatch's write-before-attend.
- recurrent-state families (rwkv / hybrid) restore the backup before
  verify (which recomputes state under target weights) and, on a
  partial accept, once more before ONE batched replay dispatch of the
  accepted tokens — device state always ends at the last verified
  token, which also makes mid-speculation preemption safe: rounds are
  atomic inside ``Engine.step`` and spill reads committed state.

The whole round costs ONE host sync (the per-slot emit counts);
emitted token values stay device-resident in the engine's token log,
and the drafted/accepted counters ride the packed device metrics
block (drained once per flush).
"""
from __future__ import annotations

import contextlib
import time
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import kv_pool

__all__ = ["sample_step", "accept_greedy", "accept_sampled",
           "emit_matrix", "SpecDecoder"]


# -- sampling (shared with the engine's vanilla step) ----------------------

def _scaled_logits(lg, temperature: float, top_k: int):
    """Temperature-scaled, optionally top-k-truncated logits (f32)."""
    lgs = lg.astype(jnp.float32) / temperature
    if top_k > 0:
        k = min(top_k, lgs.shape[-1])
        kth = jax.lax.top_k(lgs, k)[0][..., -1:]
        lgs = jnp.where(lgs < kth, -jnp.inf, lgs)
    return lgs


def sample_step(lg, *, temperature: float, top_k: int, key,
                with_probs: bool = False):
    """One sampling step over logits ``lg`` (..., V): greedy argmax at
    ``temperature == 0`` (``key`` unused), else seeded categorical over
    the temperature/top-k distribution.  Returns ``(tokens, probs)``
    where ``probs`` is the post-truncation categorical distribution
    (..., V) f32 the tokens were drawn from — the speculative rejection
    rule consumes it — or None when greedy / not requested (a pytree
    None output costs nothing)."""
    if temperature > 0.0:
        lgs = _scaled_logits(lg, temperature, top_k)
        toks = jax.random.categorical(key, lgs, axis=-1).astype(jnp.int32)
        return toks, (jax.nn.softmax(lgs, axis=-1) if with_probs else None)
    return jnp.argmax(lg, axis=-1).astype(jnp.int32), None


# -- acceptance rules (pure; unit-tested directly) -------------------------

def accept_greedy(drafts, targets, k_valid):
    """Greedy acceptance: keep the longest prefix of ``drafts`` (B, K)
    matching the target argmax ``targets`` (B, K+1) position-wise,
    considering only the first ``k_valid`` (B,) drafted positions.
    Returns ``(n_accept (B,), correction (B,))`` — the correction is
    the target token at the first mismatch (or the bonus token when
    everything matched), so the emitted stream is EXACTLY the vanilla
    greedy sequence regardless of what the draft proposed."""
    K = drafts.shape[1]
    idx = jnp.arange(K)[None, :]
    match = (drafts == targets[:, :K]) & (idx < k_valid[:, None])
    n_accept = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    correction = jnp.take_along_axis(
        targets, n_accept[:, None], axis=1)[:, 0]
    return n_accept, correction


def accept_sampled(drafts, draft_probs, tgt_probs, k_valid, key):
    """The exact speculative rejection rule: position ``i`` accepts
    draft ``d_i`` iff ``u_i <= p_i(d_i) / q_i(d_i)`` (``p`` target,
    ``q`` draft distribution, u ~ U[0,1)); the first rejection samples
    the correction from the residual ``norm(max(p - q, 0))`` and full
    acceptance samples the bonus from ``p`` at the next position.  The
    emitted marginal equals ``p`` for any proposal ``q`` with
    ``q(d) > 0`` on drawn tokens.

    drafts (B, K) int32; draft_probs (B, K, V); tgt_probs (B, K+1, V);
    k_valid (B,) drafted counts.  Returns ``(n_accept, correction)``."""
    B, K = drafts.shape
    ku, kr = jax.random.split(key)
    u = jax.random.uniform(ku, (B, K))
    p_d = jnp.take_along_axis(
        tgt_probs[:, :K], drafts[..., None], axis=-1)[..., 0]
    q_d = jnp.take_along_axis(
        draft_probs, drafts[..., None], axis=-1)[..., 0]
    idx = jnp.arange(K)[None, :]
    ok = (u * jnp.maximum(q_d, 1e-20) <= p_d) & (idx < k_valid[:, None])
    n_accept = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    # residual at the rejection position (clamped gather; masked away
    # for fully-accepted rows below)
    j = jnp.minimum(n_accept, K - 1)
    p_j = jnp.take_along_axis(
        tgt_probs, j[:, None, None], axis=1)[:, 0]
    q_j = jnp.take_along_axis(
        draft_probs, j[:, None, None], axis=1)[:, 0]
    resid = jnp.clip(p_j - q_j, 0.0)
    rs = resid.sum(axis=-1, keepdims=True)
    # numerically-empty residual (q covers p) degenerates to p itself
    resid = jnp.where(rs > 1e-20, resid / jnp.maximum(rs, 1e-20), p_j)
    p_bonus = jnp.take_along_axis(
        tgt_probs, k_valid[:, None, None], axis=1)[:, 0]
    dist = jnp.where((n_accept >= k_valid)[:, None], p_bonus, resid)
    correction = jax.random.categorical(
        kr, jnp.log(jnp.maximum(dist, 1e-30)), axis=-1).astype(jnp.int32)
    return n_accept, correction


def emit_matrix(drafts, n_accept, correction, n_valid):
    """Pack the round's emissions: (B, K+1) tokens — the accepted draft
    prefix then the correction/bonus at column ``n_accept`` — plus the
    per-slot emit count ``n_accept + 1`` (0 for slots that sat the
    round out)."""
    K = drafts.shape[1]
    idx = jnp.arange(K + 1)[None, :]
    toks = jnp.where(idx[:, :K] < n_accept[:, None], drafts, 0)
    toks = jnp.concatenate(
        [toks, jnp.zeros((drafts.shape[0], 1), jnp.int32)], axis=1)
    toks = jnp.where(idx == n_accept[:, None], correction[:, None], toks)
    n_emit = jnp.where(n_valid > 0, n_accept + 1, 0)
    return toks, n_emit


# -- compiled phase bodies -------------------------------------------------
# Each mirrors Engine._step_impl's spine (fused cache ops -> active
# block-table slice -> pending splice into column 0 -> chunk step ->
# metrics accumulate) with phase-specific heads.  They are separate
# jits from the engine step on purpose: the sharded path's fixed
# out_specs never sees them (speculation is gated to layout="paged").

def _dispatch_core(cfg, api, mor_mode, mspec, params, mor, cache,
                   tokens, n_valid, pending, ops, metrics, n_active,
                   copy_pads):
    mcounts = {}
    if metrics is not None and ops is not None:
        mcounts = kv_pool.ops_counts(cache, ops, *copy_pads)
    if ops is not None:
        cache = kv_pool.apply_cache_ops(cache, ops, *copy_pads)
    full_bt = None
    if n_active is not None and "block_table" in cache and \
            n_active < cache["block_table"].shape[1]:
        full_bt = cache["block_table"]
        cache = dict(cache, block_table=full_bt[:, :n_active])
    bt_active = cache.get("block_table")
    use_pending = n_valid > 0
    tokens = tokens.at[:, 0].set(
        jnp.where(use_pending, pending, tokens[:, 0]))
    logits, cache, aux = api.prefill_chunk(
        params, cfg, tokens, cache, n_valid=n_valid, mor=mor,
        mor_mode=mor_mode)
    if full_bt is not None:
        cache = dict(cache, block_table=full_bt)
    pages = None
    if bt_active is not None:
        pages = ((bt_active > 0) & (n_valid > 0)[:, None]).sum(
            dtype=jnp.int32)
    return logits, cache, aux, mcounts, pages


def draft_step_impl(cfg, api, mor_mode, temperature, top_k, mspec,
                    params, mor, cache, n_valid, pending, key, ops,
                    metrics=None, n_active=None, copy_pads=(0, 0)):
    """One autoregressive draft step under the clamped plans: feed each
    live slot's pending token, propose the next.  Slots past their
    per-slot draft length ride with ``n_valid == 0`` — no state change,
    no KV write, pending preserved."""
    tokens = jnp.zeros((n_valid.shape[0], 1), jnp.int32)
    logits, cache, aux, mcounts, pages = _dispatch_core(
        cfg, api, mor_mode, mspec, params, mor, cache, tokens, n_valid,
        pending, ops, metrics, n_active, copy_pads)
    nxt, probs = sample_step(
        logits[:, 0], temperature=temperature, top_k=top_k, key=key,
        with_probs=temperature > 0.0)
    new_pending = jnp.where(n_valid > 0, nxt, pending)
    if metrics is not None:
        scalars = dict(mcounts, dispatches=1,
                       tokens_drafted=n_valid.sum(dtype=jnp.int32))
        if pages is not None:
            scalars["pages_touched"] = pages
        # draft aux stats stay out of the MoR tile lanes: they describe
        # the clamped pass and would skew capacity calibration
        metrics = mspec.accumulate(metrics, scalars, {})
    return nxt, probs, new_pending, cache, metrics


def verify_step_impl(cfg, api, mor_mode, temperature, top_k, mspec,
                     params, mor, cache, tokens, n_valid, pending, key,
                     draft_probs, ops, metrics=None, n_active=None,
                     copy_pads=(0, 0)):
    """The chunked-prefill-shaped verify: ``tokens`` (B, K+1) carries
    the pending token (spliced into column 0) followed by the drafted
    continuation; ``n_valid[s] = k_s + 1`` scores every position under
    the TARGET plans in one pass (rewriting the draft KV rows with
    target values before any attend — write-before-attend).  Slots with
    ``k_s == 0`` degenerate to vanilla decode: the correction is the
    target's column-0 token.  Returns the emit matrix, per-slot emit
    counts, and the new pending (correction/bonus) token."""
    drafts = tokens[:, 1:]
    logits, cache, aux, mcounts, pages = _dispatch_core(
        cfg, api, mor_mode, mspec, params, mor, cache, tokens, n_valid,
        pending, ops, metrics, n_active, copy_pads)
    k_valid = jnp.maximum(n_valid - 1, 0)
    if temperature > 0.0:
        tgt_probs = jax.nn.softmax(
            _scaled_logits(logits, temperature, top_k), axis=-1)
        if draft_probs is None:
            # greedy draft under a sampled target: q is a point mass
            draft_probs = jax.nn.one_hot(
                drafts, logits.shape[-1], dtype=jnp.float32)
        n_accept, correction = accept_sampled(
            drafts, draft_probs, tgt_probs, k_valid, key)
    else:
        targets = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        n_accept, correction = accept_greedy(drafts, targets, k_valid)
    emit_toks, n_emit = emit_matrix(drafts, n_accept, correction, n_valid)
    new_pending = jnp.where(n_valid > 0, correction, pending)
    if metrics is not None:
        acc = jnp.where(n_valid > 0, n_emit - 1, 0).sum(dtype=jnp.int32)
        scalars = dict(mcounts, dispatches=1,
                       decode_tokens=n_emit.sum(dtype=jnp.int32),
                       tokens_accepted=acc)
        if pages is not None:
            scalars["pages_touched"] = pages
        metrics = mspec.accumulate(metrics, scalars, aux)
    return emit_toks, n_emit, new_pending, cache, aux, metrics


def replay_step_impl(cfg, api, mor_mode, mspec, params, mor, cache,
                     tokens, n_valid, pending, ops, metrics=None,
                     n_active=None, copy_pads=(0, 0)):
    """Partial-accept state replay: re-feed the ACCEPTED tokens
    (``n_valid[s] = m_s``) from the restored fork-point state under the
    target plans, so recurrent state lands exactly at the last verified
    token.  The KV rows it rewrites are identical to what verify wrote
    (same inputs, same weights); logits are discarded and nothing is
    emitted — pending is untouched."""
    _, cache, aux, mcounts, pages = _dispatch_core(
        cfg, api, mor_mode, mspec, params, mor, cache, tokens, n_valid,
        pending, ops, metrics, n_active, copy_pads)
    if metrics is not None:
        scalars = dict(mcounts, dispatches=1)
        if pages is not None:
            scalars["pages_touched"] = pages
        metrics = mspec.accumulate(metrics, scalars, {})
    return cache, metrics


# -- the round orchestrator ------------------------------------------------

class SpecDecoder:
    """Drives speculative rounds for an :class:`~repro.serving.engine.
    Engine` (paged layout, single device).  Holds the draft-mode plan
    tree and the three jitted phase bodies; ``round`` replaces one
    vanilla decode dispatch inside ``Engine.step`` whenever every live
    slot is decoding."""

    def __init__(self, engine, *, spec_k: int, draft_cap: float = 0.0,
                 draft_temperature: Optional[float] = None):
        assert spec_k >= 1
        self.eng = engine
        self.k = int(spec_k)
        self.draft_cap = float(draft_cap)
        # greedy targets may still DRAFT at temperature (forces
        # rejections while the emitted stream stays exactly greedy —
        # the rollback paths get exercised without changing output)
        self.draft_temperature = (
            engine.temperature if draft_temperature is None
            else float(draft_temperature))
        self.counters: Dict[str, float] = {
            "rounds": 0, "tokens_drafted": 0, "tokens_accepted": 0,
            "replays": 0, "aborts": 0}
        self._cooldown = 0
        self.refresh()
        e = engine
        self._draft = jax.jit(
            partial(draft_step_impl, e.cfg, e.api, e.mor_mode,
                    self.draft_temperature, e.top_k, e._mspec),
            donate_argnums=(2, 7), static_argnums=(8, 9))
        self._verify = jax.jit(
            partial(verify_step_impl, e.cfg, e.api, e.mor_mode,
                    e.temperature, e.top_k, e._mspec),
            donate_argnums=(2, 9), static_argnums=(10, 11))
        self._replay = jax.jit(
            partial(replay_step_impl, e.cfg, e.api, e.mor_mode,
                    e._mspec),
            donate_argnums=(2, 7), static_argnums=(8, 9))

    def refresh(self) -> None:
        """(Re)derive the draft plan tree from the engine's current
        plans — called at construction and after ``calibrate_capacities``
        re-attaches them.  ``draft == target`` when the engine runs
        dense (no plans); with plans, ``draft_cap > 0`` clamps every
        layer's live-tile capacity for the draft pass (a traced leaf:
        re-running this with a new value never recompiles)."""
        if self.eng.mor is None:
            self.mor_draft = None
            return
        from repro.core.executor import attach_draft_caps, map_plans
        md = self.eng.mor
        if self.draft_cap > 0.0:
            md = attach_draft_caps(md, self.draft_cap)
        self.mor_draft = map_plans(md, lambda p: p.as_draft())

    def reset(self) -> None:
        for k in self.counters:
            self.counters[k] = 0
        self._cooldown = 0

    def report(self) -> Dict:
        c = dict(self.counters)
        return {"k": self.k, "draft_cap": self.draft_cap,
                "draft_temperature": self.draft_temperature,
                "acceptance_rate": (
                    c["tokens_accepted"] / max(c["tokens_drafted"], 1)),
                **c}

    def ready(self) -> bool:
        """One-step backoff after an aborted round (pool pressure): the
        next step takes the vanilla path, whose spill machinery can
        free pages, before speculation resumes."""
        if self._cooldown > 0:
            self._cooldown -= 1
            return False
        return True

    # -- round helpers ----------------------------------------------------

    def _plan_round(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-slot draft lengths: ``min(k, remaining - 1)`` so a round
        never overshoots a request's token budget, then capped so the
        round's total verified positions ride the policy's
        ``prefill_budget`` (verify IS a prefill-shaped chunk; the first
        speculating slot always keeps >= 1, mirroring the scheduler's
        starvation guard)."""
        eng = self.eng
        k_s = np.zeros((eng.n_slots,), np.int64)
        active = np.zeros((eng.n_slots,), bool)
        budget = eng.policy.prefill_budget
        left = budget if budget > 0 else None
        for s in range(eng.n_slots):
            rem = eng.scheduler.decode_remaining(s)
            if rem <= 0:
                continue
            active[s] = True
            take = min(self.k, rem - 1)
            if left is not None and take > 0:
                cap = max(left, 0) if k_s.any() else max(left, 1)
                take = min(take, cap)
                left -= take
            k_s[s] = take
        return k_s, active

    def _abort(self, forks: List) -> None:
        for f in forks:
            self.eng.pool.spec_abort(f)
        self.counters["aborts"] += 1
        self._cooldown = 1

    # -- the round --------------------------------------------------------

    def round(self, t0: float, admitted: List[int]) -> List[int]:
        """One speculative round: fork -> k draft dispatches -> verify
        dispatch -> commit/rollback (+ optional state replay) -> feed.
        Exactly one host sync (the per-slot emit counts).  Falls back
        to one vanilla ``Engine.step`` when the pool cannot host the
        round."""
        eng = self.eng
        sched, pool = eng.scheduler, eng.pool
        K, B = self.k, eng.n_slots
        k_s, active = self._plan_round()
        kmax = int(k_s.max(initial=0))
        forks: List = []
        try:
            for s in np.nonzero(k_s > 0)[0]:
                forks.append(pool.spec_fork(int(s)))
        except kv_pool.PoolExhausted:
            self._abort(forks)
            return eng.step()

        ann = (eng._tr.annotation if eng._tr is not None
               else lambda _k: contextlib.nullcontext())

        # -- draft loop: kmax host iterations of ONE compiled step
        # (n_valid masks slots past their per-slot length) ------------
        pending = eng._pending          # round-local; committed pending
        draft_toks: List = []           # stays in eng._pending for
        draft_probs: List = []          # rollback / preemption safety
        try:
            for i in range(kmax):
                nv = (k_s > i).astype(np.int32)
                pool.plan_writes(nv)
                eng.cache, ops = pool.drain(eng.cache)
                n_active = pool.active_blocks(nv)
                copy_pads = (pool.last_pads if ops is not None
                             else (0, 0))
                key = (jax.random.fold_in(eng._base_key,
                                          eng.counters["dispatches"])
                       if self.draft_temperature > 0.0
                       else eng._base_key)
                tr_t0 = eng._tr.now() if eng._tr is not None else 0.0
                with ann("draft"):
                    nxt, probs, pending, eng.cache, eng._mblock = \
                        self._draft(
                            eng.params, self.mor_draft, eng.cache,
                            jnp.asarray(nv), pending, key, ops,
                            eng._mblock, n_active, copy_pads)
                pool.advance(nv)
                draft_toks.append(nxt)
                draft_probs.append(probs)
                eng.counters["dispatches"] += 1
                sched.dispatch_kinds["draft"] += 1
                self.counters["tokens_drafted"] += int(nv.sum())
                if eng._tr is not None:
                    eng._tr.on_dispatch(
                        "draft", tr_t0, eng._tr.now(),
                        queue_depth=len(sched.waiting),
                        n_active=int(nv.sum()))

            # -- verify: reset to the fork point, score k+1 positions
            # under the target plans in one prefill-shaped pass -------
            for f in forks:
                pool.spec_set_pos(f.slot, f.pos)
                pool.spec_restore_state(f)
            nvv = np.where(active, k_s + 1, 0).astype(np.int32)
            pool.plan_writes(nvv)
        except kv_pool.PoolExhausted:
            self._abort(forks)
            return eng.step()
        eng.cache, ops = pool.drain(eng.cache)
        n_active = pool.active_blocks(nvv)
        copy_pads = pool.last_pads if ops is not None else (0, 0)
        if kmax:
            dstack = jnp.stack(draft_toks, axis=1)
            if kmax < K:
                dstack = jnp.concatenate(
                    [dstack, jnp.zeros((B, K - kmax), jnp.int32)],
                    axis=1)
        else:
            dstack = jnp.zeros((B, K), jnp.int32)
        tokens = jnp.concatenate(
            [jnp.zeros((B, 1), jnp.int32), dstack], axis=1)
        qstack = None
        if eng.temperature > 0.0 and self.draft_temperature > 0.0:
            V = draft_probs[0].shape[-1] if kmax else eng.cfg.vocab_size
            if kmax:
                qstack = jnp.stack(draft_probs, axis=1)
                if kmax < K:
                    qstack = jnp.concatenate(
                        [qstack,
                         jnp.full((B, K - kmax, V), 1.0, jnp.float32)],
                        axis=1)
            else:
                qstack = jnp.full((B, K, V), 1.0, jnp.float32)
        key = (jax.random.fold_in(eng._base_key,
                                  eng.counters["dispatches"])
               if eng.temperature > 0.0 else eng._base_key)
        tr_t0 = eng._tr.now() if eng._tr is not None else 0.0
        with ann("verify"):
            emit_toks, n_emit_dev, new_pending, eng.cache, aux, \
                eng._mblock = self._verify(
                    eng.params, eng.mor, eng.cache, tokens,
                    jnp.asarray(nvv), eng._pending, key, qstack, ops,
                    eng._mblock, n_active, copy_pads)
        pool.advance(nvv)
        eng.counters["dispatches"] += 1
        sched.dispatch_kinds["verify"] += 1
        if eng.telemetry is not None and aux:
            eng._aux_log.append(aux)

        # the round's single host sync: per-slot emit counts drive the
        # host-side commit/rollback and the scheduler feed
        n_emit = np.asarray(jax.device_get(n_emit_dev), np.int64)

        # -- commit / rollback ----------------------------------------
        replays: List[Tuple] = []
        for f in forks:
            m = int(n_emit[f.slot])
            committed = f.pos + m
            if m < int(k_s[f.slot]) + 1:
                pool.spec_rollback_pages(f, committed)
                pool.spec_set_pos(f.slot, committed)
                if f.st_backup:
                    replays.append((f, m))
                    continue
            pool.spec_drop_backup(f)
        if replays:
            # one batched replay re-derives recurrent state at the last
            # verified token (verify over-advanced it by the rejected
            # tail); attention-only families need none — their rollback
            # is pure position truncation
            nvr = np.zeros((B,), np.int32)
            for f, m in replays:
                pool.spec_set_pos(f.slot, f.pos)
                pool.spec_restore_state(f)
                nvr[f.slot] = m
            # every page involved is already exclusively owned (written
            # this round), so this plan cannot raise
            pool.plan_writes(nvr)
            eng.cache, ops = pool.drain(eng.cache)
            n_active = pool.active_blocks(nvr)
            copy_pads = pool.last_pads if ops is not None else (0, 0)
            tr_t0r = eng._tr.now() if eng._tr is not None else 0.0
            with ann("replay"):
                eng.cache, eng._mblock = self._replay(
                    eng.params, eng.mor, eng.cache, tokens,
                    jnp.asarray(nvr), eng._pending, ops, eng._mblock,
                    n_active, copy_pads)
            pool.advance(nvr)
            eng.counters["dispatches"] += 1
            sched.dispatch_kinds["replay"] += 1
            self.counters["replays"] += 1
            for f, _ in replays:
                pool.spec_drop_backup(f)
            if eng._tr is not None:
                eng._tr.on_dispatch(
                    "replay", tr_t0r, eng._tr.now(),
                    queue_depth=len(sched.waiting),
                    n_active=len(replays))

        # -- feed / emit ------------------------------------------------
        eng._pending = new_pending
        slots = sched.slots
        emits = [(int(s), slots[s].req.rid)
                 for s in np.nonzero(active)[0]]
        if eng._tr is not None:
            tr_admitted = [(s, slots[s].req.rid) for s in admitted]
            tr_counts = [int(n_emit[s]) for s, _ in emits]
        eng._tok_log.append((emits, emit_toks, n_emit))
        finished = sched.feed_counts(n_emit)
        for _, req in finished:
            if req.rid in eng._stream_cbs:
                eng._stream_done.add(req.rid)
        for s, _ in finished:
            pool.release(s)
        emitted = int(n_emit.sum())
        accepted = emitted - len(emits)
        self.counters["rounds"] += 1
        self.counters["tokens_accepted"] += accepted
        eng.counters["decode_tokens"] += emitted
        eng.counters["wall_s"] += time.perf_counter() - t0
        if eng._tr is not None:
            eng._tr.on_dispatch(
                "verify", tr_t0, eng._tr.now(), admitted=tr_admitted,
                emits=emits, emit_counts=tr_counts,
                finished=[req.rid for _, req in finished],
                queue_depth=len(sched.waiting),
                n_active=int(np.count_nonzero(nvv)))
        return [req.rid for _, req in finished]

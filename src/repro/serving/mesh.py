"""repro.serving.mesh — the mesh-sharded paged serving layout.

``Engine(layout="paged-sharded", mesh=...)`` runs the whole serving hot
loop under ONE ``shard_map`` over the mesh's page axis
(``sharding_rules.PAGE_AXIS``):

  * every page-pool leaf of the paged cache ((stack, n_pages, page, ...)
    attention/latent pools, (L, n_spages, ...) recurrent-state pools) is
    partitioned on its page dimension — HBM capacity for the KV cache
    scales with the mesh while params, tokens, block tables and the
    residual compute stay replicated;
  * the host-side ``BlockAllocator`` is replicated but ownership-aware
    (each logical page pins to the shard that physically holds it;
    fresh allocations round-robin shards, COW destinations stay on
    their source's shard), so the packed page-edit vector splits into
    one row per shard and ``kv_pool.apply_cache_ops`` runs unchanged,
    shard-locally, inside the same compiled step;
  * attention over the paged ring becomes a DISTRIBUTED flash decode:
    each shard computes partial (m, l, acc) statistics over its
    locally-resident pages and the shards combine with one collective
    per attention layer (``collectives.flash_merge``); recurrent state
    uses a single-owner psum gather (``decode_attention.state_*``).

Prefix caching, copy-on-write and eviction keep working UNCHANGED on
top: they only ever manipulate global page ids host-side, and global
ids shard deterministically.  This module holds the glue — partition
specs for an arbitrary paged cache pytree, sharded placement, and the
``shard_map``-wrapped step/apply builders the engine and pool use.
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.decode_attention import page_shard_context
from repro.distributed.sharding_rules import PAGE_AXIS
from repro.serving import kv_pool

__all__ = ["cache_partition_specs", "shard_cache", "sharded_apply",
           "make_sharded_step", "make_sharded_shadow_step"]


def cache_partition_specs(cache: Dict) -> Dict:
    """PartitionSpec pytree for a paged cache: page-pool leaves split on
    their page axis (axis 1 — the layer stack leads), tables / pos
    replicated."""
    def kv(node):
        # per-layer tuple leaves: each element is (n_pages, page, ...)
        # with the page dim LEADING (no stack axis)
        return {k: (tuple(P(PAGE_AXIS) for _ in v)
                    if isinstance(v, tuple) else P(None, PAGE_AXIS))
                for k, v in node.items()}

    def stl(a):
        return P(None, PAGE_AXIS)

    specs: Dict = {}
    for k, v in cache.items():
        if k in kv_pool._TABLE_KEYS:
            specs[k] = P()
            continue
        v = kv_pool.map_kv_nodes(v, kv)
        specs[k] = kv_pool.map_state_leaves(v, stl)
    return specs


def _walk2(a, b, fn):
    """Zip-walk two parallel dict trees (specs are P leaves, which jax's
    tree utils may treat as tuples — so walk dicts and per-layer leaf
    tuples explicitly)."""
    if isinstance(a, dict):
        return {k: _walk2(a[k], b[k], fn) for k in a}
    if isinstance(a, tuple):                     # per-layer pool leaves
        return tuple(fn(x, s) for x, s in zip(a, b))
    return fn(a, b)


def shard_cache(cache: Dict, mesh, specs: Dict = None) -> Dict:
    """Place a freshly-built paged cache on the mesh, page-sharded."""
    specs = specs if specs is not None else cache_partition_specs(cache)
    return _walk2(cache, specs,
                  lambda a, s: jax.device_put(a, NamedSharding(mesh, s)))


def sharded_apply(mesh, specs: Dict):
    """The standalone (overflow-round) cache-ops apply as a shard_map
    step: each shard applies its own ops row to its local page range.
    The copy-pad widths are static (the pool buckets them to {0, max})
    so copy-free rounds compile without the scatter."""
    n = mesh.shape[PAGE_AXIS]

    def fn(cache, ops, pads):
        def body(cache, ops):
            with page_shard_context(PAGE_AXIS, n):
                return kv_pool.apply_cache_ops(cache, ops[0], *pads)

        return shard_map(body, mesh=mesh, in_specs=(specs, P(PAGE_AXIS)),
                         out_specs=specs, check_rep=False)(cache, ops)

    return jax.jit(fn, donate_argnums=(0,), static_argnums=(2,))


def make_sharded_step(body, mesh, cache: Dict):
    """Wrap the engine's dispatch-step body in ONE shard_map over the
    page axis and jit it (cache donated, like the single-device step).

    ``body(params, mor, cache, tokens, n_valid, use_pending, pending,
    key, ops, metrics)`` is ``Engine._step_impl`` with its static
    leading args bound; inside the region the page-shard context is
    active, so the models' paged branches run the distributed flash
    decode and the fused ``apply_cache_ops`` consumes this shard's ops
    row.  Everything except the page pools is replicated (specs
    ``P()``): the sharded layout trades replicated FFN/projection
    compute for a P-way partitioned KV cache and one merge collective
    per attention layer — multi-host serving as a config flag, not a
    cache rewrite.

    The obs device-metrics block (``metrics``, (n_shards, size) int32,
    None when observability is off) shards one row per page shard like
    the ops vector: each shard accumulates into its local row (header
    fields land replicated, page-edit counts shard-local) and the row
    rides back out still sharded — the host aggregates rows only at
    flush time."""
    specs = cache_partition_specs(cache)
    n = mesh.shape[PAGE_AXIS]

    def stepfn(params, mor, cache, tokens, n_valid, use_pending, pending,
               key, ops, metrics=None, n_active=None, copy_pads=(0, 0)):
        # n_active / copy_pads are static (bucketed active-block width
        # and {0, max} copy-pad widths) — they ride into the body via
        # closure, not as shard_map operands
        def inner(params, mor, cache, tokens, n_valid, use_pending,
                  pending, key, ops, metrics):
            with page_shard_context(PAGE_AXIS, n):
                return body(params, mor, cache, tokens, n_valid,
                            use_pending, pending, key,
                            None if ops is None else ops[0], metrics,
                            n_active, copy_pads)

        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(), specs, P(), P(), P(), P(), P(),
                      P(PAGE_AXIS), P(PAGE_AXIS)),
            out_specs=(P(), P(), specs, P(), P(PAGE_AXIS)),
            check_rep=False,
        )(params, mor, cache, tokens, n_valid, use_pending, pending, key,
          ops, metrics)

    return jax.jit(stepfn, donate_argnums=(2, 9), static_argnums=(10, 11))


def make_sharded_shadow_step(body, mesh, cache: Dict):
    """The shadow-oracle scoring pass (``Engine._shadow_impl`` with its
    leading args bound) under the same page-axis ``shard_map`` as the
    primary step.  It reads the SAME sharded cache the primary step is
    about to consume — so the cache is NOT donated here (only the
    metrics block, its one output, is) — and returns the per-shard
    metrics rows, ``P(PAGE_AXIS)`` like the primary step's."""
    specs = cache_partition_specs(cache)
    n = mesh.shape[PAGE_AXIS]

    def stepfn(params, mor, cache, tokens, n_valid, use_pending, pending,
               ops, metrics, n_active=None, copy_pads=(0, 0)):
        def inner(params, mor, cache, tokens, n_valid, use_pending,
                  pending, ops, metrics):
            with page_shard_context(PAGE_AXIS, n):
                return body(params, mor, cache, tokens, n_valid,
                            use_pending, pending,
                            None if ops is None else ops[0], metrics,
                            n_active, copy_pads)

        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(), specs, P(), P(), P(), P(),
                      P(PAGE_AXIS), P(PAGE_AXIS)),
            out_specs=P(PAGE_AXIS),
            check_rep=False,
        )(params, mor, cache, tokens, n_valid, use_pending, pending,
          ops, metrics)

    return jax.jit(stepfn, donate_argnums=(8,), static_argnums=(9, 10))

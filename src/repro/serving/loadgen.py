"""Open-loop load generation for SLO benchmarks.

Closed-loop driving (``Engine.run`` on a fixed trace) measures
throughput but can never measure TAIL latency under load: the driver
only submits as fast as the engine serves, so the queue never builds.
An OPEN-loop generator submits on a wall-clock arrival schedule that
does not care how busy the engine is — overload shows up as queue
depth, and queue depth shows up as p99 TTFT, which is exactly the
signal admission policies and page-spill preemption exist to shape.

``poisson_trace`` is fully seeded: the same (rate, seed, shape params)
produce byte-identical arrival times, prompts and priorities, so policy
A vs policy B comparisons (and CI reruns) see the SAME offered load.
``run_open_loop`` replays a trace against a live engine in real time:
arrivals whose time has come are submitted (rejections recorded, never
fatal — that is what ``RequestRejected`` is for), the engine steps
whenever it has work, and the driver sleeps only when idle ahead of the
next arrival.  Per-request latencies come out of the engine's tracer
(``Tracer.request_spans``), which shares the ``perf_counter`` timebase
with the arrival clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.scheduler import RequestRejected

__all__ = ["Arrival", "poisson_trace", "run_open_loop"]


@dataclass
class Arrival:
    """One scheduled request: submit at ``t`` (seconds from the run's
    start), with a priority class for policies that use one."""
    t: float
    prompt: np.ndarray
    max_new_tokens: int
    priority: int = 0


@dataclass
class OpenLoopResult:
    """What one open-loop replay observed (token values stay in
    ``engine.results``): rid -> arrival index for joining engine spans
    back to the trace, plus the rejection log."""
    submitted: Dict[int, int] = field(default_factory=dict)
    rejected: List[Tuple[int, str]] = field(default_factory=list)
    wall_s: float = 0.0

    @property
    def n_submitted(self) -> int:
        return len(self.submitted)


def poisson_trace(rate: float, duration_s: float, vocab_size: int,
                  seed: int = 0, prompt_len: Tuple[int, int] = (8, 48),
                  max_new: Tuple[int, int] = (4, 16),
                  hi_pri_frac: float = 0.0, hi_pri: int = 5,
                  oversize_frac: float = 0.0,
                  max_len: int = 0) -> List[Arrival]:
    """Seeded Poisson arrivals at ``rate`` req/s for ``duration_s``
    seconds, with prompt/generation lengths uniform over the given
    inclusive ranges and a ``hi_pri_frac`` fraction of requests tagged
    ``hi_pri``.  ``oversize_frac`` > 0 injects unservable requests
    (prompt past ``max_len``) to exercise the rejection path under
    load."""
    assert rate > 0 and duration_s > 0
    rng = np.random.default_rng(seed)
    out: List[Arrival] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= duration_s:
            break
        p_lo, p_hi = prompt_len
        n_p = int(rng.integers(p_lo, p_hi + 1))
        n_g = int(rng.integers(max_new[0], max_new[1] + 1))
        if oversize_frac > 0 and rng.random() < oversize_frac:
            assert max_len > 0, "oversize_frac needs max_len"
            n_p = max_len          # prompt + gen + 1 always > max_len
        prompt = rng.integers(1, vocab_size,
                              size=max(n_p, 1)).astype(np.int32)
        pri = hi_pri if (hi_pri_frac > 0
                         and rng.random() < hi_pri_frac) else 0
        out.append(Arrival(t, prompt, n_g, pri))
    return out


def run_open_loop(engine, arrivals: List[Arrival], *,
                  time_scale: float = 1.0,
                  idle_sleep_cap: float = 0.002) -> OpenLoopResult:
    """Replay ``arrivals`` against ``engine`` in real time: submit every
    arrival whose (scaled) time has passed, step the engine whenever it
    has work, sleep only when idle before the next arrival, then drain.
    Rejections (oversize injections, etc.) are recorded and the run
    continues — a load generator that dies on one bad request measures
    nothing."""
    res = OpenLoopResult()
    t0 = time.perf_counter()
    i, n = 0, len(arrivals)
    while i < n or engine.scheduler.has_work:
        now = (time.perf_counter() - t0) / time_scale
        while i < n and arrivals[i].t <= now:
            a = arrivals[i]
            try:
                rid = engine.submit(a.prompt, a.max_new_tokens,
                                    priority=a.priority)
                res.submitted[rid] = i
            except RequestRejected as e:
                res.rejected.append((i, e.reason))
            i += 1
        if engine.scheduler.has_work:
            engine.step()
        elif i < n:
            wait = arrivals[i].t * time_scale - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, idle_sleep_cap))
    engine.drain()
    res.wall_s = time.perf_counter() - t0
    return res


def latency_stats(spans: Dict[int, Dict], submitted: Dict[int, int],
                  arrivals: List[Arrival],
                  quantiles: Tuple[float, ...] = (0.5, 0.99)
                  ) -> Dict[str, Dict[str, float]]:
    """Per-priority-class TTFT quantiles from ``Tracer.request_spans``
    joined back to the trace (plus the all-requests row under "all")."""
    by_class: Dict[str, List[float]] = {"all": []}
    for rid, idx in submitted.items():
        sp = spans.get(rid)
        if sp is None or sp.get("ttft_s") is None:
            continue
        by_class["all"].append(sp["ttft_s"])
        key = f"pri{arrivals[idx].priority}"
        by_class.setdefault(key, []).append(sp["ttft_s"])
    out: Dict[str, Dict[str, float]] = {}
    for key, vals in by_class.items():
        if not vals:
            continue
        out[key] = {"n": len(vals)}
        for q in quantiles:
            out[key][f"p{int(q * 100)}"] = float(
                np.percentile(vals, q * 100))
    return out

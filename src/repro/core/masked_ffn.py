"""Execution modes for a MoR-guarded ReLU matmul / FFN.

Modes:
  dense  — plain matmul (baseline, predictor off).
  exact  — full compute, then zero the neurons the hybrid predictor would
           have skipped.  Bit-identical to what the paper's accelerator
           outputs; used for accuracy evaluation (paper Figs. 6/9/12).
  tiled  — tile-granular skipping semantics in pure jnp (the oracle for
           the Pallas kernels): a 128-col x tile_m-row block is skipped
           iff every neuron in it is predicted zero.
  kernel — Pallas: fused binary-rookie mask + gather_matmul that only
           DMAs live weight tiles (see repro/kernels).

All modes operate in *permuted* column space — the permutation is folded
into the surrounding weights offline (policy.py), so callers never pay a
runtime gather.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.predictor import MoRLayer, hybrid_predict
from repro.core.policy import expand_tile_mask, tile_mask_from_neuron_mask


def _act(h, activation: str):
    if activation == "relu":
        return jax.nn.relu(h)
    if activation == "relu2":
        return jnp.square(jax.nn.relu(h))
    raise ValueError(f"MoR requires a ReLU-family activation, got {activation!r}")


def mor_relu_matmul(x: jax.Array, w: jax.Array, mor: Optional[MoRLayer],
                    *, activation: str = "relu", mode: str = "dense",
                    tile_m: int = 8, tile_n: int = 128,
                    residual: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """y = act(x @ w) with MoR skipping.  x: (T, K), w: (K, N) permuted.

    Returns (y, stats) where stats carries the realised skip fractions
    (stats are jnp scalars, jit-safe)."""
    T = x.shape[0]
    N = w.shape[1]
    if mode == "dense" or mor is None:
        pre = x @ w
        y = _act(pre + (residual if residual is not None else 0.0), activation)
        z = jnp.zeros((), jnp.float32)
        return y, {"frac_computed": jnp.ones((), jnp.float32),
                   "frac_tiles_live": jnp.ones((), jnp.float32),
                   "frac_mispredicted_zero": z}

    if mode == "exact":
        pre = (x @ w).astype(jnp.float32)
        pre_bn = pre * mor["bn_scale"] + mor["bn_bias"]
        if residual is not None:
            pre_bn = pre_bn + residual
        computed = hybrid_predict(x, w, mor, preact_full=pre,
                                  residual=residual)
        y = jnp.where(computed, _act(pre_bn, activation), 0.0).astype(x.dtype)
        truly_nonzero = pre_bn > 0
        stats = {
            "frac_computed": computed.mean(dtype=jnp.float32),
            "frac_tiles_live": tile_mask_from_neuron_mask(
                computed.reshape(-1, N), tile_m, tile_n
            ).mean(dtype=jnp.float32),
            "frac_mispredicted_zero":
                (~computed & truly_nonzero).mean(dtype=jnp.float32),
        }
        return y, stats

    if mode == "tiled":
        computed = hybrid_predict(x, w, mor, residual=residual)  # (T, N)
        tiles = tile_mask_from_neuron_mask(computed, tile_m, tile_n)
        keep = expand_tile_mask(tiles, tile_m, tile_n, T, N)
        pre = (x @ w).astype(jnp.float32)
        pre_bn = pre * mor["bn_scale"] + mor["bn_bias"]
        if residual is not None:
            pre_bn = pre_bn + residual
        y = jnp.where(keep, _act(pre_bn, activation), 0.0).astype(x.dtype)
        stats = {
            "frac_computed": computed.mean(dtype=jnp.float32),
            "frac_tiles_live": tiles.mean(dtype=jnp.float32),
            "frac_mispredicted_zero": jnp.zeros((), jnp.float32),
        }
        return y, stats

    if mode == "kernel":
        from repro.kernels import ops as kops
        computed = hybrid_predict(x, w, mor, residual=residual)
        tiles = tile_mask_from_neuron_mask(computed, tile_m, tile_n)
        pre = kops.masked_matmul(x, w, tiles, tile_m=tile_m, tile_n=tile_n)
        pre_bn = pre.astype(jnp.float32) * mor["bn_scale"] + mor["bn_bias"]
        if residual is not None:
            pre_bn = pre_bn + residual
        keep = expand_tile_mask(tiles, tile_m, tile_n, T, N)
        y = jnp.where(keep, _act(pre_bn, activation), 0.0).astype(x.dtype)
        stats = {
            "frac_computed": computed.mean(dtype=jnp.float32),
            "frac_tiles_live": tiles.mean(dtype=jnp.float32),
            "frac_mispredicted_zero": jnp.zeros((), jnp.float32),
        }
        return y, stats

    raise ValueError(f"unknown MoR mode {mode!r}")


def mor_ffn_apply(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
                  mor: Optional[MoRLayer], *, activation: str,
                  mode: str, w_gate: Optional[jax.Array] = None,
                  tile_m: int = 8, tile_n: int = 128,
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full FFN with MoR on the ReLU pre-activation.

    GLU case (relufied SwiGLU -> ReLU-GLU): h = relu(x@w_gate) * (x@w_up).
    A skipped gate neuron zeroes h, so the up-projection column and the
    down-projection row are skipped too (3x the per-neuron saving) — in
    tiled/kernel mode the same tile mask gates the up matmul.
    """
    if w_gate is not None:
        g, stats = mor_relu_matmul(x, w_gate, mor, activation=activation,
                                   mode=mode, tile_m=tile_m, tile_n=tile_n)
        if mode in ("tiled", "kernel") and mor is not None:
            computed = hybrid_predict(x, w_gate, mor)
            tiles = tile_mask_from_neuron_mask(computed, tile_m, tile_n)
            if mode == "kernel":
                from repro.kernels import ops as kops
                u = kops.masked_matmul(x, w_up, tiles,
                                       tile_m=tile_m, tile_n=tile_n)
                keep = expand_tile_mask(tiles, tile_m, tile_n,
                                        x.shape[0], w_up.shape[1])
                u = jnp.where(keep, u, 0.0).astype(x.dtype)
            else:
                keep = expand_tile_mask(tiles, tile_m, tile_n,
                                        x.shape[0], w_up.shape[1])
                u = jnp.where(keep, x @ w_up, 0.0).astype(x.dtype)
        else:
            u = x @ w_up
        h = (g * u).astype(x.dtype)
    else:
        h, stats = mor_relu_matmul(x, w_up, mor, activation=activation,
                                   mode=mode, tile_m=tile_m, tile_n=tile_n)
    return h @ w_down, stats

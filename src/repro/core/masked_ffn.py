"""Thin dispatcher over :class:`repro.core.executor.MoRExecutionPlan`.

Historically this module implemented the dense/exact/tiled/kernel
execution modes inline (and, in the GLU path, re-ran the hybrid
predictor for the up-projection).  The mode logic now lives in
``executor.py`` as per-layer execution plans that run the predictor
exactly once; these wrappers keep the long-standing call signatures for
models and tests while routing everything through plans.

Modes (see executor.py for the full contract):
  dense  — plain matmul (baseline, predictor off).
  exact  — full compute, then zero predicted-dead neurons (accuracy
           evaluation; bit-identical to the paper's accelerator).
  tiled  — tile-granular skipping in pure jnp (the kernel oracle).
  kernel — fused Pallas predictor (``mor_tile_mask``) + DMA-skipping
           ``gather_matmul`` + contraction-masked down projection.

All modes operate in *permuted* column space — the permutation is folded
into the surrounding weights offline (policy.py), so callers never pay a
runtime gather.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.core.executor import MoRExecutionPlan, as_plan
from repro.core.predictor import MoRLayer


def mor_relu_matmul(x: jax.Array, w: jax.Array, mor: Optional[MoRLayer],
                    *, activation: str = "relu", mode: str = "dense",
                    tile_m: int = 8, tile_n: int = 128,
                    residual: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """y = act(x @ w) with MoR skipping.  x: (T, K), w: (K, N) permuted.

    ``mor`` may be a bare MoRLayer (wrapped with the given mode/tiling)
    or an already-attached MoRExecutionPlan (its config wins).
    Returns (y, stats) where stats carries the realised skip fractions
    (stats are jnp scalars, jit-safe)."""
    plan = as_plan(mor, mode=mode, tile_m=tile_m, tile_n=tile_n)
    return plan.relu_matmul(x, w, activation=activation, residual=residual)


def mor_ffn_apply(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
                  mor: Optional[MoRLayer], *, activation: str,
                  mode: str, w_gate: Optional[jax.Array] = None,
                  tile_m: int = 8, tile_n: int = 128,
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full FFN with MoR on the ReLU pre-activation.

    GLU case (relufied SwiGLU -> ReLU-GLU): h = relu(x@w_gate) * (x@w_up).
    A skipped gate neuron zeroes h, so the up-projection column and the
    down-projection row are skipped too (3x the per-neuron saving) — the
    plan's ONE gate prediction gates all three matmuls.
    """
    plan = as_plan(mor, mode=mode, tile_m=tile_m, tile_n=tile_n)
    return plan.ffn(x, w_up, w_down, activation=activation, w_gate=w_gate)


__all__ = ["mor_relu_matmul", "mor_ffn_apply", "MoRExecutionPlan", "as_plan"]

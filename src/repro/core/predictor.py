"""The hybrid predictor itself (paper §3.2): binary rookie + proxy rookie.

``MoRLayer`` is a plain pytree so it checkpoints/shards like any other
parameters.  All online operations are jit-safe; the offline fitting lives
in ``calibration.py`` / ``clustering.py``.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

# A MoRLayer is a dict pytree with per-output-neuron fields (all length N,
# stored in *permuted* (tile-packed) column order):
#   m, b        : fitted line  p_hat = m * p_bin + b          (paper §3.2.1)
#   enable      : binary rookie enabled (pearson c > T)        (paper Fig. 6)
#   proxy_slot  : permuted column index of this neuron's proxy (paper §3.2.2)
#   is_proxy    : proxies are always evaluated at base precision
#   perm        : permuted -> original column index (int32[N])
#   inv_perm    : original -> permuted column index (int32[N])
#   bn_scale/bn_bias : folded batch-norm (gamma/sigma, beta - mu*gamma/sigma);
#                      identity (1, 0) when the layer has no BN.
MoRLayer = Dict[str, jax.Array]

# Predictor-evaluation counter (trace-time): incremented once per
# ``hybrid_predict`` call and once per fused ``kernels.ops.mor_tile_mask``
# call.  The MoRExecutionPlan contract is ONE evaluation per FFN forward;
# tests assert it through this counter.
_PREDICTOR_EVALS = [0]


def note_predictor_eval() -> None:
    _PREDICTOR_EVALS[0] += 1


def predictor_eval_count() -> int:
    return _PREDICTOR_EVALS[0]


def reset_predictor_eval_count() -> None:
    _PREDICTOR_EVALS[0] = 0


def make_identity_layer(n: int) -> MoRLayer:
    """A no-op MoRLayer (nothing enabled, identity permutation)."""
    idx = jnp.arange(n, dtype=jnp.int32)
    return {
        "m": jnp.ones((n,), jnp.float32),
        "b": jnp.zeros((n,), jnp.float32),
        "enable": jnp.zeros((n,), bool),
        "proxy_slot": idx,
        "is_proxy": jnp.ones((n,), bool),
        "perm": idx,
        "inv_perm": idx,
        "bn_scale": jnp.ones((n,), jnp.float32),
        "bn_bias": jnp.zeros((n,), jnp.float32),
    }


def binarize(x: jax.Array) -> jax.Array:
    """Weight binarisation to +-1 from the sign bit (paper §3.2.1: 'the
    1-bit weights are obtained from the sign bits'; zero maps to +1)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(jnp.int8)


def binarize_act(x: jax.Array) -> jax.Array:
    """ACTIVATION binarisation: strictly-positive -> +1, else -1.

    This differs from the weight convention at exactly x == 0, which is
    measure-zero for signed inputs (layernormed TDS features, the paper's
    Fig. 4 case) but is ~50% of entries for post-ReLU CNN inputs — with
    zero -> +1 the binary dot product would carry NO information about
    the input sparsity pattern (measured: Pearson 0.25 vs 0.8+).  An
    XNOR-popcount binCU implements either convention at identical cost."""
    return jnp.where(x > 0, 1.0, -1.0).astype(jnp.int8)


def binary_preact(x: jax.Array, w: jax.Array) -> jax.Array:
    """Binarised dot product: sign_act(x) . sign(w), accumulated in int32.

    x: (..., K)   w: (K, N)   ->   (..., N) float32.
    On TPU this lowers to an int8 MXU matmul (the Pallas kernel in
    ``repro.kernels.binary_dot`` is the hand-tiled version)."""
    xs = binarize_act(x)
    ws = binarize(w)
    out = jax.lax.dot_general(
        xs, ws, (((xs.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return out.astype(jnp.float32)


def estimate_preact(p_bin: jax.Array, mor: MoRLayer,
                    residual: Optional[jax.Array] = None) -> jax.Array:
    """Fitted line + BN fold (+ residual) -> estimated ReLU input.

    Paper §3.2.1: 'p_hat = m * p_bin + b; if batch normalization and
    residual connections are used, p_hat is transformed by the batch
    normalization parameters and the residual input is added'."""
    p_hat = mor["m"] * p_bin + mor["b"]
    p_hat = p_hat * mor["bn_scale"] + mor["bn_bias"]
    if residual is not None:
        p_hat = p_hat + residual.astype(p_hat.dtype)
    return p_hat


def hybrid_predict(x: jax.Array, w_perm: jax.Array, mor: MoRLayer,
                   preact_full: Optional[jax.Array] = None,
                   residual: Optional[jax.Array] = None) -> jax.Array:
    """Return a boolean mask (..., N) — True where the neuron MUST be
    computed (predicted non-zero), False where both rookies agree the ReLU
    output is zero.

    ``w_perm`` is the weight matrix with columns already permuted into
    tile-packed order.  ``preact_full``, when given (the "exact" evaluation
    mode), supplies the true pre-activations from which proxy outcomes are
    read; otherwise proxy pre-activations are computed here (only the proxy
    columns are ever needed — in the tiled path they live in the leading
    tiles and are computed anyway).
    """
    note_predictor_eval()
    # proxy_slot == -1 is the "binary rookie alone" sentinel (no spatial
    # predictor): the proxy test passes unconditionally.
    slot = jnp.maximum(mor["proxy_slot"], 0)
    if preact_full is None:
        # gather proxy columns and evaluate them at base precision
        proxy_cols = jnp.take(w_perm, slot, axis=1)
        proxy_pre = jax.lax.dot_general(
            x, proxy_cols, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        proxy_pre = jnp.take(preact_full.astype(jnp.float32), slot, axis=-1)
    proxy_relu_in = proxy_pre * mor["bn_scale"][slot] + mor["bn_bias"][slot]
    if residual is not None:
        proxy_relu_in = proxy_relu_in + jnp.take(
            residual.astype(jnp.float32), slot, axis=-1)
    proxy_says_zero = (proxy_relu_in < 0.0) | (mor["proxy_slot"] < 0)

    p_bin = binary_preact(x, w_perm)
    p_hat = estimate_preact(p_bin, mor, residual)
    binary_says_zero = p_hat < 0.0

    skip = proxy_says_zero & binary_says_zero & mor["enable"] & ~mor["is_proxy"]
    return ~skip


def prediction_breakdown(true_preact: jax.Array, computed_mask: jax.Array):
    """Paper Fig. 12 categories, as fractions of all outputs.

    true_preact: the real ReLU inputs (after BN/residual); computed_mask:
    the hybrid predictor's decision (True = evaluated at base precision).
    """
    truly_zero = true_preact <= 0.0
    pred_zero = ~computed_mask
    n = true_preact.size
    return {
        "correct_zero": jnp.sum(pred_zero & truly_zero) / n,
        "incorrect_zero": jnp.sum(pred_zero & ~truly_zero) / n,
        "correct_nonzero": jnp.sum(computed_mask & ~truly_zero) / n,
        "incorrect_nonzero": jnp.sum(computed_mask & truly_zero) / n,
    }

"""Offline angle-based clustering (paper §3.2.2).

The sign of ``dot(C, A)`` vs ``dot(C, B)`` disagrees with probability
theta/360 for uniformly distributed C (paper Eqs. 3-6), so neurons whose
weight vectors subtend a small angle can share one *proxy* evaluation.

Algorithm (verbatim from the paper): build a directed graph with an edge
from every neuron to its angularly-closest neuron, sort nodes by
descending indegree, and greedily pop nodes: the popped node becomes a
proxy and all nodes pointing at it join its cluster.  This runs offline
(weights are fixed), so it is plain numpy — but the angle computation is
blocked so d_ff ~ 50k fits in memory.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def pairwise_cosines(w: np.ndarray, block: int = 2048) -> np.ndarray:
    """w: (K, N) — one weight vector per output neuron (column).
    Returns the (N, N) cosine matrix, computed in (block x N) slabs."""
    wn = w / np.maximum(np.linalg.norm(w, axis=0, keepdims=True), 1e-12)
    n = wn.shape[1]
    out = np.empty((n, n), np.float32)
    for i in range(0, n, block):
        out[i:i + block] = (wn[:, i:i + block].T @ wn).astype(np.float32)
    return out


def closest_neighbor_graph(w: np.ndarray, max_angle_deg: float = 90.0,
                           block: int = 2048) -> Tuple[np.ndarray, np.ndarray]:
    """-> (nn_idx, nn_angle): for each neuron, its closest other neuron by
    angle, and that angle in degrees.  Neurons whose closest angle exceeds
    ``max_angle_deg`` point at themselves (they will not be clustered).
    Memory: O(block * N)."""
    wn = (w / np.maximum(np.linalg.norm(w, axis=0, keepdims=True), 1e-12)
          ).astype(np.float32)
    n = wn.shape[1]
    nn_idx = np.empty((n,), np.int64)
    best_cos = np.empty((n,), np.float32)
    for i in range(0, n, block):
        cos = wn[:, i:i + block].T @ wn              # (b, N)
        cols = np.arange(i, min(i + block, n))
        cos[np.arange(len(cols)), cols] = -2.0        # exclude self
        nn_idx[cols] = np.argmax(cos, axis=1)
        best_cos[cols] = cos[np.arange(len(cols)), nn_idx[cols]]
    nn_angle = np.degrees(np.arccos(np.clip(best_cos, -1.0, 1.0)))
    too_far = nn_angle >= max_angle_deg
    nn_idx[too_far] = np.where(too_far)[0]            # self-loop = unclustered
    return nn_idx, nn_angle


def greedy_proxy_clustering(nn_idx: np.ndarray) -> Tuple[np.ndarray,
                                                         np.ndarray]:
    """Paper's indegree-greedy proxy election.

    -> (proxy_of, is_proxy): proxy_of[j] = the proxy neuron for j (itself
    if j is a proxy or unclustered)."""
    n = len(nn_idx)
    indegree = np.bincount(nn_idx, minlength=n)
    # self-loops mark unclustered nodes; don't let them inflate indegree
    self_loop = nn_idx == np.arange(n)
    indegree[self_loop] = np.maximum(indegree[self_loop] - 1, 0)

    alive = np.ones(n, bool)
    proxy_of = np.arange(n)
    is_proxy = np.zeros(n, bool)
    # process nodes in descending indegree (stable order for determinism)
    order = np.argsort(-indegree, kind="stable")
    # reverse adjacency: who points at me
    rev_sorted = np.argsort(nn_idx, kind="stable")
    starts = np.searchsorted(nn_idx[rev_sorted], np.arange(n))
    ends = np.searchsorted(nn_idx[rev_sorted], np.arange(n), side="right")
    for node in order:
        if not alive[node]:
            continue
        alive[node] = False
        is_proxy[node] = True
        members = rev_sorted[starts[node]:ends[node]]
        members = members[alive[members] & (members != node)]
        proxy_of[members] = node
        alive[members] = False
    # anything left alive (shouldn't happen) becomes its own proxy
    is_proxy[alive] = True
    return proxy_of, is_proxy


def cluster_layer(w: np.ndarray, max_angle_deg: float = 90.0) -> Dict:
    """Full offline clustering for one layer's (K, N) weight matrix."""
    nn_idx, nn_angle = closest_neighbor_graph(w, max_angle_deg)
    proxy_of, is_proxy = greedy_proxy_clustering(nn_idx)
    return {
        "nn_idx": nn_idx,
        "nn_angle": nn_angle,
        "proxy_of": proxy_of,
        "is_proxy": is_proxy,
        "n_proxies": int(is_proxy.sum()),
    }


def montecarlo_sign_agreement(theta_deg: float, dim: int, n_samples: int,
                              seed: int = 0) -> float:
    """Paper's Monte-Carlo check that P[sign disagree] = theta/180 holds in
    high dimension (used by tests; the paper states theta/360 per
    single-sided region, i.e. theta/180 total disagreement)."""
    rng = np.random.default_rng(seed)
    a = np.zeros(dim)
    a[0] = 1.0
    b = np.zeros(dim)
    th = np.radians(theta_deg)
    b[0], b[1] = np.cos(th), np.sin(th)
    c = rng.normal(size=(n_samples, dim))
    sa = c @ a > 0
    sb = c @ b > 0
    return float(np.mean(sa != sb))

"""Offline MoR deployment: calibrate a trained model, cluster its ReLU
layers, fold the tile permutation into the weights, and emit the stacked
MoRLayer pytree the runtime consumes.

This is the paper's offline stage (§3.2) end-to-end:
  taps -> per-neuron (m, b, c) regression   [calibration.py]
  weights -> angle clusters -> proxies       [clustering.py]
  -> column permutation folded into w_gate/w_up (cols) + w_down (rows)
  -> MoRLayer pytree stacked over layers (scan-consumable)
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.calibration import (finalize_regression, init_accumulator,
                                    update_accumulator)
from repro.core.clustering import cluster_layer
from repro.core.executor import MoRExecutionPlan
from repro.core.policy import build_mor_layer


def _stack_mor(layers: List[Dict]) -> Dict:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


def attach_plans(mor, cfg: ModelConfig, mode: str,
                 capacities: Optional[Dict] = None,
                 draft_cap: Optional[float] = None):
    """Wrap calibrated MoR layers in per-layer execution plans.

    Replaces the old convention of threading bare ``(mor, mode, tile_m,
    tile_n)`` tuples through every call site: the plan carries the mode,
    tile geometry, and gather_matmul capacity from ``cfg.mor`` once, and
    the runtime (``masked_ffn`` / ``executor``) consumes it as-is.

    ``capacities`` (optional, {layer group -> (L,) fractions or scalar})
    attaches PER-LAYER calibrated gather_matmul capacities as the plan's
    traced ``cap_live`` leaf (``serving.telemetry.calibrate_capacity``'s
    output): a stacked plan rides through ``lax.scan`` with one static
    provisioning while every layer clamps to its own observed budget.

    ``draft_cap`` (optional scalar fraction) additionally stores the
    self-speculative draft budget on every plan (see
    ``executor.attach_draft_caps``); it stays dormant until the serving
    engine derives the draft twin with ``as_draft()``.

    Accepts the shapes the calibrators emit — a dict of stacked layer
    pytrees (``calibrate_lm``: plans ride through ``lax.scan`` because
    MoRExecutionPlan is a registered pytree with static aux config) or a
    list of per-layer MoRLayers (``calibrate_cnn`` / ``calibrate_tds``).
    """
    def wrap(layer, caps=None):
        if layer is None:
            return None
        if isinstance(layer, dict) and "experts" in layer:
            # expert-MoR group ({"experts": (L, E)-stacked MoRLayer}):
            # the plan wraps the stack whole; calibrated capacities come
            # back flat from the per-(layer, expert) telemetry and fold
            # to the stack's leading dims, so each scan step sees an
            # (E,)-row of per-expert budgets
            inner = layer["experts"]
            if isinstance(inner, MoRExecutionPlan):
                inner = inner.mor
            if inner is None:
                return {"experts": None}
            cap_live = None
            if caps is not None:
                cap_live = jnp.asarray(caps, jnp.float32)
                if cap_live.ndim > 0:
                    cap_live = cap_live.reshape(inner["m"].shape[:-1])
                else:
                    # scalar spec (serve --capacity): same budget for
                    # every (layer, expert) — broadcast so the stacked
                    # plan's scan/unroll can index its leading dims
                    cap_live = jnp.broadcast_to(cap_live,
                                                inner["m"].shape[:-1])
            return {"experts": MoRExecutionPlan(
                inner, mode=mode, tile_m=cfg.mor.tile_m,
                tile_n=cfg.mor.tile_n, capacity_frac=cfg.mor.capacity,
                cap_live=cap_live)}
        cap_live = None
        if caps is not None:
            cap_live = jnp.asarray(caps, jnp.float32)
            if cap_live.ndim > 0 and layer["m"].ndim == 1:
                # a single shared layer (hybrid) observed at several
                # call sites: provision for the worst of them
                cap_live = cap_live.max()
            elif cap_live.ndim == 0 and layer["m"].ndim > 1:
                # scalar spec (serve --capacity) on a stacked plan:
                # broadcast so scan/unroll can index the layer dim
                cap_live = jnp.broadcast_to(cap_live,
                                            layer["m"].shape[:1])
        return MoRExecutionPlan(layer, mode=mode, tile_m=cfg.mor.tile_m,
                                tile_n=cfg.mor.tile_n,
                                capacity_frac=cfg.mor.capacity,
                                cap_live=cap_live)

    if mor is None or mode == "dense":
        return mor
    if isinstance(mor, MoRExecutionPlan):
        out = mor
    elif isinstance(mor, list):
        out = [wrap(m) for m in mor]
    elif isinstance(mor, dict) and "enable" not in mor:
        caps = capacities or {}
        out = {k: wrap(v, caps.get(k)) for k, v in mor.items()}
    else:
        # bare single layer: only an unambiguous capacity spec is accepted
        caps = capacities
        if isinstance(caps, dict):
            assert len(caps) <= 1, \
                f"ambiguous capacities for a single MoR layer: {sorted(caps)}"
            caps = next(iter(caps.values())) if caps else None
        out = wrap(mor, caps)
    if draft_cap is not None:
        from repro.core.executor import attach_draft_caps
        out = attach_draft_caps(out, draft_cap)
    return out


def calibrate_lm(params: Dict, cfg: ModelConfig, forward: Callable,
                 batches: Iterator[Dict], n_batches: int,
                 layer_key: str = "layers") -> Tuple[Dict, Dict, Dict]:
    """Calibrate a scan-stacked LM (dense/ssm/audio families).

    -> (params with permuted FFN weights, mor pytree {layer_key: stacked},
        report dict with Pearson stats)."""
    L = cfg.n_layers
    # locate the target weight stack: mlp (w_gate|w_up) or rwkv cm w_up
    lp = params[layer_key]
    if "mlp" in lp:
        w_stack = lp["mlp"].get("w_gate", lp["mlp"]["w_up"])
    else:
        w_stack = lp["cm"]["w_up"]
    N = w_stack.shape[-1]

    acc = jax.vmap(lambda _: init_accumulator(N))(jnp.arange(L))
    upd = jax.jit(jax.vmap(update_accumulator))
    fwd = jax.jit(lambda p, b: forward(p, cfg, b, with_taps=True)[1]["taps"])
    seen = 0
    for batch in batches:
        taps = fwd(params, batch)
        acc = upd(acc, taps["p_bin"], taps["p_base"])
        seen += 1
        if seen >= n_batches:
            break
    m, b, c = jax.vmap(finalize_regression)(acc)
    m, b, c = np.asarray(m), np.asarray(b), np.asarray(c)

    mor_layers = []
    w_np = np.asarray(w_stack, np.float32)
    for l in range(L):
        cl = cluster_layer(w_np[l], cfg.mor.max_cluster_angle)
        mor_layers.append(build_mor_layer(m[l], b[l], c[l], cl, cfg.mor))
    mor_stack = _stack_mor(mor_layers)

    # fold permutations into the weights (offline, zero runtime cost)
    perm = np.asarray(mor_stack["perm"])          # (L, N)
    new_params = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy

    def permute_stack(w, axis):
        w = np.asarray(w)
        out = np.empty_like(w)
        for l in range(L):
            idx = perm[l]
            out[l] = np.take(w[l], idx, axis=axis - 1)
        return jnp.asarray(out)

    if "mlp" in lp:
        mlp = dict(lp["mlp"])
        if "w_gate" in mlp:
            mlp["w_gate"] = permute_stack(mlp["w_gate"], 2)
        mlp["w_up"] = permute_stack(mlp["w_up"], 2)
        mlp["w_down"] = permute_stack(mlp["w_down"], 1)
        new_lp = dict(lp)
        new_lp["mlp"] = mlp
        new_params[layer_key] = new_lp
    else:
        cm = dict(lp["cm"])
        cm["w_up"] = permute_stack(cm["w_up"], 2)
        cm["w_down"] = permute_stack(cm["w_down"], 1)
        new_lp = dict(lp)
        new_lp["cm"] = cm
        new_params[layer_key] = new_lp

    report = {
        "pearson_mean": float(c.mean()),
        "pearson_frac_above_T": float((c > cfg.mor.corr_threshold).mean()),
        "n_proxies_mean": float(np.mean([
            len(np.unique(np.asarray(ml["proxy_slot"])))
            for ml in mor_layers])),
        "enabled_frac": float(np.asarray(mor_stack["enable"]).mean()),
    }
    return new_params, {layer_key: mor_stack}, report


def calibrate_hybrid(params: Dict, cfg: ModelConfig, forward: Callable,
                     batches: Iterator[Dict], n_batches: int
                     ) -> Tuple[Dict, Dict, Dict]:
    """Calibrate a hybrid (mamba + shared-attention) model.

    The ONE shared block's MLP is the only ReLU-family FFN; it is
    observed at every segment boundary, so its taps come back
    (n_seg, ...)-stacked and the segment axis folds into the batch —
    one regression, one clustering pass, one MoRLayer under the
    ``"shared"`` key (which is where the runtime looks:
    ``mor.get("shared")`` in the hybrid forward / chunk paths and
    ``telemetry.mor_group_map``)."""
    mlp = params["shared"]["mlp"]
    w = mlp["w_gate"] if "w_gate" in mlp else mlp["w_up"]
    N = w.shape[-1]
    acc = init_accumulator(N)
    upd = jax.jit(update_accumulator)
    fwd = jax.jit(lambda p, b: forward(p, cfg, b, with_taps=True)[1]["taps"])
    seen = 0
    for batch in batches:
        taps = fwd(params, batch)
        acc = upd(acc, taps["p_bin"], taps["p_base"])
        seen += 1
        if seen >= n_batches:
            break
    m, b, c = finalize_regression(acc)
    m, b, c = np.asarray(m), np.asarray(b), np.asarray(c)
    cl = cluster_layer(np.asarray(w, np.float32),
                       cfg.mor.max_cluster_angle)
    ml = build_mor_layer(m, b, c, cl, cfg.mor)

    # fold the permutation into the shared MLP weights (offline)
    perm = np.asarray(ml["perm"])
    mlp2 = dict(mlp)
    if "w_gate" in mlp2:
        mlp2["w_gate"] = jnp.asarray(
            np.take(np.asarray(mlp2["w_gate"]), perm, axis=1))
    mlp2["w_up"] = jnp.asarray(
        np.take(np.asarray(mlp2["w_up"]), perm, axis=1))
    mlp2["w_down"] = jnp.asarray(
        np.take(np.asarray(mlp2["w_down"]), perm, axis=0))
    new_params = jax.tree_util.tree_map(lambda x: x, params)
    new_params["shared"] = dict(params["shared"], mlp=mlp2)

    report = {
        "pearson_mean": float(c.mean()),
        "pearson_frac_above_T": float((c > cfg.mor.corr_threshold).mean()),
        "n_proxies_mean": float(
            len(np.unique(np.asarray(ml["proxy_slot"])))),
        "enabled_frac": float(np.asarray(ml["enable"]).mean()),
    }
    return new_params, {"shared": ml}, report


def calibrate_moe(params: Dict, cfg: ModelConfig, forward: Callable,
                  batches: Iterator[Dict], n_batches: int, *,
                  cluster_experts: bool = True,
                  inject_dead_frac: float = 0.0,
                  inject_scale: float = 4.0) -> Tuple[Dict, Dict, Dict]:
    """Calibrate a scan-stacked MoE LM end to end.

    The leading dense layers get the ``calibrate_lm`` treatment
    (regression + clustering + permutation folded into the mlp weights);
    every (layer, expert) FFN additionally gets its own hybrid predictor
    fitted from routing-independent taps (``moe_taps``: each expert is
    evaluated over the FULL token stream its dispatch subsamples, so all
    E regressions share one forward pass per batch).

    ``cluster_experts=False`` builds binary-rookie-only expert layers
    (identity permutation, no proxies) — no per-expert weight
    permutation, at the cost of the spatial predictor.

    ``inject_dead_frac`` > 0 emulates a trained model's column-skewed
    ReLU sparsity (paper Fig. 1: real DNNs zero 50-90% of ReLU outputs,
    concentrated in persistently-dead neurons) on a random-init model:
    the trailing fraction of each expert's (permuted) columns gets a
    folded bias of ``-inject_scale`` observed pre-activation sigmas.
    The bias is part of the deployed model (bn_bias — exact mode zeroes
    the same neurons), so predictor and truth agree; benchmark scenarios
    use it to exercise tile skipping end to end, since random-init
    weights have no structured sparsity (measured frac_tiles_live = 1.0).

    -> (params with permuted weights,
        {"dense_layers"?: stacked MoRLayer,
         "moe_layers": {"experts": (L_moe, E)-stacked MoRLayer}},
        report)."""
    assert cfg.family == "moe"
    L_d = cfg.first_k_dense
    L_m = cfg.n_layers - L_d
    E = cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff

    acc_e = jax.vmap(jax.vmap(lambda _: init_accumulator(f)))(
        jnp.zeros((L_m, E)))
    upd_e = jax.jit(jax.vmap(jax.vmap(update_accumulator)))
    acc_d = None
    if L_d:
        N_d = params["dense_layers"]["mlp"].get(
            "w_gate", params["dense_layers"]["mlp"]["w_up"]).shape[-1]
        acc_d = jax.vmap(lambda _: init_accumulator(N_d))(jnp.arange(L_d))
        upd_d = jax.jit(jax.vmap(update_accumulator))
    fwd = jax.jit(lambda p, b: forward(p, cfg, b, with_taps=True)[1])
    seen = 0
    for batch in batches:
        aux = fwd(params, batch)
        taps = aux["taps"]                        # (L_m, E, T, f)
        acc_e = upd_e(acc_e, taps["p_bin"], taps["p_base"])
        if L_d:
            acc_d = upd_d(acc_d, aux["dense_taps"]["p_bin"],
                          aux["dense_taps"]["p_base"])
        seen += 1
        if seen >= n_batches:
            break
    m, b, c = jax.vmap(jax.vmap(finalize_regression))(acc_e)
    m, b, c = np.asarray(m), np.asarray(b), np.asarray(c)
    # observed per-column base pre-activation sigma (for injection)
    n = np.maximum(np.asarray(acc_e["count"]), 1.0)[..., None]
    sig = np.sqrt(np.maximum(
        np.asarray(acc_e["syy"]) / n
        - (np.asarray(acc_e["sy"]) / n) ** 2, 0.0))

    new_params = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy
    mp = dict(params["moe_layers"])
    moe_p = dict(mp["moe"])
    glu = "w_gate" in moe_p
    w_np = np.asarray(moe_p.get("w_gate", moe_p["w_up"]), np.float32)
    tn = min(cfg.mor.tile_n, f)
    n_dead = 0
    if inject_dead_frac > 0:
        # whole trailing column-tiles so deadness is tile-resolvable
        n_dead = max(int(inject_dead_frac * f) // tn * tn, tn)
        n_dead = min(n_dead, f - tn)              # keep a live leading tile

    w_gate_new = np.array(moe_p["w_gate"], np.float32) if glu else None
    w_up_new = np.array(moe_p["w_up"], np.float32)
    w_down_new = np.array(moe_p["w_down"], np.float32)
    layer_stacks = []
    for l in range(L_m):
        per_expert = []
        for e in range(E):
            cl = (cluster_layer(w_np[l, e], cfg.mor.max_cluster_angle)
                  if cluster_experts else None)
            ml = build_mor_layer(m[l, e], b[l, e], c[l, e], cl, cfg.mor)
            perm = np.asarray(ml["perm"])
            if cluster_experts:
                if glu:
                    w_gate_new[l, e] = w_gate_new[l, e][:, perm]
                w_up_new[l, e] = w_up_new[l, e][:, perm]
                w_down_new[l, e] = w_down_new[l, e][perm, :]
            if n_dead:
                bias = np.asarray(ml["bn_bias"]).copy()
                bias[f - n_dead:] -= inject_scale * sig[l, e][perm][
                    f - n_dead:]
                ml["bn_bias"] = jnp.asarray(bias, jnp.float32)
                # a column whose folded bias exceeds its dynamic range is
                # statically dead — enabling its rookie is always safe
                en = np.asarray(ml["enable"]).copy()
                en[f - n_dead:] = True
                ml["enable"] = jnp.asarray(en)
            per_expert.append(ml)
        layer_stacks.append(_stack_mor(per_expert))
    experts_stack = _stack_mor(layer_stacks)      # leaves (L_m, E, ...)
    if cluster_experts or n_dead:
        if glu:
            moe_p["w_gate"] = jnp.asarray(w_gate_new)
        moe_p["w_up"] = jnp.asarray(w_up_new)
        moe_p["w_down"] = jnp.asarray(w_down_new)
        mp["moe"] = moe_p
        new_params["moe_layers"] = mp

    mor: Dict = {"moe_layers": {"experts": experts_stack}}
    report = {
        "pearson_mean": float(c.mean()),
        "pearson_frac_above_T": float((c > cfg.mor.corr_threshold).mean()),
        "enabled_frac": float(np.asarray(experts_stack["enable"]).mean()),
        "injected_dead_cols": int(n_dead),
    }

    if L_d:
        md, bd, cd = jax.vmap(finalize_regression)(acc_d)
        md, bd, cd = np.asarray(md), np.asarray(bd), np.asarray(cd)
        lp = params["dense_layers"]
        wd_np = np.asarray(lp["mlp"].get("w_gate", lp["mlp"]["w_up"]),
                           np.float32)
        dense_layers = []
        for l in range(L_d):
            cl = cluster_layer(wd_np[l], cfg.mor.max_cluster_angle)
            dense_layers.append(build_mor_layer(md[l], bd[l], cd[l], cl,
                                                cfg.mor))
        dense_stack = _stack_mor(dense_layers)
        perm = np.asarray(dense_stack["perm"])

        def permute_stack(w, axis):
            w = np.asarray(w)
            out = np.empty_like(w)
            for l in range(L_d):
                out[l] = np.take(w[l], perm[l], axis=axis - 1)
            return jnp.asarray(out)

        mlp = dict(lp["mlp"])
        if "w_gate" in mlp:
            mlp["w_gate"] = permute_stack(mlp["w_gate"], 2)
        mlp["w_up"] = permute_stack(mlp["w_up"], 2)
        mlp["w_down"] = permute_stack(mlp["w_down"], 1)
        new_lp = dict(lp)
        new_lp["mlp"] = mlp
        new_params["dense_layers"] = new_lp
        mor["dense_layers"] = dense_stack
        report["dense_pearson_mean"] = float(cd.mean())
    return new_params, mor, report


def calibrate_cnn(params: Dict, state: Dict, cfg: ModelConfig,
                  forward: Callable, batches: Iterator[Dict],
                  n_batches: int) -> Tuple[List, Dict]:
    """Calibrate the paper's CNNs (per-conv-layer MoR with BN folding).
    -> (mor list aligned with conv layers, report)."""
    from repro.models.cnn import bn_fold, layer_weight_matrices
    n_layers = len(params["layers"])
    accs = [init_accumulator(lp["w"].shape[-1]) for lp in params["layers"]]
    upd = jax.jit(update_accumulator)
    fwd = jax.jit(lambda p, s, im: forward(p, s, cfg, im, train=False,
                                           with_taps=True))
    seen = 0
    for batch in batches:
        _, _, aux = fwd(params, state, batch["images"])
        for i, tap in enumerate(aux["taps"]):
            accs[i] = upd(accs[i], tap["p_bin"], tap["p_base"])
        seen += 1
        if seen >= n_batches:
            break
    mors = []
    cs = []
    for i, lp in enumerate(params["layers"]):
        m, b, c = finalize_regression(accs[i])
        w = np.asarray(lp["w"].reshape(-1, lp["w"].shape[-1]), np.float32)
        cl = cluster_layer(w, cfg.mor.max_cluster_angle)
        bn_s = bn_b = None
        if cfg.batchnorm:
            s, bias = bn_fold(lp["bn"], state["bn"][i])
            bn_s, bn_b = np.asarray(s), np.asarray(bias)
        mors.append(build_mor_layer(np.asarray(m), np.asarray(b),
                                    np.asarray(c), cl, cfg.mor,
                                    bn_scale=bn_s, bn_bias=bn_b))
        cs.append(np.asarray(c))
    report = {
        "pearson_mean": float(np.mean([c.mean() for c in cs])),
        "pearson_per_layer": [float(c.mean()) for c in cs],
        "enabled_frac": float(np.mean(
            [np.asarray(m["enable"]).mean() for m in mors])),
    }
    return mors, report


def calibrate_tds(params: Dict, cfg: ModelConfig, forward: Callable,
                  batches: Iterator[Dict], n_batches: int
                  ) -> Tuple[List, Dict]:
    """Calibrate TDS FC1 layers (taps alternate conv/fc — fc are odd)."""
    n_layers = len(params["layers"])
    accs = [init_accumulator(cfg.d_ff) for _ in range(n_layers)]
    upd = jax.jit(update_accumulator)
    fwd = jax.jit(lambda p, b: forward(p, cfg, b, with_taps=True))
    seen = 0
    for batch in batches:
        _, aux = fwd(params, batch)
        fc_taps = aux["taps"][1::2]       # conv tap, fc tap per layer
        for i, tap in enumerate(fc_taps):
            accs[i] = upd(accs[i], tap["p_bin"], tap["p_base"])
        seen += 1
        if seen >= n_batches:
            break
    mors = []
    for i, lp in enumerate(params["layers"]):
        m, b, c = finalize_regression(accs[i])
        w = np.asarray(lp["fc1"], np.float32)
        cl = cluster_layer(w, cfg.mor.max_cluster_angle)
        # the FC bias folds into the predictor's affine term
        mors.append(build_mor_layer(
            np.asarray(m), np.asarray(b), np.asarray(c), cl, cfg.mor,
            bn_bias=np.asarray(lp["fc1_b"])))
    report = {"pearson_mean": float(np.mean(
        [np.asarray(finalize_regression(a)[2]).mean() for a in accs]))}
    return mors, report

"""Offline calibration (paper §3.2.1): streaming per-neuron linear
regression between binarised and base-precision pre-activations.

Uses Welford-style moment accumulation so calibration streams over an
arbitrary number of batches in O(N) memory per layer — no activation
series is ever stored (important when a 'neuron' count is d_ff = 49152).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# Accumulator pytree per layer: first/second moments of (x=p_bin, y=p_base).
CalibAccumulator = Dict[str, jax.Array]


def init_accumulator(n: int) -> CalibAccumulator:
    z = jnp.zeros((n,), jnp.float64 if jax.config.jax_enable_x64
                  else jnp.float32)
    return {"count": jnp.zeros((), z.dtype), "sx": z, "sy": z,
            "sxx": z, "syy": z, "sxy": z}


def update_accumulator(acc: CalibAccumulator, p_bin: jax.Array,
                       p_base: jax.Array) -> CalibAccumulator:
    """p_bin/p_base: (..., N) pre-activation samples for this batch."""
    x = p_bin.reshape(-1, p_bin.shape[-1]).astype(acc["sx"].dtype)
    y = p_base.reshape(-1, p_base.shape[-1]).astype(acc["sx"].dtype)
    return {
        "count": acc["count"] + x.shape[0],
        "sx": acc["sx"] + x.sum(0),
        "sy": acc["sy"] + y.sum(0),
        "sxx": acc["sxx"] + (x * x).sum(0),
        "syy": acc["syy"] + (y * y).sum(0),
        "sxy": acc["sxy"] + (x * y).sum(0),
    }


def finalize_regression(acc: CalibAccumulator, eps: float = 1e-12
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (m, b, c): slope, intercept, Pearson correlation per neuron.

    Degenerate neurons (zero variance on either side) get c = 0 so the
    threshold test disables the binary rookie for them."""
    n = jnp.maximum(acc["count"], 1.0)
    mx, my = acc["sx"] / n, acc["sy"] / n
    vx = acc["sxx"] / n - mx * mx
    vy = acc["syy"] / n - my * my
    cov = acc["sxy"] / n - mx * my
    m = cov / jnp.maximum(vx, eps)
    b = my - m * mx
    denom = jnp.sqrt(jnp.maximum(vx, eps) * jnp.maximum(vy, eps))
    c = jnp.where((vx > eps) & (vy > eps), cov / denom, 0.0)
    return (m.astype(jnp.float32), b.astype(jnp.float32),
            c.astype(jnp.float32))


def calibrate_from_taps(tap_stream, n: int) -> Tuple[jax.Array, jax.Array,
                                                     jax.Array]:
    """Convenience: consume an iterator of (p_bin, p_base) batch pairs."""
    acc = init_accumulator(n)
    upd = jax.jit(update_accumulator)
    for p_bin, p_base in tap_stream:
        acc = upd(acc, p_bin, p_base)
    return finalize_regression(acc)

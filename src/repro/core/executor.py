"""MoR execution plans: one predictor pass per layer call, reused
everywhere downstream.

The paper's speedup model (§4.1) runs the cheap binCU predictor strictly
ahead of the heavy compute — once.  ``MoRExecutionPlan`` is the runtime
embodiment of that contract: a per-layer, compile-once bundle of
(MoRLayer, mode, tile geometry, capacity) whose ``predict`` method
produces a single :class:`MoRPrediction`, and whose matmul helpers all
consume that one prediction.  The GLU path in particular threads one
tile mask through the gate matmul, the up-projection, AND the
down-projection row skip — three savings from one predictor evaluation.

Execution modes (see ``core/masked_ffn.py`` for the thin dispatcher):

  dense  — plain matmul, predictor off.
  exact  — full compute, then zero the neurons the hybrid predictor
           would have skipped (bit-identical to the paper's accelerator
           output; accuracy-evaluation mode).
  tiled  — tile-granular skipping semantics in pure jnp: the oracle for
           the Pallas kernels.
  kernel — Pallas fast path: the fused ``kernels.ops.mor_tile_mask``
           predictor (binary rookie int8 matmul + fitted line + proxy
           AND, reduced to tile liveness in one kernel) feeds
           ``gather_matmul``, which only DMAs live weight tiles, under a
           static ``capacity`` budget; the down-projection skips dead
           contraction blocks via ``masked_matmul_kdim``.
  shadow — the dense-oracle scoring twin (predictor-quality
           observability): PROPAGATES the plain dense activations (so a
           shadow forward is the reference computation, bit-for-bit the
           dense path) while evaluating the predictor alongside and
           scoring its tile decisions against the dense truth —
           false-skip / false-keep tile counts, neuron sign agreement,
           and the output-error norm the skips would have caused land
           in the stats dict as ``shadow_*`` leaves.  The serving
           engine samples 1-in-N dispatches through ``as_shadow()``
           twins of the active plans and drains the scores through the
           device metrics block.

Plans are registered pytrees: the MoRLayer is the only child, the mode /
tile / capacity knobs are static aux data.  A plan built from a stacked
(L-leading) MoRLayer pytree can therefore ride through ``jax.lax.scan``
— each scan step sees a per-layer plan with identical static config,
which is exactly how ``deploy.attach_plans`` wires calibrated models.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.predictor import MoRLayer, hybrid_predict
from repro.core.policy import expand_tile_mask, tile_mask_from_neuron_mask

MODES = ("dense", "exact", "tiled", "kernel", "shadow", "scored")

# per-layer predictor-quality leaves the shadow mode adds to its stats
# dict (int tile counters + f32 fractions; the obs device block packs
# them into its quality lanes)
SHADOW_STAT_KEYS = ("shadow_tiles", "shadow_false_skip",
                    "shadow_false_keep", "shadow_truth_live",
                    "shadow_sign_agree", "shadow_err")


def _act(h, activation: str):
    if activation == "relu":
        return jax.nn.relu(h)
    if activation == "relu2":
        return jnp.square(jax.nn.relu(h))
    raise ValueError(f"MoR requires a ReLU-family activation, got {activation!r}")


def _dense_stats(shadow: bool = False) -> Dict[str, jax.Array]:
    z = jnp.zeros((), jnp.float32)
    zi = jnp.zeros((), jnp.int32)
    out = {"frac_computed": jnp.ones((), jnp.float32),
           "frac_tiles_live": jnp.ones((), jnp.float32),
           "frac_tiles_computed": jnp.ones((), jnp.float32),
           "frac_mispredicted_zero": z,
           # integer tile counters (obs device-metrics lanes); dense
           # has no tile grid, so both are zero — the keyset still has
           # to match MoRPrediction.stats() for per-layer stacking
           "n_tiles": zi, "tiles_skipped": zi}
    if shadow:
        # inactive layers inside a shadow-mode stack score nothing but
        # must emit the same keyset so per-layer stacking stays regular
        out.update(_zero_shadow_stats())
    return out


def _zero_shadow_stats() -> Dict[str, jax.Array]:
    z = jnp.zeros((), jnp.float32)
    zi = jnp.zeros((), jnp.int32)
    return {"shadow_tiles": zi, "shadow_false_skip": zi,
            "shadow_false_keep": zi, "shadow_truth_live": zi,
            "shadow_sign_agree": z, "shadow_err": z}


class MoRPrediction:
    """The result of ONE predictor pass, shared by every consumer.

    ``computed``: (T, N) bool neuron mask, or None in kernel mode (the
    fused kernel reduces straight to tiles without materialising it).
    ``tiles``: (T/tile_m, N/tile_n) bool tile-liveness mask.
    ``kept``: tiles actually computed under the capacity budget (equals
    ``tiles`` when capacity covers every live tile).
    ``kernel_counts``: (n_live, n_computed) tile counters reported by
    ``gather_matmul`` itself in kernel mode — the authoritative source
    for the serving telemetry's realised-skip stats on that path."""

    __slots__ = ("computed", "tiles", "kept", "kernel_counts")

    def __init__(self, computed: Optional[jax.Array], tiles: jax.Array,
                 kept: Optional[jax.Array] = None):
        self.computed = computed
        self.tiles = tiles
        self.kept = tiles if kept is None else kept
        self.kernel_counts = None

    def keep_mask(self, T: int, N: int, tile_m: int, tile_n: int):
        return expand_tile_mask(self.kept, tile_m, tile_n, T, N)

    def stats(self) -> Dict[str, jax.Array]:
        n_tiles = float(self.tiles.size)
        if self.kernel_counts is not None:
            n_live, n_comp = self.kernel_counts
            tiles_live = n_live.astype(jnp.float32) / n_tiles
            tiles_computed = n_comp.astype(jnp.float32) / n_tiles
            n_computed = n_comp.astype(jnp.int32)
        else:
            tiles_live = self.tiles.mean(dtype=jnp.float32)
            # realised compute after the capacity clamp — the number the
            # serving telemetry compares against the demand
            tiles_computed = self.kept.mean(dtype=jnp.float32)
            n_computed = self.kept.sum(dtype=jnp.int32)
        if self.computed is not None:
            frac_computed = self.computed.mean(dtype=jnp.float32)
        else:
            # kernel mode: the neuron mask never exists; report the
            # tile-level compute fraction (its tight upper bound).
            frac_computed = tiles_live
        n_tiles_i = jnp.asarray(int(n_tiles), jnp.int32)
        return {"frac_computed": frac_computed,
                "frac_tiles_live": tiles_live,
                "frac_tiles_computed": tiles_computed,
                "frac_mispredicted_zero": jnp.zeros((), jnp.float32),
                # exact integer tile counters for the obs device block
                "n_tiles": n_tiles_i,
                "tiles_skipped": n_tiles_i - n_computed}


@jax.tree_util.register_pytree_node_class
class MoRExecutionPlan:
    """Per-layer, compile-once MoR execution plan.

    Pytree contract: ``mor`` (a MoRLayer dict pytree, possibly stacked
    over layers, possibly None) and ``cap_live`` (optional TRACED
    per-layer capacity fraction, possibly (L,)-stacked) are the
    children; ``mode``/``tile_m``/``tile_n``/``capacity_frac`` are
    static aux data, so plans survive ``tree_map``, ``lax.scan``
    slicing, and jit boundaries unchanged.

    ``capacity_frac`` (static) provisions the gather_matmul slot list —
    one compiled body for a whole layer scan.  ``cap_live`` (traced) is
    the telemetry-calibrated PER-LAYER budget clamped under it
    (``serving.telemetry.calibrate_capacity``): updating its values
    re-provisions every layer without recompiling the serving step.

    ``draft_cap`` (traced, optional) is a SECOND capacity budget for
    self-speculative decoding: the same weights/predictor with a much
    harsher clamp act as the draft model.  The static ``draft`` flag
    selects which budget is active — draft=True plans read ``draft_cap``
    where target plans read ``cap_live`` — so the serving engine
    compiles exactly two step executables (target + draft treedefs) and
    sweeping draft_cap VALUES never recompiles either.
    """

    def __init__(self, mor: Optional[MoRLayer], *, mode: str = "dense",
                 tile_m: int = 8, tile_n: int = 128,
                 capacity_frac: float = 1.0, cap_live=None,
                 draft_cap=None, draft: bool = False):
        if mode not in MODES:
            raise ValueError(f"unknown MoR mode {mode!r}")
        self.mor = mor
        self.mode = mode
        self.tile_m = tile_m
        self.tile_n = tile_n
        self.capacity_frac = capacity_frac
        self.cap_live = cap_live
        self.draft_cap = draft_cap
        self.draft = draft

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return ((self.mor, self.cap_live, self.draft_cap),
                (self.mode, self.tile_m, self.tile_n, self.capacity_frac,
                 self.draft))

    @classmethod
    def tree_unflatten(cls, aux, children):
        mode, tile_m, tile_n, capacity_frac, draft = aux
        return cls(children[0], mode=mode, tile_m=tile_m, tile_n=tile_n,
                   capacity_frac=capacity_frac, cap_live=children[1],
                   draft_cap=children[2], draft=draft)

    def __repr__(self):
        return (f"MoRExecutionPlan(mode={self.mode!r}, tile_m={self.tile_m},"
                f" tile_n={self.tile_n}, capacity_frac={self.capacity_frac},"
                f" calibrated={self.mor is not None},"
                f" per_layer_capacity={self.cap_live is not None},"
                f" draft={self.draft})")

    def as_draft(self, draft_cap=None) -> "MoRExecutionPlan":
        """The draft-mode twin of this plan: same weights and leaves,
        ``draft=True`` so ``draft_cap`` becomes the active budget.  When
        ``draft_cap`` is given it replaces the stored leaf (scalar or
        per-layer, broadcastable like ``cap_live``)."""
        dc = self.draft_cap if draft_cap is None else draft_cap
        return MoRExecutionPlan(
            self.mor, mode=self.mode, tile_m=self.tile_m, tile_n=self.tile_n,
            capacity_frac=self.capacity_frac, cap_live=self.cap_live,
            draft_cap=dc, draft=True)

    def as_shadow(self) -> "MoRExecutionPlan":
        """The dense-oracle scoring twin of this plan: same leaves and
        capacity budgets, ``mode="shadow"`` so the forward propagates
        plain dense activations while scoring the predictor's decisions
        against them.  Uncalibrated plans pass through unchanged (there
        is no predictor to score)."""
        if self.mor is None:
            return self
        return MoRExecutionPlan(
            self.mor, mode="shadow", tile_m=self.tile_m,
            tile_n=self.tile_n, capacity_frac=self.capacity_frac,
            cap_live=self.cap_live, draft_cap=self.draft_cap,
            draft=self.draft)

    def as_scored(self) -> "MoRExecutionPlan":
        """The IN-STEP scoring twin of a TILED plan: same dense-oracle
        scoring as ``as_shadow()``, but the forward propagates the
        tile-MASKED activations — bitwise identical to what the tiled
        plan computes, because tiled mode itself evaluates the dense
        matmul and selects (``masked_matmul``).  A scored dispatch can
        therefore REPLACE the primary tiled dispatch outright: one
        forward, tokens unchanged, and the only extra work is the
        elementwise truth/score arithmetic — this is what keeps the
        sampled-scoring overhead a few percent instead of a whole
        second forward.  Only valid as a stand-in for ``tiled`` plans
        (kernel's gather matmul may reassociate accumulation; exact
        mode is neuron- not tile-granular)."""
        if self.mor is None:
            return self
        assert self.mode in ("tiled", "scored"), \
            f"as_scored() replaces tiled plans only, not {self.mode!r}"
        return MoRExecutionPlan(
            self.mor, mode="scored", tile_m=self.tile_m,
            tile_n=self.tile_n, capacity_frac=self.capacity_frac,
            cap_live=self.cap_live, draft_cap=self.draft_cap,
            draft=self.draft)

    # -- predicates --------------------------------------------------------
    @property
    def active(self) -> bool:
        """True when the predictor actually runs (calibrated + not dense)."""
        return self.mor is not None and self.mode != "dense"

    @property
    def _active_cap(self):
        """The traced capacity budget in force: ``draft_cap`` when this
        plan runs as the speculative drafter, ``cap_live`` otherwise."""
        if self.draft and self.draft_cap is not None:
            return self.draft_cap
        return self.cap_live

    # -- the single predictor pass -----------------------------------------
    def predict(self, x: jax.Array, w: jax.Array, *,
                preact_full: Optional[jax.Array] = None,
                residual: Optional[jax.Array] = None,
                row_mask: Optional[jax.Array] = None) -> MoRPrediction:
        """Run the hybrid predictor exactly once -> MoRPrediction.

        ``kernel`` mode routes through the fused Pallas
        ``kernels.ops.mor_tile_mask`` (binary rookie + fitted line +
        proxy AND + tile reduction in one pass over the activations);
        every other mode uses the pure-jnp ``hybrid_predict`` oracle.

        ``row_mask`` (optional (T,) bool, True = real row) force-skips
        dead input rows: MoE expert buffers are capacity-padded with the
        zero row, and without the mask those rows can mark tiles live
        (the fitted intercept alone may predict non-zero at x = 0) and
        pollute the per-expert liveness telemetry the capacity
        calibration reads.  Masked rows use the kernel's forced-skip
        sentinel (proxy state 2), the same mechanism as shape padding.
        """
        assert self.active, "predict() on an inactive plan"
        mor = self.mor
        if self.mode == "kernel" and preact_full is None:
            from repro.kernels import ops as kops
            # proxy rookie at base precision (only the unique proxy
            # columns are touched; they live in the always-computed
            # leading tiles of the permuted layout)
            slot = jnp.maximum(mor["proxy_slot"], 0)
            proxy_cols = jnp.take(w, slot, axis=1)
            proxy_pre = jax.lax.dot_general(
                x, proxy_cols, (((x.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            proxy_relu_in = (proxy_pre * mor["bn_scale"][slot]
                             + mor["bn_bias"][slot])
            if residual is not None:
                proxy_relu_in = proxy_relu_in + jnp.take(
                    residual.astype(jnp.float32), slot, axis=-1)
            proxy_neg = (proxy_relu_in < 0.0) | (mor["proxy_slot"] < 0)
            pn = proxy_neg.astype(jnp.int8)
            if row_mask is not None:
                pn = jnp.where(row_mask[:, None], pn, jnp.int8(2))
            # proxies themselves are always computed: fold ~is_proxy into
            # the kernel's enable row
            mor_eff = dict(mor)
            mor_eff["enable"] = mor["enable"] & ~mor["is_proxy"]
            tiles = kops.mor_tile_mask(x, w, mor_eff, pn,
                                       residual=residual,
                                       tile_m=self.tile_m, tile_n=self.tile_n)
            return MoRPrediction(None, tiles,
                                 kept=self._capacity_clip(tiles))
        computed = hybrid_predict(x, w, mor, preact_full=preact_full,
                                  residual=residual)
        if row_mask is not None:
            computed = computed & row_mask[..., None]
        tiles = tile_mask_from_neuron_mask(
            computed.reshape(-1, computed.shape[-1]), self.tile_m, self.tile_n)
        # shadow mode mirrors whatever clip the active plan would apply
        # (identity when uncapped), so its scored `kept` mask equals the
        # tiled/kernel decision it shadows
        kept = (self._capacity_clip(tiles)
                if self.mode in ("kernel", "shadow", "scored")
                or self._active_cap is not None
                else None)
        return MoRPrediction(computed, tiles, kept=kept)

    def _capacity_clip(self, tiles: jax.Array) -> jax.Array:
        """Capacity truncation mirroring gather_matmul's slot list: only
        the first ``capacity`` live tiles (row-major) are computed.  The
        static ``capacity_frac`` provisions; the traced ``cap_live``
        (per-layer calibrated fraction) clamps under it."""
        cap_live = self._active_cap
        if self.capacity_frac >= 1.0 and cap_live is None:
            return tiles
        n_tiles = tiles.shape[0] * tiles.shape[1]
        capacity = jnp.asarray(max(1, int(self.capacity_frac * n_tiles)),
                               jnp.int32)
        if cap_live is not None:
            capacity = jnp.minimum(capacity, jnp.maximum(1, jnp.ceil(
                jnp.asarray(cap_live, jnp.float32) * n_tiles)
            ).astype(jnp.int32))
        flat = tiles.reshape(-1)
        live_rank = jnp.cumsum(flat) - 1
        return (flat & (live_rank < capacity)).reshape(tiles.shape)

    # -- mask-consuming matmuls --------------------------------------------
    def masked_matmul(self, x: jax.Array, w: jax.Array,
                      pred: MoRPrediction) -> jax.Array:
        """x @ w with ``pred``'s tile mask applied — dead tiles are exact
        zeros.  kernel mode DMAs only live tiles (gather_matmul);
        tiled/exact modes compute densely and select (the jnp oracle).
        Returns float32 pre-activations."""
        T, N = x.shape[0], w.shape[1]
        if self.mode == "kernel":
            from repro.kernels import ops as kops
            # gather_matmul already selects dead/overflow tiles to exact
            # zero internally (same capacity-clipped mask as pred.kept);
            # re-applying the keep mask here would be a redundant (T, N)
            # expansion + select on the serving hot path
            pre, n_live, n_comp = kops.gather_matmul(
                x, w, pred.tiles, capacity_frac=self.capacity_frac,
                capacity_frac_live=self._active_cap, tile_m=self.tile_m,
                tile_n=self.tile_n, with_counts=True)
            # the kernel's own tile counters feed the serving telemetry
            pred.kernel_counts = (n_live, n_comp)
            return pre.astype(jnp.float32)
        pre = (x @ w).astype(jnp.float32)
        keep = pred.keep_mask(T, N, self.tile_m, self.tile_n)
        return jnp.where(keep, pre, 0.0)

    def down_matmul(self, h: jax.Array, w_down: jax.Array,
                    pred: Optional[MoRPrediction]) -> jax.Array:
        """h @ w_down with dead hidden tiles skipped along the CONTRACTION
        dim (the paper's 3x GLU saving: a dead gate tile kills the
        matching up column and down row).  Dead h tiles are exact zeros,
        so the skip is numerically exact.  kernel mode uses the
        contraction-masked Pallas kernel; other modes rely on the zeros
        (XLA sees a dense matmul — the skip is semantic only)."""
        if pred is None or self.mode != "kernel":
            return h @ w_down
        from repro.kernels import ops as kops
        return kops.masked_matmul_kdim(h, w_down, pred.kept,
                                       tile_m=self.tile_m,
                                       tile_k=self.tile_n).astype(h.dtype)

    # -- the mor_relu_matmul / mor_ffn_apply entry points -------------------
    def relu_matmul(self, x: jax.Array, w: jax.Array, *,
                    activation: str = "relu",
                    residual: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """y = act(x @ w) with MoR skipping; x: (T, K), w: (K, N) permuted.
        Exactly ONE predictor evaluation regardless of mode."""
        y, pred, stats = self._relu_matmul_pred(x, w, activation=activation,
                                                residual=residual)
        return y, stats

    def _relu_matmul_pred(self, x, w, *, activation: str,
                          residual: Optional[jax.Array] = None,
                          row_mask: Optional[jax.Array] = None):
        """relu_matmul that also returns the MoRPrediction for reuse
        (the GLU path threads it into the up/down projections)."""
        T, N = x.shape[0], w.shape[1]
        if not self.active:
            pre = x @ w
            y = _act(pre + (residual if residual is not None else 0.0),
                     activation)
            return y, None, _dense_stats(
                shadow=self.mode in ("shadow", "scored"))
        mor = self.mor

        if self.mode in ("shadow", "scored"):
            return self._shadow_relu_matmul(x, w, activation=activation,
                                            residual=residual,
                                            row_mask=row_mask)

        if self.mode == "exact":
            pre = (x @ w).astype(jnp.float32)
            pre_bn = pre * mor["bn_scale"] + mor["bn_bias"]
            if residual is not None:
                pre_bn = pre_bn + residual
            pred = self.predict(x, w, preact_full=pre, residual=residual,
                                row_mask=row_mask)
            y = jnp.where(pred.computed, _act(pre_bn, activation),
                          0.0).astype(x.dtype)
            truly_nonzero = pre_bn > 0
            if row_mask is not None:
                truly_nonzero = truly_nonzero & row_mask[:, None]
            stats = pred.stats()
            stats["frac_mispredicted_zero"] = (
                ~pred.computed & truly_nonzero).mean(dtype=jnp.float32)
            return y, pred, stats

        # tiled / kernel: one predictor pass -> tile mask -> masked matmul
        pred = self.predict(x, w, residual=residual, row_mask=row_mask)
        pre = self.masked_matmul(x, w, pred)
        pre_bn = pre * mor["bn_scale"] + mor["bn_bias"]
        if residual is not None:
            pre_bn = pre_bn + residual
        keep = pred.keep_mask(T, N, self.tile_m, self.tile_n)
        y = jnp.where(keep, _act(pre_bn, activation), 0.0).astype(x.dtype)
        return y, pred, pred.stats()

    def _shadow_relu_matmul(self, x, w, *, activation: str,
                            residual: Optional[jax.Array] = None,
                            row_mask: Optional[jax.Array] = None):
        """Dense-oracle scoring pass (modes "shadow" / "scored"):
        compute the DENSE reference pre-activations, run the predictor
        exactly as the tiled/kernel plan would (no ``preact_full`` —
        same decision basis, same capacity clip), and score the tile
        decisions against the dense truth; the stats dict gains the
        ``shadow_*`` quality leaves.  Mode "shadow" propagates the
        DENSE activations (a standalone twin forward IS the reference
        computation); mode "scored" propagates the tile-MASKED
        activations, bitwise identical to the tiled path it stands in
        for (inside a kept tile both paths apply the same elementwise
        BN/act chain to the same dense matmul result; outside, both
        are exact zeros)."""
        mor = self.mor
        T, N = x.shape[0], w.shape[1]
        pre = (x @ w).astype(jnp.float32)
        pre_bn = pre * mor["bn_scale"] + mor["bn_bias"]
        if residual is not None:
            pre_bn = pre_bn + residual
        pred = self.predict(x, w, residual=residual, row_mask=row_mask)
        truth = pre_bn > 0
        if row_mask is not None:
            truth = truth & row_mask[:, None]
        truth_tiles = tile_mask_from_neuron_mask(
            truth.reshape(-1, N), self.tile_m, self.tile_n)
        stats = pred.stats()
        # exact integer tile counters: a false skip silently zeroes a
        # truly-live tile; a false keep burns compute on a dead one
        stats["shadow_tiles"] = jnp.asarray(int(truth_tiles.size),
                                            jnp.int32)
        stats["shadow_false_skip"] = (
            truth_tiles & ~pred.kept).sum(dtype=jnp.int32)
        stats["shadow_false_keep"] = (
            pred.kept & ~truth_tiles).sum(dtype=jnp.int32)
        stats["shadow_truth_live"] = truth_tiles.sum(dtype=jnp.int32)
        stats["shadow_sign_agree"] = (
            pred.computed == truth).mean(dtype=jnp.float32)
        y = _act(pre_bn, activation)
        # relative output-error norm the active plan's skips would have
        # caused on THIS dispatch (<= 1 by construction: the masked
        # output is a subset of the dense one)
        y_mor = jnp.where(pred.keep_mask(T, N, self.tile_m, self.tile_n),
                          y, 0.0)
        norm = jnp.sqrt(jnp.sum(jnp.square(y)))
        stats["shadow_err"] = (jnp.sqrt(jnp.sum(jnp.square(y_mor - y)))
                               / (norm + 1e-6))
        out = y_mor if self.mode == "scored" else y
        return out.astype(x.dtype), pred, stats

    def ffn(self, x: jax.Array, w_up: jax.Array, w_down: jax.Array, *,
            activation: str, w_gate: Optional[jax.Array] = None,
            row_mask: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Full FFN with MoR on the ReLU pre-activation.

        GLU case (relufied SwiGLU -> ReLU-GLU): h = relu(x@w_gate) *
        (x@w_up).  The SINGLE gate prediction gates the up matmul (same
        tile mask — a skipped gate neuron zeroes h, so its up column is
        dead work) and the down matmul (dead h rows skipped along the
        contraction).  One predictor evaluation total.
        """
        if w_gate is not None:
            g, pred, stats = self._relu_matmul_pred(x, w_gate,
                                                    activation=activation,
                                                    row_mask=row_mask)
            if pred is not None and self.mode in ("tiled", "kernel",
                                                  "scored"):
                u = self.masked_matmul(x, w_up, pred).astype(x.dtype)
            else:
                # dense / exact: g already zeroes h where skipped; the
                # up matmul stays dense (exact mode is neuron-granular)
                u = x @ w_up
            h = (g * u).astype(x.dtype)
        else:
            h, pred, stats = self._relu_matmul_pred(x, w_up,
                                                    activation=activation,
                                                    row_mask=row_mask)
        return self.down_matmul(h, w_down, pred), stats

    # -- batched-expert form (MoE): leading E axis on everything -----------
    def expert_ffn(self, eb: jax.Array, w_up: jax.Array, w_down: jax.Array,
                   *, activation: str, w_gate: Optional[jax.Array] = None,
                   row_mask: Optional[jax.Array] = None,
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """``ffn`` over a stack of experts: eb (E, C, d), weights
        (E, d, f) / (E, f, d), ``self.mor`` an (E,)-stacked MoRLayer and
        ``self.cap_live`` an optional scalar-or-(E,) calibrated budget.

        The per-expert plan (identical static config, per-expert leaves)
        runs under ``jax.vmap``, so the fused ``mor_tile_mask`` /
        ``gather_matmul`` Pallas kernels trace ONCE and batch over the
        expert grid — on TPU the batching rule prepends the expert axis
        to the kernel grid, giving per-expert DMA skipping with
        per-expert ``cap_live`` clamps from one compiled body.

        ``row_mask`` (E, C) marks the rows of each expert's capacity
        buffer that hold real routed tokens; padding rows are force-
        skipped (see ``predict``).  Returns (out (E, C, d), stats with
        (E,)-shaped realised skip fractions — the per-(layer, expert)
        telemetry feed)."""
        assert self.active, "expert_ffn() on an inactive plan"
        mode, tm, tn = self.mode, self.tile_m, self.tile_n
        cf, draft = self.capacity_frac, self.draft
        operands = {"x": eb, "w_up": w_up, "w_down": w_down,
                    "mor": self.mor}
        if w_gate is not None:
            operands["w_gate"] = w_gate
        if row_mask is not None:
            operands["row_mask"] = row_mask
        if self.cap_live is not None:
            operands["cap"] = jnp.broadcast_to(
                jnp.asarray(self.cap_live, jnp.float32), (eb.shape[0],))
        if self.draft_cap is not None:
            operands["dcap"] = jnp.broadcast_to(
                jnp.asarray(self.draft_cap, jnp.float32), (eb.shape[0],))

        def one(o):
            plan = MoRExecutionPlan(o["mor"], mode=mode, tile_m=tm,
                                    tile_n=tn, capacity_frac=cf,
                                    cap_live=o.get("cap"),
                                    draft_cap=o.get("dcap"), draft=draft)
            return plan.ffn(o["x"], o["w_up"], o["w_down"],
                            activation=activation, w_gate=o.get("w_gate"),
                            row_mask=o.get("row_mask"))

        return jax.vmap(one)(operands)


def as_plan(mor, *, mode: str = "dense", tile_m: int = 8, tile_n: int = 128,
            capacity_frac: float = 1.0) -> MoRExecutionPlan:
    """Coerce ``mor`` (a plan, a MoRLayer dict, or None) into a plan.

    An existing plan wins outright — its own mode/tiling is authoritative
    (it was attached offline by ``deploy.attach_plans``).  A bare
    MoRLayer gets wrapped with the caller's knobs (the legacy
    ``(mor, mode, tile_m, tile_n)`` tuple-passing path).
    """
    if isinstance(mor, MoRExecutionPlan):
        return mor
    if mor is not None and not _looks_like_mor_layer(mor):
        # e.g. the expert-MoR pytree {"experts": ...} handled upstream
        mor = None
    return MoRExecutionPlan(mor, mode=mode if mor is not None else "dense",
                            tile_m=tile_m, tile_n=tile_n,
                            capacity_frac=capacity_frac)


def as_expert_plan(em, *, mode: str = "dense", tile_m: int = 8,
                   tile_n: int = 128, capacity_frac: float = 1.0
                   ) -> MoRExecutionPlan:
    """Coerce an expert-MoR entry (``mor["experts"]``: an attached plan,
    an (E,)-stacked MoRLayer pytree, or None) into an execution plan for
    ``expert_ffn``.

    An attached plan's own mode/tiling/budget is authoritative (it was
    wired offline by ``deploy.attach_plans``, possibly with calibrated
    per-(layer, expert) ``cap_live``); a bare stacked MoRLayer gets the
    caller's knobs — exactly the contract dense FFNs get from
    ``as_plan``, so ``mode="dense"`` deactivates the predictor outright
    instead of silently forcing exact mode."""
    if isinstance(em, MoRExecutionPlan):
        return em
    if em is None or not _looks_like_mor_layer(em):
        return MoRExecutionPlan(None)
    return MoRExecutionPlan(em, mode=mode, tile_m=tile_m, tile_n=tile_n,
                            capacity_frac=capacity_frac)


def attach_draft_caps(mor, draft_cap):
    """Store a draft capacity budget on every plan in an attached-MoR
    pytree.  ``draft_cap`` (scalar fraction, or anything broadcastable
    to a plan's stacked leading dims) lands as the traced ``draft_cap``
    leaf — broadcast exactly like ``cap_live`` so stacked plans can ride
    ``lax.scan``/unrolled layer loops — and stays dormant until
    ``as_draft()`` flips the plan into draft mode."""
    def one(p):
        if p.mor is None:
            return p
        dc = jnp.broadcast_to(jnp.asarray(draft_cap, jnp.float32),
                              p.mor["m"].shape[:-1])
        return MoRExecutionPlan(
            p.mor, mode=p.mode, tile_m=p.tile_m, tile_n=p.tile_n,
            capacity_frac=p.capacity_frac, cap_live=p.cap_live,
            draft_cap=dc, draft=p.draft)
    return map_plans(mor, one)


def map_plans(mor, fn):
    """Apply ``fn`` to every MoRExecutionPlan inside an attached-MoR
    pytree (plans are pytree NODES, so a plain tree_map would descend
    into their leaves; this one stops at the plan boundary).  Non-plan
    leaves pass through untouched.  Used by the serving engine to derive
    the draft-mode twin of an attached model in one sweep."""
    return jax.tree_util.tree_map(
        lambda p: fn(p) if isinstance(p, MoRExecutionPlan) else p, mor,
        is_leaf=lambda x: isinstance(x, MoRExecutionPlan))


def _looks_like_mor_layer(mor) -> bool:
    return isinstance(mor, dict) and "enable" in mor and "bn_scale" in mor

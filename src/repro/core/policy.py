"""Tile policy: fold calibration + clustering into a TPU-ready MoRLayer.

This is the TPU translation of the paper's DNN memory format (§4.2):
the paper stores proxies in one table and cluster members contiguously by
cluster; we produce a **column permutation** that (a) packs each cluster's
members into the same 128-wide output tile and (b) places proxies in the
leading tiles, which are always computed.  The permutation is folded into
the adjacent weight matrices offline, so the runtime never gathers.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np
import jax.numpy as jnp

from repro.configs.base import MoRConfig
from repro.core.predictor import MoRLayer


def build_permutation(proxy_of: np.ndarray, is_proxy: np.ndarray
                      ) -> np.ndarray:
    """perm[new_pos] = old_index.  Proxies first (ordered by descending
    cluster size so busy proxies land earliest), then members grouped by
    their proxy — the paper's two-table layout, flattened."""
    n = len(proxy_of)
    sizes = np.bincount(proxy_of, minlength=n)
    proxies = np.where(is_proxy)[0]
    proxies = proxies[np.argsort(-sizes[proxies], kind="stable")]
    members_of = {int(p): [] for p in proxies}
    for j in range(n):
        if not is_proxy[j]:
            members_of[int(proxy_of[j])].append(j)
    order = list(proxies)
    for p in proxies:
        order.extend(members_of[int(p)])
    perm = np.asarray(order, np.int32)
    assert len(np.unique(perm)) == n
    return perm


def build_mor_layer(m: np.ndarray, b: np.ndarray, c: np.ndarray,
                    cluster: Optional[Dict], cfg: MoRConfig,
                    bn_scale: Optional[np.ndarray] = None,
                    bn_bias: Optional[np.ndarray] = None) -> MoRLayer:
    """Assemble the online MoRLayer pytree in permuted column order.

    ``cluster=None`` builds a binary-rookie-only layer (no spatial
    predictor, identity permutation, proxy_slot = -1 sentinel)."""
    n = len(m)
    if cluster is None:
        perm = np.arange(n, dtype=np.int32)
        inv_perm = perm
        proxy_slot = np.full(n, -1, np.int32)
        is_proxy = np.zeros(n, bool)
        enable = (c > cfg.corr_threshold)
        return {
            "m": jnp.asarray(m, jnp.float32),
            "b": jnp.asarray(b, jnp.float32),
            "enable": jnp.asarray(enable),
            "proxy_slot": jnp.asarray(proxy_slot),
            "is_proxy": jnp.asarray(is_proxy),
            "perm": jnp.asarray(perm),
            "inv_perm": jnp.asarray(inv_perm),
            "bn_scale": jnp.asarray(
                bn_scale if bn_scale is not None else np.ones(n),
                jnp.float32),
            "bn_bias": jnp.asarray(
                bn_bias if bn_bias is not None else np.zeros(n),
                jnp.float32),
        }
    perm = build_permutation(cluster["proxy_of"], cluster["is_proxy"])
    inv_perm = np.empty_like(perm)
    inv_perm[perm] = np.arange(n, dtype=np.int32)
    proxy_slot = inv_perm[cluster["proxy_of"][perm]]  # permuted proxy index
    enable = (c[perm] > cfg.corr_threshold)
    return {
        "m": jnp.asarray(m[perm], jnp.float32),
        "b": jnp.asarray(b[perm], jnp.float32),
        "enable": jnp.asarray(enable),
        "proxy_slot": jnp.asarray(proxy_slot, jnp.int32),
        "is_proxy": jnp.asarray(cluster["is_proxy"][perm]),
        "perm": jnp.asarray(perm, jnp.int32),
        "inv_perm": jnp.asarray(inv_perm, jnp.int32),
        "bn_scale": jnp.asarray(
            bn_scale[perm] if bn_scale is not None else np.ones(n),
            jnp.float32),
        "bn_bias": jnp.asarray(
            bn_bias[perm] if bn_bias is not None else np.zeros(n),
            jnp.float32),
    }


def tile_mask_from_neuron_mask(computed: jnp.ndarray, tile_m: int,
                               tile_n: int) -> jnp.ndarray:
    """computed: (M, N) bool neuron-level 'must compute' mask (permuted
    order) -> (ceil(M/tile_m), ceil(N/tile_n)) bool tile mask.  A tile is
    live iff ANY neuron in it must be computed for ANY row in the block."""
    M, N = computed.shape
    pm = (-M) % tile_m
    pn = (-N) % tile_n
    padded = jnp.pad(computed, ((0, pm), (0, pn)))
    t = padded.reshape((M + pm) // tile_m, tile_m, (N + pn) // tile_n, tile_n)
    return jnp.any(t, axis=(1, 3))


def expand_tile_mask(tile_mask: jnp.ndarray, tile_m: int, tile_n: int,
                     M: int, N: int) -> jnp.ndarray:
    """Inverse of tile_mask_from_neuron_mask: broadcast back to (M, N)."""
    big = jnp.repeat(jnp.repeat(tile_mask, tile_m, axis=0), tile_n, axis=1)
    return big[:M, :N]

"""Mixture-of-Rookies core: the paper's hybrid ReLU-output predictor.

Pipeline (paper §3.2):
  1. offline: ``calibration`` fits, per output neuron, a line between the
     binarized (+-1) and base-precision pre-activations and a Pearson
     correlation coefficient.
  2. offline: ``clustering`` groups neurons by weight-vector angle and
     elects proxy neurons (closest-neighbour graph, greedy by indegree).
  3. offline: ``policy`` folds both into tile-structured ``MoRLayer``
     parameters: a column permutation packing cluster members into the
     same 128-wide TPU tile, fitted-line coefficients, enable masks.
  4. online: ``predictor`` evaluates proxies at base precision, runs the
     binary rookie for proxy-negative neurons, and skips a neuron iff
     BOTH rookies predict a zero ReLU output.  ``executor`` packages the
     predictor into per-layer ``MoRExecutionPlan``s (ONE predictor pass
     per FFN forward, reused by gate/up/down matmuls); ``masked_ffn`` is
     the thin dense/"exact"/tiled/Pallas dispatcher over plans.
"""
from repro.core.predictor import (  # noqa: F401
    MoRLayer, binarize, binary_preact, hybrid_predict, make_identity_layer,
    predictor_eval_count, reset_predictor_eval_count,
)
from repro.core.executor import MoRExecutionPlan, as_plan  # noqa: F401
from repro.core.calibration import (  # noqa: F401
    CalibAccumulator, init_accumulator, update_accumulator, finalize_regression,
)
from repro.core.clustering import (  # noqa: F401
    pairwise_cosines, closest_neighbor_graph, greedy_proxy_clustering,
    cluster_layer,
)
from repro.core.policy import build_mor_layer, tile_mask_from_neuron_mask  # noqa: F401
from repro.core.masked_ffn import mor_relu_matmul  # noqa: F401

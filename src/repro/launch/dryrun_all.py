"""Driver: run the full (arch x shape x mesh) dry-run grid, one subprocess
per cell (the XLA device-count env must be set before jax init, and a
compiler crash in one cell must not kill the sweep).

Writes experiments/dryrun/<arch>_<shape>_<mesh>.json; cells with an
existing OK record are skipped, so the sweep is resumable.

Usage: PYTHONPATH=src python -m repro.launch.dryrun_all [--mesh pod]
           [--archs a,b,...] [--force] [--timeout 1200]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = ["qwen1.5-110b", "granite-20b", "granite-3-2b", "qwen2-7b",
         "deepseek-v2-236b", "mixtral-8x7b", "rwkv6-3b",
         "phi-3-vision-4.2b", "zamba2-7b", "hubert-xlarge"]
SHAPE_NAMES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
OUT_DIR = "experiments/dryrun"


def cell_path(arch, shape, mesh):
    return os.path.join(OUT_DIR, f"{arch}_{shape}_{mesh}.json")


def is_done(path, force):
    if force or not os.path.exists(path):
        return False
    try:
        rec = json.load(open(path))
        return rec.get("status", "").startswith(("ok", "skip"))
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=("pod", "multipod",
                                                       "both"))
    ap.add_argument("--archs", default=None)
    ap.add_argument("--shapes", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()
    archs = args.archs.split(",") if args.archs else ARCHS
    shapes = args.shapes.split(",") if args.shapes else SHAPE_NAMES
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    os.makedirs(OUT_DIR, exist_ok=True)

    cells = [(a, s, m) for m in meshes for a in archs for s in shapes]
    t_start = time.time()
    n_ok = n_skip = n_fail = 0
    for i, (a, s, m) in enumerate(cells):
        path = cell_path(a, s, m)
        if is_done(path, args.force):
            print(f"[{i+1}/{len(cells)}] {a} {s} {m}: cached")
            n_skip += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--mesh", m, "--out", path]
        t0 = time.time()
        try:
            r = subprocess.run(cmd, timeout=args.timeout,
                               capture_output=True, text=True)
            tail = (r.stdout + r.stderr).strip().splitlines()
            msg = tail[-1][:160] if tail else ""
            status = "ok" if r.returncode == 0 else "FAIL"
        except subprocess.TimeoutExpired:
            status, msg = "TIMEOUT", ""
            json.dump({"arch": a, "shape": s, "mesh": m,
                       "status": f"error: compile timeout {args.timeout}s"},
                      open(path, "w"))
        n_ok += status == "ok"
        n_fail += status != "ok"
        print(f"[{i+1}/{len(cells)}] {a} {s} {m}: {status} "
              f"({time.time()-t0:.0f}s)  {msg}", flush=True)
    print(f"done in {(time.time()-t_start)/60:.1f} min: "
          f"{n_ok} ok, {n_skip} cached, {n_fail} failed")


if __name__ == "__main__":
    main()

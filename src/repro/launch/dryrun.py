import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape) cell against
the production mesh, prove memory fit, and extract roofline terms.

MUST be its own process (the XLA_FLAGS line above runs before any other
import so the 512 placeholder devices exist before jax locks the device
count).  Smoke tests / benches never import this module.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k \
      --mesh pod --out experiments/dryrun/qwen2-7b_train_4k_pod.json
Perf-iteration knobs: --no-seq-parallel --remat ... --grad-accum N
--moe-sharding ep|tp --mor-mode dense|tiled
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs
from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding_rules import (
    activation_context, batch_sharding, param_sharding)
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_serve_step, make_train_step, make_loss_fn
from repro.models import cache_shapes, get_model, param_shapes, \
    supports_long_context
from repro.optim import OptConfig, adamw_init

SKIP_REASONS = {
    ("decode", "audio"): "encoder-only arch: no decode step",
}


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> str:
    """'run' or a skip reason (DESIGN.md §Arch-applicability)."""
    if shape.kind == "decode" and cfg.family == "audio":
        return "skip: encoder-only arch has no decode step"
    if shape.name == "long_500k" and not supports_long_context(cfg):
        return ("skip: full-attention arch is quadratic/unbounded-KV at "
                "500k (sub-quadratic archs only)")
    return "run"


def _cache_sharding(cache_sds, mesh):
    """Heuristic cache sharding: batch (dim 1) over dp; largest later dim
    divisible by the model-axis size over 'model'."""
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    mp = mesh.shape.get("model", 1)
    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    def one(x):
        spec = [None] * x.ndim
        if x.ndim >= 2 and x.shape[1] % dp == 0 and x.shape[1] >= dp:
            spec[1] = dp_spec
        best, best_dim = 0, -1
        for i in range(2, x.ndim):
            if x.shape[i] % mp == 0 and x.shape[i] > best:
                best, best_dim = x.shape[i], i
        if best_dim >= 0 and mp > 1:
            spec[best_dim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, cache_sds)


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               seq_parallel: bool = True, mor_mode: str = "dense",
               layout: str = "fsdp_tp"):
    """Returns (lowered, n_chips)."""
    api = get_model(cfg)
    p_sds = param_shapes(cfg)
    p_shard = param_sharding(p_sds, mesh, moe_mode=cfg.expert_sharding,
                             layout=layout)
    data = input_specs(cfg, shape)

    with activation_context(mesh, sequence_parallel=seq_parallel):
        if shape.kind == "train":
            opt_sds = jax.eval_shape(
                lambda p: adamw_init(p, OptConfig()), p_sds)
            o_shard = {"step": NamedSharding(mesh, P()),
                       "mu": jax.tree_util.tree_map(lambda s: s, p_shard),
                       "nu": jax.tree_util.tree_map(lambda s: s, p_shard)}
            if "master" in opt_sds:
                o_shard["master"] = jax.tree_util.tree_map(
                    lambda s: s, p_shard)
            b_shard = batch_sharding(data, mesh)
            step = make_train_step(cfg, OptConfig())
            fn = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
            return fn.lower(p_sds, opt_sds, data)
        if shape.kind == "prefill":
            from repro.launch.steps import make_prefill
            fn = jax.jit(make_prefill(cfg, mor_mode=mor_mode),
                         in_shardings=(p_shard, batch_sharding(data, mesh)))
            return fn.lower(p_sds, data)
        # decode
        c_sds = cache_shapes(cfg, shape.global_batch, shape.seq_len)
        c_shard = _cache_sharding(c_sds, mesh)
        b_shard = batch_sharding(data["tokens"], mesh)
        step = make_serve_step(cfg, mor_mode=mor_mode)
        fn = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard),
                     out_shardings=(None, c_shard), donate_argnums=(1,))
        return fn.lower(p_sds, c_sds, data["tokens"])


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             seq_parallel: bool = True, mor_mode: str = "dense",
             remat: str = None, grad_accum: int = None,
             moe_sharding: str = None, out_path: str = None,
             layout: str = None) -> dict:
    cfg = get_config(arch)
    layout = layout or cfg.param_layout
    from repro.models.layers.attention import set_flash_threshold
    set_flash_threshold(cfg.flash_threshold)
    if remat:
        cfg = cfg.replace(remat=remat)
    if grad_accum:
        cfg = cfg.replace(grad_accum=grad_accum)
    if moe_sharding:
        cfg = cfg.replace(expert_sharding=moe_sharding)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "seq_parallel": seq_parallel, "mor_mode": mor_mode,
           "remat": cfg.remat, "grad_accum": cfg.grad_accum,
           "layout": layout}

    status = cell_status(cfg, shape)
    if status != "run":
        rec["status"] = status
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: {status}")
        if out_path:
            os.makedirs(os.path.dirname(out_path), exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    multi_pod = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    devices_per_pod = 256 if multi_pod else None
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, seq_parallel=seq_parallel,
                             mor_mode=mor_mode, layout=layout)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
        from repro.launch import hlo_cost
        tripped = hlo_cost.analyze(
            hlo, bf16_promoted=(cfg.dtype == "bfloat16"))
        summary = roofline.summarize(cost or {}, hlo, cfg, shape, n_chips,
                                     devices_per_pod, tripped=tripped)
        summary["xla_cost_analysis_raw"] = {
            "flops": float((cost or {}).get("flops", 0.0)),
            "bytes_accessed": float((cost or {}).get("bytes accessed", 0.0)),
            "note": "loop bodies counted once by XLA; see hlo_cost",
        }
        mem_rec = {}
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_rec[attr] = int(v)
        per_dev_bytes = (mem_rec.get("temp_size_in_bytes", 0)
                         + mem_rec.get("argument_size_in_bytes", 0))
        # CPU FloatNormalization promotes bf16 buffers to f32, doubling
        # the reported temp vs the TPU target; correct temp by 0.5 for
        # bf16-dtype models (optimizer args stay as measured).
        if cfg.dtype == "bfloat16":
            corrected = (mem_rec.get("temp_size_in_bytes", 0) * 0.5
                         + mem_rec.get("argument_size_in_bytes", 0))
        else:
            corrected = per_dev_bytes
        rec["per_device_gib_bf16_corrected"] = round(corrected / 2**30, 3)
        # memory-bound cells: fraction of ideal traffic (args+outputs-alias
        # = every byte that must be touched at least once) vs actual
        min_bytes = (mem_rec.get("argument_size_in_bytes", 0)
                     + mem_rec.get("output_size_in_bytes", 0)
                     - mem_rec.get("alias_size_in_bytes", 0))
        if summary.get("hlo_bytes_per_chip"):
            summary["memory_roofline_fraction"] = round(
                min_bytes / summary["hlo_bytes_per_chip"], 4)
        rec.update({
            "status": "ok",
            "n_chips": int(n_chips),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": mem_rec,
            "per_device_bytes": per_dev_bytes,
            "per_device_gib": round(per_dev_bytes / 2**30, 3),
            "fits_16gib_hbm": corrected < 16 * 2**30,
            "roofline": summary,
        })
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
              f"{rec['per_device_gib']} GiB/dev, "
              f"dominant={summary['dominant']}, "
              f"roofline_frac={summary['roofline_fraction']:.3f})")
        print("  memory_analysis:", mem_rec)
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (summary["hlo_flops_per_chip"], summary["hlo_bytes_per_chip"]))
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        rec["status"] = f"error: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: FAILED {e}",
              file=sys.stderr)
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod"))
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--mor-mode", default="dense")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--moe-sharding", default=None,
                choices=(None, "ep", "tp", "ep_shmap"))
    ap.add_argument("--flash-threshold", type=int, default=None)
    ap.add_argument("--param-layout", default=None,
                    choices=(None, "fsdp_tp", "contract_tp"))
    args = ap.parse_args()
    if args.flash_threshold is not None:
        from repro.models.layers.attention import set_flash_threshold
        set_flash_threshold(args.flash_threshold)
    rec = run_cell(args.arch, args.shape, args.mesh,
                   seq_parallel=not args.no_seq_parallel,
                   mor_mode=args.mor_mode, remat=args.remat,
                   grad_accum=args.grad_accum,
                   moe_sharding=args.moe_sharding, out_path=args.out,
                   layout=args.param_layout)
    if rec["status"].startswith("error"):
        sys.exit(1)


if __name__ == "__main__":
    main()

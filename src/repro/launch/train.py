"""Training driver: fault-tolerant loop with auto-resume, async
checkpointing, straggler monitoring, deterministic data, and optional
post-training MoR calibration.

CPU-runnable end-to-end on reduced configs:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --steps 200 --batch 16 --seq 64 --ckpt-dir /tmp/ckpt
On a real cluster the same driver runs the full config against
``make_production_mesh()`` (--mesh pod).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.data import DataConfig
from repro.data.pipeline import make_batch
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.distributed.sharding_rules import (activation_context,
                                              batch_sharding, param_sharding)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.models import get_model
from repro.optim import OptConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="host", choices=("host", "pod"))
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--calibrate", action="store_true",
                    help="run MoR calibration after training")
    ap.add_argument("--out-json", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    cfg = cfg.replace(grad_accum=1)
    api = get_model(cfg)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    opt_cfg = OptConfig(lr=args.lr, moment_dtype="float32"
                        if cfg.dtype == "float32" else "bfloat16")
    mesh = (make_production_mesh() if args.mesh == "pod"
            else make_host_mesh(args.model_parallel))
    dcfg = DataConfig(seed=args.seed)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    key = jax.random.PRNGKey(args.seed)
    params, opt_state = init_train_state(key, cfg, opt_cfg)
    start_step = 0
    if mgr and mgr.latest_step() is not None:
        # elastic restore: the checkpoint re-places onto whatever mesh we
        # have now (device counts may differ from the saving job)
        shardings = {"params": param_sharding(params, mesh),
                     "opt": None}
        state, extra = mgr.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = extra["step"]
        print(f"[train] resumed from step {start_step}")

    train_step = jax.jit(make_train_step(cfg, opt_cfg,
                                         total_steps=args.steps),
                         donate_argnums=(0, 1))
    monitor = StragglerMonitor(n_hosts=1)
    losses = []
    t_start = time.time()
    with activation_context(mesh, sequence_parallel=False):
        for step in range(start_step, args.steps):
            batch_np = make_batch(cfg, shape, dcfg, step)
            batch = jax.tree_util.tree_map(jnp.asarray, batch_np)
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            dt = time.time() - t0
            monitor.record_step({0: dt})
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if mgr and (step + 1) % args.save_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state},
                 block=True)
        mgr.wait()

    report = {
        "arch": cfg.name, "steps": args.steps,
        "loss_first": losses[0] if losses else None,
        "loss_last": float(np.mean(losses[-10:])) if losses else None,
        "wall_s": round(time.time() - t_start, 1),
    }

    if args.calibrate:
        from repro.core.deploy import calibrate_lm
        def batches():
            s = 10_000
            while True:
                b = make_batch(cfg, shape, dcfg, s)
                yield jax.tree_util.tree_map(jnp.asarray, b)
                s += 1
        params2, mor, cal = calibrate_lm(params, cfg, api.forward,
                                         batches(), cfg.mor.calib_batches)
        report["calibration"] = cal
        if mgr:
            mgr.save(args.steps + 1,
                     {"params": params2, "opt": opt_state}, block=True)
        print("[train] calibration:", cal)

    print("[train] done:", report)
    if args.out_json:
        json.dump(report, open(args.out_json, "w"), indent=1)
    return report


if __name__ == "__main__":
    main()

"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds-per-step-per-chip:
  compute    = HLO_FLOPs / PEAK_FLOPS
  memory     = HLO_bytes_accessed / HBM_BW
  collective = wire_bytes / ICI_BW_EFFECTIVE

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (per-device,
post-SPMD).  Collective bytes are NOT in cost_analysis: we parse the
compiled HLO text and sum result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (including
async -start forms), applying the standard ring-wire factors
(ar=2(g-1)/g~2, ag/rs=(g-1)/g~1, a2a~1/g... kept at 1 as a conservative
bound, cp=1).

Hardware constants (TPU v5e class, per chip):
  197 TFLOP/s bf16; 819 GB/s HBM; ICI ~50 GB/s/link, 2 links engaged per
  ring collective -> 100 GB/s effective.  Inter-pod (DCI) collectives are
  charged at 25 GB/s; an HLO collective is charged to DCI iff its replica
  group spans the pod axis (group size > devices-per-pod or the
  channel-id heuristic fails closed to ICI).
"""
from __future__ import annotations

import json
import math
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 2 * 50e9            # bytes/s / chip (2 links per ring)
DCI_BW = 25e9                # bytes/s / chip across pods

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^)]*?,?\s*)+)?"
    r"\s*((?:f|bf|s|u|pred|c)[a-z0-9]*\[[0-9,]*\])?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"((?:f|bf|s|u|c)[0-9e: alnum]*?[0-9]+|pred)\[([0-9,]*)\]")

_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str,
                      devices_per_pod: Optional[int] = None) -> Dict:
    """Sum collective wire bytes per type from (post-SPMD) HLO text."""
    out = {k: 0.0 for k in _FACTORS}
    dci_bytes = 0.0
    for line in hlo_text.splitlines():
        m = re.search(r"\s=\s(.+?)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        shapes = _SHAPE_RE.findall(m.group(1))
        if not shapes:
            continue
        bytes_ = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += bytes_ * _FACTORS[kind]
        if devices_per_pod:
            g = _replica_group_size(line)
            if g and g > devices_per_pod:
                dci_bytes += bytes_ * _FACTORS[kind]
    out["dci_bytes"] = dci_bytes
    out["total_wire_bytes"] = sum(v for k, v in out.items()
                                  if k in _FACTORS)
    return out


def _replica_group_size(line: str) -> Optional[int]:
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return None


def roofline_terms(cost: Dict, collectives: Dict,
                   trip_multiplier: float = 1.0) -> Dict:
    flops = float(cost.get("flops", 0.0)) * trip_multiplier
    hbm = float(cost.get("bytes accessed", 0.0)) * trip_multiplier
    ici_bytes = (collectives["total_wire_bytes"]
                 - collectives.get("dci_bytes", 0.0)) * trip_multiplier
    dci_bytes = collectives.get("dci_bytes", 0.0) * trip_multiplier
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = ici_bytes / ICI_BW + dci_bytes / DCI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": hbm,
        "wire_bytes_per_chip": ici_bytes + dci_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_time_s": max(t_compute, t_memory, t_coll),
    }


def model_flops(cfg, shape, n_chips: int) -> Dict:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (per chip)."""
    from repro.configs.base import param_count
    total, active = param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = 6.0 * active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2.0 * active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mf = 2.0 * active * tokens
    return {"params_total": total, "params_active": active,
            "model_flops_per_chip": mf / n_chips}


def summarize(cost: Dict, hlo_text: str, cfg, shape, n_chips: int,
              devices_per_pod: Optional[int] = None,
              tripped: Optional[Dict] = None) -> Dict:
    """``tripped``: result of hlo_cost.analyze() — trip-count-corrected
    flops/bytes/collective-bytes.  When given it overrides XLA's
    loop-body-once cost_analysis; the DCI share is estimated from the
    per-line replica-group parse (collectives inside loop bodies keep the
    same pod/intra-pod mix)."""
    colls = parse_collectives(hlo_text, devices_per_pod)
    if tripped is not None:
        dci_frac = (colls.get("dci_bytes", 0.0) /
                    colls["total_wire_bytes"]) if colls.get(
                        "total_wire_bytes") else 0.0
        wired = {k: _FACTORS[k] * v
                 for k, v in tripped["coll_bytes_by_type"].items()}
        total = sum(wired.values())
        colls = {**wired, "total_wire_bytes": total,
                 "dci_bytes": total * dci_frac}
        cost = {"flops": tripped["flops"],
                "bytes accessed": tripped["bytes"]}
    terms = roofline_terms(cost, colls)
    mf = model_flops(cfg, shape, n_chips)
    useful = (mf["model_flops_per_chip"] /
              terms["hlo_flops_per_chip"]) if terms["hlo_flops_per_chip"] else 0.0
    mfu_bound = (mf["model_flops_per_chip"] / PEAK_FLOPS /
                 terms["bound_time_s"]) if terms["bound_time_s"] else 0.0
    return {
        **terms, **mf,
        "collective_breakdown": colls,
        "useful_flop_ratio": useful,
        "roofline_fraction": mfu_bound,
    }

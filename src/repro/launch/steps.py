"""Step functions: train_step (loss + grads + AdamW, with microbatch
accumulation and optional int8 inter-pod gradient compression) and
serve_step (single-token decode) / prefill.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.optim import OptConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule

LB_LOSS_WEIGHT = 0.01


def cross_entropy(logits, labels) -> jax.Array:
    """TP-friendly CE: the gold-logit pick is a one-hot contraction (not a
    gather), so a vocab-sharded logits tensor reduces locally + one scalar
    all-reduce instead of being all-gathered to every device."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    oh = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
    gold = jnp.einsum("...v,...v->...", lf, oh)
    return (lse - gold).mean()


def make_loss_fn(cfg: ModelConfig) -> Callable:
    api = get_model(cfg)

    def loss_fn(params, batch):
        logits, aux = api.forward(params, cfg, batch)
        labels = batch["labels"]
        if cfg.frontend == "vision_stub":
            logits = logits[:, -labels.shape[1]:, :]
        loss = cross_entropy(logits, labels)
        if cfg.family == "moe" and "lb_loss" in aux:
            loss = loss + LB_LOSS_WEIGHT * jnp.mean(aux["lb_loss"])
        return loss, aux

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    total_steps: int = 10000, warmup: int = 100,
                    ) -> Callable:
    """-> train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``cfg.grad_accum`` > 1 splits the global batch into microbatches and
    accumulates grads in a scan — the live activation set is one
    microbatch (this is how the 110B/236B train shapes fit HBM)."""
    loss_fn = make_loss_fn(cfg)
    accum = max(cfg.grad_accum, 1)

    def train_step(params, opt_state, batch):
        if accum > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        lr_scale = cosine_schedule(opt_state["step"], total_steps, warmup)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg, lr_scale)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill(cfg: ModelConfig, mor=None, mor_mode: str = "dense"
                 ) -> Callable:
    api = get_model(cfg)

    def prefill(params, batch):
        logits, _ = api.forward(params, cfg, batch, mor=mor,
                                mor_mode=mor_mode)
        return jnp.argmax(logits[:, -1, :], axis=-1) \
            if logits.ndim == 3 else logits

    return prefill


def make_prefill_step(cfg: ModelConfig, mor=None, mor_mode: str = "dense",
                      chunk: int = 0) -> Callable:
    """prefill_step(params, cache, prompts (B, P)) -> (next_tokens (B,),
    cache) on the serving slot-pool cache (``serving.kv_pool.init``).

    Transformer families whose prompt fits the kv ring run ONE batched
    dispatch (``api.prefill``).  Recurrent families (ssm / hybrid) and
    prompts longer than the sliding-window ring run CHUNKED prefill —
    state-carrying fixed-shape (B, C) dispatches of ``api.prefill_chunk``
    (one compiled step reused across chunks).  The old scanned-decode
    fallback (P sequential single-token steps inside a lax.scan) is
    gone: both paths produce logits identical to the teacher-forced
    forward."""
    api = get_model(cfg)
    chunk = chunk or cfg.serve_chunk
    assert api.prefill_chunk is not None, f"{cfg.name} has no chunk step"

    batched = None
    if api.prefill is not None:
        def _batched(params, cache, prompts):
            logits, cache = api.prefill(params, cfg, prompts, cache,
                                        mor=mor, mor_mode=mor_mode)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
        batched = jax.jit(_batched, donate_argnums=(1,))

    def _chunk(params, cache, toks, n_valid):
        logits, cache, _ = api.prefill_chunk(params, cfg, toks, cache,
                                             n_valid=n_valid, mor=mor,
                                             mor_mode=mor_mode)
        last = jnp.clip(n_valid - 1, 0)
        lg = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
        return jnp.argmax(lg, axis=-1).astype(jnp.int32), cache
    chunk_step = jax.jit(_chunk, donate_argnums=(1,))

    def prefill_step(params, cache, prompts):
        B, P = prompts.shape
        if batched is not None and \
                (not cfg.sliding_window or P <= cfg.sliding_window):
            return batched(params, cache, prompts)
        off = 0
        while off < P:
            take = min(chunk, P - off)
            toks = jnp.pad(prompts[:, off:off + take],
                           ((0, 0), (0, chunk - take)))
            nxt, cache = chunk_step(params, cache, toks,
                                    jnp.full((B,), take, jnp.int32))
            off += take
        return nxt, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, mor=None, mor_mode: str = "dense"
                     ) -> Callable:
    """decode_step(params, cache, tokens (B, 1)) -> (next_tokens, cache,
    aux) on the slot-pool cache: a chunk dispatch of width 1, so decode
    shares the compiled path (and the MoR telemetry stats in ``aux``)
    with chunked prefill."""
    api = get_model(cfg)
    assert api.prefill_chunk is not None, f"{cfg.name} has no chunk step"

    def decode_step(params, cache, tokens):
        B = tokens.shape[0]
        logits, cache, aux = api.prefill_chunk(
            params, cfg, tokens, cache, n_valid=jnp.ones((B,), jnp.int32),
            mor=mor, mor_mode=mor_mode)
        return (jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32), cache,
                aux)

    return decode_step


def make_serve_step(cfg: ModelConfig, mor=None, mor_mode: str = "dense"
                    ) -> Callable:
    """serve_step(params, cache, tokens (B,1)) -> (next_tokens, cache)."""
    api = get_model(cfg)
    assert api.decode_step is not None, f"{cfg.name} has no decode step"

    def serve_step(params, cache, tokens):
        logits, cache = api.decode_step(params, cfg, tokens, cache,
                                        mor=mor, mor_mode=mor_mode)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step


def init_train_state(key, cfg: ModelConfig, opt_cfg: OptConfig
                     ) -> Tuple[Any, Any]:
    api = get_model(cfg)
    params = api.init(key, cfg)
    return params, adamw_init(params, opt_cfg)

"""Serving driver: the continuous-batching MoR engine under a mixed
prompt-length trace — the paper's deployment scenario (inference
accelerator serving real traffic).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --reduced --requests 16 --prompt-min 16 --prompt-max 512 \
      --gen-len 32 --mor tiled --calibrate-capacity 0.95 --compare

Requests with heterogeneous prompt/generation lengths stream through a
fixed slot pool (``repro.serving.Engine``): prompts are prefilled in
fixed-size chunks mixed into the same dispatches as ongoing decodes,
finished sequences are evicted and their KV pages recycled mid-flight.
The cache is PAGED by default (block-table indirection + refcounted
pages) with prefix caching across requests: a --shared-prefix trace
dedups its common prompt pages and skips the fully-hit prefill chunks
(--no-prefix-cache / --layout slotted select the baselines).  Sampling
is greedy by default; --temperature/--top-k enable seeded sampling.
Reports tokens/s, the realised PER-LAYER skip fractions from the
serving telemetry, the prefix-cache hit counters, and (with
--calibrate-capacity) the per-layer gather_matmul capacities chosen
from the observed tile-liveness quantiles.  --baseline additionally
measures the static-batch path (every prompt padded to the trace
maximum) on the same trace.

--obs / --metrics-json / --trace-out attach the ``repro.obs`` stack to
the primary engine: a metrics registry (JSON/Prometheus export), the
device-resident dispatch counters (accumulated inside the compiled
step, drained only at flush boundaries — zero extra device syncs), and
the span tracer whose timeline loads in Perfetto / chrome://tracing.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data.pipeline import synthetic_lm_batch
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import get_model
from repro.serving import Engine
from repro.serving.telemetry import STAT_KEYS


def generate(cfg, api, params, prompts, gen_len: int, mor=None,
             mor_mode: str = "dense"):
    """Static-batch generate (the pre-engine serving path, kept as the
    baseline): prompts (B, P) -> (tokens (B, gen_len), stats).

    Prefill is one batched dispatch (or chunked-prefill dispatches for
    recurrent families / prompts beyond the sliding-window ring); decode
    is a width-1 chunk step per token.  stats carries throughput AND the
    realised per-layer skip fractions accumulated over decode steps."""
    from repro.serving import kv_pool
    B, P = prompts.shape
    max_len = P + gen_len + 1
    cache = kv_pool.init(cfg, B, max_len)
    prefill = make_prefill_step(cfg, mor=mor, mor_mode=mor_mode)
    step = jax.jit(make_decode_step(cfg, mor=mor, mor_mode=mor_mode),
                   donate_argnums=(1,))

    t0 = time.time()
    nxt, cache = prefill(params, cache, prompts)
    jax.block_until_ready(nxt)
    prefill_dt = time.time() - t0

    tok = nxt[:, None]
    out = []
    layer_stats = []
    # the first decode step JIT-compiles the (B, 1) step; keep it outside
    # the timed window so tok/s reports steady-state throughput
    nxt, cache, aux = step(params, cache, tok)
    tok = nxt[:, None]
    out.append(nxt)
    jax.block_until_ready(tok)
    timed = max(gen_len - 1, 1)
    t0 = time.time()
    for t in range(gen_len - 1):
        nxt, cache, aux = step(params, cache, tok)
        tok = nxt[:, None]
        out.append(nxt)
        if aux:
            layer_stats.append(aux)
    jax.block_until_ready(tok)
    dt = max(time.time() - t0, 1e-9)
    toks = np.stack([np.asarray(o) for o in out], 1)
    stats = {"decode_tokens_per_s": B * timed / dt,
             "decode_ms_per_step": dt / timed * 1e3,
             "prefill_tokens_per_s": B * P / max(prefill_dt, 1e-9),
             "prefill_ms": prefill_dt * 1e3}
    stats.update(_mean_layer_stats(layer_stats))
    return toks, stats


# report-key prefix per stat group: the dense-stack stats keep the
# historical per_layer_* names; expert stats ((L, E)-shaped) get their
# own namespace so groups never overwrite each other in the report
_STAT_PREFIX = {"mor_stats": "per_layer_",
                "dense_mor_stats": "per_layer_dense_",
                "moe_mor_stats": "per_expert_"}


def _mean_layer_stats(aux_list):
    """Average per-layer MoR skip stats over dispatches -> report lists
    (nested (L, E) lists for the expert group)."""
    out = {}
    for key in STAT_KEYS:
        rows = [a[key] for a in aux_list if a.get(key)]
        if not rows:
            continue
        for name in ("frac_computed", "frac_tiles_live",
                     "frac_tiles_computed"):
            vals = [np.asarray(r[name], np.float64) for r in rows
                    if name in r]
            if vals:
                out[_STAT_PREFIX[key] + name] = \
                    np.mean(vals, 0).round(4).tolist()
    return out


def _trace(cfg, n_requests, pmin, pmax, gmin, gmax, seed,
           shared_prefix: int = 0):
    """Mixed trace: log-uniform prompt lengths in [pmin, pmax] AND
    generation lengths in [gmin, gmax] — heterogeneous on both axes,
    like real traffic (the static batch convoys on the longest of
    each per group; the engine evicts at each request's own length).

    ``shared_prefix`` > 0 prepends the SAME ``shared_prefix``-token
    prompt prefix to every request (system-prompt traffic) — the
    shared-prompt trace the prefix cache dedups."""
    rng = np.random.default_rng(seed)
    prefix = np.zeros((0,), np.int32)
    if shared_prefix:
        prefix = np.asarray(
            synthetic_lm_batch(cfg, 1, shared_prefix, seed=seed, step=999)
            ["tokens"][0], np.int32)
    reqs = []
    for i in range(n_requests):
        plen = (int(np.exp(rng.uniform(np.log(pmin), np.log(pmax))))
                if pmax > pmin else pmin)
        glen = (int(np.exp(rng.uniform(np.log(gmin), np.log(gmax))))
                if gmax > gmin else gmax)
        prompt = np.asarray(
            synthetic_lm_batch(cfg, 1, plen, seed=seed, step=1000 + i)
            ["tokens"][0], np.int32)
        reqs.append((np.concatenate([prefix, prompt]), glen))
    return reqs


def _run_engine(cfg, params, reqs, *, mor, mor_mode, n_slots, max_len,
                chunk=0, capacities=None, layout="paged",
                prefix_cache=True, temperature=0.0, top_k=0,
                sample_seed=0, mesh=None, obs=None, policy=None,
                spec_k=0, draft_cap=0.0, spec_draft_temperature=None,
                shadow_rate=0.0, drift_threshold=0.25):
    eng = Engine(cfg, params, mor=mor, mor_mode=mor_mode, n_slots=n_slots,
                 max_len=max_len, chunk=chunk, capacities=capacities,
                 layout=layout, prefix_cache=prefix_cache,
                 temperature=temperature, top_k=top_k,
                 sample_seed=sample_seed, mesh=mesh, obs=obs,
                 policy=policy, spec_k=spec_k, draft_cap=draft_cap,
                 spec_draft_temperature=spec_draft_temperature,
                 shadow_rate=shadow_rate, drift_threshold=drift_threshold)
    # first pass compiles the two dispatch shapes; then take the best of
    # three timed passes — single-shot wall clock on a shared CPU is
    # ~2x noisy (the static baseline gets the same warmup + best-of).
    # eng.run() ends with a blocking flush, so these walls include the
    # device drain (unlike counters["wall_s"], which is host dispatch
    # time only — the hot loop never syncs).
    eng.run(list(reqs))
    wall = float("inf")
    for _ in range(3):
        eng.reset_counters()
        t0 = time.time()
        results = eng.run(list(reqs))   # deterministic: passes agree
        wall = min(wall, max(time.time() - t0, 1e-9))
    base = min(results)
    results = {rid - base: toks for rid, toks in results.items()}
    rep = eng.report()
    rep["requests_finished"] = len(results)      # the timed pass only
    total = rep["prefill_tokens"] + rep["decode_tokens"]
    rep["tokens_per_s"] = total / wall
    rep["decode_tokens_per_s"] = rep["decode_tokens"] / wall
    rep["wall_s"] = wall
    tel = rep.pop("telemetry", None)
    if tel:
        for key in STAT_KEYS:
            if key in tel:
                for name, vals in tel[key].items():
                    if name in ("frac_computed", "frac_tiles_live",
                                "frac_tiles_computed"):
                        rep[_STAT_PREFIX[key] + name] = \
                            np.round(np.asarray(vals), 4).tolist()
    return eng, results, rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dims", default=None,
                    help="override reduced dims: d_model,d_ff,n_layers "
                         "(bench knob for compute-dominated scales)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=8,
                    help="slot-pool size (n_slots)")
    ap.add_argument("--requests", type=int, default=0,
                    help="trace length (default: one per slot)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--prompt-min", type=int, default=0,
                    help="mixed trace: min prompt length (default uniform)")
    ap.add_argument("--prompt-max", type=int, default=0)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--gen-min", type=int, default=0,
                    help="mixed trace: min generation length "
                         "(default uniform = gen-len)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="prefill chunk length (default cfg.serve_chunk)")
    ap.add_argument("--layout", default="paged",
                    choices=("paged", "paged-sharded", "slotted"),
                    help="KV cache layout (paged-sharded = page pool "
                         "partitioned over a device mesh, distributed "
                         "flash decode; slotted = PR 2 baseline)")
    ap.add_argument("--shards", type=int, default=0,
                    help="paged-sharded: mesh size over the page axis "
                         "(default: all visible devices; force host "
                         "devices via XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--stream", action="store_true",
                    help="demo the detokenizing stream API: re-serve "
                         "the first request through Engine.stream() "
                         "and report the incrementally streamed tokens")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="prefix caching across requests (default on; "
                         "paged layout only)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a shared N-token prefix to every "
                         "request (shared-prompt trace)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for temperature sampling "
                         "(0 = full distribution)")
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative decoding: draft up to k "
                         "tokens per slot per round and verify them in "
                         "one target pass (0 = off; paged layout only; "
                         "greedy output is token-identical to vanilla)")
    ap.add_argument("--draft-cap", type=float, default=0.0,
                    help="MoR capacity fraction for the DRAFT pass "
                         "(traced leaf — sweeping it never recompiles; "
                         "0 = draft at full target capacity)")
    ap.add_argument("--spec-draft-temperature", type=float, default=None,
                    help="draft-pass sampling temperature (default: "
                         "the target --temperature)")
    ap.add_argument("--mor", default="dense",
                    choices=("dense", "exact", "tiled", "kernel"))
    ap.add_argument("--calib-steps", type=int, default=4)
    ap.add_argument("--capacity", type=float, default=0.0,
                    help="static gather_matmul capacity fraction applied "
                         "to every MoR layer (0 = cfg.mor.capacity; the "
                         "clamp drops live tiles beyond the budget, so "
                         "tile-skip counters are nonzero even on "
                         "uncalibrated weights)")
    ap.add_argument("--obs", action="store_true",
                    help="enable the repro.obs stack (metrics registry, "
                         "device-resident dispatch counters, request "
                         "tracer) on the primary engine; implied by "
                         "--metrics-json / --trace-out")
    ap.add_argument("--metrics-json", default=None,
                    help="write the obs metrics-registry snapshot "
                         "(counters, gauges, histogram summaries) to "
                         "this path as JSON")
    ap.add_argument("--trace-out", default=None,
                    help="write the request tracer's timeline to this "
                         "path as Chrome-trace JSON (load in Perfetto "
                         "or chrome://tracing)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve GET /metrics (Prometheus text) and "
                         "GET /metrics.json (registry snapshot) on "
                         "this port from a stdlib http.server thread "
                         "for the run's duration (implies --obs; 0 = "
                         "ephemeral port, printed at startup)")
    ap.add_argument("--shadow-rate", type=float, default=0.0,
                    help="shadow-oracle predictor scoring: sample "
                         "1-in-round(1/RATE) dispatches through a "
                         "dense-oracle twin that scores the predictor's "
                         "tile decisions (false skips / false keeps) "
                         "into the device metrics block; tokens stay "
                         "identical to --shadow-rate 0 (implies --obs; "
                         "needs --mor != dense)")
    ap.add_argument("--drift-threshold", type=float, default=0.25,
                    help="per-(layer, expert) EWMA false-skip-rate "
                         "threshold above which the drift detector "
                         "flags the series")
    ap.add_argument("--calibrate-capacity", type=float, default=0.0,
                    help="liveness quantile for per-layer gather capacity "
                         "(0 = static cfg.mor.capacity)")
    ap.add_argument("--compare", action="store_true",
                    help="also run the dense engine; report token agreement")
    ap.add_argument("--baseline", action="store_true",
                    help="also run the static-batch path on the same trace")
    ap.add_argument("--policy", default="fcfs",
                    choices=("fcfs", "priority", "sjf"),
                    help="admission/preemption policy (priority can "
                         "spill lower classes; sjf = shortest remaining "
                         "prefill first)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="cap on prompt tokens per mixed dispatch "
                         "(decode-vs-prefill knob; 0 = unlimited)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-json", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.dims:
        d, ff, L = (int(v) for v in args.dims.split(","))
        cfg = cfg.replace(d_model=d, d_ff=ff, n_layers=L)
    api = get_model(cfg)
    assert api.has_decode, f"{cfg.name} is encoder-only"
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key, cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        state, _ = mgr.restore({"params": params})
        params = state["params"]

    mor = None
    report = {"arch": cfg.name, "mor_mode": args.mor}
    if args.mor != "dense":
        from repro.core.deploy import (calibrate_hybrid, calibrate_lm,
                                       calibrate_moe)

        def batches():
            s = 0
            while True:
                b = synthetic_lm_batch(cfg, args.batch, 128,
                                       seed=args.seed, step=s)
                yield {"tokens": jnp.asarray(b["tokens"])}
                s += 1
        if cfg.family == "moe":
            # per-(layer, expert) predictors for the expert FFNs plus the
            # calibrate_lm treatment for any leading dense layers
            params, mor, cal = calibrate_moe(params, cfg, api.forward,
                                             batches(), args.calib_steps)
        elif cfg.family == "hybrid":
            # the one shared block's MLP, observed at every segment
            # boundary, gets a single MoRLayer under mor["shared"]
            params, mor, cal = calibrate_hybrid(params, cfg, api.forward,
                                                batches(), args.calib_steps)
        else:
            params, mor, cal = calibrate_lm(params, cfg, api.forward,
                                            batches(), args.calib_steps)
        report["calibration"] = cal

    pmin = args.prompt_min or args.prompt_len
    pmax = args.prompt_max or args.prompt_len
    gmin = args.gen_min or args.gen_len
    reqs = _trace(cfg, args.requests or args.batch, pmin, pmax,
                  gmin, args.gen_len, args.seed,
                  shared_prefix=args.shared_prefix)
    max_len = args.shared_prefix + pmax + args.gen_len + 2

    mesh = None
    if args.layout == "paged-sharded":
        from repro.launch.mesh import make_page_mesh
        mesh = make_page_mesh(args.shards)

    if args.shadow_rate > 0:
        assert args.mor != "dense", \
            "--shadow-rate scores the MoR predictor; pick --mor " \
            "exact/tiled/kernel"
    obs = None
    if args.obs or args.metrics_json or args.trace_out or \
            args.shadow_rate > 0 or args.metrics_port is not None:
        from repro.obs import Observability
        obs = Observability()
    server = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer
        server = MetricsServer(obs, port=args.metrics_port)
        print(f"[serve] metrics endpoint: {server.url}/metrics "
              f"(+ /metrics.json)")

    capacities = None
    if args.capacity > 0 and args.mor != "dense":
        from repro.serving.telemetry import mor_group_map
        capacities = {k: args.capacity for k in mor_group_map(cfg)}
        report["static_capacity"] = args.capacity

    from repro.serving.policy import get_policy
    policy = get_policy(args.policy, prefill_budget=args.prefill_budget)
    eng, results, rep = _run_engine(
        cfg, params, reqs, mor=mor, mor_mode=args.mor, n_slots=args.batch,
        max_len=max_len, chunk=args.chunk, capacities=capacities,
        layout=args.layout, prefix_cache=args.prefix_cache,
        temperature=args.temperature, top_k=args.top_k,
        sample_seed=args.sample_seed, mesh=mesh, obs=obs, policy=policy,
        spec_k=args.spec_k, draft_cap=args.draft_cap,
        spec_draft_temperature=args.spec_draft_temperature,
        shadow_rate=args.shadow_rate,
        drift_threshold=args.drift_threshold)
    report.update(rep)
    report["policy"] = args.policy
    if args.prefill_budget:
        report["prefill_budget"] = args.prefill_budget
    print(f"[serve] {cfg.name} mor={args.mor} layout={args.layout}: "
          f"{rep['tokens_per_s']:.1f} tok/s over {len(reqs)} requests "
          f"({rep['dispatches']} dispatches, "
          f"prompts {pmin}-{pmax})")
    if "quality" in rep:
        q = rep["quality"]
        dr = q.get("drift", {})
        print(f"[serve] shadow oracle: rate {q['shadow_rate']:.4f} "
              f"(1 in {q['shadow_every']}), "
              f"{q.get('shadow_dispatches', 0)} dispatches scored, "
              f"{dr.get('n_drifted', 0)}/{dr.get('n_series', 0)} "
              f"series drifted")
    if "spec" in rep:
        sp = rep["spec"]
        print(f"[serve] spec: k={sp['k']} draft_cap={sp['draft_cap']} "
              f"acceptance {sp['acceptance_rate']:.2f} "
              f"({sp['tokens_accepted']}/{sp['tokens_drafted']} drafts "
              f"over {sp['rounds']} rounds, {sp['replays']} replays, "
              f"{sp['aborts']} aborts)")
    if "sharding" in rep:
        sh = rep["sharding"]
        print(f"[serve] page mesh: {sh['n_shards']} shards, kv pages "
              f"hiwater/shard "
              f"{sh.get('kv_pages_hiwater_per_shard', sh.get('state_pages_hiwater_per_shard'))}")

    if args.stream:
        # detokenizing stream demo: re-serve request 0 through the
        # iterator API (tokens arrive at flush granularity — the hot
        # loop stays device-resident, no per-token syncs)
        p0, g0 = reqs[0]
        streamed = list(eng.stream(p0, g0, interval=1))
        report["stream"] = {"tokens": len(streamed), "interval": 1}
        print(f"[serve] --stream: request 0 re-served, {len(streamed)} "
              f"tokens streamed incrementally")
    if "prefix_cache" in rep:
        pc = rep["prefix_cache"]
        print(f"[serve] prefix cache: hit rate {pc['hit_rate']:.2f} "
              f"({pc['prefix_hits']}/{pc['prefix_queries']} requests), "
              f"{pc['pages_shared']} pages shared, "
              f"{pc['chunks_skipped']} prefill chunks skipped, "
              f"{pc['pages_cowed']} pages copy-on-written")

    if args.calibrate_capacity > 0 and args.mor not in ("dense",):
        caps = eng.calibrate_capacities(quantile=args.calibrate_capacity)
        _, results_cal, rep_cal = _run_engine(
            cfg, params, reqs, mor=mor, mor_mode=args.mor,
            n_slots=args.batch, max_len=max_len, chunk=args.chunk,
            capacities=caps, layout=args.layout,
            prefix_cache=args.prefix_cache, mesh=mesh)
        report["per_layer_capacity"] = {
            k: np.asarray(v).round(4).tolist() for k, v in caps.items()}
        report["calibrated_tokens_per_s"] = rep_cal["tokens_per_s"]
        # token_agreement_vs_dense below measures the UNCALIBRATED run;
        # the capacity clamp intentionally drops live tiles beyond the
        # chosen quantile, so its accuracy cost is reported separately:
        report["calibrated_token_agreement"] = float(np.mean([
            np.mean(np.asarray(results_cal[r]) == np.asarray(results[r]))
            for r in results]))
        print(f"[serve] capacity-calibrated "
              f"(q={args.calibrate_capacity}): "
              f"{rep_cal['tokens_per_s']:.1f} tok/s; per-layer capacity "
              f"{report['per_layer_capacity']}")

    if args.compare and args.mor != "dense":
        _, results_d, rep_d = _run_engine(cfg, params, reqs, mor=None,
                                          mor_mode="dense",
                                          n_slots=args.batch,
                                          max_len=max_len, chunk=args.chunk,
                                          layout=args.layout,
                                          prefix_cache=args.prefix_cache,
                                          mesh=mesh)
        agree = np.mean([
            np.mean(np.asarray(results[r]) == np.asarray(results_d[r]))
            for r in results_d])
        report["dense_tokens_per_s"] = rep_d["tokens_per_s"]
        report["token_agreement_vs_dense"] = float(agree)
        print(f"[serve] dense baseline: {rep_d['tokens_per_s']:.1f} tok/s; "
              f"token agreement {agree:.3f}")

    if args.baseline:
        # static batch: every prompt padded to the TRACE maximum, groups
        # of n_slots at a time — what serve.py did before the engine.
        # The steps compile ONCE (fixed (B, Pmax) shapes) and a warmup
        # group runs outside the timer, so the speedup measures padding/
        # convoy waste, not compile time.
        from repro.serving import kv_pool
        Pmax = max(len(p) for p, _ in reqs)
        prefill = make_prefill_step(cfg, mor=mor, mor_mode=args.mor)
        step = jax.jit(make_decode_step(cfg, mor=mor, mor_mode=args.mor),
                       donate_argnums=(1,))

        def run_group(group):
            prompts = np.zeros((args.batch, Pmax), np.int32)
            for j, (p, _) in enumerate(group):
                prompts[j, Pmax - len(p):] = p   # left-pad to trace max
            cache = kv_pool.init(cfg, args.batch, Pmax + args.gen_len + 1)
            nxt, cache = prefill(params, cache, jnp.asarray(prompts))
            tok = nxt[:, None]
            # the convoy effect: every slot rides until the group's
            # longest generation finishes
            for _ in range(max(g for _, g in group)):
                nxt, cache, _ = step(params, cache, tok)
                tok = nxt[:, None]
            jax.block_until_ready(tok)

        groups = [reqs[i:i + args.batch]
                  for i in range(0, len(reqs), args.batch)]
        run_group(groups[0])                     # compile warmup, untimed
        wall = float("inf")
        for _ in range(3):                       # best-of-3, like the engine
            t0 = time.time()
            for group in groups:
                run_group(group)
            wall = min(wall, max(time.time() - t0, 1e-9))
        n_tok = sum(len(p) + g for p, g in reqs)
        report["static_batch_tokens_per_s"] = n_tok / wall
        report["engine_speedup_vs_static"] = \
            report["tokens_per_s"] / (n_tok / wall)
        print(f"[serve] static-batch baseline: {n_tok / wall:.1f} tok/s "
              f"(engine speedup "
              f"{report['engine_speedup_vs_static']:.2f}x)")

    if obs is not None:
        # files are written LAST so --stream / calibration re-runs on the
        # same engine land in the exported snapshot too
        if args.metrics_json:
            obs.write_metrics_json(args.metrics_json)
        if args.trace_out and obs.tracer is not None:
            obs.write_trace(args.trace_out)
        tr = obs.tracer.summary() if obs.tracer is not None else {}
        ttft = (tr.get("ttft") or {}).get("p50")
        itl = (tr.get("itl") or {}).get("p50")
        print(f"[serve] obs: {len(obs.registry.snapshot())} metric "
              f"families"
              + (f", ttft p50 {ttft * 1e3:.1f} ms" if ttft else "")
              + (f", itl p50 {itl * 1e3:.2f} ms" if itl else "")
              + (f"; metrics -> {args.metrics_json}"
                 if args.metrics_json else "")
              + (f"; trace -> {args.trace_out}" if args.trace_out else ""))
    if server is not None:
        # written files above already captured the final flush; shut
        # the scrape thread down cleanly with the run
        server.close()

    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(report, f, indent=1)
    return report


if __name__ == "__main__":
    main()

"""Serving driver: batched prefill-then-decode with the MoR predictor —
the paper's deployment scenario (inference accelerator).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --reduced --batch 8 --prompt-len 32 --gen-len 32 --mor tiled

Reports tokens/s and the realised MoR skip statistics (neuron- and
tile-level), comparing against the dense baseline when --compare is set.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data import DataConfig
from repro.data.pipeline import synthetic_lm_batch
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import get_model


def generate(cfg, api, params, prompts, gen_len: int, mor=None,
             mor_mode: str = "dense"):
    """prompts: (B, P) int32.  Returns (tokens (B, gen_len), stats).

    Prefill is ONE batched step (the whole prompt per dispatch), so the
    reported throughput reflects the predictor's compute saving rather
    than per-token Python dispatch overhead."""
    B, P = prompts.shape
    max_len = P + gen_len + 1
    cache = api.cache_init(cfg, B, max_len, cfg.jdtype)
    prefill = jax.jit(make_prefill_step(cfg, mor=mor, mor_mode=mor_mode),
                      donate_argnums=(1,))
    step = jax.jit(make_serve_step(cfg, mor=mor, mor_mode=mor_mode),
                   donate_argnums=(1,))

    t0 = time.time()
    nxt, cache = prefill(params, cache, prompts)
    jax.block_until_ready(nxt)
    prefill_dt = time.time() - t0

    tok = nxt[:, None]
    out = []
    # the first decode step JIT-compiles the (B, 1) serve step; keep it
    # outside the timed window so tok/s reports steady-state throughput
    nxt, cache = step(params, cache, tok)
    tok = nxt[:, None]
    out.append(nxt)
    jax.block_until_ready(tok)
    timed = max(gen_len - 1, 1)
    t0 = time.time()
    for t in range(gen_len - 1):
        nxt, cache = step(params, cache, tok)
        tok = nxt[:, None]
        out.append(nxt)
    jax.block_until_ready(tok)
    dt = max(time.time() - t0, 1e-9)
    toks = np.stack([np.asarray(o) for o in out], 1)
    return toks, {"decode_tokens_per_s": B * timed / dt,
                  "decode_ms_per_step": dt / timed * 1e3,
                  "prefill_tokens_per_s": B * P / max(prefill_dt, 1e-9),
                  "prefill_ms": prefill_dt * 1e3}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--mor", default="dense",
                    choices=("dense", "exact", "tiled", "kernel"))
    ap.add_argument("--calib-steps", type=int, default=4)
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-json", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    api = get_model(cfg)
    assert api.has_decode, f"{cfg.name} is encoder-only"
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key, cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        state, _ = mgr.restore({"params": params})
        params = state["params"]

    mor = None
    report = {"arch": cfg.name, "mor_mode": args.mor}
    if args.mor != "dense":
        from repro.core.deploy import calibrate_lm
        def batches():
            s = 0
            while True:
                b = synthetic_lm_batch(cfg, args.batch, 128,
                                       seed=args.seed, step=s)
                yield {"tokens": jnp.asarray(b["tokens"])}
                s += 1
        params, mor, cal = calibrate_lm(params, cfg, api.forward, batches(),
                                        args.calib_steps)
        report["calibration"] = cal
        # attach per-layer execution plans: mode/tiling/capacity travel
        # with the calibrated layers instead of as loose tuples
        from repro.core.deploy import attach_plans
        mor = attach_plans(mor, cfg, args.mor)

    prompts = jnp.asarray(
        synthetic_lm_batch(cfg, args.batch, args.prompt_len,
                           seed=args.seed, step=999)["tokens"])
    toks, stats = generate(cfg, api, params, prompts, args.gen_len,
                           mor=mor, mor_mode=args.mor)
    report.update(stats)
    print(f"[serve] {cfg.name} mor={args.mor}: "
          f"{stats['decode_tokens_per_s']:.1f} tok/s "
          f"({stats['decode_ms_per_step']:.1f} ms/step)")
    if args.compare and args.mor != "dense":
        toks_d, stats_d = generate(cfg, api, params, prompts, args.gen_len)
        agree = float((toks == toks_d).mean())
        report["dense_tokens_per_s"] = stats_d["decode_tokens_per_s"]
        report["token_agreement_vs_dense"] = agree
        print(f"[serve] dense baseline: "
              f"{stats_d['decode_tokens_per_s']:.1f} tok/s; "
              f"token agreement {agree:.3f}")
    if args.out_json:
        json.dump(report, open(args.out_json, "w"), indent=1)
    return report


if __name__ == "__main__":
    main()

"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (device count is locked at first jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_page_mesh(n_shards: int = 0):
    """1-D mesh over the serving page axis (``Engine(layout=
    "paged-sharded")``): physical KV/state pages partitioned across the
    devices, everything else replicated.  ``n_shards`` defaults to all
    visible devices."""
    from repro.distributed.sharding_rules import PAGE_AXIS
    n = n_shards or len(jax.devices())
    return jax.make_mesh((n,), (PAGE_AXIS,))

"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
in this repo: a 10-step scanned matmul reports 1x flops).  Scan-stacked
models are 98% while-loop, so we walk the optimized HLO text ourselves:

  * computations are parsed into op lists; ``while`` ops recurse into
    their body/condition with a trip-count multiplier extracted from the
    condition's ``constant(N)`` bound (jax scans lower to
    ``lt(counter, N)`` — we take the largest s32 constant compared
    against, a heuristic that is exact for scan/fori_loop);
  * flops: dot (2 * prod(result) * prod(lhs contracting dims)) and
    convolution (2 * prod(result) * prod(kernel spatial+input-feature));
    elementwise flops are ignored (sub-1% for these models);
  * bytes: optimized HLO is fused, so every op at computation level is a
    fusion boundary; bytes = operand + result bytes summed over
    non-trivial ops (parameters/constants/tuples/gte excluded as they are
    buffer aliases, fusion-internal ops never appear at this level).

Both are multiplied through nested loop trip counts.  This mirrors what a
real-hardware profile would integrate over time, from the compiled
artifact alone.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_bytes_promoted(type_str: str) -> int:
    """f32 charged at 2 bytes/elem (bf16 promoted by CPU FloatNormalization)."""
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nb = 2 if dt == "f32" else _DTYPE_BYTES[dt]
        total += n * nb
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_TOKEN.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


class Op:
    __slots__ = ("name", "result_type", "opcode", "rest", "line")

    def __init__(self, name, result_type, opcode, rest, line):
        self.name = name
        self.result_type = result_type
        self.opcode = opcode
        self.rest = rest
        self.line = line


def parse_hlo(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            comps[cur].append(Op(m.group(1), m.group(2), m.group(3),
                                 m.group(4), line))
    return comps


# operand = optional inline "f32[8,16]{1,0} " type prefix + %name.
# Optimized HLO text comes in both spellings (types inline at the op line,
# or name-only with the type on the operand's own def line), so parse both.
_OPERAND_RE = re.compile(
    r"(?:([a-z][a-z0-9]*\[[0-9,]*\](?:\{[0-9,:TS()]*\})?)\s+)?"
    r"%([\w\.\-]+)")


def _operand_type(op: Op, idx: int, shapes: Dict[str, str]) -> str:
    """Type string of the op's idx-th operand: inline type when the HLO
    dialect spells it at the call site, else looked up by operand name
    (naive comma-splitting breaks on shape commas like f32[128,256]).

    Scans the whole rest-of-line rather than truncating at the first
    ')': tiled layout annotations like {1,0:T(8,128)} contain parens.
    Operands precede attributes, so low indices stay correct."""
    ops_ = _OPERAND_RE.findall(op.rest)
    if idx >= len(ops_):
        return ""
    inline, name = ops_[idx]
    return inline if inline else shapes.get(name, "")


def _dot_flops(op: Op, comps, shapes: Dict[str, str]) -> float:
    """2 * prod(result) * prod(lhs contracting dims)."""
    out = _shape_elems(op.result_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    sm = _SHAPE_TOKEN.search(_operand_type(op, 0, shapes))
    if not (m and sm):
        return 2.0 * out  # fallback: K unknown
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contracted = 1
    for i in m.group(1).split(","):
        if i != "" and int(i) < len(dims):
            contracted *= dims[int(i)]
    return 2.0 * out * contracted


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    out = _shape_elems(op.result_type)
    sm = _SHAPE_TOKEN.search(_operand_type(op, 1, shapes))
    if not sm:
        return 2.0 * out
    kdims = [int(d) for d in sm.group(2).split(",") if d]
    # kernel = spatial... x in_features x out_features: flops multiplier is
    # prod(kernel)/out_features
    mult = 1
    for d in kdims[:-1]:
        mult *= d
    return 2.0 * out * mult


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "copy-start", "copy-done", "after-all",
               "iota", "broadcast"}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def _trip_count(cond_ops: List[Op]) -> int:
    """Largest integer constant in the loop condition (exact for
    scan/fori_loop bounds; 1 if none found)."""
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _called(op: Op, attr: str) -> Optional[str]:
    m = re.search(attr + r"=%?([\w\.\-]+)", op.line)
    return m.group(1) if m else None


_PARAM_RE = re.compile(r"%?(param_(\d+)[\w\.]*)")


def _param_slice_usage(fops: List[Op], bytes_fn=_shape_bytes) -> Tuple[Dict[int, float], set]:
    """Per-param operand utilization inside a fused computation.

    XLA fusions compute element-wise backwards from the root: a param
    whose every use flows through transparent ops (convert/bitcast/
    copy/transpose) into a narrowing op (slice/dynamic-slice/gather) is
    only read at the narrowed size.  Returns (sliced: param idx ->
    charged bytes, full_use: params read in full)."""
    by_name: Dict[str, Op] = {f.name: f for f in fops}
    consumers: Dict[str, List[Op]] = {}
    for f in fops:
        for tok in re.findall(r"%([\w\.\-]+)", f.rest):
            consumers.setdefault(tok, []).append(f)
    TRANSPARENT = {"convert", "bitcast", "copy", "transpose", "reshape"}
    NARROW = {"slice", "dynamic-slice", "gather"}
    sliced: Dict[int, float] = {}
    full_use: set = set()
    for f in fops:
        if f.opcode != "parameter":
            continue
        m = _PARAM_RE.match(f.name)
        if not m:
            continue
        idx = int(m.group(2))
        charged = 0.0
        full = False
        frontier = [f.name]
        seen = set()
        while frontier and not full:
            nm = frontier.pop()
            if nm in seen:
                continue
            seen.add(nm)
            for c in consumers.get(nm, []):
                if c.opcode in NARROW:
                    charged += bytes_fn(c.result_type)
                elif c.opcode == "dynamic-update-slice":
                    # base operand of a DUS is updated in place (output
                    # aliasing): charge the update window, not the buffer
                    refs = re.findall(r"%([\w\.\-]+)", c.rest)
                    if refs and refs[0] == nm and len(refs) > 1 \
                            and refs[1] in by_name:
                        charged += bytes_fn(by_name[refs[1]].result_type)
                    else:
                        full = True
                        break
                elif c.opcode in TRANSPARENT:
                    frontier.append(c.name)
                else:
                    full = True
                    break
        if full:
            full_use.add(idx)
        else:
            sliced[idx] = sliced.get(idx, 0.0) + charged
    return sliced, full_use


def analyze(text: str, bf16_promoted: bool = False) -> Dict[str, float]:
    """-> {flops, bytes, coll_bytes, coll_bytes_by_type, per-collective
    wire bytes with trip counts applied}.

    ``bf16_promoted``: the CPU backend's FloatNormalization pass promotes
    bf16 buffers to f32 (measured: 7300 f32 vs 1500 bf16 tokens in a
    bf16-model train step).  When set, f32 tensors inside while bodies
    (model activations/weights — bf16 on the TPU target) are charged at
    2 bytes/elem; f32 outside loops (optimizer update, fp32 CE) stays 4."""
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    result = {"flops": 0.0, "bytes": 0.0,
              "coll": defaultdict(float)}
    contrib = defaultdict(float)   # (opcode, shape) -> bytes with trips

    shapes_cache: Dict[str, Dict[str, str]] = {}

    def shapes_of(comp: str) -> Dict[str, str]:
        if comp not in shapes_cache:
            d = {}
            for op in comps[comp]:
                d[op.name] = op.result_type
            # parameters appear as ops too in optimized HLO
            shapes_cache[comp] = d
        return shapes_cache[comp]

    visited_stack = []

    def walk(comp: str, mult: float, in_loop: bool = False):
        if comp not in comps or comp in visited_stack:
            return
        visited_stack.append(comp)
        sh = shapes_of(comp)
        sb = (_shape_bytes_promoted if (bf16_promoted and in_loop)
              else _shape_bytes)
        for op in comps[comp]:
            oc = op.opcode
            if oc == "while":
                body = _called(op, "body")
                cond = _called(op, "condition")
                # XLA records the analysed trip count in backend_config
                m = re.search(r'known_trip_count[":{\s]+n["\s:]+(\d+)',
                              op.line)
                if m:
                    trips = int(m.group(1))
                else:
                    trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    walk(body, mult * trips, True)
                continue
            if oc in ("call", "custom-call"):
                tgt = _called(op, "to_apply") or _called(op, "called_computations")
                if tgt:
                    walk(tgt, mult, in_loop)
            if oc == "conditional":
                for attr in ("true_computation", "false_computation"):
                    tgt = _called(op, attr)
                    if tgt:
                        walk(tgt, mult, in_loop)
            if oc == "fusion":
                tgt = _called(op, "calls")
                if tgt:
                    # flops of ops inside the fusion, bytes at boundary only
                    for fop in comps.get(tgt, []):
                        if fop.opcode == "dot":
                            result["flops"] += mult * _dot_flops(
                                fop, comps, shapes_of(tgt))
                        elif fop.opcode == "convolution":
                            result["flops"] += mult * _conv_flops(
                                fop, shapes_of(tgt))
                    # bytes: params only touched via dynamic-slice are
                    # charged at slice size (weight streaming through a
                    # scan reads one layer per trip, not the full stack)
                    operands = [t.lstrip("%") for t in
                                re.findall(r"%[\w\.\-]+",
                                           op.rest.split("kind=")[0])]
                    fbytes = sb(op.result_type)
                    sliced, full_use = _param_slice_usage(comps[tgt], sb)
                    for pos, opnd in enumerate(operands):
                        if opnd not in sh:
                            continue
                        if pos in full_use or pos not in sliced:
                            fbytes += sb(sh[opnd])
                        else:
                            fbytes += min(sliced[pos], sb(sh[opnd]))
                    result["bytes"] += mult * fbytes
                    contrib[("fusion", op.result_type.split("{")[0][:60])] \
                        += mult * fbytes
                    continue
            if oc == "dot":
                result["flops"] += mult * _dot_flops(op, comps, sh)
            elif oc == "convolution":
                result["flops"] += mult * _conv_flops(op, sh)
            base = oc.replace("-start", "")
            if base in _COLL:
                result["coll"][base] += mult * sb(op.result_type)
            if oc in ("dynamic-slice",):
                # touches only the slice, not the full buffer
                result["bytes"] += mult * 2 * sb(op.result_type)
                contrib[(oc, op.result_type.split("{")[0][:60])] \
                    += mult * 2 * sb(op.result_type)
            elif oc == "dynamic-update-slice":
                # reads + writes the update region (in-place on TPU)
                ops_ = [t.lstrip("%") for t in
                        re.findall(r"%[\w\.\-]+", op.rest)]
                upd = sb(sh[ops_[1]]) if len(ops_) > 1 \
                    and ops_[1] in sh else sb(op.result_type)
                result["bytes"] += mult * 2 * upd
            elif oc not in _SKIP_BYTES and not oc.endswith("-done"):
                opnd_bytes = 0.0
                # operand types are not inline in optimized HLO; use
                # result-only accounting + operand lookup by name
                for token in re.findall(r"%([\w\.\-]+)", op.rest):
                    if token in sh:
                        opnd_bytes += sb(sh[token])
                result["bytes"] += mult * (sb(op.result_type)
                                           + opnd_bytes)
                contrib[(oc, op.result_type.split("{")[0][:60])] \
                    += mult * (sb(op.result_type) + opnd_bytes)
        visited_stack.pop()

    if entry:
        walk(entry, 1.0)
    coll = dict(result["coll"])
    top = sorted(contrib.items(), key=lambda kv: -kv[1])[:20]
    return {"flops": result["flops"], "bytes": result["bytes"],
            "coll_bytes_by_type": coll,
            "coll_bytes": sum(coll.values()),
            "top_byte_contributors": [
                {"op": k[0], "shape": k[1], "bytes": v} for k, v in top]}

"""Span-based request tracer for the serving engine.

The engine's hot loop is host-synchronous per dispatch (one
``step()`` = one compiled-step launch), so the tracer records spans
from the host dispatch timeline: for each request admit → prefill
chunks → decode emits → finish, and for the engine a span per
dispatch.  TTFT is measured submit → end of the dispatch that emitted
the request's first token; inter-token latency is the gap between the
ends of consecutive emitting dispatches.  Both are host-timeline
approximations (a dispatch emits tokens for many slots at once), which
is exactly the granularity the scheduler can act on.

Export is Chrome-trace JSON (``{"traceEvents": [...]}`` with "ph":"X"
complete events) — loadable in Perfetto / chrome://tracing.  Span
events per request live on a per-slot track so concurrent requests
stack visually the way they share slots physically.

Optionally (``jax_annotations=True``) each dispatch is wrapped in a
``jax.profiler.TraceAnnotation`` so the spans line up with XLA events
in a device profile; off by default to keep the overhead budget.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Tracer", "validate_chrome_trace"]

# inter-token latencies at reduced dims are sub-ms; extend the default
# latency buckets downward for ITL
ITL_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
               0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5)
TTFT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                0.5, 1.0, 2.5, 5.0, 10.0, 30.0)


@dataclass
class _Req:
    rid: int
    t_submit: float
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_last_emit: Optional[float] = None
    t_finish: Optional[float] = None
    slot: Optional[int] = None
    n_tokens: int = 0


@dataclass
class _Event:
    name: str
    ts: float            # seconds, perf_counter timebase
    dur: float
    pid: str
    tid: object
    args: Dict = field(default_factory=dict)


class Tracer:
    """Collects dispatch + request spans; drains into registry
    histograms and a Chrome-trace event list."""

    def __init__(self, registry=None, jax_annotations: bool = False,
                 max_events: int = 100_000):
        self.registry = registry
        self.jax_annotations = jax_annotations
        self.max_events = max_events
        self.reset()
        if registry is not None:
            self._h_ttft = registry.histogram(
                "repro_serving_ttft_seconds",
                "time from submit to first emitted token",
                buckets=TTFT_BUCKETS)
            self._h_itl = registry.histogram(
                "repro_serving_itl_seconds",
                "inter-token latency between emitting dispatches",
                buckets=ITL_BUCKETS)
            self._h_queue = registry.histogram(
                "repro_serving_queue_wait_seconds",
                "time from submit to slot admission",
                buckets=TTFT_BUCKETS)
            self._h_dispatch = registry.histogram(
                "repro_serving_dispatch_seconds",
                "wall time of one engine dispatch", ("kind",),
                buckets=ITL_BUCKETS)
        else:
            self._h_ttft = self._h_itl = None
            self._h_queue = self._h_dispatch = None

    def reset(self) -> None:
        self._reqs: Dict[int, _Req] = {}
        self._events: List[_Event] = []
        self._n_dispatch = 0
        self._n_preempt = 0
        self._n_restore = 0
        self._n_drift = 0
        self._dropped = 0
        # the tracer owns its latency histograms: a reset boundary (the
        # engine's reset_counters between timed passes) zeroes them too,
        # so exported quantiles describe the LAST pass, not the compile-
        # heavy warmup
        for h in (getattr(self, "_h_ttft", None),
                  getattr(self, "_h_itl", None),
                  getattr(self, "_h_queue", None),
                  getattr(self, "_h_dispatch", None)):
            if h is not None:
                h.clear()

    # -- hooks the engine calls -------------------------------------------
    @staticmethod
    def now() -> float:
        return time.perf_counter()

    def on_submit(self, rid: int, t: Optional[float] = None) -> None:
        self._reqs[rid] = _Req(rid, self.now() if t is None else t)

    def annotation(self, kind: str):
        """Context manager wrapping a dispatch; jax.profiler annotation
        when enabled, else a no-op."""
        if self.jax_annotations:
            import jax
            return jax.profiler.TraceAnnotation(f"repro.dispatch.{kind}")
        import contextlib
        return contextlib.nullcontext()

    def on_dispatch(self, kind: str, t0: float, t1: float, *,
                    admitted: Sequence[Tuple[int, int]] = (),
                    prefilling: Sequence[Tuple[int, int, int, int]] = (),
                    emits: Sequence[Tuple[int, int]] = (),
                    emit_counts: Optional[Sequence[int]] = None,
                    finished: Sequence[int] = (),
                    queue_depth: int = 0,
                    n_active: int = 0) -> None:
        """One engine step.  admitted: (slot, rid) pairs newly placed;
        prefilling: (slot, rid, offset, take) chunks consumed this
        dispatch; emits: (slot, rid) that produced a token; finished:
        rids that completed.  Speculative verify dispatches emit UP TO
        k+1 tokens per slot at once — ``emit_counts`` (aligned with
        ``emits``) carries the per-slot count so request token totals
        stay exact; TTFT/ITL remain per-emitting-dispatch timestamps
        (an accepted run reaches the host as one batch, so the
        per-round gap IS its inter-token cadence).  Any ``kind`` string
        flows through to the span name and the dispatch histogram
        label — the speculative round uses draft/verify/replay."""
        i = self._n_dispatch
        self._n_dispatch += 1
        n_emits = (sum(emit_counts) if emit_counts is not None
                   else len(emits))
        self._emit(_Event(f"dispatch/{kind}", t0, t1 - t0, "engine",
                          "dispatch",
                          {"i": i, "kind": kind,
                           "queue_depth": queue_depth,
                           "n_active": n_active,
                           "n_emits": n_emits}))
        if self._h_dispatch is not None:
            self._h_dispatch.observe(t1 - t0, kind=kind)

        for slot, rid in admitted:
            r = self._reqs.get(rid)
            if r is None:        # request submitted before tracer reset
                r = self._reqs[rid] = _Req(rid, t0)
            r.t_admit = t0
            r.slot = slot
            self._emit(_Event(f"queued rid={rid}", r.t_submit,
                              t0 - r.t_submit, "requests", f"rid {rid}",
                              {"rid": rid}))
            if self._h_queue is not None:
                self._h_queue.observe(t0 - r.t_submit)

        for item in prefilling:
            slot, rid, off, take = item
            self._emit(_Event(f"prefill rid={rid} [{off}:{off + take}]",
                              t0, t1 - t0, "slots", f"slot {slot}",
                              {"rid": rid, "offset": off, "take": take}))

        for j, (slot, rid) in enumerate(emits):
            n_tok = emit_counts[j] if emit_counts is not None else 1
            r = self._reqs.get(rid)
            self._emit(_Event(f"decode rid={rid}", t0, t1 - t0, "slots",
                              f"slot {slot}",
                              {"rid": rid, "n_tokens": n_tok}))
            if r is None:
                continue
            r.n_tokens += n_tok
            if r.t_first_token is None:
                r.t_first_token = t1
                if self._h_ttft is not None:
                    self._h_ttft.observe(t1 - r.t_submit)
            elif r.t_last_emit is not None and self._h_itl is not None:
                self._h_itl.observe(t1 - r.t_last_emit)
            r.t_last_emit = t1

        for rid in finished:
            r = self._reqs.get(rid)
            if r is None:
                continue
            r.t_finish = t1
            self._emit(_Event(f"request rid={rid}", r.t_submit,
                              t1 - r.t_submit, "requests", f"rid {rid}",
                              {"rid": rid, "n_tokens": r.n_tokens,
                               "ttft_s": None if r.t_first_token is None
                               else round(r.t_first_token - r.t_submit,
                                          6)}))

    def on_preempt(self, rid: int, slot: int,
                   t: Optional[float] = None) -> None:
        """Request ``rid`` was spilled out of ``slot`` (pages moved to
        host; it re-enters the waiting queue at its exact progress)."""
        t = self.now() if t is None else t
        self._n_preempt += 1
        self._emit(_Event(f"preempt rid={rid}", t, 0.0, "requests",
                          f"rid {rid}", {"rid": rid, "slot": slot}))

    def on_restore(self, rid: int, slot: int,
                   t: Optional[float] = None) -> None:
        """Spilled request ``rid`` re-admitted into ``slot``."""
        t = self.now() if t is None else t
        self._n_restore += 1
        self._emit(_Event(f"restore rid={rid}", t, 0.0, "requests",
                          f"rid {rid}", {"rid": rid, "slot": slot}))

    def on_drift(self, group: str, layer: int, expert: Optional[int],
                 rate: float, t: Optional[float] = None) -> None:
        """Predictor drift flagged on one (group, layer[, expert])
        series — an instant marker on its own "quality" track so the
        degradation onset lines up against the dispatch timeline."""
        t = self.now() if t is None else t
        self._n_drift += 1
        where = f"{group}/L{layer}" + ("" if expert is None
                                       else f"/E{expert}")
        self._emit(_Event(f"drift {where}", t, 0.0, "quality", group,
                          {"group": group, "layer": layer,
                           "expert": expert,
                           "false_skip_rate": round(rate, 6)}))

    def _emit(self, ev: _Event) -> None:
        if len(self._events) >= self.max_events:
            self._dropped += 1
            return
        self._events.append(ev)

    # -- introspection / export -------------------------------------------
    @property
    def n_dispatches(self) -> int:
        return self._n_dispatch

    def request_spans(self) -> Dict[int, Dict]:
        out = {}
        for rid, r in sorted(self._reqs.items()):
            out[rid] = {
                "t_submit": r.t_submit, "t_admit": r.t_admit,
                "t_first_token": r.t_first_token,
                "t_finish": r.t_finish, "slot": r.slot,
                "n_tokens": r.n_tokens,
                "ttft_s": None if r.t_first_token is None
                else r.t_first_token - r.t_submit}
        return out

    def summary(self) -> Dict:
        out: Dict = {"n_dispatches": self._n_dispatch,
                     "n_requests": len(self._reqs),
                     "n_preemptions": self._n_preempt,
                     "n_restores": self._n_restore,
                     "n_drift_events": self._n_drift,
                     "events_dropped": self._dropped}
        if self._h_ttft is not None:
            out["ttft"] = self._h_ttft.summary()
            out["itl"] = self._h_itl.summary()
            out["queue_wait"] = self._h_queue.summary()
        return out

    def to_chrome_trace(self) -> Dict:
        """Chrome trace event format; ts/dur in microseconds."""
        pids = sorted({ev.pid for ev in self._events})
        pid_ids = {p: i + 1 for i, p in enumerate(pids)}
        tid_ids: Dict[Tuple[str, object], int] = {}
        events: List[Dict] = []
        for pid, pi in pid_ids.items():
            events.append({"ph": "M", "name": "process_name", "pid": pi,
                           "tid": 0, "args": {"name": pid}})
        for ev in self._events:
            key = (ev.pid, ev.tid)
            if key not in tid_ids:
                tid_ids[key] = len([k for k in tid_ids
                                    if k[0] == ev.pid]) + 1
                events.append({"ph": "M", "name": "thread_name",
                               "pid": pid_ids[ev.pid],
                               "tid": tid_ids[key],
                               "args": {"name": str(ev.tid)}})
            events.append({"ph": "X", "name": ev.name,
                           "ts": round(ev.ts * 1e6, 3),
                           "dur": round(max(ev.dur, 0.0) * 1e6, 3),
                           "pid": pid_ids[ev.pid], "tid": tid_ids[key],
                           "args": ev.args})
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "metadata": {"tool": "repro.obs.tracer"}}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


def validate_chrome_trace(obj) -> List[str]:
    """Schema check for the exported trace; returns a list of problems
    (empty == valid).  Shared by tests and the CI smoke job."""
    problems: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["missing top-level traceEvents"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M", "B", "E", "i"):
            problems.append(f"event {i}: bad ph {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev:
            problems.append(f"event {i}: missing name/pid")
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)):
                problems.append(f"event {i}: bad ts {ts!r}")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
    return problems

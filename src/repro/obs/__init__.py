"""repro.obs — unified observability for the serving stack.

One :class:`Observability` bundle carries the three pieces:

- ``registry`` — :class:`~repro.obs.registry.MetricsRegistry`
  (counters / gauges / histograms with labels; JSON snapshot +
  Prometheus text exposition).
- ``tracer`` — :class:`~repro.obs.tracer.Tracer` (per-request and
  per-dispatch spans, TTFT / inter-token latency histograms, Chrome
  trace / Perfetto export).  ``None`` when tracing is disabled.
- ``device_metrics`` flag — when True the engine threads a packed
  int32 :class:`~repro.obs.device.DeviceMetricsSpec` block through the
  compiled step and drains it only at flush boundaries.

See README.md in this directory for the metric namespace.
"""
from __future__ import annotations

from repro.obs.device import SCALE, DeviceMetricsSpec
from repro.obs.quality import (DriftDetector, EwmaDetector,
                               PageHinkleyDetector,
                               inject_coefficient_drift)
from repro.obs.registry import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge,
                                Histogram, MetricsRegistry)
from repro.obs.server import MetricsServer
from repro.obs.tracer import Tracer, validate_chrome_trace

__all__ = ["Observability", "MetricsRegistry", "Counter", "Gauge",
           "Histogram", "Tracer", "DeviceMetricsSpec", "SCALE",
           "DEFAULT_LATENCY_BUCKETS", "validate_chrome_trace",
           "DriftDetector", "EwmaDetector", "PageHinkleyDetector",
           "inject_coefficient_drift", "MetricsServer"]


class Observability:
    """Bundle handed to :class:`repro.serving.engine.Engine` (and the
    benchmark scenarios) tying registry + tracer + device-metrics
    toggle together.  Multiple engines may share one bundle — series
    are disambiguated by labels (layout, group, shard)."""

    def __init__(self, registry: MetricsRegistry = None, *,
                 device_metrics: bool = True, tracing: bool = True,
                 jax_annotations: bool = False):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.device_metrics = device_metrics
        self.jax_annotations = jax_annotations
        self.tracer = Tracer(self.registry,
                             jax_annotations=jax_annotations) \
            if tracing else None

    def snapshot(self) -> dict:
        out = {"metrics": self.registry.snapshot()}
        if self.tracer is not None:
            out["tracing"] = self.tracer.summary()
        return out

    def write_metrics_json(self, path: str) -> None:
        import json
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)

    def write_trace(self, path: str) -> None:
        assert self.tracer is not None, "tracing disabled"
        self.tracer.write_chrome_trace(path)

"""Live metrics endpoint — a stdlib-only scrape target.

``MetricsServer(obs)`` runs a ``http.server.ThreadingHTTPServer`` on a
daemon thread serving the shared :class:`~repro.obs.Observability`
bundle:

- ``GET /metrics`` — Prometheus text exposition
  (``registry.to_prometheus()``, content type ``text/plain;
  version=0.0.4``) for scrapers;
- ``GET /metrics.json`` — the full registry snapshot + tracer summary
  (``obs.snapshot()``) for humans and tests.

Both render at REQUEST time from the registry's current state, so
whatever the engine mirrored at its last flush is what a scrape sees —
the server never touches the engine or the device.  ``port=0`` binds
an ephemeral port (read it back from ``.port``); ``close()`` shuts the
listener down and joins the thread, which is what ``serve
--metrics-port`` does when the engine winds down.  No dependencies
beyond the standard library.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

__all__ = ["MetricsServer"]

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Background scrape endpoint over an ``Observability`` bundle."""

    def __init__(self, obs, host: str = "127.0.0.1", port: int = 0):
        self.obs = obs
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                          # noqa: N802
                path = self.path.split("?", 1)[0]
                if path in ("/metrics", "/"):
                    body = outer.obs.registry.to_prometheus().encode()
                    ctype = PROM_CONTENT_TYPE
                elif path == "/metrics.json":
                    body = json.dumps(outer.obs.snapshot(),
                                      indent=1).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404, "unknown path "
                                    f"{path!r} (try /metrics)")
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):
                pass                # scrapes must not spam the console

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="repro-metrics-server", daemon=True)
        self._thread.start()

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Predictor-quality observability: drift detection over shadow-oracle
scores (ISSUE 10, the trigger signal for ROADMAP item 4's online
recalibration loop).

The serving engine samples 1-in-N dispatches through a "shadow" twin of
its active MoR execution plans (``MoRExecutionPlan.as_shadow``): the
sampled dispatch ALSO runs the dense-oracle forward, scoring the
predictor's tile decisions against the dense truth, and the exact
per-(layer, expert) false-skip / false-keep counts accumulate in the
device metrics block's quality lanes (``obs.device.QUALITY_FIELDS``) —
zero extra host syncs, drained once per flush like everything else.

This module is the HOST side: :class:`DriftDetector` consumes the
drained cumulative counters flush-over-flush, turns them into
per-series false-skip rates, and runs a pluggable change detector per
(group, layer[, expert]) series — EWMA-vs-threshold by default (an
absolute misprediction budget: the paper's accuracy cliff lives at a
few percent of incorrectly-predicted zeros, Fig. 12), or Page-Hinkley
for relative mean-shift detection.  The engine mirrors the rates into
``repro_mor_false_skip_rate`` / ``repro_mor_drift`` gauges, fires
tracer drift events into the Perfetto timeline, and surfaces the state
in ``report()["quality"]``.

``inject_coefficient_drift`` is the test/benchmark knob: it perturbs
ONE layer's fitted-line intercept in a calibrated MoR tree (the
predictor goes wrong; the model's dense truth is untouched), which is
exactly the degradation signature the detector exists to catch.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

__all__ = ["DriftDetector", "EwmaDetector", "PageHinkleyDetector",
           "inject_coefficient_drift"]


class EwmaDetector:
    """Exponentially-weighted moving average vs an ABSOLUTE threshold.

    ``update(rate)`` folds one per-flush false-skip rate in and returns
    True while the smoothed rate sits above ``threshold``.  The EWMA
    (not the raw sample) is compared so a single noisy flush on a tiny
    shadow sample cannot flap the flag."""

    def __init__(self, threshold: float, alpha: float = 0.5):
        assert 0.0 < alpha <= 1.0
        self.threshold = float(threshold)
        self.alpha = float(alpha)
        self.ewma: Optional[float] = None

    def update(self, rate: float) -> bool:
        self.ewma = (rate if self.ewma is None
                     else self.alpha * rate + (1 - self.alpha) * self.ewma)
        return self.ewma > self.threshold

    @property
    def value(self) -> float:
        return 0.0 if self.ewma is None else self.ewma


class PageHinkleyDetector:
    """Page-Hinkley mean-shift test: fires when the cumulative positive
    deviation from the running mean exceeds ``threshold`` (lambda).
    Detects RELATIVE degradation from whatever baseline the series
    establishes, where the EWMA detector needs an absolute budget."""

    def __init__(self, threshold: float, delta: float = 0.005):
        self.threshold = float(threshold)
        self.delta = float(delta)
        self.n = 0
        self.mean = 0.0
        self.cum = 0.0
        self.cum_min = 0.0

    def update(self, rate: float) -> bool:
        self.n += 1
        self.mean += (rate - self.mean) / self.n
        self.cum += rate - self.mean - self.delta
        self.cum_min = min(self.cum_min, self.cum)
        return (self.cum - self.cum_min) > self.threshold

    @property
    def value(self) -> float:
        return self.cum - self.cum_min


_DETECTORS = {"ewma": EwmaDetector, "page-hinkley": PageHinkleyDetector}


class DriftDetector:
    """Per-(group, layer[, expert]) drift detection over the drained
    shadow-score counters.

    ``update(device_metrics)`` takes the engine's host-side
    ``DeviceMetricsSpec.read`` output (CUMULATIVE counters), diffs it
    against the previous flush, feeds each series' per-flush false-skip
    rate into its detector instance, and returns the NEWLY-drifted
    series as event dicts ``{"group", "layer", "expert", "rate"}``
    (``expert`` is None for (L,)-shaped groups) — the engine turns
    those into tracer timeline events.  Series with fewer than
    ``min_tiles`` truly-live tiles since the last flush are skipped
    (nothing to score).  ``rebase()`` forgets the cumulative snapshot
    (the engine calls it from ``reset_counters`` when the device block
    re-inits) without losing detector state."""

    def __init__(self, threshold: float = 0.25, detector: str = "ewma",
                 min_tiles: int = 1, **det_kw):
        assert detector in _DETECTORS, \
            f"unknown drift detector {detector!r} (have {sorted(_DETECTORS)})"
        self.threshold = float(threshold)
        self.detector = detector
        self.min_tiles = int(min_tiles)
        self._det_kw = det_kw
        self._dets: Dict = {}            # (group, idx) -> detector
        self._drifted: Dict = {}         # (group, idx) -> bool
        self._rates: Dict = {}           # (group, idx) -> last rate
        self._last: Dict = {}            # group -> (false_skip, truth_live)
        self.n_updates = 0

    def _series(self, key):
        det = self._dets.get(key)
        if det is None:
            det = self._dets[key] = _DETECTORS[self.detector](
                self.threshold, **self._det_kw)
        return det

    def update(self, device_metrics: Dict) -> List[Dict]:
        events: List[Dict] = []
        self.n_updates += 1
        for g, d in device_metrics.get("groups", {}).items():
            fs = np.asarray(d["false_skip"], np.int64)
            tl = np.asarray(d["truth_live"], np.int64)
            pfs, ptl = self._last.get(g, (np.zeros_like(fs),
                                          np.zeros_like(tl)))
            dfs, dtl = fs - pfs, tl - ptl
            self._last[g] = (fs, tl)
            for idx in np.ndindex(fs.shape):
                if dtl[idx] < self.min_tiles:
                    continue                  # no shadow sample to score
                rate = float(dfs[idx]) / float(dtl[idx])
                key = (g, idx)
                self._rates[key] = rate
                was = self._drifted.get(key, False)
                now = self._series(key).update(rate)
                self._drifted[key] = now
                if now and not was:
                    events.append({
                        "group": g, "layer": int(idx[0]),
                        "expert": int(idx[1]) if len(idx) > 1 else None,
                        "rate": rate})
        return events

    def rebase(self) -> None:
        """Forget the cumulative-counter snapshot (the source counters
        were zeroed, e.g. ``Engine.reset_counters``); detector state —
        EWMA / Page-Hinkley accumulators and raised flags — survives."""
        self._last = {}

    def reset(self) -> None:
        """Full reset: snapshot AND every per-series detector."""
        self._last = {}
        self._dets = {}
        self._drifted = {}
        self._rates = {}
        self.n_updates = 0

    # -- introspection -----------------------------------------------------
    def state(self) -> Dict[str, Dict]:
        """{group: {"rate": smoothed array, "last_rate": array,
        "drifted": bool array}} shaped like the source counters."""
        out: Dict[str, Dict] = {}
        for g, (fs, _tl) in self._last.items():
            rate = np.zeros(fs.shape, np.float64)
            last = np.zeros(fs.shape, np.float64)
            drifted = np.zeros(fs.shape, bool)
            for idx in np.ndindex(fs.shape):
                det = self._dets.get((g, idx))
                if det is not None:
                    rate[idx] = det.value
                last[idx] = self._rates.get((g, idx), 0.0)
                drifted[idx] = self._drifted.get((g, idx), False)
            out[g] = {"rate": rate, "last_rate": last, "drifted": drifted}
        return out

    def drifted_series(self) -> List[Dict]:
        """Every series whose flag is currently raised."""
        out = []
        for (g, idx), flag in sorted(self._drifted.items()):
            if flag:
                out.append({"group": g, "layer": int(idx[0]),
                            "expert": int(idx[1]) if len(idx) > 1 else None,
                            "rate": self._rates.get((g, idx), 0.0)})
        return out

    def summary(self) -> Dict:
        st = self.state()
        return {
            "detector": self.detector,
            "threshold": self.threshold,
            "n_updates": self.n_updates,
            "n_series": len(self._dets),
            "n_drifted": sum(1 for v in self._drifted.values() if v),
            "drifted": self.drifted_series(),
            "false_skip_rate": {
                g: np.round(d["rate"], 6).tolist()
                for g, d in st.items()},
        }


def inject_coefficient_drift(raw_mor: Dict, group: str, layer: int, *,
                             shift: Optional[float] = None) -> Dict:
    """Return a copy of a RAW calibrated MoR tree ({group: stacked
    MoRLayer}) with ONE layer's predictor wrecked, while the model's
    dense truth is untouched — the degradation signature of stale
    calibration coefficients, which is what the drift detector exists
    to catch.  Both calibration artifacts the hybrid predictor rests on
    go stale together:

    - the fitted-line intercept ``b`` is shifted hard negative, so the
      binary rookie estimates every pre-activation below zero (``b``
      feeds only ``estimate_preact``; the real pre-activations never
      see it);
    - the proxy assignments are cleared (``proxy_slot = -1``), so the
      proxy rookie abstains instead of vetoing the binary rookie's
      skips (``hybrid_predict`` skips only when BOTH rookies say zero
      — a live proxy column would rescue every neuron it covers and
      mask the broken line).

    The layer is force-enabled so calibration's own accuracy gate
    cannot hide the injection.  ``shift`` defaults to a value large
    enough to dominate any realistically-calibrated line."""
    import jax.numpy as jnp
    stack = raw_mor[group]
    b = jnp.asarray(stack["b"], jnp.float32)
    if shift is None:
        shift = 10.0 * (float(jnp.abs(b[layer]).mean())
                        + float(jnp.abs(stack["m"][layer]).mean()) + 1.0)
    new = dict(stack)
    new["b"] = b.at[layer].add(-float(shift))
    new["proxy_slot"] = jnp.asarray(stack["proxy_slot"]).at[layer].set(-1)
    new["enable"] = jnp.asarray(stack["enable"], bool).at[layer].set(True)
    out = dict(raw_mor)
    out[group] = new
    return out

"""Metrics registry: counters / gauges / histograms with labels, one
namespace for every signal the stack emits.

The registry is deliberately small and dependency-free: metric families
are created idempotently (``registry.counter(name, ...)`` returns the
existing family on repeat calls, kind-checked), each family holds one
series per label-value tuple, and two exports cover the consumers we
have — ``snapshot()`` (plain dict, lands in the BENCH_*.json files and
``--metrics-json``) and ``to_prometheus()`` (text exposition for
scraping / eyeballing).

Semantics note: serving sources (engine dispatch counters, the
device-resident metrics block, pool/prefix counters) keep their own
cumulative accounting and MIRROR it into the registry at flush
boundaries via ``Counter.set`` — so a registry counter tracks its
source, including ``Engine.reset_counters()`` zeroing between a warmup
and a timed pass.  ``inc`` is for sources whose only accounting IS the
registry (e.g. the tracer's span counts).
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram",
           "DEFAULT_LATENCY_BUCKETS"]

# log-spaced seconds, sized for host-side serving latencies (sub-ms
# dispatch spans up to multi-second requests); +Inf is implicit
DEFAULT_LATENCY_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)


def _label_key(label_names: Sequence[str], labels: Dict) -> Tuple:
    extra = set(labels) - set(label_names)
    assert not extra, f"unknown labels {sorted(extra)} (have {label_names})"
    return tuple(str(labels.get(n, "")) for n in label_names)


class _Family:
    """One named metric family; holds a series per label-value tuple."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 label_names: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._series: Dict[Tuple, object] = {}

    def _get(self, labels: Dict):
        key = _label_key(self.label_names, labels)
        if key not in self._series:
            self._series[key] = self._new_series()
        return self._series[key]

    def series(self) -> Iterable[Tuple[Dict, object]]:
        for key, val in sorted(self._series.items()):
            yield dict(zip(self.label_names, key)), val

    def clear(self) -> None:
        """Drop every series (an owner's reset boundary — e.g. the
        tracer zeroes its latency histograms between timed passes)."""
        self._series = {}


class Counter(_Family):
    """Monotone-by-convention count.  ``set`` mirrors an externally
    accumulated cumulative value (see module docstring)."""

    kind = "counter"

    def _new_series(self):
        return 0.0

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(self.label_names, labels)
        self._series[key] = self._series.get(key, 0.0) + value

    def set(self, value: float, **labels) -> None:
        key = _label_key(self.label_names, labels)
        self._series[key] = float(value)

    def get(self, **labels) -> float:
        return self._series.get(_label_key(self.label_names, labels), 0.0)


class Gauge(_Family):
    kind = "gauge"

    def _new_series(self):
        return 0.0

    def set(self, value: float, **labels) -> None:
        key = _label_key(self.label_names, labels)
        self._series[key] = float(value)

    def get(self, **labels) -> float:
        return self._series.get(_label_key(self.label_names, labels), 0.0)


class _HistSeries:
    __slots__ = ("counts", "sum", "count", "vmin", "vmax")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)      # +1 = +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.vmin = math.inf
        self.vmax = -math.inf


class Histogram(_Family):
    """Fixed upper-edge buckets (Prometheus-style cumulative on export;
    stored per-bucket).  ``quantile`` interpolates within the winning
    bucket — good enough for p50/p99 reporting, exact at the edges."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 label_names: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help, label_names)
        assert list(buckets) == sorted(buckets) and len(buckets) >= 1
        self.buckets = tuple(float(b) for b in buckets)

    def _new_series(self):
        return _HistSeries(len(self.buckets))

    def observe(self, value: float, **labels) -> None:
        s: _HistSeries = self._get(labels)
        value = float(value)
        i = 0
        while i < len(self.buckets) and value > self.buckets[i]:
            i += 1
        s.counts[i] += 1
        s.sum += value
        s.count += 1
        s.vmin = min(s.vmin, value)
        s.vmax = max(s.vmax, value)

    def quantile(self, q: float, **labels) -> Optional[float]:
        """Bucket-interpolated quantile in [0, 1]; None when empty."""
        s = self._series.get(_label_key(self.label_names, labels))
        if s is None or s.count == 0:
            return None
        rank = q * s.count
        cum = 0
        for i, c in enumerate(s.counts):
            if c == 0:
                continue
            lo = 0.0 if i == 0 else self.buckets[i - 1]
            hi = s.vmax if i == len(self.buckets) else self.buckets[i]
            if cum + c >= rank:
                frac = (rank - cum) / c
                # clamp interpolation to the observed range: buckets know
                # only edges, vmin/vmax know the actual extremes
                return min(max(lo + frac * (hi - lo), s.vmin), s.vmax)
            cum += c
        return s.vmax

    def summary(self, **labels) -> Dict:
        s = self._series.get(_label_key(self.label_names, labels))
        if s is None or s.count == 0:
            return {"count": 0}
        return {"count": s.count, "sum": round(s.sum, 6),
                "mean": round(s.sum / s.count, 6),
                "min": round(s.vmin, 6), "max": round(s.vmax, 6),
                "p50": round(self.quantile(0.50, **labels), 6),
                "p90": round(self.quantile(0.90, **labels), 6),
                "p99": round(self.quantile(0.99, **labels), 6)}


class MetricsRegistry:
    def __init__(self):
        self._families: Dict[str, _Family] = {}

    def _register(self, cls, name: str, help: str, labels: Sequence[str],
                  **kw) -> _Family:
        fam = self._families.get(name)
        if fam is not None:
            assert isinstance(fam, cls), \
                f"{name} already registered as {fam.kind}"
            return fam
        fam = cls(name, help, labels, **kw)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._register(Histogram, name, help, labels,
                              buckets=buckets)

    def get(self, name: str) -> Optional[_Family]:
        return self._families.get(name)

    # -- exports -----------------------------------------------------------
    def snapshot(self) -> Dict:
        """Plain-dict export (JSON-safe): {name: {type, help, values}}.
        Histogram values carry bucket counts + the summary stats."""
        out: Dict = {}
        for name, fam in sorted(self._families.items()):
            rows: List[Dict] = []
            for labels, val in fam.series():
                row: Dict = {"labels": labels}
                if fam.kind == "histogram":
                    row["buckets"] = {
                        **{str(b): c for b, c in zip(fam.buckets,
                                                     val.counts)},
                        "+Inf": val.counts[-1]}
                    row.update(fam.summary(**labels))
                else:
                    row["value"] = val
                rows.append(row)
            out[name] = {"type": fam.kind, "help": fam.help,
                         "values": rows}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (counters get no _total suffix
        appended — name them *_total at creation)."""
        def esc(v) -> str:
            # label VALUES escape backslash, double-quote and newline
            # (exposition format) — model/config names with odd
            # characters would otherwise break the scrape
            return (str(v).replace("\\", r"\\").replace('"', r"\"")
                    .replace("\n", r"\n"))

        def fmt_labels(d: Dict) -> str:
            if not d:
                return ""
            body = ",".join(f'{k}="{esc(v)}"' for k, v in d.items())
            return "{" + body + "}"

        lines: List[str] = []
        for name, fam in sorted(self._families.items()):
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for labels, val in fam.series():
                if fam.kind == "histogram":
                    cum = 0
                    for b, c in zip(fam.buckets, val.counts):
                        cum += c
                        lb = dict(labels, le=repr(float(b)))
                        lines.append(f"{name}_bucket{fmt_labels(lb)} {cum}")
                    cum += val.counts[-1]
                    lb = dict(labels, le="+Inf")
                    lines.append(f"{name}_bucket{fmt_labels(lb)} {cum}")
                    lines.append(f"{name}_sum{fmt_labels(labels)} "
                                 f"{val.sum}")
                    lines.append(f"{name}_count{fmt_labels(labels)} "
                                 f"{val.count}")
                else:
                    v = val
                    v = int(v) if float(v).is_integer() else v
                    lines.append(f"{name}{fmt_labels(labels)} {v}")
        return "\n".join(lines) + "\n"

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)

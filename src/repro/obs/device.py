"""Device-resident dispatch counters.

A packed int32 metrics block rides through the compiled step the same
way ``apply_cache_ops`` packs page edits: the engine threads a
``(n_rows, size)`` buffer into ``_step_impl`` as a donated operand, the
step adds a delta vector built from values already live on device
(token counts from ``n_valid``/``use_pending``, per-layer predictor
tile counts from the aux stats, page-edit counts from the ops vector),
and the host reads the buffer back ONCE at flush boundaries.  No
``.item()`` / host sync per dispatch — the default path's device-sync
count is identical with the block present or absent.

Layout (all int32, fixed at spec construction so the jit signature is
stable):

- header fields (replicated across shard rows; read takes row 0):
  ``dispatches, prefill_tokens, decode_tokens, pages_touched,
  tokens_drafted, tokens_accepted`` (the last two advance only on
  speculative draft/verify dispatches — engine.spec — and drain with
  the rest of the block, zero extra syncs)
- shard-local fields (each shard row accumulates its own; read sums
  rows): ``kv_page_resets, kv_page_copies, state_page_resets,
  state_page_copies``
- per MoR stat group (``mor_stats`` / ``dense_mor_stats`` /
  ``moe_mor_stats``), flattened per-layer(-expert):
  ``tiles_total`` and ``tiles_skipped`` (exact integer tile counts)
  and ``live_q`` (running sum of ``round(frac_tiles_live * SCALE)``,
  fixed-point so a fraction can accumulate in an int32 lane; divide by
  ``SCALE * dispatches`` to recover the mean).
- per stat group, the predictor-QUALITY lanes fed by shadow-oracle
  dispatches (``QUALITY_FIELDS``, same per-layer(-expert) shape):
  exact ``shadow_tiles`` / ``false_skip`` / ``false_keep`` /
  ``truth_live`` tile counts plus fixed-point running sums
  ``sign_agree_q`` / ``err_q`` (divide by ``SCALE *
  shadow_dispatches`` for the means).  The lanes exist
  unconditionally — the layout is internal and stays stable whether
  shadow scoring is on or off; a primary dispatch's aux simply lacks
  the ``shadow_*`` keys and writes zeros, while a shadow dispatch's
  aux is filtered TO those keys so it never double-counts the base
  tile lanes.

Sharded engines give the block one row per page shard with spec
``P(PAGE_AXIS, None)``; inside ``shard_map`` each shard updates its
local row, replicated fields land identically in every row and
shard-local ops counts differ per row, which is exactly what ``read``
assumes.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["SCALE", "DeviceMetricsSpec", "QUALITY_FIELDS"]

# fixed-point scale for fraction lanes; 4096 keeps dispatch-count *
# SCALE well inside int32 for any realistic run length
SCALE = 4096

HEADER_FIELDS = ("dispatches", "prefill_tokens", "decode_tokens",
                 "pages_touched", "tokens_drafted", "tokens_accepted",
                 "shadow_dispatches")
SHARD_LOCAL_FIELDS = ("kv_page_resets", "kv_page_copies",
                      "state_page_resets", "state_page_copies")
GROUP_FIELDS = ("tiles_total", "tiles_skipped", "live_q")
# (lane name, aux stats key, fixed-point?) — shadow-oracle quality
# lanes; the aux keys are the SHADOW_STAT_KEYS the shadow execution
# mode emits (core.executor)
QUALITY_FIELDS = (
    ("shadow_tiles", "shadow_tiles", False),
    ("false_skip", "shadow_false_skip", False),
    ("false_keep", "shadow_false_keep", False),
    ("truth_live", "shadow_truth_live", False),
    ("sign_agree_q", "shadow_sign_agree", True),
    ("err_q", "shadow_err", True),
)


class DeviceMetricsSpec:
    """Static layout of the packed metrics block.

    ``stat_shapes`` maps aux stat group name -> shape of that group's
    stacked ``frac_tiles_live`` leaf ((L,) or (L, E)), probed by the
    engine with ``jax.eval_shape`` so no compile happens up front.
    """

    def __init__(self, stat_shapes: Dict[str, Tuple[int, ...]]):
        self.stat_shapes: Dict[str, Tuple[int, ...]] = {
            k: tuple(int(d) for d in v)
            for k, v in sorted(stat_shapes.items())}
        self.offsets: Dict[str, Tuple[int, int]] = {}
        off = 0
        for name in HEADER_FIELDS + SHARD_LOCAL_FIELDS:
            self.offsets[name] = (off, 1)
            off += 1
        for g, shp in self.stat_shapes.items():
            n = int(np.prod(shp)) if shp else 1
            for f in GROUP_FIELDS + tuple(q[0] for q in QUALITY_FIELDS):
                self.offsets[f"{g}/{f}"] = (off, n)
                off += n
        self.size = off

    def init(self, n_rows: int = 1):
        import jax.numpy as jnp
        return jnp.zeros((n_rows, self.size), jnp.int32)

    # -- device side (runs under jit / shard_map) --------------------------
    def delta(self, scalars: Dict, aux: Dict):
        """Build the per-dispatch delta vector (size,) from traced
        values.  ``scalars`` maps header/shard-local field name ->
        int32 scalar (missing -> 0); ``aux`` maps stat group -> stats
        dict carrying ``n_tiles``/``tiles_skipped``/``frac_tiles_live``
        stacked leaves."""
        import jax.numpy as jnp
        segs = []
        for name in HEADER_FIELDS + SHARD_LOCAL_FIELDS:
            v = scalars.get(name, 0)
            segs.append(jnp.asarray(v, jnp.int32).reshape(1))
        n_lanes = len(GROUP_FIELDS) + len(QUALITY_FIELDS)
        for g, shp in self.stat_shapes.items():
            n = int(np.prod(shp)) if shp else 1
            stats = aux.get(g)
            if stats is None:
                segs.append(jnp.zeros(n_lanes * n, jnp.int32))
                continue

            # every lane is optional: primary dispatches carry the base
            # tile keys but no shadow_* keys, shadow dispatches are
            # filtered to ONLY shadow_* keys — missing lanes add zero
            def lane(key, fixed_point=False):
                v = stats.get(key)
                if v is None:
                    return jnp.zeros(n, jnp.int32)
                v = jnp.ravel(v)
                if fixed_point:
                    v = jnp.round(v * SCALE)
                return v.astype(jnp.int32)

            segs.append(jnp.concatenate(
                [lane("n_tiles"), lane("tiles_skipped"),
                 lane("frac_tiles_live", fixed_point=True)]
                + [lane(key, fp) for _, key, fp in QUALITY_FIELDS]))
        return jnp.concatenate(segs)

    def accumulate(self, block, scalars: Dict, aux: Dict):
        """block (n_rows, size) += delta, broadcast to every row.
        Single-device blocks have one row; under shard_map each shard
        holds its local row, so the broadcast is per-shard."""
        return block + self.delta(scalars, aux)[None, :]

    # -- host side ---------------------------------------------------------
    def read(self, block) -> Dict:
        """One host transfer; returns plain-python counters plus
        per-group per-layer arrays and derived fractions."""
        b = np.asarray(block)
        assert b.ndim == 2 and b.shape[1] == self.size, b.shape

        def seg(name):
            off, n = self.offsets[name]
            return b[:, off:off + n]

        out: Dict = {name: int(seg(name)[0, 0]) for name in HEADER_FIELDS}
        out.update({name: int(seg(name).sum())
                    for name in SHARD_LOCAL_FIELDS})
        disp = max(out["dispatches"], 1)
        sdisp = max(out["shadow_dispatches"], 1)
        groups: Dict = {}
        for g, shp in self.stat_shapes.items():
            total = seg(f"{g}/tiles_total")[0].reshape(shp)
            skipped = seg(f"{g}/tiles_skipped")[0].reshape(shp)
            live_q = seg(f"{g}/live_q")[0].reshape(shp)
            with np.errstate(divide="ignore", invalid="ignore"):
                skip_frac = np.where(total > 0, skipped / np.maximum(
                    total, 1), 0.0)
            # shadow-oracle quality lanes (zero when shadow scoring is
            # off or no dispatch was sampled yet)
            stiles = seg(f"{g}/shadow_tiles")[0].reshape(shp)
            fskip = seg(f"{g}/false_skip")[0].reshape(shp)
            fkeep = seg(f"{g}/false_keep")[0].reshape(shp)
            tlive = seg(f"{g}/truth_live")[0].reshape(shp)
            sa_q = seg(f"{g}/sign_agree_q")[0].reshape(shp)
            err_q = seg(f"{g}/err_q")[0].reshape(shp)
            groups[g] = {
                "tiles_total": total.astype(np.int64),
                "tiles_skipped": skipped.astype(np.int64),
                "skip_frac": skip_frac,
                "mean_frac_tiles_live": live_q / (SCALE * disp),
                "shadow_tiles": stiles.astype(np.int64),
                "false_skip": fskip.astype(np.int64),
                "false_keep": fkeep.astype(np.int64),
                "truth_live": tlive.astype(np.int64),
                # rate denominators: a false skip is scored against the
                # truly-live tiles, a false keep against the truly-dead
                "false_skip_rate": fskip / np.maximum(tlive, 1),
                "false_keep_rate": fkeep / np.maximum(stiles - tlive, 1),
                "mean_sign_agree": sa_q / (SCALE * sdisp),
                "mean_shadow_err": err_q / (SCALE * sdisp)}
        out["groups"] = groups
        return out

    def read_json(self, block) -> Dict:
        """``read`` with arrays converted to JSON-safe lists."""
        out = self.read(block)
        groups = {}
        for g, d in out["groups"].items():
            groups[g] = {
                "tiles_total": d["tiles_total"].tolist(),
                "tiles_skipped": d["tiles_skipped"].tolist(),
                "skip_frac": np.round(d["skip_frac"], 6).tolist(),
                "mean_frac_tiles_live": np.round(
                    d["mean_frac_tiles_live"], 6).tolist(),
                "shadow_tiles": d["shadow_tiles"].tolist(),
                "false_skip": d["false_skip"].tolist(),
                "false_keep": d["false_keep"].tolist(),
                "truth_live": d["truth_live"].tolist(),
                "false_skip_rate": np.round(
                    d["false_skip_rate"], 6).tolist(),
                "false_keep_rate": np.round(
                    d["false_keep_rate"], 6).tolist(),
                "mean_sign_agree": np.round(
                    d["mean_sign_agree"], 6).tolist(),
                "mean_shadow_err": np.round(
                    d["mean_shadow_err"], 6).tolist()}
        out["groups"] = groups
        return out

"""Checkpoint manager: atomic commit, keep-last-k, async background
writer, auto-resume, and elastic restore onto a different mesh.

Fault-tolerance contract (tested in tests/test_checkpoint.py):
  * a step directory becomes visible only after its COMMIT file exists
    (writer crash mid-save can never corrupt the restore point);
  * ``latest_step`` scans for the newest committed step, so a training
    job restarted after SIGKILL resumes from the last durable state;
  * restore takes a *template* pytree (from the live mesh's init shapes)
    and re-places leaves under the new mesh's sharding — the same
    checkpoint restores onto 512, 256 or 1 device(s).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax

from repro.checkpoint.serialization import load_pytree, save_pytree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------- write path ----------
    def save(self, step: int, state: Any, extra: Dict | None = None,
             block: bool = False) -> None:
        """Snapshot is taken synchronously (device_get) into host memory;
        the disk write happens on the background thread."""
        self.wait()                       # one in-flight save at a time
        host_state = jax.tree_util.tree_map(
            lambda x: jax.device_get(x), state)

        def _write():
            d = self._step_dir(step)
            tmp = d + ".writing"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            save_pytree(host_state, os.path.join(tmp, "state"),
                        {"step": step, **(extra or {})})
            open(os.path.join(tmp, "COMMIT"), "w").write(str(step))
            if os.path.exists(d):          # re-save of the same step
                shutil.rmtree(d)
            os.replace(tmp, d)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------- read path ----------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Load into ``template``'s structure; if ``shardings`` (a pytree
        of NamedSharding from the *current* mesh) is given, device_put
        each leaf accordingly — this is the elastic-rescale path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        state, extra = load_pytree(
            template, os.path.join(self._step_dir(step), "state"))
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, extra

    # ---------- internals ----------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.dir))
            if m)
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

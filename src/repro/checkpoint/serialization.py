"""Pytree <-> disk serialization: flat npz payload + JSON tree manifest.

Arrays are fetched shard-by-shard (``jax.device_get``) so saving a
fully-sharded 236B state never materialises more than one leaf on host.
Restore is mesh-agnostic: leaves are plain numpy and get re-placed with
whatever sharding the *new* mesh prescribes (elastic re-scale path).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = leaf
    jax.tree_util.tree_map_with_path(walk, tree)
    return flat


def save_pytree(tree, path: str, extra_meta: Dict | None = None) -> None:
    flat = _flatten_with_paths(tree)
    arrays = {}
    meta = {"keys": [], "extra": extra_meta or {}}
    for i, (k, v) in enumerate(sorted(flat.items())):
        arrays[f"a{i}"] = np.asarray(jax.device_get(v))
        meta["keys"].append(k)
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path + ".npz")
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def load_pytree(template, path: str) -> Tuple[Any, Dict]:
    """Restore into the structure of ``template`` (shapes must match)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    payload = np.load(path + ".npz")
    by_key = {k: payload[f"a{i}"] for i, k in enumerate(meta["keys"])}
    tmpl_flat = _flatten_with_paths(template)
    missing = set(tmpl_flat) - set(by_key)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")

    def walk(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = by_key[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt {arr.shape} != template {leaf.shape}")
        return arr.astype(leaf.dtype)

    restored = jax.tree_util.tree_map_with_path(walk, template)
    return restored, meta["extra"]

from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.serialization import save_pytree, load_pytree  # noqa: F401

from repro.distributed.sharding_rules import (  # noqa: F401
    ShardingRules, default_rules, param_sharding, activation_context,
    constrain, batch_sharding, mesh_axes,
)

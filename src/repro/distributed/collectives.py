"""Explicit-schedule collectives (shard_map): overlapped all-gather
matmul.

XLA's GSPMD inserts a *blocking* all-gather before an FSDP matmul.  The
classic fix (Wang et al., "Overlap communication with dependent
computation") is a bidirectional ring: at each of ceil(P/2) steps the
local shard pair is matmul'd while the next shards ppermute in both ring
directions — compute hides the collective.  ``ag_matmul_overlapped`` is
that schedule in ``shard_map`` form; the dry-run HLO shows
collective-permute ops interleaved with dots instead of one fused
all-gather, and the §Perf log measures the collective-term change.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _ring_ag_matmul(x, w, axis_name: str, axis_size: int):
    """Per-shard body: x is the *local* activation shard (M_local, K);
    w is the local K-shard of the weight (K, N) split along K across the
    axis: w_local (K/P, N).  Computes x @ w_full with the x K-dim gathered
    ring-wise and overlapped.

    Layout convention: x: (M, K/P) sharded on K; w: (K/P, N) sharded on K.
    Result: (M, N) partial-sum all-reduced over the axis.

    ``axis_size`` is threaded statically from the mesh (jax 0.4.x has no
    ``jax.lax.axis_size``; the ring schedule needs it at trace time to
    pick the step count anyway).
    """
    p = axis_size
    idx = jax.lax.axis_index(axis_name)
    kb = w.shape[0]

    def xslice(k):
        return jax.lax.dynamic_slice_in_dim(x, k * kb, kb, axis=1)

    # bidirectional ring: our shard pair circulates both ways; each step
    # matmuls the two resident shards while the next pair permutes in —
    # the collective hides behind the dependent compute.
    def step(carry, i):
        acc, fwd, bwd = carry
        k_fwd = (idx + i) % p
        k_bwd = (idx - i) % p
        acc = acc + xslice(k_fwd) @ fwd
        use_bwd = ((i > 0) & (k_bwd != k_fwd)).astype(acc.dtype)
        acc = acc + use_bwd * (xslice(k_bwd) @ bwd)
        fwd = jax.lax.ppermute(
            fwd, axis_name, [(j, (j - 1) % p) for j in range(p)])
        bwd = jax.lax.ppermute(
            bwd, axis_name, [(j, (j + 1) % p) for j in range(p)])
        return (acc, fwd, bwd), None

    acc0 = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    n_steps = (p + 1) // 2 + (0 if p % 2 else 1)
    (acc, _, _), _ = jax.lax.scan(step, (acc0, w, w),
                                  jnp.arange(max(n_steps, 1)))
    return acc.astype(x.dtype)


def ag_matmul_overlapped(x: jax.Array, w: jax.Array, mesh: Mesh,
                         axis: str = "model") -> jax.Array:
    """x: (M, K) activations (replicated over ``axis``); w: (K, N)
    K-sharded over ``axis`` (as FSDP leaves it).  Computes x @ w_full
    WITHOUT materialising the weight all-gather: the ring circulates the
    w shards while each is consumed against its matching x column block.
    Returns (M, N) replicated over ``axis``."""
    fn = shard_map(
        functools.partial(_ring_ag_matmul, axis_name=axis,
                          axis_size=mesh.shape[axis]),
        mesh=mesh,
        in_specs=(P(None, None), P(axis, None)),
        out_specs=P(None, None),
        check_rep=False,
    )
    return fn(x, w)


def flash_merge(m: jax.Array, l: jax.Array, acc: jax.Array,
                axis: str) -> jax.Array:
    """Exact softmax merge of per-shard flash-attention partials with
    ONE collective (the distributed flash-decode combine, ISSUE 5).

    Each shard holds partial statistics over its locally-resident KV
    pages: running max ``m`` (..., ), denominator ``l`` (..., ) and
    un-normalised accumulator ``acc`` (..., Dv).  The naive exact merge
    is a pmax (global max) followed by two psums (rescaled l and acc) —
    three collectives per attention layer (see ``_tp_flash_decode``'s
    sequence-sharded schedule).  Here the three tensors are packed into
    one (..., Dv + 2) buffer and ALL-GATHERED once over ``axis``; every
    shard then combines all P partials locally:

        m* = max_i m_i;  o = sum_i e^{m_i - m*} acc_i
                             / max(sum_i e^{m_i - m*} l_i, eps)

    The combine is O(P * Dv) local flops against one collective of the
    same bytes a psum pair would move — one collective per attention
    layer per dispatch, which is what the serve-sharded acceptance
    criterion counts.  Fully-masked shards (no resident in-window pages
    for a row: m_i at the mask floor) get weight ~0 from the max-shift,
    so empty shards never pollute the merge.  Must be called inside a
    ``shard_map`` region over ``axis``; returns the normalised output
    (..., Dv) in float32."""
    packed = jnp.concatenate(
        [m[..., None].astype(jnp.float32),
         l[..., None].astype(jnp.float32),
         acc.astype(jnp.float32)], axis=-1)
    allp = jax.lax.all_gather(packed, axis)          # (P, ..., Dv + 2)
    m_all, l_all, a_all = allp[..., 0], allp[..., 1], allp[..., 2:]
    m_glob = m_all.max(0)
    w = jnp.exp(m_all - m_glob[None])
    l_tot = (w * l_all).sum(0)
    acc_tot = (w[..., None] * a_all).sum(0)
    return acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]


def psum_scatter_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                        axis: str = "model") -> jax.Array:
    """TP down-projection: x (M, F/P) local, w (F/P, N) local ->
    reduce-scattered (M, N/P) result, letting the matmul and the
    reduce-scatter pipeline in one shard_map region."""
    def body(xl, wl):
        out = xl @ wl                    # (M, N) partial sum
        return jax.lax.psum_scatter(out, axis, scatter_dimension=1,
                                    tiled=True)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, axis), P(axis, None)),
                   out_specs=P(None, axis), check_rep=False)
    return fn(x, w)

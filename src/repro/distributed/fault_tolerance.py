"""Fault tolerance & straggler mitigation policy layer.

On a real multi-pod job these hooks sit in the launcher process:
  * ``StragglerMonitor`` ingests per-step wall times (one per host via the
    coordination service), keeps rolling quantiles, and recommends an
    action when p_max/p50 exceeds the threshold for `patience`
    consecutive steps — the two production actions being (a) shrink the
    offending host's microbatch share (rebalance) and (b) mark the host
    for eviction + elastic re-mesh at the next checkpoint boundary.
  * ``ElasticPlan`` computes the new mesh + per-arch batch split after a
    node-count change; restore goes through CheckpointManager.restore
    with the new mesh's shardings (mesh-agnostic npz payload).

The policy logic is deterministic and unit-tested with injected step-time
traces (no real failures needed); the elastic restore path is exercised
end-to-end in tests/test_checkpoint.py by re-meshing 8 -> 4 -> 8 host
devices.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple


@dataclass
class StragglerConfig:
    window: int = 20            # rolling window of step times
    ratio_threshold: float = 1.5  # pmax/p50 that flags a straggler
    patience: int = 5           # consecutive flagged steps before action
    rebalance_step: float = 0.25  # fraction of microbatch to shift away


@dataclass
class StragglerMonitor:
    n_hosts: int
    cfg: StragglerConfig = field(default_factory=StragglerConfig)
    _times: Dict[int, Deque[float]] = field(default_factory=dict)
    _flagged: Dict[int, int] = field(default_factory=dict)
    microbatch_share: Dict[int, float] = field(default_factory=dict)

    def __post_init__(self):
        for h in range(self.n_hosts):
            self._times[h] = collections.deque(maxlen=self.cfg.window)
            self._flagged[h] = 0
            self.microbatch_share[h] = 1.0 / self.n_hosts

    def record_step(self, step_times: Dict[int, float]) -> List[Tuple[str, int]]:
        """Feed one step's per-host times; returns recommended actions:
        [("rebalance", host)] or [("evict", host)]."""
        actions: List[Tuple[str, int]] = []
        for h, t in step_times.items():
            self._times[h].append(t)
        med = sorted(t[-1] for t in self._times.values() if t)[
            len(self._times) // 2]
        for h in range(self.n_hosts):
            if not self._times[h]:
                continue
            ratio = self._times[h][-1] / max(med, 1e-9)
            if ratio > self.cfg.ratio_threshold:
                self._flagged[h] += 1
            else:
                self._flagged[h] = 0
            if self._flagged[h] == self.cfg.patience:
                actions.append(("rebalance", h))
                self._shift_share(h)
            elif self._flagged[h] >= 2 * self.cfg.patience:
                actions.append(("evict", h))
        return actions

    def _shift_share(self, straggler: int) -> None:
        """Move a slice of the straggler's microbatch share to the others."""
        delta = self.microbatch_share[straggler] * self.cfg.rebalance_step
        self.microbatch_share[straggler] -= delta
        others = [h for h in range(self.n_hosts) if h != straggler]
        for h in others:
            self.microbatch_share[h] += delta / len(others)


@dataclass(frozen=True)
class ElasticPlan:
    """Mesh + batch plan after an elastic resize."""
    n_devices: int
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    global_batch: int

    @staticmethod
    def plan(n_devices: int, model_parallel: int, global_batch: int,
             multi_pod_size: int = 0) -> "ElasticPlan":
        """Keep TP fixed (model weights' shard layout is the expensive
        thing to reshuffle); absorb node loss in the data axis.  Batch is
        kept divisible by the new dp size by rounding down."""
        if n_devices % model_parallel != 0:
            raise ValueError(
                f"{n_devices} devices not divisible by TP={model_parallel}")
        dp = n_devices // model_parallel
        if multi_pod_size and dp % multi_pod_size == 0:
            shape = (multi_pod_size, dp // multi_pod_size, model_parallel)
            names = ("pod", "data", "model")
        else:
            shape = (dp, model_parallel)
            names = ("data", "model")
        gb = (global_batch // dp) * dp
        return ElasticPlan(n_devices, shape, names, max(gb, dp))

"""Distributed flash decode over the mesh-sharded paged KV pool.

The paged serving cache (``serving.kv_pool.PagedPool``) expresses every
cache access as a (block table, physical page) indirection; sharding the
page pool over a mesh axis (``sharding_rules.PAGE_AXIS``) turns serving
attention into a DISTRIBUTED flash decode:

  * block tables stay replicated and hold GLOBAL page ids; each shard
    physically holds the contiguous id range
    ``[idx * n_local, (idx + 1) * n_local)`` of every pool leaf
    (``n_local`` = the leaf's local page count under ``shard_map``);
  * writes map global -> local ids and DROP pages another shard owns
    (``pool_set``); reads gather only locally-resident pages, filling
    foreign pages with the mask value (``pool_view``) — a -1 position
    tag, so they contribute nothing to the local softmax;
  * each shard computes partial flash statistics (m, l, acc) over its
    local ring view and the shards combine with ONE collective per
    attention layer (``collectives.flash_merge``);
  * recurrent state pools shard the same way with a SINGLE-OWNER
    gather: exactly one shard holds each slot's state row, contributes
    it, and a psum (zeros elsewhere) replicates it (``state_take`` /
    ``state_put``).

Every helper degrades to the single-device paged behaviour when no
page-shard context is active, so the model code has exactly one paged
branch.  The context is trace-time state (the engine's sharded step
enters it around the shard_map body), mirroring
``sharding_rules.activation_context``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.collectives import flash_merge

_TLS = threading.local()

NEG_INF = -1e30


@contextlib.contextmanager
def page_shard_context(axis: str, n_shards: int):
    """Activate the page-shard context for a shard_map body trace."""
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (axis, n_shards)
    try:
        yield
    finally:
        _TLS.ctx = prev


def shard_info() -> Optional[Tuple[str, int]]:
    """-> (mesh axis name, n_shards), or None outside a sharded trace."""
    return getattr(_TLS, "ctx", None)


def _local_base(n_local: int, axis: str):
    """First global page id resident on this shard."""
    return jax.lax.axis_index(axis) * n_local


# ==========================================================================
# pool access through the (replicated) block/state tables
# ==========================================================================

def pool_set(pool, pidx, off, val, valid):
    """Scatter ``val`` into a page pool at (page ``pidx``, row ``off``)
    — ``pool``: (n_pages[, ...]) with the page dim leading, ``pidx`` /
    ``off`` / ``valid``: (B, C) global page ids, in-page offsets and
    validity.  Invalid tokens are dropped (OOB scatter index); under a
    page-shard context, pages resident on OTHER shards are dropped too
    (their owner performs the same scatter with the roles reversed)."""
    n_local = pool.shape[0]
    info = shard_info()
    if info is None:
        tgt = jnp.where(valid, pidx, n_local)
        return pool.at[tgt, off].set(val, mode="drop")
    lo = _local_base(n_local, info[0])
    loc = pidx - lo
    ok = valid & (loc >= 0) & (loc < n_local)
    tgt = jnp.where(ok, loc, n_local)                # OOB -> dropped
    return pool.at[tgt, off].set(val, mode="drop")


def pool_view(pool, block_table, fill):
    """Gather a slot-major view of the pool through the block table —
    (B, n_blocks) global ids -> (B, n_blocks, page, ...).  Under a
    page-shard context only locally-resident pages are read; foreign
    pages return ``fill`` (use -1 for position-tag pools so the masked
    rows drop out of the local softmax, 0 for k/v payloads).

    The gather runs as a flat row-take over (n_pages, page*feat) — one
    contiguous row copy per page, which XLA:CPU lowers markedly faster
    than the equivalent n-d gather (the jnp fallback's per-step cost is
    dominated by exactly this materialisation)."""
    def take_rows(idx):
        flat = pool.reshape((pool.shape[0], -1))
        out = jnp.take(flat, idx.reshape(-1), axis=0)
        return out.reshape(idx.shape + pool.shape[1:])

    info = shard_info()
    if info is None:
        return take_rows(block_table)
    n_local = pool.shape[0]
    lo = _local_base(n_local, info[0])
    loc = block_table - lo
    ok = (loc >= 0) & (loc < n_local)
    out = take_rows(jnp.where(ok, loc, 0))
    mask = ok.reshape(ok.shape + (1,) * (out.ndim - ok.ndim))
    return jnp.where(mask, out, jnp.asarray(fill, out.dtype))


# ==========================================================================
# distributed flash decode: partial (m, l, acc) + one-collective merge
# ==========================================================================

def position_ok(q_pos, kv_pos, causal: bool, window: int):
    """THE slot-pool mask predicate: a kv row is visible iff its tag is
    a real position (>= 0), not in the causal future, and inside the
    sliding window.  ``q_pos`` / ``kv_pos`` are any broadcast-compatible
    int arrays — every mask in the system (``attention._mask_bias``,
    ``batched_bias`` below, the paged-kernel oracles, the MLA dense
    path) evaluates exactly this predicate, so the layouts can never
    drift apart."""
    rel = q_pos - kv_pos
    ok = kv_pos >= 0
    if causal:
        ok &= rel >= 0
    if window > 0:
        ok &= rel < window
    return ok


def batched_bias(q_pos, kv_pos, causal: bool, window: int):
    """(B, Sq, Skv) additive causal/window bias with PER-BATCH-ROW
    positions; kv entries tagged -1 mask out.
    ``attention.attend_batched`` (single-device paged/slotted) and the
    sharded partial-flash attends below all build their scores mask
    here."""
    ok = position_ok(q_pos[:, :, None], kv_pos[:, None, :], causal, window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def pool_positions(ppool, block_table):
    """Per-slot OWNERSHIP-masked position rows over the flat pool —
    (n_pages, page) tag pool + (B, n_blocks) table -> (B, n_pages*page)
    int32 where entries of pages NOT in slot b's table are -1.

    This is the pool-direct dual of the materialised ring view: instead
    of gathering each slot's pages out of the pool (the jnp fallback's
    dominant cost — a (B, ring, ...) copy per pool leaf per layer),
    attention runs against the pool IN PLACE and visibility is carried
    entirely by these rows.  Building them costs one tiny tag gather
    plus a (B, n_blocks, page) scatter — no k/v bytes move.  Null pages
    (id 0) scatter -1; under a page-shard context foreign pages are
    dropped from the scatter, so they stay -1 and the local softmax
    skips them (exactly the kernel's grid-level skip, made dense)."""
    n_pages, page = ppool.shape
    B = block_table.shape[0]
    tags = pool_view(ppool, block_table, -1)         # (B, nb, page)
    tags = jnp.where(block_table[..., None] > 0, tags, -1)
    info = shard_info()
    if info is None:
        tgt = jnp.where(block_table > 0, block_table, n_pages)
    else:
        lo = _local_base(n_pages, info[0])
        loc = block_table - lo
        ok = (block_table > 0) & (loc >= 0) & (loc < n_pages)
        tgt = jnp.where(ok, loc, n_pages)            # OOB -> dropped
    rows = jnp.full((B, n_pages, page), -1, jnp.int32)
    rows = rows.at[jnp.arange(B)[:, None], tgt].set(tags, mode="drop")
    return rows.reshape(B, n_pages * page)


def gqa_pool_flash(q, kpool, vpool, kv_pos, qpos, *, window: int = 0,
                   partial: bool = False):
    """GQA attention DIRECTLY against the page pool (no ring view): q
    (B, C, H, D) vs the whole flattened pool (n_pages*page, hkv, ·),
    with per-slot visibility from ``kv_pos`` (``pool_positions`` rows).
    The kv-head loop runs as plain (B*C*G, D) x (D, N) GEMMs — on CPU
    these hit BLAS and beat the gather-then-attend fallback ~2x while
    reading each pool byte exactly once.  ``partial`` returns flash
    (m, l, acc) shaped for ``collectives.flash_merge`` ((B,hkv,G,C) m/l,
    (B,hkv,G,C,Dv) acc); otherwise the full softmax (B, C, H, Dv)."""
    B, C, H, D = q.shape
    hkv, Dv = vpool.shape[-2], vpool.shape[-1]
    G = H // hkv
    N = kpool.shape[0] * kpool.shape[1]
    kf = kpool.reshape(N, hkv, D)
    vf = vpool.reshape(N, hkv, Dv)
    bias = jnp.where(position_ok(qpos[:, :, None], kv_pos[:, None, :],
                                 True, window),
                     0.0, NEG_INF).astype(jnp.float32)   # (B, C, N)
    ms, ls, accs, outs = [], [], [], []
    for kh in range(hkv):
        qk = q.reshape(B, C, hkv, G, D)[:, :, kh].astype(jnp.float32)
        s = (qk.reshape(B * C * G, D) @ kf[:, kh].T.astype(jnp.float32))
        s = s.reshape(B, C, G, N) * (D ** -0.5) + bias[:, :, None]
        m = s.max(-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(-1)
        acc = (p.reshape(B * C * G, N).astype(vf.dtype) @ vf[:, kh])
        acc = acc.reshape(B, C, G, Dv).astype(jnp.float32)
        if partial:
            # (B, C, G, ·) -> (B, G, C, ·); heads stack to (B, hkv, ...)
            ms.append(m.transpose(0, 2, 1))
            ls.append(l.transpose(0, 2, 1))
            accs.append(acc.transpose(0, 2, 1, 3))
        else:
            outs.append(acc / l[..., None])
    if partial:
        return (jnp.stack(ms, 1), jnp.stack(ls, 1), jnp.stack(accs, 1))
    o = jnp.stack(outs, 2)                           # (B, C, hkv, G, Dv)
    return o.reshape(B, C, H, Dv).astype(q.dtype)


def mla_pool_flash(q_lat, q_pe, ck_pool, cpe_pool, kv_pos, qpos, *,
                   scale: float, partial: bool = False):
    """Absorbed-MLA attention directly against the latent page pools:
    q_lat (B, C, h, kr) + q_pe (B, C, h, rd) vs the flat pools
    (n_pages*page, kr / rd), visibility from ``pool_positions`` rows.
    One GEMM per projection — no ring view.  ``partial`` returns
    ((B, h, C) m/l, (B, h, C, kr) acc) for ``flash_merge``; otherwise
    o_lat (B, C, h, kr) (caller absorbs W_uv)."""
    B, C, h, kr = q_lat.shape
    rd = q_pe.shape[-1]
    N = ck_pool.shape[0] * ck_pool.shape[1]
    ckf = ck_pool.reshape(N, kr)
    cpef = cpe_pool.reshape(N, rd)
    s = (q_lat.reshape(B * C * h, kr).astype(jnp.float32)
         @ ckf.T.astype(jnp.float32)
         + q_pe.reshape(B * C * h, rd).astype(jnp.float32)
         @ cpef.T.astype(jnp.float32)).reshape(B, C, h, N) * scale
    ok = position_ok(qpos[:, :, None], kv_pos[:, None, :], True, 0)
    s = jnp.where(ok[:, :, None], s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = (p.reshape(B * C * h, N).astype(ckf.dtype) @ ckf)
    acc = acc.reshape(B, C, h, kr).astype(jnp.float32)
    if partial:
        return (m.transpose(0, 2, 1), l.transpose(0, 2, 1),
                acc.transpose(0, 2, 1, 3))
    return (acc / l[..., None]).astype(q_lat.dtype)


def gqa_paged_attend(q, kpool, vpool, ppool, block_table, qpos, *,
                     window: int = 0):
    """Sharded GQA paged attention: partial flash statistics over the
    locally-resident pages of each slot's ring view, merged across
    shards with ONE collective (``flash_merge``).  q: (B, C, H, D);
    pools: (n_local, page, hkv, ·); block_table: (B, n_blocks) global
    ids; qpos: (B, C).  Returns (B, C, H, Dv) in q's dtype, numerically
    the exact softmax over all resident pages."""
    info = shard_info()
    assert info is not None, "gqa_paged_attend needs a page-shard context"
    B, C, H, D = q.shape
    Dv = vpool.shape[-1]
    from repro.kernels import paged_attention as pk
    if pk.enabled():
        # fused kernel variant: partial (m, l, acc) straight off the
        # block table — null/foreign pages are grid-level skips, the
        # ring view is never materialised
        n_local = kpool.shape[0]
        m, l, acc = pk.gqa_paged_flash(
            q, kpool, vpool, ppool, block_table, qpos,
            window=window, lo=_local_base(n_local, info[0]),
            n_local=n_local, partial=True)
        o = flash_merge(m, l, acc, info[0])
        return o.transpose(0, 3, 1, 2, 4).reshape(B, C, H, Dv).astype(
            q.dtype)
    # jnp fallback: gather the LOCAL ring view and take dense partial
    # stats — cost scales with the ring (n_blocks*page per slot), not
    # with the pool (which spare pages double); the pool-direct
    # ``gqa_pool_flash`` only wins when the pool is table-sized (see
    # --scenario paged-kernel)
    page = kpool.shape[1]
    ring = block_table.shape[1] * page
    hkv = kpool.shape[-2]
    gk = pool_view(kpool, block_table, 0).reshape(B, ring, hkv, D)
    gv = pool_view(vpool, block_table, 0).reshape(B, ring, hkv, Dv)
    gp = pool_view(ppool, block_table, -1).reshape(B, ring)
    G = H // hkv
    qf = q.reshape(B, C, hkv, G, D)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf, gk,
                   preferred_element_type=jnp.float32)
    s = s * (D ** -0.5) + batched_bias(qpos, gp, True, window)[:, None, None]
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(gv.dtype),
                     gv).astype(jnp.float32)
    o = flash_merge(m, l, acc, info[0])              # (B,hkv,G,C,Dv)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, C, H, Dv).astype(q.dtype)


def mla_paged_attend(q_lat, q_pe, ck_pool, cpe_pool, cp_pool, block_table,
                     qpos, *, scale: float):
    """Sharded MLA paged attention (absorbed latent space): partial
    flash statistics against the locally-resident latent pages, merged
    with ONE collective.  q_lat: (B, C, h, kr) (W_uk absorbed), q_pe:
    (B, C, h, rd); pools: (n_local, page, ·); returns the merged latent
    output o_lat (B, C, h, kr) — the caller absorbs W_uv."""
    info = shard_info()
    assert info is not None, "mla_paged_attend needs a page-shard context"
    from repro.kernels import paged_attention as pk
    if pk.enabled():
        n_local = ck_pool.shape[0]
        m, l, acc = pk.mla_paged_flash(
            q_lat, q_pe, ck_pool, cpe_pool, cp_pool, block_table, qpos,
            scale=scale, lo=_local_base(n_local, info[0]),
            n_local=n_local, partial=True)
        o = flash_merge(m, l, acc, info[0])
        return o.transpose(0, 2, 1, 3).astype(q_lat.dtype)
    # jnp fallback: local ring gather + dense partial stats (see the
    # gqa fallback note — ring-proportional, pool-size-independent)
    B, C = qpos.shape
    page = ck_pool.shape[1]
    ring = block_table.shape[1] * page
    kr = ck_pool.shape[-1]
    rd = cpe_pool.shape[-1]
    ck = pool_view(ck_pool, block_table, 0).reshape(B, ring, kr)
    cpe = pool_view(cpe_pool, block_table, 0).reshape(B, ring, rd)
    cp = pool_view(cp_pool, block_table, -1).reshape(B, ring)
    s = (jnp.einsum("bchk,btk->bhct", q_lat, ck,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bchr,btr->bhct", q_pe, cpe,
                      preferred_element_type=jnp.float32))
    s = s * scale + batched_bias(qpos, cp, True, 0)[:, None]
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bhct,btk->bhck", p.astype(ck.dtype),
                     ck).astype(jnp.float32)
    o = flash_merge(m, l, acc, info[0])              # (B, h, C, kr)
    return o.transpose(0, 2, 1, 3).astype(q_lat.dtype)


# ==========================================================================
# recurrent-state pools: single-owner gather / owner-local scatter
# ==========================================================================

def state_take(pool, table):
    """Gather each slot's state row through the (B,) state table —
    pool: (L, n_spages, ...) -> (L, B, ...).  Sharded: exactly one
    shard holds each row (single owner); it contributes the value,
    everyone else zeros, and a psum replicates the result.  This is the
    one place state sharding pays a collective — once per dispatch per
    leaf, at the top of the chunk step, NOT per layer (the (L, ...)
    stack gathers in one shot)."""
    info = shard_info()
    if info is None:
        return pool[:, table]
    n_local = pool.shape[1]
    lo = _local_base(n_local, info[0])
    loc = table - lo
    ok = (loc >= 0) & (loc < n_local)
    g = pool[:, jnp.where(ok, loc, 0)]
    mask = ok.reshape((1,) + ok.shape + (1,) * (g.ndim - ok.ndim - 1))
    g = jnp.where(mask, g, jnp.zeros((), g.dtype))
    return jax.lax.psum(g, info[0])


def state_put(pool, table, val):
    """Scatter updated state rows back through the (B,) state table;
    sharded: only the owning shard writes, everyone else drops."""
    info = shard_info()
    if info is None:
        return pool.at[:, table].set(val)
    n_local = pool.shape[1]
    lo = _local_base(n_local, info[0])
    loc = table - lo
    tgt = jnp.where((loc >= 0) & (loc < n_local), loc, n_local)
    return pool.at[:, tgt].set(val, mode="drop")

"""Distributed flash decode over the mesh-sharded paged KV pool.

The paged serving cache (``serving.kv_pool.PagedPool``) expresses every
cache access as a (block table, physical page) indirection; sharding the
page pool over a mesh axis (``sharding_rules.PAGE_AXIS``) turns serving
attention into a DISTRIBUTED flash decode:

  * block tables stay replicated and hold GLOBAL page ids; each shard
    physically holds the contiguous id range
    ``[idx * n_local, (idx + 1) * n_local)`` of every pool leaf
    (``n_local`` = the leaf's local page count under ``shard_map``);
  * writes map global -> local ids and DROP pages another shard owns
    (``pool_set``); reads gather only locally-resident pages, filling
    foreign pages with the mask value (``pool_view``) — a -1 position
    tag, so they contribute nothing to the local softmax;
  * each shard computes partial flash statistics (m, l, acc) over its
    local ring view and the shards combine with ONE collective per
    attention layer (``collectives.flash_merge``);
  * recurrent state pools shard the same way with a SINGLE-OWNER
    gather: exactly one shard holds each slot's state row, contributes
    it, and a psum (zeros elsewhere) replicates it (``state_take`` /
    ``state_put``).

Every helper degrades to the single-device paged behaviour when no
page-shard context is active, so the model code has exactly one paged
branch.  The context is trace-time state (the engine's sharded step
enters it around the shard_map body), mirroring
``sharding_rules.activation_context``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.collectives import flash_merge

_TLS = threading.local()

NEG_INF = -1e30


@contextlib.contextmanager
def page_shard_context(axis: str, n_shards: int):
    """Activate the page-shard context for a shard_map body trace."""
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (axis, n_shards)
    try:
        yield
    finally:
        _TLS.ctx = prev


def shard_info() -> Optional[Tuple[str, int]]:
    """-> (mesh axis name, n_shards), or None outside a sharded trace."""
    return getattr(_TLS, "ctx", None)


def _local_base(n_local: int, axis: str):
    """First global page id resident on this shard."""
    return jax.lax.axis_index(axis) * n_local


# ==========================================================================
# pool access through the (replicated) block/state tables
# ==========================================================================

def pool_set(pool, pidx, off, val, valid):
    """Scatter ``val`` into a page pool at (page ``pidx``, row ``off``)
    — ``pool``: (n_pages[, ...]) with the page dim leading, ``pidx`` /
    ``off`` / ``valid``: (B, C) global page ids, in-page offsets and
    validity.  Invalid tokens are dropped (OOB scatter index); under a
    page-shard context, pages resident on OTHER shards are dropped too
    (their owner performs the same scatter with the roles reversed)."""
    n_local = pool.shape[0]
    info = shard_info()
    if info is None:
        tgt = jnp.where(valid, pidx, n_local)
        return pool.at[tgt, off].set(val, mode="drop")
    lo = _local_base(n_local, info[0])
    loc = pidx - lo
    ok = valid & (loc >= 0) & (loc < n_local)
    tgt = jnp.where(ok, loc, n_local)                # OOB -> dropped
    return pool.at[tgt, off].set(val, mode="drop")


def pool_view(pool, block_table, fill):
    """Gather a slot-major view of the pool through the block table —
    (B, n_blocks) global ids -> (B, n_blocks, page, ...).  Under a
    page-shard context only locally-resident pages are read; foreign
    pages return ``fill`` (use -1 for position-tag pools so the masked
    rows drop out of the local softmax, 0 for k/v payloads)."""
    info = shard_info()
    if info is None:
        return pool[block_table]
    n_local = pool.shape[0]
    lo = _local_base(n_local, info[0])
    loc = block_table - lo
    ok = (loc >= 0) & (loc < n_local)
    out = pool[jnp.where(ok, loc, 0)]
    mask = ok.reshape(ok.shape + (1,) * (out.ndim - ok.ndim))
    return jnp.where(mask, out, jnp.asarray(fill, out.dtype))


# ==========================================================================
# distributed flash decode: partial (m, l, acc) + one-collective merge
# ==========================================================================

def batched_bias(q_pos, kv_pos, causal: bool, window: int):
    """(B, Sq, Skv) additive causal/window bias with PER-BATCH-ROW
    positions; kv entries tagged -1 mask out.  The single source of the
    slot-pool mask semantics: ``attention.attend_batched`` (single-
    device paged/slotted) and the sharded partial-flash attends below
    all build their scores mask here, so the two layouts can never
    drift apart."""
    rel = q_pos[:, :, None] - kv_pos[:, None, :]
    ok = jnp.ones(rel.shape, bool)
    if causal:
        ok &= rel >= 0
    if window > 0:
        ok &= rel < window
    ok &= kv_pos[:, None, :] >= 0
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def gqa_paged_attend(q, kpool, vpool, ppool, block_table, qpos, *,
                     window: int = 0):
    """Sharded GQA paged attention: partial flash statistics over the
    locally-resident pages of each slot's ring view, merged across
    shards with ONE collective (``flash_merge``).  q: (B, C, H, D);
    pools: (n_local, page, hkv, ·); block_table: (B, n_blocks) global
    ids; qpos: (B, C).  Returns (B, C, H, Dv) in q's dtype, numerically
    the exact softmax over all resident pages."""
    info = shard_info()
    assert info is not None, "gqa_paged_attend needs a page-shard context"
    B, C, H, D = q.shape
    page = kpool.shape[1]
    ring = block_table.shape[1] * page
    hkv = kpool.shape[-2]
    Dv = vpool.shape[-1]
    gk = pool_view(kpool, block_table, 0).reshape(B, ring, hkv, D)
    gv = pool_view(vpool, block_table, 0).reshape(B, ring, hkv, Dv)
    gp = pool_view(ppool, block_table, -1).reshape(B, ring)
    G = H // hkv
    qf = q.reshape(B, C, hkv, G, D)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf, gk,
                   preferred_element_type=jnp.float32)
    s = s * (D ** -0.5) + batched_bias(qpos, gp, True, window)[:, None, None]
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(gv.dtype),
                     gv).astype(jnp.float32)
    o = flash_merge(m, l, acc, info[0])              # (B,hkv,G,C,Dv)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, C, H, Dv).astype(q.dtype)


def mla_paged_attend(q_lat, q_pe, ck_pool, cpe_pool, cp_pool, block_table,
                     qpos, *, scale: float):
    """Sharded MLA paged attention (absorbed latent space): partial
    flash statistics against the locally-resident latent pages, merged
    with ONE collective.  q_lat: (B, C, h, kr) (W_uk absorbed), q_pe:
    (B, C, h, rd); pools: (n_local, page, ·); returns the merged latent
    output o_lat (B, C, h, kr) — the caller absorbs W_uv."""
    info = shard_info()
    assert info is not None, "mla_paged_attend needs a page-shard context"
    B, C = qpos.shape
    page = ck_pool.shape[1]
    ring = block_table.shape[1] * page
    kr = ck_pool.shape[-1]
    rd = cpe_pool.shape[-1]
    ck = pool_view(ck_pool, block_table, 0).reshape(B, ring, kr)
    cpe = pool_view(cpe_pool, block_table, 0).reshape(B, ring, rd)
    cp = pool_view(cp_pool, block_table, -1).reshape(B, ring)
    s = (jnp.einsum("bchk,btk->bhct", q_lat, ck,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bchr,btr->bhct", q_pe, cpe,
                      preferred_element_type=jnp.float32))
    s = s * scale
    ok = (cp[:, None, None, :] <= qpos[:, None, :, None]) & \
        (cp[:, None, None, :] >= 0)
    s = jnp.where(ok, s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bhct,btk->bhck", p.astype(ck.dtype),
                     ck).astype(jnp.float32)
    o = flash_merge(m, l, acc, info[0])              # (B, h, C, kr)
    return o.transpose(0, 2, 1, 3).astype(q_lat.dtype)


# ==========================================================================
# recurrent-state pools: single-owner gather / owner-local scatter
# ==========================================================================

def state_take(pool, table):
    """Gather each slot's state row through the (B,) state table —
    pool: (L, n_spages, ...) -> (L, B, ...).  Sharded: exactly one
    shard holds each row (single owner); it contributes the value,
    everyone else zeros, and a psum replicates the result.  This is the
    one place state sharding pays a collective — once per dispatch per
    leaf, at the top of the chunk step, NOT per layer (the (L, ...)
    stack gathers in one shot)."""
    info = shard_info()
    if info is None:
        return pool[:, table]
    n_local = pool.shape[1]
    lo = _local_base(n_local, info[0])
    loc = table - lo
    ok = (loc >= 0) & (loc < n_local)
    g = pool[:, jnp.where(ok, loc, 0)]
    mask = ok.reshape((1,) + ok.shape + (1,) * (g.ndim - ok.ndim - 1))
    g = jnp.where(mask, g, jnp.zeros((), g.dtype))
    return jax.lax.psum(g, info[0])


def state_put(pool, table, val):
    """Scatter updated state rows back through the (B,) state table;
    sharded: only the owning shard writes, everyone else drops."""
    info = shard_info()
    if info is None:
        return pool.at[:, table].set(val)
    n_local = pool.shape[1]
    lo = _local_base(n_local, info[0])
    loc = table - lo
    tgt = jnp.where((loc >= 0) & (loc < n_local), loc, n_local)
    return pool.at[:, tgt].set(val, mode="drop")

"""Sharding rules: logical-axis -> mesh-axis mapping for params and
activations (DP + FSDP + TP + SP + EP, pod axis = extra DP dim).

Params are named by pytree path; ``param_sharding`` pattern-matches path
suffixes to PartitionSpecs.  Activations are constrained inside model code
through ``constrain(x, kind)`` which is a no-op outside an
``activation_context`` — so the same model code runs un-sharded on CPU
smoke tests and fully sharded in the dry-run/launcher.
"""
from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()

# Mesh axis the serving page pools shard over (ISSUE 5): physical KV /
# state pages partitioned, block tables + params + activations
# replicated.  Deliberately distinct from the train-time axes ('pod',
# 'data', 'model') so _dp_axes / 'tp' resolution never capture it and
# the same model code runs un-sharded, TP-sharded, or page-sharded.
PAGE_AXIS = "pages"


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _dp_axes(mesh: Mesh):
    """Data-parallel axes: ('pod','data') when a pod axis exists."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


@dataclass(frozen=True)
class ShardingRules:
    """Pattern (regex on '/'-joined param path) -> PartitionSpec factory.

    Specs may reference the logical axes 'dp' (data+pod), 'tp' ('model');
    they are resolved against the active mesh."""
    rules: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...]
    sequence_parallel: bool = False

    def resolve(self, spec: Tuple[Optional[str], ...], mesh: Mesh) -> P:
        out = []
        for ax in spec:
            if ax is None:
                out.append(None)
            elif ax == "dp":
                dp = _dp_axes(mesh)
                out.append(dp if len(dp) > 1 else (dp[0] if dp else None))
            elif ax == "tp":
                out.append("model" if "model" in mesh.axis_names else None)
            else:
                out.append(ax if ax in mesh.axis_names else None)
        return P(*out)


# Parameter rules: matched against the '/'-joined path, first match wins.
# Layout: TP on the 'model' axis over heads/d_ff/experts/vocab, FSDP over
# 'data' on the other major dim (ZeRO-3; XLA inserts the all-gathers).
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / unembedding
    (r"embed$", ("tp", "dp")),
    (r"lm_head$", ("dp", "tp")),
    # attention (GQA + MLA)
    (r"(wq|wk|wv)$", ("dp", "tp")),
    (r"wo$", ("tp", "dp")),
    (r"(bq|bk|bv)$", ("tp",)),
    (r"wq_a$", ("dp", "tp")),
    (r"wq_b$", ("dp", "tp")),
    (r"wkv_a$", ("dp", "tp")),
    (r"(wk_b|wv_b)$", ("dp", "tp")),
    # dense FFN
    (r"(w_gate|w_up)$", ("dp", "tp")),
    (r"w_down$", ("tp", "dp")),
    # MoE experts: EP handled by moe-specific rule injected per-config
    (r"router$", ("dp", "tp")),
    (r"moe_ep/(w_gate|w_up)$", ("tp", "dp", None)),
    (r"moe_ep/w_down$", ("tp", "dp", None)),
    (r"moe_tp/(w_gate|w_up)$", (None, "dp", "tp")),
    (r"moe_tp/w_down$", (None, "tp", "dp")),
    # mamba2 / rwkv
    (r"in_proj$", ("dp", "tp")),
    (r"out_proj$", ("tp", "dp")),
    (r"(Wr|Wk|Wv|Wg|Wo|wA|wB)$", ("dp", "tp")),
    (r"conv_w$", (None, "tp")),
    (r"conv_b$", ("tp",)),
    (r"norm_scale$", ("tp",)),
    # MoR predictor tables: per-output-neuron vectors follow d_ff (tp)
    (r"mor/.*(m|b|enable|proxy_slot|is_proxy|perm|inv_perm|bn_scale|bn_bias)$",
     ("tp",)),
    # everything else (norms, scalars, small tables): replicated
    (r".*", ()),
)


# Alternative layout (measured better for mid-size dense models on the
# 16x16 mesh): weights sharded on the CONTRACTION dim over 'model'
# (Megatron column-parallel in, row-parallel out), FSDP over 'data' on
# the other dim.  A/B-able via param_sharding(layout=...).
_PARAM_RULES_CONTRACT: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed$", ("tp", "dp")),
    (r"lm_head$", ("tp", "dp")),
    (r"(wq|wk|wv)$", ("tp", "dp")),
    (r"wo$", ("dp", "tp")),
    (r"(bq|bk|bv)$", ()),
    (r"wq_a$", ("tp", "dp")),
    (r"wq_b$", ("tp", "dp")),
    (r"wkv_a$", ("tp", "dp")),
    (r"(wk_b|wv_b)$", ("tp", "dp")),
    (r"(w_gate|w_up)$", ("tp", "dp")),
    (r"w_down$", ("dp", "tp")),
    (r"router$", ("tp", None)),
    (r"moe_ep/(w_gate|w_up)$", ("tp", "dp", None)),
    (r"moe_ep/w_down$", ("tp", None, "dp")),
    (r"moe_tp/(w_gate|w_up)$", (None, "tp", "dp")),
    (r"moe_tp/w_down$", (None, "dp", "tp")),
    (r"in_proj$", ("tp", "dp")),
    (r"out_proj$", ("dp", "tp")),
    (r"(Wr|Wk|Wv|Wg|Wo|wA|wB)$", ("tp", "dp")),
    (r"conv_w$", (None, "tp")),
    (r"conv_b$", ("tp",)),
    (r"norm_scale$", ("tp",)),
    (r"mor/.*", ("tp",)),
    (r".*", ()),
)


def default_rules(sequence_parallel: bool = False,
                  layout: str = "fsdp_tp") -> ShardingRules:
    rules = (_PARAM_RULES_CONTRACT if layout == "contract_tp"
             else _PARAM_RULES)
    return ShardingRules(rules=rules, sequence_parallel=sequence_parallel)


def param_sharding(params, mesh: Mesh, rules: Optional[ShardingRules] = None,
                   moe_mode: str = "tp", layout: str = "fsdp_tp"):
    """Build a NamedSharding pytree matching ``params``."""
    rules = rules or default_rules(layout=layout)

    def spec_for(path_str: str, leaf) -> P:
        p = path_str
        # tag expert tensors so EP/TP rules can disambiguate
        if re.search(r"moe/(w_gate|w_up|w_down)$", p):
            mode = moe_mode
            if moe_mode == "ep_shmap":
                # expert dim is leaf dim -3 for (L, E, d, f) stacks
                e_dim = leaf.shape[-3]
                mp = mesh.shape.get("model", 1)
                mode = "ep" if e_dim % mp == 0 else "tp"
            p = p.replace("moe/", f"moe_{mode}/")
        for pat, spec in rules.rules:
            if re.search(pat, p):
                resolved = rules.resolve(spec, mesh)
                specs = list(resolved)
                # rules describe the LOGICAL per-layer shape; scan-stacked
                # params carry a leading L dim (and only that) extra —
                # right-align the spec so L stays unsharded (the scan
                # streams one layer per trip; sharding L would turn every
                # layer slice into a cross-device gather)
                if leaf.ndim > len(specs):
                    specs = [None] * (leaf.ndim - len(specs)) + specs
                specs = specs[:leaf.ndim]
                # drop sharding on dims that don't divide evenly
                for i, ax in enumerate(specs):
                    if ax is None:
                        continue
                    axes = (ax,) if isinstance(ax, str) else tuple(ax)
                    size = 1
                    for a in axes:
                        size *= mesh.shape[a]
                    if leaf.shape[i] % size != 0:
                        specs[i] = None
                return P(*specs)
        return P()

    def walk(path, leaf):
        path_str = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
        return NamedSharding(mesh, spec_for(path_str, leaf))

    return jax.tree_util.tree_map_with_path(walk, params)


def batch_sharding(batch, mesh: Mesh):
    """Shard the leading (global-batch) dim over all DP axes."""
    dp = _dp_axes(mesh)
    spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(x):
        if x.ndim == 0 or (spec and x.shape[0] % _dp_size(mesh) != 0):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(spec))
    return jax.tree_util.tree_map(one, batch)


def _dp_size(mesh: Mesh) -> int:
    s = 1
    for a in _dp_axes(mesh):
        s *= mesh.shape[a]
    return s


# --- activation constraints -------------------------------------------------

@contextlib.contextmanager
def activation_context(mesh: Mesh, sequence_parallel: bool = False):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, sequence_parallel)
    try:
        yield
    finally:
        _TLS.ctx = prev


_ACT_SPECS: Dict[str, Tuple] = {
    # (B, S, D) residual stream; S over model axis if sequence-parallel
    "residual": ("dp", "sp_seq", None),
    "residual_decode": ("dp", None, None),
    "logits": ("dp", None, "tp"),
    "ffn_hidden": ("dp", None, "tp"),
    "heads": ("dp", None, "tp", None),       # (B, S, H, hd)
    "kv_cache": ("dp", None, "tp", None),
    "expert_buf": ("tp", None, None),        # (E, C, d) under EP
    "expert_hidden_ep": ("tp", None, None),  # (E, C, f) under EP
    "expert_hidden_tp": (None, None, "tp"),  # (E, C, f) under TP
    # TP-standard FFN/attention interior layouts (2D flattened tokens):
    # input gathered on model, hidden sharded over model -> single
    # all-reduce of the (T, d) down-projection partials
    "ffn_in_2d": ("dp", None),
    "ffn_hidden_2d": ("dp", "tp"),
    "w_down_grad": ("tp", "dp"),
    "attn_in": ("dp", None, None),
}


def constrain(x, kind: str):
    """Sharding constraint applied to BOTH the primal and (via custom_vjp)
    its cotangent: without the backward pin, XLA derives gather-heavy
    layouts through `transpose(jvp())` (measured: a 9.9 GB/layer
    all-gather of the full-d_ff hidden grad in the qwen2-7b train cell)."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    return _constrain_vjp(x, kind)


import functools as _functools  # noqa: E402


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _constrain_vjp(x, kind: str):
    return _constrain_impl(x, kind)


def _constrain_fwd(x, kind: str):
    return _constrain_impl(x, kind), None


def _constrain_bwd(kind: str, _, g):
    return (_constrain_impl(g, kind),)


_constrain_vjp.defvjp(_constrain_fwd, _constrain_bwd)


def constrain_grad(x, kind: str):
    """Identity in the forward pass; constrains only the COTANGENT.

    Used at TP block outputs: the forward residual stays sequence-
    sharded, but the incoming backward cotangent is pinned to the
    seq-gathered layout before it transposes through the block's matmuls
    (pinning the forward output instead forces an extra forward
    all-gather per layer — measured 3.4x flops via recompute)."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    return _constrain_grad_vjp(x, kind)


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _constrain_grad_vjp(x, kind: str):
    return x


def _cg_fwd(x, kind: str):
    return x, None


def _cg_bwd(kind: str, _, g):
    return (_constrain_impl(g, kind),)


_constrain_grad_vjp.defvjp(_cg_fwd, _cg_bwd)


def _constrain_impl(x, kind: str):
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, seq_par = ctx
    spec = _ACT_SPECS.get(kind)
    if spec is None:
        return x
    out = []
    for i, ax in enumerate(spec[:x.ndim]):
        if ax == "dp":
            dp = _dp_axes(mesh)
            ax_r = dp if len(dp) > 1 else (dp[0] if dp else None)
        elif ax == "sp_seq":
            ax_r = "model" if (seq_par and "model" in mesh.axis_names) else None
        elif ax == "tp":
            ax_r = "model" if "model" in mesh.axis_names else None
        else:
            ax_r = None
        # skip non-divisible dims
        if ax_r is not None:
            axes = (ax_r,) if isinstance(ax_r, str) else tuple(ax_r)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if x.shape[i] % size != 0:
                ax_r = None
        out.append(ax_r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*out)))

"""Deterministic, host-sharded data pipeline.

Synthetic corpora with real learnable structure (Markov token chains,
class-conditional image patterns, formant-like audio frames) so small
models actually *learn* and develop the activation statistics MoR
exploits — pure-noise data would give degenerate ReLU sparsity.

Sharding contract: host h of H draws disjoint streams via
fold_in(seed, step * H + h); a restart at step s reproduces the exact
batch sequence (checkpoint/restore determinism, tested).
"""
from __future__ import annotations

import threading
import queue as _queue
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    prefetch: int = 2


_MARKOV_STATES = 64


def _markov_tables(vocab: int, seed: int):
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.full(_MARKOV_STATES, 0.3), _MARKOV_STATES)
    emit = rng.integers(0, vocab, size=(_MARKOV_STATES, 8))
    return trans, emit


def synthetic_lm_batch(cfg: ModelConfig, batch: int, seq: int, *,
                       seed: int, step: int, host: int = 0,
                       n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """Markov-chain token stream: next-token prediction is learnable."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step * n_hosts + host]))
    trans, emit = _markov_tables(max(cfg.vocab_size, 8), seed)
    states = rng.integers(0, _MARKOV_STATES, size=batch)
    toks = np.empty((batch, seq + 1), np.int32)
    for t in range(seq + 1):
        toks[:, t] = emit[states, rng.integers(0, 8, size=batch)]
        cum = np.cumsum(trans[states], axis=1)
        states = (cum > rng.random((batch, 1))).argmax(1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def synthetic_image_batch(cfg: ModelConfig, batch: int, *, seed: int,
                          step: int, host: int = 0, n_hosts: int = 1
                          ) -> Dict[str, np.ndarray]:
    """Class-conditional frequency patterns + noise (CIFAR-like task)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step * n_hosts + host]))
    n_cls = cfg.cnn_num_classes
    s = cfg.img_size
    labels = rng.integers(0, n_cls, size=batch).astype(np.int32)
    yy, xx = np.mgrid[0:s, 0:s] / s
    imgs = np.empty((batch, s, s, 3), np.float32)
    for c in range(3):
        freq = 1.0 + labels[:, None, None] * 0.7 + c
        phase = labels[:, None, None] * 1.3 + c * 2.1
        imgs[..., c] = np.sin(2 * np.pi * freq * (xx + yy)[None] + phase)
    imgs += 0.35 * rng.standard_normal(imgs.shape).astype(np.float32)
    return {"images": imgs, "labels": labels}


def synthetic_frames_batch(cfg: ModelConfig, batch: int, seq: int, *,
                           seed: int, step: int, host: int = 0,
                           n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """Formant-like frame features + piecewise-constant targets (TDS/ASR)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step * n_hosts + host]))
    d = cfg.d_model
    labels = np.repeat(rng.integers(0, cfg.vocab_size, (batch, seq // 4 + 1)),
                       4, axis=1)[:, :seq].astype(np.int32)
    t = np.arange(seq)[None, :, None]
    k = np.arange(d)[None, None, :]
    frames = np.sin(0.1 * (labels[..., None] + 1) * t / (1 + k % 7)) \
        + 0.3 * rng.standard_normal((batch, seq, d))
    return {"frames": frames.astype(np.float32), "labels": labels}


def make_batch(cfg: ModelConfig, shape: ShapeSpec, dcfg: DataConfig,
               step: int, batch_override: Optional[int] = None) -> Dict:
    b = batch_override or shape.global_batch
    if cfg.family == "cnn":
        return synthetic_image_batch(cfg, b, seed=dcfg.seed, step=step,
                                     host=dcfg.host_id, n_hosts=dcfg.n_hosts)
    if cfg.family == "tds" or cfg.frontend == "audio_stub":
        d = synthetic_frames_batch(cfg, b, shape.seq_len, seed=dcfg.seed,
                                   step=step, host=dcfg.host_id,
                                   n_hosts=dcfg.n_hosts)
        return d
    return synthetic_lm_batch(cfg, b, shape.seq_len, seed=dcfg.seed,
                              step=step, host=dcfg.host_id,
                              n_hosts=dcfg.n_hosts)


def make_train_iterator(cfg: ModelConfig, shape: ShapeSpec, dcfg: DataConfig,
                        start_step: int = 0,
                        batch_override: Optional[int] = None,
                        ) -> Iterator[Dict]:
    """Background-thread prefetching iterator (overlap host data gen with
    device compute); deterministic given (seed, start_step)."""
    q: _queue.Queue = _queue.Queue(maxsize=dcfg.prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(make_batch(cfg, shape, dcfg, step, batch_override),
                      timeout=0.5)
                step += 1
            except _queue.Full:
                continue

    th = threading.Thread(target=worker, daemon=True)
    th.start()

    class _It:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _It()

from repro.data.pipeline import (  # noqa: F401
    DataConfig, make_train_iterator, synthetic_lm_batch, synthetic_image_batch,
    synthetic_frames_batch,
)

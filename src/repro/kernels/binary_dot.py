"""Pallas TPU kernel: the binary rookie's +-1 sign matmul.

TPU adaptation of the paper's binary Compute Units (binCUs, §4.4): signs
are materialised as int8 in VMEM and the product runs on the MXU as an
int8 x int8 -> int32 matmul.  Block shapes keep the working set
(bm*bk + bk*bn int8 + bm*bn int32) well under VMEM and MXU-aligned
(multiples of 128 in the lane dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = jnp.where(x_ref[...] > 0, 1, -1).astype(jnp.int8)   # act: 0 -> -1
    ws = jnp.where(w_ref[...] >= 0, 1, -1).astype(jnp.int8)  # weight sign
    acc_ref[...] += jax.lax.dot_general(
        xs, ws, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def binary_dot(x: jax.Array, w: jax.Array, *, bm: int = 128, bk: int = 512,
               bn: int = 128, interpret: bool = False) -> jax.Array:
    """x: (M, K), w: (K, N) -> float32 (M, N) = sign(x) @ sign(w).
    M/K/N must be multiples of the block shape (ops.py pads)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)

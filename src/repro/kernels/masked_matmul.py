"""Pallas TPU kernel: matmul with tile-granular output skipping.

The MoR tile mask (scalar-prefetched into SMEM) gates the MXU work for
each (row-block x 128-col) output tile: dead tiles write zeros without
issuing dot products.  This is the compute-skip half of the paper's
benefit; the DMA-skip half needs the compacted variant
(``gather_matmul``), because block DMAs declared via BlockSpec are
unconditional under a static grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(mask_ref, x_ref, w_ref, o_ref, acc_ref):
    j, k = pl.program_id(1), pl.program_id(2)
    live = mask_ref[pl.program_id(0) * pl.num_programs(1) + j] != 0

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _mac():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_m", "tile_n", "bk", "interpret",
                                    "return_counts"))
def masked_matmul(x: jax.Array, w: jax.Array, tile_mask: jax.Array, *,
                  tile_m: int = 128, tile_n: int = 128, bk: int = 512,
                  interpret: bool = False, return_counts: bool = False):
    """x: (M, K) @ w: (K, N) with (M/tile_m, N/tile_n) bool tile mask.

    ``return_counts`` additionally returns the live-tile count — the
    liveness counter for the compute-skip path (gather_matmul's
    counters are the ones the executor wires into serving telemetry)."""
    M, K = x.shape
    _, N = w.shape
    tile_m, bk, tile_n = min(tile_m, M), min(bk, K), min(tile_n, N)
    assert M % tile_m == 0 and K % bk == 0 and N % tile_n == 0
    grid = (M // tile_m, N // tile_n, K // bk)
    assert tile_mask.shape == (grid[0], grid[1]), (tile_mask.shape, grid)
    mask_flat = tile_mask.reshape(-1).astype(jnp.int32)
    if return_counts:
        out = masked_matmul(x, w, tile_mask, tile_m=tile_m, tile_n=tile_n,
                            bk=bk, interpret=interpret)
        return out, jnp.sum(mask_flat)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile_m, bk), lambda i, j, k, m_ref: (i, k)),
                pl.BlockSpec((bk, tile_n), lambda i, j, k, m_ref: (k, j)),
            ],
            out_specs=pl.BlockSpec((tile_m, tile_n),
                                   lambda i, j, k, m_ref: (i, j)),
            scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(mask_flat, x, w)


def _kernel_kdim(mask_ref, x_ref, w_ref, o_ref, acc_ref):
    i, k = pl.program_id(0), pl.program_id(2)
    live = mask_ref[i * pl.num_programs(2) + k] != 0

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _mac():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_m", "tile_k", "bn", "interpret"))
def masked_matmul_kdim(x: jax.Array, w: jax.Array, tile_mask: jax.Array, *,
                       tile_m: int = 8, tile_k: int = 128, bn: int = 128,
                       interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) skipping dead CONTRACTION blocks.

    ``tile_mask``: (M/tile_m, K/tile_k) bool — the MoR down-projection
    mask: tile_mask[i, k] == 0 means rows [k*tile_k, (k+1)*tile_k) of
    ``x`` block-row i are known-zero (a dead FFN hidden tile), so the
    accumulation for that (i, k) pair never issues.  Exact when the dead
    x tiles really are zero (the MoR contract)."""
    M, K = x.shape
    _, N = w.shape
    tile_m, tile_k, bn = min(tile_m, M), min(tile_k, K), min(bn, N)
    assert M % tile_m == 0 and K % tile_k == 0 and N % bn == 0
    grid = (M // tile_m, N // bn, K // tile_k)
    assert tile_mask.shape == (grid[0], grid[2]), (tile_mask.shape, grid)
    mask_flat = tile_mask.reshape(-1).astype(jnp.int32)
    return pl.pallas_call(
        _kernel_kdim,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile_m, tile_k), lambda i, j, k, m_ref: (i, k)),
                pl.BlockSpec((tile_k, bn), lambda i, j, k, m_ref: (k, j)),
            ],
            out_specs=pl.BlockSpec((tile_m, bn),
                                   lambda i, j, k, m_ref: (i, j)),
            scratch_shapes=[pltpu.VMEM((tile_m, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(mask_flat, x, w)

"""Pallas TPU kernel: capacity-compacted matmul — the DMA-skipping MoR
execution path.

The wrapper compacts the live (row-block, col-tile) pairs into a static
``capacity``-slot index list (MoE-capacity style; calibration picks the
provisioning, DESIGN.md §2).  The grid iterates over slots, and the
weight/x/out BlockSpec index_maps read the tile coordinates from the
scalar-prefetched list — so **only live weight tiles are ever DMA'd from
HBM**, which is where decode-time FFNs spend their roofline.

Slot layout of the prefetch array ``meta``:
  meta[0]            = n_live (clamped to capacity)
  meta[1 + s]        = flattened tile id (i * n_tiles_n + j) for slot s;
                       padded slots repeat a designated dead tile (whose
                       correct output is zero) or tile 0 when fully live.

Batched-expert contract (MoE): the kernel composes with ``jax.vmap`` —
the batching rule prepends the expert axis to the grid, so E experts'
(x, w, tile_mask, cap_live) stacks run as one expert-grid kernel with
per-expert slot lists and per-expert traced ``cap_live`` clamps.  This
is how ``MoRExecutionPlan.expert_ffn`` executes kernel-mode expert FFNs
(oracle: ``ref.expert_gather_matmul_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(meta_ref, x_ref, w_ref, o_ref, acc_ref):
    s, k = pl.program_id(0), pl.program_id(1)
    n_live = meta_ref[0]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < n_live)
    def _mac():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(1) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "bk",
                                             "capacity", "interpret",
                                             "return_counts"))
def gather_matmul(x: jax.Array, w: jax.Array, tile_mask: jax.Array, *,
                  capacity: int, tile_m: int = 128, tile_n: int = 128,
                  bk: int = 512, cap_live=None, interpret: bool = False,
                  return_counts: bool = False):
    """x: (M, K) @ w: (K, N); only the first ``capacity`` live tiles (in
    row-major order) are computed.  Dead/overflow tiles are exact zeros.

    ``capacity`` is the STATIC slot provisioning (it sizes the grid, so
    it bounds the DMA issue).  ``cap_live`` is an optional TRACED int32
    budget clamped under it — the telemetry-calibrated per-layer
    capacity: scan-stacked layers share one compiled body (one static
    capacity) while each layer's realised compute is cut to its own
    observed liveness quantile.

    ``return_counts`` additionally returns (n_live_total, n_computed) —
    the tile-liveness counters the executor stashes on its prediction
    (``MoRPrediction.kernel_counts``) for the serving telemetry."""
    M, K = x.shape
    _, N = w.shape
    tile_m, bk, tile_n = min(tile_m, M), min(bk, K), min(tile_n, N)
    assert M % tile_m == 0 and K % bk == 0 and N % tile_n == 0
    nm, nn = M // tile_m, N // tile_n
    assert tile_mask.shape == (nm, nn)
    assert 1 <= capacity <= nm * nn

    flat = tile_mask.reshape(-1).astype(bool)
    n_tiles = nm * nn
    # live tiles first (stable), then dead tiles (used for slot padding)
    order = jnp.argsort(~flat, stable=True).astype(jnp.int32)
    n_live_total = jnp.sum(flat).astype(jnp.int32)
    cap_eff = jnp.asarray(capacity, jnp.int32)
    if cap_live is not None:
        cap_eff = jnp.minimum(cap_eff, jnp.maximum(
            jnp.asarray(cap_live, jnp.int32), 1))
    n_live = jnp.minimum(n_live_total, cap_eff)
    # padded slots point at the first dead tile; if everything is live,
    # they point at live tiles already computed (harmless re-compute).
    first_dead = order[jnp.minimum(n_live_total, n_tiles - 1)]
    slots = order[:capacity]
    slot_ids = jnp.where(jnp.arange(capacity) < n_live, slots, first_dead)
    meta = jnp.concatenate([n_live[None], slot_ids]).astype(jnp.int32)

    grid = (capacity, K // bk)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile_m, bk),
                             lambda s, k, meta: (meta[1 + s] // nn, k)),
                pl.BlockSpec((bk, tile_n),
                             lambda s, k, meta: (k, meta[1 + s] % nn)),
            ],
            out_specs=pl.BlockSpec(
                (tile_m, tile_n),
                lambda s, k, meta: (meta[1 + s] // nn, meta[1 + s] % nn)),
            scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(meta, x, w)
    # tiles never visited by any slot hold undefined memory -> select them
    # to zero with the (cheap, VPU) mask expansion.  jnp.where (a select)
    # is garbage-safe, unlike multiplying by 0.
    live_rank = jnp.cumsum(flat) - 1
    kept = (flat & (live_rank < cap_eff)).reshape(nm, nn)
    keep = jnp.repeat(jnp.repeat(kept, tile_m, 0), tile_n, 1)
    out = jnp.where(keep, out, jnp.zeros((), out.dtype))
    if return_counts:
        return out, n_live_total, n_live
    return out

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binary_dot_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """sign_act(x) . sign(w): activations x > 0 -> +1 else -1 (post-ReLU
    zeros are informative), weights w >= 0 -> +1 (sign-bit convention).
    x: (M, K) float, w: (K, N) float -> (M, N) float32."""
    xs = jnp.where(x > 0, 1.0, -1.0).astype(jnp.float32)
    ws = jnp.where(w >= 0, 1.0, -1.0).astype(jnp.float32)
    return xs @ ws


def _expand_mask(mask, tile_m, tile_n, M, N):
    big = jnp.repeat(jnp.repeat(mask, tile_m, 0), tile_n, 1)
    return big[:M, :N]


def masked_matmul_ref(x: jax.Array, w: jax.Array, tile_mask: jax.Array,
                      tile_m: int, tile_n: int) -> jax.Array:
    """x @ w where output tiles with mask==0 are exactly zero.
    tile_mask: (ceil(M/tile_m), ceil(N/tile_n)) bool/int."""
    out = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    keep = _expand_mask(tile_mask.astype(bool), tile_m, tile_n,
                        x.shape[0], w.shape[1])
    return jnp.where(keep, out, 0.0).astype(x.dtype)


def gather_matmul_ref(x: jax.Array, w: jax.Array, tile_mask: jax.Array,
                      tile_m: int, tile_n: int, capacity: int) -> jax.Array:
    """Like masked_matmul_ref but only the first ``capacity`` live tiles
    (row-major scan order) are computed — overflow tiles degrade to
    predicted-zero, mirroring the static-capacity Pallas kernel."""
    flat = tile_mask.astype(bool).reshape(-1)
    live_rank = jnp.cumsum(flat) - 1          # rank among live tiles
    kept = flat & (live_rank < capacity)
    kept = kept.reshape(tile_mask.shape)
    return masked_matmul_ref(x, w, kept, tile_m, tile_n)


def gather_matmul_cap_ref(x: jax.Array, w: jax.Array, tile_mask: jax.Array,
                          tile_m: int, tile_n: int, capacity: int,
                          cap_live=None) -> jax.Array:
    """``gather_matmul_ref`` with the traced ``cap_live`` clamp applied
    under the static ``capacity`` — the oracle for the per-(layer,
    expert) calibrated budgets."""
    cap = jnp.asarray(capacity, jnp.int32)
    if cap_live is not None:
        cap = jnp.minimum(cap, jnp.maximum(
            jnp.asarray(cap_live, jnp.int32), 1))
    flat = tile_mask.astype(bool).reshape(-1)
    live_rank = jnp.cumsum(flat) - 1
    kept = (flat & (live_rank < cap)).reshape(tile_mask.shape)
    return masked_matmul_ref(x, w, kept, tile_m, tile_n)


def expert_gather_matmul_ref(x: jax.Array, w: jax.Array,
                             tile_mask: jax.Array, tile_m: int, tile_n: int,
                             capacity: int, cap_live=None) -> jax.Array:
    """Batched-expert oracle: x (E, M, K), w (E, K, N), tile_mask
    (E, nm, nn), optional per-expert cap_live (E,).  vmap of the
    single-expert reference — the allclose target for the expert-grid
    Pallas path (``MoRExecutionPlan.expert_ffn`` in kernel mode)."""
    def one(xe, we, me, ce):
        return gather_matmul_cap_ref(xe, we, me, tile_m, tile_n, capacity,
                                     cap_live=ce)
    caps = (jnp.broadcast_to(jnp.asarray(cap_live, jnp.int32), x.shape[:1])
            if cap_live is not None
            else jnp.full(x.shape[:1], capacity, jnp.int32))
    return jax.vmap(one)(x, w, tile_mask, caps)


def masked_matmul_kdim_ref(x: jax.Array, w: jax.Array,
                           tile_mask: jax.Array, tile_m: int, tile_k: int
                           ) -> jax.Array:
    """x @ w with dead (row-block, k-block) pairs of x zeroed before the
    contraction — the oracle for the contraction-masked down matmul."""
    keep = _expand_mask(tile_mask.astype(bool), tile_m, tile_k,
                        x.shape[0], x.shape[1])
    xz = jnp.where(keep, x, 0.0)
    return (xz.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def _paged_view(pool, block_table, lo, n_local, fill):
    """Ring view through the block table with the kernel's grid-skip
    semantics made dense: null pages (global id 0) and — given a shard
    window [lo, lo + n_local) — foreign pages gather page 0 and take
    ``fill`` (use -1 for position tags so skipped rows mask out)."""
    if lo is None:
        loc, ok = block_table, block_table > 0
    else:
        loc = block_table - lo
        ok = (block_table > 0) & (loc >= 0) & (loc < n_local)
    out = pool[jnp.where(ok, loc, 0)]
    mask = ok.reshape(ok.shape + (1,) * (out.ndim - ok.ndim))
    return jnp.where(mask, out, jnp.asarray(fill, out.dtype))


def gqa_paged_ref(q, kpool, vpool, ppool, block_table, qpos, *,
                  window: int = 0, lo=None, n_local=None,
                  partial: bool = False):
    """Oracle for ``paged_attention.gqa_paged_flash``: materialise the
    ring view (skipped pages as -1-tagged rows), run the dense masked
    softmax — or emit the (m, l, acc) flash stats with ``partial``."""
    B, C, H, D = q.shape
    page, hkv = kpool.shape[1], kpool.shape[2]
    Dv = vpool.shape[-1]
    ring = block_table.shape[1] * page
    gk = _paged_view(kpool, block_table, lo, n_local, 0).reshape(
        B, ring, hkv, D)
    gv = _paged_view(vpool, block_table, lo, n_local, 0).reshape(
        B, ring, hkv, Dv)
    gp = _paged_view(ppool, block_table, lo, n_local, -1).reshape(B, ring)
    G = H // hkv
    qf = q.reshape(B, C, hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf, gk.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    rel = qpos[:, :, None] - gp[:, None, :]
    ok = (gp[:, None, :] >= 0) & (rel >= 0)
    if window > 0:
        ok &= rel < window
    s = jnp.where(ok[:, None, None], s, -1e30)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bkgqt,btkd->bkgqd", p, gv.astype(jnp.float32))
    if partial:
        return m, l, acc
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, C, H, Dv).astype(q.dtype)


def mla_paged_ref(q_lat, q_pe, ck_pool, cpe_pool, cp_pool, block_table,
                  qpos, *, scale: float, lo=None, n_local=None,
                  partial: bool = False):
    """Oracle for ``paged_attention.mla_paged_flash`` (absorbed latent
    attention over the paged pools)."""
    B, C, h, kr = q_lat.shape
    rd = q_pe.shape[-1]
    page = ck_pool.shape[1]
    ring = block_table.shape[1] * page
    ck = _paged_view(ck_pool, block_table, lo, n_local, 0).reshape(
        B, ring, kr).astype(jnp.float32)
    cpe = _paged_view(cpe_pool, block_table, lo, n_local, 0).reshape(
        B, ring, rd).astype(jnp.float32)
    cp = _paged_view(cp_pool, block_table, lo, n_local, -1).reshape(B, ring)
    s = (jnp.einsum("bchk,btk->bhct", q_lat.astype(jnp.float32), ck,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bchr,btr->bhct", q_pe.astype(jnp.float32), cpe,
                      preferred_element_type=jnp.float32)) * scale
    ok = (cp[:, None, None, :] >= 0) & \
        (cp[:, None, None, :] <= qpos[:, None, :, None])
    s = jnp.where(ok, s, -1e30)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bhct,btk->bhck", p, ck)
    if partial:
        return m, l, acc
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q_lat.dtype)


def mor_tile_mask_ref(x: jax.Array, w: jax.Array, m: jax.Array,
                      b: jax.Array, bn_scale: jax.Array, bn_bias: jax.Array,
                      enable: jax.Array, proxy_neg: jax.Array,
                      tile_m: int, tile_n: int,
                      residual=None) -> jax.Array:
    """Oracle for the fused predictor kernel: binary rookie line + BN fold
    (+ optional per-element residual input), AND with the proxy rookie,
    reduce to a tile-liveness mask.

    proxy_neg: (M, N) bool — True where the neuron's proxy predicted zero
    (for proxies themselves this is False: they are always computed).
    -> (ceil(M/tile_m), ceil(N/tile_n)) bool."""
    p_bin = binary_dot_ref(x, w)
    p_hat = (m * p_bin + b) * bn_scale + bn_bias
    if residual is not None:
        p_hat = p_hat + residual
    skip = (p_hat < 0.0) & enable & proxy_neg
    computed = ~skip
    M, N = computed.shape
    pm, pn = (-M) % tile_m, (-N) % tile_n
    padded = jnp.pad(computed, ((0, pm), (0, pn)))
    t = padded.reshape((M + pm) // tile_m, tile_m, (N + pn) // tile_n, tile_n)
    return jnp.any(t, axis=(1, 3))

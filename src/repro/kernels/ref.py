"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binary_dot_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """sign_act(x) . sign(w): activations x > 0 -> +1 else -1 (post-ReLU
    zeros are informative), weights w >= 0 -> +1 (sign-bit convention).
    x: (M, K) float, w: (K, N) float -> (M, N) float32."""
    xs = jnp.where(x > 0, 1.0, -1.0).astype(jnp.float32)
    ws = jnp.where(w >= 0, 1.0, -1.0).astype(jnp.float32)
    return xs @ ws


def _expand_mask(mask, tile_m, tile_n, M, N):
    big = jnp.repeat(jnp.repeat(mask, tile_m, 0), tile_n, 1)
    return big[:M, :N]


def masked_matmul_ref(x: jax.Array, w: jax.Array, tile_mask: jax.Array,
                      tile_m: int, tile_n: int) -> jax.Array:
    """x @ w where output tiles with mask==0 are exactly zero.
    tile_mask: (ceil(M/tile_m), ceil(N/tile_n)) bool/int."""
    out = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    keep = _expand_mask(tile_mask.astype(bool), tile_m, tile_n,
                        x.shape[0], w.shape[1])
    return jnp.where(keep, out, 0.0).astype(x.dtype)


def gather_matmul_ref(x: jax.Array, w: jax.Array, tile_mask: jax.Array,
                      tile_m: int, tile_n: int, capacity: int) -> jax.Array:
    """Like masked_matmul_ref but only the first ``capacity`` live tiles
    (row-major scan order) are computed — overflow tiles degrade to
    predicted-zero, mirroring the static-capacity Pallas kernel."""
    flat = tile_mask.astype(bool).reshape(-1)
    live_rank = jnp.cumsum(flat) - 1          # rank among live tiles
    kept = flat & (live_rank < capacity)
    kept = kept.reshape(tile_mask.shape)
    return masked_matmul_ref(x, w, kept, tile_m, tile_n)


def gather_matmul_cap_ref(x: jax.Array, w: jax.Array, tile_mask: jax.Array,
                          tile_m: int, tile_n: int, capacity: int,
                          cap_live=None) -> jax.Array:
    """``gather_matmul_ref`` with the traced ``cap_live`` clamp applied
    under the static ``capacity`` — the oracle for the per-(layer,
    expert) calibrated budgets."""
    cap = jnp.asarray(capacity, jnp.int32)
    if cap_live is not None:
        cap = jnp.minimum(cap, jnp.maximum(
            jnp.asarray(cap_live, jnp.int32), 1))
    flat = tile_mask.astype(bool).reshape(-1)
    live_rank = jnp.cumsum(flat) - 1
    kept = (flat & (live_rank < cap)).reshape(tile_mask.shape)
    return masked_matmul_ref(x, w, kept, tile_m, tile_n)


def expert_gather_matmul_ref(x: jax.Array, w: jax.Array,
                             tile_mask: jax.Array, tile_m: int, tile_n: int,
                             capacity: int, cap_live=None) -> jax.Array:
    """Batched-expert oracle: x (E, M, K), w (E, K, N), tile_mask
    (E, nm, nn), optional per-expert cap_live (E,).  vmap of the
    single-expert reference — the allclose target for the expert-grid
    Pallas path (``MoRExecutionPlan.expert_ffn`` in kernel mode)."""
    def one(xe, we, me, ce):
        return gather_matmul_cap_ref(xe, we, me, tile_m, tile_n, capacity,
                                     cap_live=ce)
    caps = (jnp.broadcast_to(jnp.asarray(cap_live, jnp.int32), x.shape[:1])
            if cap_live is not None
            else jnp.full(x.shape[:1], capacity, jnp.int32))
    return jax.vmap(one)(x, w, tile_mask, caps)


def masked_matmul_kdim_ref(x: jax.Array, w: jax.Array,
                           tile_mask: jax.Array, tile_m: int, tile_k: int
                           ) -> jax.Array:
    """x @ w with dead (row-block, k-block) pairs of x zeroed before the
    contraction — the oracle for the contraction-masked down matmul."""
    keep = _expand_mask(tile_mask.astype(bool), tile_m, tile_k,
                        x.shape[0], x.shape[1])
    xz = jnp.where(keep, x, 0.0)
    return (xz.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def mor_tile_mask_ref(x: jax.Array, w: jax.Array, m: jax.Array,
                      b: jax.Array, bn_scale: jax.Array, bn_bias: jax.Array,
                      enable: jax.Array, proxy_neg: jax.Array,
                      tile_m: int, tile_n: int,
                      residual=None) -> jax.Array:
    """Oracle for the fused predictor kernel: binary rookie line + BN fold
    (+ optional per-element residual input), AND with the proxy rookie,
    reduce to a tile-liveness mask.

    proxy_neg: (M, N) bool — True where the neuron's proxy predicted zero
    (for proxies themselves this is False: they are always computed).
    -> (ceil(M/tile_m), ceil(N/tile_n)) bool."""
    p_bin = binary_dot_ref(x, w)
    p_hat = (m * p_bin + b) * bn_scale + bn_bias
    if residual is not None:
        p_hat = p_hat + residual
    skip = (p_hat < 0.0) & enable & proxy_neg
    computed = ~skip
    M, N = computed.shape
    pm, pn = (-M) % tile_m, (-N) % tile_n
    padded = jnp.pad(computed, ((0, pm), (0, pn)))
    t = padded.reshape((M + pm) // tile_m, tile_m, (N + pn) // tile_n, tile_n)
    return jnp.any(t, axis=(1, 3))

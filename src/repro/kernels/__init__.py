"""Pallas TPU kernels for the MoR hot paths (+ ops.py wrappers, ref.py
oracles).  Validated in interpret mode on CPU; lowering targets TPU."""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kwargs):
    """Version-compat shim: jax <= 0.4.x names the Mosaic compiler-param
    dataclass ``pltpu.TPUCompilerParams``; newer jax renames it to
    ``pltpu.CompilerParams``.  Prefer the new name when present."""
    cls = getattr(_pltpu, "CompilerParams", None) \
        or getattr(_pltpu, "TPUCompilerParams")
    return cls(**kwargs)

"""Pallas TPU kernels for the MoR hot paths (+ ops.py wrappers, ref.py
oracles).  Validated in interpret mode on CPU; lowering targets TPU."""

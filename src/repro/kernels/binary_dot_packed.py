"""Pallas TPU kernel: binary dot product from BIT-PACKED sign weights.

The paper keeps 1-bit weights in a dedicated 2 KB binWeight SRAM (§4.4)
at zero DRAM overhead (sign bits of the 8-bit weights).  The TPU
translation: the signs are packed offline 8-per-uint8 (`pack_signs`), so
the predictor's weight traffic is 1/16 of the bf16 weights — the packed
table stays VMEM-resident for realistic layer sizes, exactly like the
paper's SRAM.  The kernel unpacks in-register (shift+mask on the VPU)
and feeds the +-1 int8 matmul to the MXU.

Layout: packed[k8, n] bit b of packed[k8, n] = sign bit (1 = negative)
of w[k8 * 8 + b, n].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def pack_signs(w: jax.Array) -> jax.Array:
    """(K, N) float -> (ceil(K/8), N) uint8 sign bitmap (1 = negative)."""
    K, N = w.shape
    pad = (-K) % 8
    bits = (w < 0).astype(jnp.uint8)
    if pad:
        bits = jnp.pad(bits, ((0, pad), (0, 0)))  # pad signs = 0 -> +1
    bits = bits.reshape(-1, 8, N)
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, :, None]
    return jnp.sum(bits << shifts, axis=1).astype(jnp.uint8)


def unpack_signs(packed: jax.Array, K: int) -> jax.Array:
    """Inverse of pack_signs -> (K, N) int8 in {+1, -1}."""
    k8, N = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint8(1)
    signs = 1 - 2 * bits.astype(jnp.int8)
    return signs.reshape(k8 * 8, N)[:K]


def _kernel(x_ref, wp_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = jnp.where(x_ref[...] > 0, 1, -1).astype(jnp.int8)
    # in-register unpack: (bk/8, bn) uint8 -> (bk, bn) +-1 int8
    packed = wp_ref[...]
    bk8, bn = packed.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (bk8, 8, bn), 1)
    bits = (packed[:, None, :] >> shifts) & jnp.uint8(1)
    ws = (1 - 2 * bits.astype(jnp.int8)).reshape(bk8 * 8, bn)
    acc_ref[...] += jax.lax.dot_general(
        xs, ws, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def binary_dot_packed(x: jax.Array, w_packed: jax.Array, *, bm: int = 128,
                      bk: int = 512, bn: int = 128,
                      interpret: bool = False) -> jax.Array:
    """x: (M, K) float; w_packed: (K/8, N) uint8 -> (M, N) float32.
    K must be a multiple of 8 and of bk; M/N multiples of bm/bn."""
    M, K = x.shape
    k8, N = w_packed.shape
    assert k8 * 8 == K, (x.shape, w_packed.shape)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 and bk % 8 == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed)

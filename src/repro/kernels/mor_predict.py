"""Pallas TPU kernel: fused MoR tile-mask predictor.

One pass over the activations produces the per-tile liveness mask:
int8 sign matmul (binary rookie) -> fitted line + BN fold (+ optional
per-element residual input) -> AND with the proxy rookie's verdict ->
any() reduction over the tile.  The mask feeds ``gather_matmul`` for the
main matmul, so the predictor runs ahead of the heavy compute exactly
like the paper's binCUs overlap the CUs (§4.1).

The coef table carries SIX rows: [m, b, bn_scale, bn_bias, enable,
res_scale].  Rows 0-4 are per-column; row 5 scales the (M, N)
``residual`` tensor input (1.0 when a residual is attached, 0.0 rows
make the input a no-op) — per-element residual inputs (paper §3.2.1:
"the residual input is added") cannot ride in a per-column table, so
they arrive as a second VMEM input with the same block tiling as the
proxy verdicts.

The ``proxy_neg`` input is tri-state int8: 0/1 = the proxy rookie's
verdict, 2 = forced skip.  State 2 marks both shape padding AND (in the
batched-expert MoE path) capacity-buffer rows holding the zero pad row
— without it the fitted intercept alone can mark pad rows live.  Like
``gather_matmul``, the kernel composes with ``jax.vmap`` over a leading
expert axis (x/w/coef/proxy_neg all (E, ...)-stacked): one trace, one
expert-grid kernel for every expert's predictor pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

N_COEF_ROWS = 6


def _kernel(has_res, x_ref, w_ref, coef_ref, pn_ref, *rest):
    if has_res:
        res_ref, o_ref, acc_ref = rest
    else:
        o_ref, acc_ref = rest
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = jnp.where(x_ref[...] > 0, 1, -1).astype(jnp.int8)   # act: 0 -> -1
    ws = jnp.where(w_ref[...] >= 0, 1, -1).astype(jnp.int8)  # weight sign
    acc_ref[...] += jax.lax.dot_general(
        xs, ws, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        p_bin = acc_ref[...].astype(jnp.float32)
        m, b = coef_ref[0, :], coef_ref[1, :]
        sc, bi = coef_ref[2, :], coef_ref[3, :]
        en = coef_ref[4, :]
        p_hat = (m[None, :] * p_bin + b[None, :]) * sc[None, :] + bi[None, :]
        if has_res:
            p_hat = p_hat + coef_ref[5, :][None, :] * res_ref[...]
        pn = pn_ref[...]
        # pn: 0 = proxy predicted non-zero, 1 = proxy predicted zero,
        # 2 = padded row/col (forced skip, so padding never marks a tile
        # live — matches the oracle's pad-with-False reduction)
        skip = ((p_hat < 0.0) & (en[None, :] > 0.5) & (pn == 1)) | (pn > 1)
        o_ref[0, 0] = jnp.any(~skip).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "bk",
                                             "interpret"))
def mor_tile_mask(x: jax.Array, w: jax.Array, coef: jax.Array,
                  proxy_neg: jax.Array, residual=None, *, tile_m: int = 8,
                  tile_n: int = 128, bk: int = 512,
                  interpret: bool = False) -> jax.Array:
    """x: (M, K); w: (K, N); coef: (6, N) float32 rows = [m, b, bn_scale,
    bn_bias, enable, res_scale]; proxy_neg: (M, N) int8 (0 = proxy
    predicted non-zero, 1 = proxy predicted zero, 2 = padding: forced
    skip); residual: optional (M, N) float32 per-element ReLU-input
    residual (scaled by coef row 5).
    -> (M/tile_m, N/tile_n) int32 tile liveness."""
    M, K = x.shape
    _, N = w.shape
    tile_m, bk, tile_n = min(tile_m, M), min(bk, K), min(tile_n, N)
    assert M % tile_m == 0 and K % bk == 0 and N % tile_n == 0
    assert coef.shape[0] == N_COEF_ROWS
    grid = (M // tile_m, N // tile_n, K // bk)
    has_res = residual is not None
    in_specs = [
        pl.BlockSpec((tile_m, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, tile_n), lambda i, j, k: (k, j)),
        pl.BlockSpec((N_COEF_ROWS, tile_n), lambda i, j, k: (0, j)),
        pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j)),
    ]
    args = [x, w, coef, proxy_neg]
    if has_res:
        in_specs.append(pl.BlockSpec((tile_m, tile_n),
                                     lambda i, j, k: (i, j)))
        args.append(residual.astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_kernel, has_res),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.int32),
        scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)

"""Fused Pallas paged flash-decode: attend straight off the block table.

The paged serving layouts (``serving.kv_pool.PagedPool``) used to pay
for every attend twice: gather the whole ring view through the block
table (``pool[block_table].reshape(B, ring, ...)``), THEN run dense
masked attention over it — every null page (reserved id 0) and, under
the mesh-sharded layout, every foreign page was materialised, masked
and softmaxed.  This kernel inverts that: the (B, n_blocks) block table
rides in as a SCALAR-PREFETCH operand, the grid walks (slot, block),
and each step's BlockSpec index_map pulls exactly one KV page out of
the pool — pages that are null (never written) or foreign (resident on
another shard) are grid-level skips (``pl.when``), so their DMA target
is the always-resident null page and their FLOPs never issue.  Online
softmax statistics (m, l, acc) accumulate in VMEM scratch across the
block dimension, exactly the ``gather_matmul`` DMA-on-demand idiom
applied to KV pages instead of weight tiles.

Two layouts share the machinery:

  * GQA rings: pools (n_pages, page, hkv, hd), grouped queries
    (B, C, H, hd), causal + optional sliding window — ring wrap needs
    no special casing because masking is entirely position-tag driven;
  * absorbed-MLA latent: pools (n_pages, page, kr) / (n_pages, page,
    rd), scores in the rank-kr latent space (W_uk already absorbed into
    the query), accumulator over the latent rows.

Each kernel has two output variants: the normalised output (single-
device paged layout) and the raw partial (m, l, acc) flash statistics
(``partial=True``) — the mesh-sharded layout feeds those straight into
``collectives.flash_merge``, so the sharded attends keep their
one-collective-per-layer contract without ever building the ring view.

Like every kernel here it runs ``interpret=True`` off-TPU; mode
selection for the serving paths lives in ``enabled()`` (env
``REPRO_PAGED_KERNEL``, defaulting to the kernel on TPU and the jnp
gather fallback elsewhere), mirroring ``ops._interpret``.  The pure-jnp
oracles are ``ref.gqa_paged_ref`` / ``ref.mla_paged_ref``.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params
from repro.kernels.ops import _interpret

NEG_INF = -1e30


def enabled() -> bool:
    """Kernel-vs-jnp toggle for the PAGED ATTEND serving paths: env
    ``REPRO_PAGED_KERNEL`` forces it ("1"/"0"); default is the fused
    kernel on TPU and the jnp gather fallback elsewhere (interpret-mode
    Pallas serialises the page grid, so CPU serving keeps the fused-XLA
    path and the differential tests force the kernel explicitly)."""
    env = os.environ.get("REPRO_PAGED_KERNEL")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() == "tpu"


# trace-time dispatch counters: how many pallas_call sites each serving
# step compiled in (telemetry / CI proof that the kernel path engaged —
# a cached executable re-dispatches without retracing, so these count
# kernel *traces*, not per-token launches).  Counters are a SCOPE
# STACK: the root scope is process-global (historical behaviour), and
# ``trace_scope()`` pushes a fresh frame so back-to-back benchmark
# scenarios can each read their own counts without bleed-through —
# kernels bump every active frame, ``kernel_traces()`` reads the
# innermost.
_SCOPES = [{"gqa": 0, "mla": 0}]


def _bump_trace(kind: str) -> None:
    for frame in _SCOPES:
        frame[kind] += 1


def kernel_traces() -> dict:
    """Counts in the innermost active scope (the process-global root
    when no ``trace_scope`` is open)."""
    return dict(_SCOPES[-1])


def reset_kernel_traces() -> None:
    """Zero the innermost active scope."""
    for k in _SCOPES[-1]:
        _SCOPES[-1][k] = 0


@contextlib.contextmanager
def trace_scope():
    """Scoped kernel-trace counting: yields a dict that accumulates
    only the traces that happen inside the ``with`` block (it keeps its
    final counts after exit); outer scopes keep accumulating too."""
    frame = {"gqa": 0, "mla": 0}
    _SCOPES.append(frame)
    try:
        yield frame
    finally:
        _SCOPES.remove(frame)


def _live_tables(block_table, lo, n_local):
    """(pool page index to DMA, live flag) per (slot, block).  Null
    pages (global id 0) are never live; under a shard's local window
    [lo, lo + n_local) foreign pages aren't either — both DMA the
    always-resident page 0 and skip all compute."""
    if lo is None:
        loc, ok = block_table, block_table > 0
    else:
        loc = block_table - lo
        ok = (block_table > 0) & (loc >= 0) & (loc < n_local)
    return (jnp.where(ok, loc, 0).astype(jnp.int32),
            ok.astype(jnp.int32))


# ==========================================================================
# GQA over paged rings
# ==========================================================================

def _gqa_kernel(tbl_ref, live_ref, qp_ref, q_ref, k_ref, v_ref, p_ref,
                *refs, n_blocks: int, scale: float, window: int,
                partial: bool):
    if partial:
        m_ref, l_ref, a_ref, m_s, l_s, a_s = refs
    else:
        (o_ref, m_s, l_s, a_s) = refs
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG_INF)
        l_s[...] = jnp.zeros_like(l_s[...])
        a_s[...] = jnp.zeros_like(a_s[...])

    @pl.when(live_ref[b, j] > 0)
    def _block():
        q = q_ref[0].astype(jnp.float32)         # (C, hkv, G, D)
        k = k_ref[0].astype(jnp.float32)         # (page, hkv, D)
        v = v_ref[0].astype(jnp.float32)         # (page, hkv, Dv)
        tags = p_ref[0]                          # (page,) int32
        qp = qp_ref[0]                           # (C,) int32
        s = jnp.einsum("ckgd,tkd->kgct", q, k,
                       preferred_element_type=jnp.float32) * scale
        rel = qp[:, None] - tags[None, :]        # (C, page)
        ok = (tags[None, :] >= 0) & (rel >= 0)
        if window > 0:
            ok &= rel < window
        s = jnp.where(ok[None, None], s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(-1)
        a_s[...] = a_s[...] * corr[..., None] + jnp.einsum(
            "kgct,tkd->kgcd", p, v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _emit():
        if partial:
            m_ref[0] = m_s[...]
            l_ref[0] = l_s[...]
            a_ref[0] = a_s[...]
        else:
            o_ref[0] = (a_s[...] / jnp.maximum(l_s[...], 1e-30)[..., None]
                        ).astype(o_ref.dtype)


def gqa_paged_flash(q, kpool, vpool, ppool, block_table, qpos, *,
                    window: int = 0, lo=None, n_local: Optional[int] = None,
                    partial: bool = False, interpret: Optional[bool] = None):
    """Fused GQA paged flash decode.  q: (B, C, H, D); pools:
    (n_pages, page, hkv, ·) with position tags ``ppool`` (n_pages,
    page); block_table: (B, n_blocks) page ids (global under sharding —
    pass ``lo``/``n_local`` for the shard's resident window); qpos:
    (B, C) query positions.  Returns (B, C, H, Dv) in q's dtype, or the
    partial flash stats ((B, hkv, G, C) m / l, (B, hkv, G, C, Dv) acc,
    all fp32) with ``partial=True`` — the ``flash_merge`` operands."""
    _bump_trace("gqa")
    B, C, H, D = q.shape
    page, hkv = kpool.shape[1], kpool.shape[2]
    Dv = vpool.shape[-1]
    G = H // hkv
    n_blocks = block_table.shape[1]
    tbl, live = _live_tables(block_table, lo, n_local)
    qf = q.reshape(B, C, hkv, G, D)
    kernel = functools.partial(_gqa_kernel, n_blocks=n_blocks,
                               scale=D ** -0.5, window=window,
                               partial=partial)
    in_specs = [
        pl.BlockSpec((1, C), lambda b, j, tbl, live: (b, 0)),
        pl.BlockSpec((1, C, hkv, G, D),
                     lambda b, j, tbl, live: (b, 0, 0, 0, 0)),
        pl.BlockSpec((1, page, hkv, D),
                     lambda b, j, tbl, live: (tbl[b, j], 0, 0, 0)),
        pl.BlockSpec((1, page, hkv, Dv),
                     lambda b, j, tbl, live: (tbl[b, j], 0, 0, 0)),
        pl.BlockSpec((1, page), lambda b, j, tbl, live: (tbl[b, j], 0)),
    ]
    if partial:
        out_shape = (
            jax.ShapeDtypeStruct((B, hkv, G, C), jnp.float32),
            jax.ShapeDtypeStruct((B, hkv, G, C), jnp.float32),
            jax.ShapeDtypeStruct((B, hkv, G, C, Dv), jnp.float32),
        )
        out_specs = (
            pl.BlockSpec((1, hkv, G, C),
                         lambda b, j, tbl, live: (b, 0, 0, 0)),
            pl.BlockSpec((1, hkv, G, C),
                         lambda b, j, tbl, live: (b, 0, 0, 0)),
            pl.BlockSpec((1, hkv, G, C, Dv),
                         lambda b, j, tbl, live: (b, 0, 0, 0, 0)),
        )
    else:
        out_shape = jax.ShapeDtypeStruct((B, hkv, G, C, Dv), q.dtype)
        out_specs = pl.BlockSpec(
            (1, hkv, G, C, Dv), lambda b, j, tbl, live: (b, 0, 0, 0, 0))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, n_blocks),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((hkv, G, C), jnp.float32),
                pltpu.VMEM((hkv, G, C), jnp.float32),
                pltpu.VMEM((hkv, G, C, Dv), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=_interpret() if interpret is None else interpret,
    )(tbl, live, qpos.astype(jnp.int32), qf, kpool, vpool, ppool)
    if partial:
        return out
    return out.transpose(0, 3, 1, 2, 4).reshape(B, C, H, Dv)


# ==========================================================================
# absorbed-MLA over paged latent pools
# ==========================================================================

def _mla_kernel(tbl_ref, live_ref, qp_ref, ql_ref, qe_ref, ck_ref, pe_ref,
                p_ref, *refs, n_blocks: int, scale: float, partial: bool):
    if partial:
        m_ref, l_ref, a_ref, m_s, l_s, a_s = refs
    else:
        (o_ref, m_s, l_s, a_s) = refs
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG_INF)
        l_s[...] = jnp.zeros_like(l_s[...])
        a_s[...] = jnp.zeros_like(a_s[...])

    @pl.when(live_ref[b, j] > 0)
    def _block():
        ql = ql_ref[0].astype(jnp.float32)       # (C, h, kr)
        qe = qe_ref[0].astype(jnp.float32)       # (C, h, rd)
        ck = ck_ref[0].astype(jnp.float32)       # (page, kr)
        pe = pe_ref[0].astype(jnp.float32)       # (page, rd)
        tags = p_ref[0]                          # (page,) int32
        qp = qp_ref[0]                           # (C,) int32
        s = (jnp.einsum("chk,tk->hct", ql, ck,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("chr,tr->hct", qe, pe,
                          preferred_element_type=jnp.float32)) * scale
        ok = (tags[None, :] >= 0) & (tags[None, :] <= qp[:, None])
        s = jnp.where(ok[None], s, NEG_INF)      # (h, C, page)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(-1)
        a_s[...] = a_s[...] * corr[..., None] + jnp.einsum(
            "hct,tk->hck", p, ck, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _emit():
        if partial:
            m_ref[0] = m_s[...]
            l_ref[0] = l_s[...]
            a_ref[0] = a_s[...]
        else:
            o_ref[0] = (a_s[...] / jnp.maximum(l_s[...], 1e-30)[..., None]
                        ).astype(o_ref.dtype)


def mla_paged_flash(q_lat, q_pe, ck_pool, cpe_pool, cp_pool, block_table,
                    qpos, *, scale: float, lo=None,
                    n_local: Optional[int] = None, partial: bool = False,
                    interpret: Optional[bool] = None):
    """Fused absorbed-MLA paged flash decode (latent space).  q_lat:
    (B, C, h, kr) with W_uk absorbed, q_pe: (B, C, h, rd); pools:
    (n_pages, page, ·) latent / rope rows with position tags
    ``cp_pool``.  Returns o_lat (B, C, h, kr) in q_lat's dtype (the
    caller absorbs W_uv), or with ``partial=True`` the flash stats
    ((B, h, C) m / l, (B, h, C, kr) acc, fp32) for ``flash_merge``."""
    _bump_trace("mla")
    B, C, h, kr = q_lat.shape
    rd = q_pe.shape[-1]
    page = ck_pool.shape[1]
    n_blocks = block_table.shape[1]
    tbl, live = _live_tables(block_table, lo, n_local)
    kernel = functools.partial(_mla_kernel, n_blocks=n_blocks, scale=scale,
                               partial=partial)
    in_specs = [
        pl.BlockSpec((1, C), lambda b, j, tbl, live: (b, 0)),
        pl.BlockSpec((1, C, h, kr),
                     lambda b, j, tbl, live: (b, 0, 0, 0)),
        pl.BlockSpec((1, C, h, rd),
                     lambda b, j, tbl, live: (b, 0, 0, 0)),
        pl.BlockSpec((1, page, kr),
                     lambda b, j, tbl, live: (tbl[b, j], 0, 0)),
        pl.BlockSpec((1, page, rd),
                     lambda b, j, tbl, live: (tbl[b, j], 0, 0)),
        pl.BlockSpec((1, page), lambda b, j, tbl, live: (tbl[b, j], 0)),
    ]
    if partial:
        out_shape = (
            jax.ShapeDtypeStruct((B, h, C), jnp.float32),
            jax.ShapeDtypeStruct((B, h, C), jnp.float32),
            jax.ShapeDtypeStruct((B, h, C, kr), jnp.float32),
        )
        out_specs = (
            pl.BlockSpec((1, h, C), lambda b, j, tbl, live: (b, 0, 0)),
            pl.BlockSpec((1, h, C), lambda b, j, tbl, live: (b, 0, 0)),
            pl.BlockSpec((1, h, C, kr),
                         lambda b, j, tbl, live: (b, 0, 0, 0)),
        )
    else:
        out_shape = jax.ShapeDtypeStruct((B, h, C, kr), q_lat.dtype)
        out_specs = pl.BlockSpec(
            (1, h, C, kr), lambda b, j, tbl, live: (b, 0, 0, 0))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, n_blocks),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((h, C), jnp.float32),
                pltpu.VMEM((h, C), jnp.float32),
                pltpu.VMEM((h, C, kr), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=_interpret() if interpret is None else interpret,
    )(tbl, live, qpos.astype(jnp.int32), q_lat, q_pe, ck_pool, cpe_pool,
      cp_pool)
    if partial:
        return out
    return out.transpose(0, 2, 1, 3)

"""Jit'd public wrappers around the Pallas kernels: shape padding,
interpret-mode fallback on CPU, and the MoRLayer-facing helpers.

On this (CPU) container every kernel runs with ``interpret=True`` — the
kernel body executes in Python against the same BlockSpec tiling the TPU
would use, so correctness (incl. the scalar-prefetch index plumbing) is
what is validated here; the lowering targets TPU.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import binary_dot as _bd
from repro.kernels import gather_matmul as _gm
from repro.kernels import masked_matmul as _mm
from repro.kernels import mor_predict as _mp


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


def _pad_to(x, mult0, mult1):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def binary_dot(x: jax.Array, w: jax.Array, *, bm: int = 128, bk: int = 512,
               bn: int = 128) -> jax.Array:
    """Padded/unpadded wrapper for kernels.binary_dot."""
    M, K = x.shape
    N = w.shape[1]
    bm_, bk_, bn_ = min(bm, max(M, 8)), min(bk, K), min(bn, N)
    xp = _pad_to(x, bm_, bk_)
    wp = _pad_to(w, bk_, bn_)
    out = _bd.binary_dot(xp, wp, bm=bm_, bk=bk_, bn=bn_,
                         interpret=_interpret())
    # K padding contributes sign_act(0)*sign_w(0) = (-1)*(+1) = -1 per
    # padded k to every cell (exactly), so add it back.
    k_pad = xp.shape[1] - K
    if k_pad:
        out = out + float(k_pad)
    return out[:M, :N]


def masked_matmul(x: jax.Array, w: jax.Array, tile_mask: jax.Array, *,
                  tile_m: int = 8, tile_n: int = 128,
                  bk: int = 512, with_counts: bool = False):
    """``with_counts`` also returns the live-tile count (telemetry)."""
    M, K = x.shape
    N = w.shape[1]
    bk_ = min(bk, K)
    if K % bk_ != 0:
        bk_ = K  # single K step when K is small/odd
    xp = _pad_to(x, tile_m, bk_)
    wp = _pad_to(w, bk_, tile_n)
    nm = xp.shape[0] // tile_m
    nn = wp.shape[1] // tile_n
    mask = tile_mask
    if mask.shape != (nm, nn):
        mask = jnp.pad(mask.astype(jnp.int32),
                       ((0, nm - mask.shape[0]), (0, nn - mask.shape[1])))
    out = _mm.masked_matmul(xp, wp, mask, tile_m=tile_m, tile_n=tile_n,
                            bk=bk_, interpret=_interpret(),
                            return_counts=with_counts)
    if with_counts:
        out, n_live = out
        return out[:M, :N], n_live
    return out[:M, :N]


def gather_matmul(x: jax.Array, w: jax.Array, tile_mask: jax.Array, *,
                  capacity: Optional[int] = None, capacity_frac: float = 1.0,
                  capacity_frac_live=None, tile_m: int = 8, tile_n: int = 128,
                  bk: int = 512, with_counts: bool = False):
    """``capacity``/``capacity_frac`` provision the STATIC slot list;
    ``capacity_frac_live`` (traced scalar fraction, e.g. the serving
    telemetry's per-layer calibrated budget) clamps the realised live
    count under it without recompiling.  ``with_counts`` also returns
    (n_live_total, n_computed) tile counters."""
    M, K = x.shape
    N = w.shape[1]
    bk_ = min(bk, K)
    if K % bk_ != 0:
        bk_ = K
    xp = _pad_to(x, tile_m, bk_)
    wp = _pad_to(w, bk_, tile_n)
    nm = xp.shape[0] // tile_m
    nn = wp.shape[1] // tile_n
    mask = tile_mask
    if mask.shape != (nm, nn):
        mask = jnp.pad(mask.astype(jnp.int32),
                       ((0, nm - mask.shape[0]), (0, nn - mask.shape[1])))
    if capacity is None:
        capacity = max(1, int(capacity_frac * nm * nn))
    capacity = min(capacity, nm * nn)
    cap_live = None
    if capacity_frac_live is not None:
        cap_live = jnp.maximum(1, jnp.ceil(
            jnp.asarray(capacity_frac_live, jnp.float32) * nm * nn)
        ).astype(jnp.int32)
    out = _gm.gather_matmul(xp, wp, mask, capacity=capacity, tile_m=tile_m,
                            tile_n=tile_n, bk=bk_, cap_live=cap_live,
                            interpret=_interpret(),
                            return_counts=with_counts)
    if with_counts:
        out, n_live, n_comp = out
        return out[:M, :N], n_live, n_comp
    return out[:M, :N]


def masked_matmul_kdim(x: jax.Array, w: jax.Array, tile_mask: jax.Array, *,
                       tile_m: int = 8, tile_k: int = 128,
                       bn: int = 128) -> jax.Array:
    """Contraction-masked matmul (MoR down projection): tile_mask[i, k]
    gates the (tile_m x tile_k) block of x rows feeding output row-block
    i — dead FFN hidden tiles (exact zeros) are skipped, never MAC'd."""
    M, K = x.shape
    N = w.shape[1]
    bn_ = min(bn, N)
    xp = _pad_to(x, tile_m, tile_k)
    wp = _pad_to(w, tile_k, bn_)
    nm = xp.shape[0] // tile_m
    nk = xp.shape[1] // tile_k
    mask = tile_mask
    if mask.shape != (nm, nk):
        # padded x blocks are zero -> mark them dead (skip is exact)
        mask = jnp.pad(mask.astype(jnp.int32),
                       ((0, nm - mask.shape[0]), (0, nk - mask.shape[1])))
    out = _mm.masked_matmul_kdim(xp, wp, mask, tile_m=tile_m, tile_k=tile_k,
                                 bn=bn_, interpret=_interpret())
    return out[:M, :N]


def mor_tile_mask(x: jax.Array, w_perm: jax.Array, mor, proxy_neg: jax.Array,
                  *, residual=None, tile_m: int = 8, tile_n: int = 128,
                  bk: int = 512) -> jax.Array:
    """Fused predictor: build the (6, N) coef table from a MoRLayer and
    run the fused kernel.  proxy_neg: (M, N) bool or tri-state int8
    (0/1 = proxy verdict, 2 = forced skip, e.g. MoE capacity-pad rows).
    ``residual``: optional (M, N) per-element ReLU-input residual —
    enabled through the coef table's 6th row (res_scale = 1), so
    kernel-mode masks with a residual input no longer fall back to the
    jnp predictor.

    Counts as ONE predictor evaluation (same counter as the jnp
    ``hybrid_predict`` oracle — the MoRExecutionPlan once-per-forward
    contract is asserted across both paths)."""
    from repro.core.predictor import note_predictor_eval
    note_predictor_eval()
    M, K = x.shape
    N = w_perm.shape[1]
    res_row = (jnp.ones((N,), jnp.float32) if residual is not None
               else jnp.zeros((N,), jnp.float32))
    coef = jnp.stack([mor["m"], mor["b"], mor["bn_scale"], mor["bn_bias"],
                      mor["enable"].astype(jnp.float32), res_row], 0)
    bk_ = min(bk, K)
    if K % bk_ != 0:
        bk_ = K
    xp = _pad_to(x, tile_m, bk_)
    wp = _pad_to(w_perm, bk_, tile_n)
    # K padding adds (-1)*(+1) to every p_bin entry -> pre-compensate b
    k_pad = xp.shape[1] - K
    if k_pad:
        coef = coef.at[1, :].add(coef[0, :] * k_pad)
    n_pad = wp.shape[1] - N
    if n_pad:
        coef = jnp.pad(coef, ((0, 0), (0, n_pad)))
    # padded rows/cols must never mark a tile live (the jnp oracle pads
    # the neuron mask with False): encode them as proxy_neg = 2, the
    # kernel's forced-skip sentinel
    pn = jnp.pad(proxy_neg.astype(jnp.int8),
                 ((0, xp.shape[0] - M), (0, n_pad)), constant_values=2)
    res = None
    if residual is not None:
        res = jnp.pad(residual.astype(jnp.float32),
                      ((0, xp.shape[0] - M), (0, n_pad)))
    mask = _mp.mor_tile_mask(xp, wp, coef, pn, res, tile_m=tile_m,
                             tile_n=tile_n, bk=bk_, interpret=_interpret())
    return mask.astype(bool)

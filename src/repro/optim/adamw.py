"""AdamW with dtype-configurable moments (bf16 moments halve optimizer
HBM at 236B scale), decoupled weight decay, global-norm clipping, and
optional grad accumulation handled by the train driver.

Pure-pytree: opt state mirrors the param tree, so it inherits the params'
sharding (fully sharded ZeRO-style under FSDP rules).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "bfloat16"   # bf16 moments: 4 bytes/param saved
    master_dtype: str = "float32"


def _needs_master(params, cfg: OptConfig) -> bool:
    leaves = jax.tree_util.tree_leaves(params)
    return bool(leaves) and leaves[0].dtype != jnp.dtype(cfg.master_dtype)


def adamw_init(params, cfg: OptConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)  # noqa: E731
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
    }
    if _needs_master(params, cfg):
        # fp32 master copy lives here; params stay bf16 for compute/FSDP
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params)
    return state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: OptConfig,
                 lr_scale: jax.Array | float = 1.0,
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """-> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)
    has_master = "master" in state

    def upd(p, g, mu, nu, master):
        g = g.astype(jnp.float32) * clip
        mu_f = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu_f = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mu_hat = mu_f / bc1
        nu_hat = nu_f / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p_f = (master if master is not None else p).astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            p_f = p_f * (1.0 - lr * cfg.weight_decay)
        p_new = p_f - lr * delta
        return (p_new.astype(p.dtype), mu_f.astype(mdt), nu_f.astype(mdt),
                p_new if master is not None else None)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    flat_ma = (jax.tree_util.tree_leaves(state["master"]) if has_master
               else [None] * len(flat_p))
    out = [upd(p, g, m, n, ma) for p, g, m, n, ma in
           zip(flat_p, flat_g, flat_mu, flat_nu, flat_ma)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = {
        "step": step,
        "mu": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
    }
    if has_master:
        new_state["master"] = jax.tree_util.tree_unflatten(
            treedef, [o[3] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, new_state, metrics

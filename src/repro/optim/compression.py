"""Int8 error-feedback gradient compression for the thin inter-pod link.

``error_feedback_allreduce`` quantises each gradient leaf to int8 with a
per-leaf scale, all-reduces the int8 payload (8x fewer DCI bytes than
fp32, 4x fewer than bf16), dequantises, and keeps the quantisation
residual locally — adding it back into the next step's gradient so the
error is *fed back*, not lost (Seide et al. / 1-bit Adam lineage).

Inside jit the collective is a ``jax.lax.pmean`` over the named pod axis
(usable under shard_map); outside shard_map the caller passes
``axis_name=None`` and supplies its own reduction.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 payload, fp32 scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def error_feedback_allreduce(grads: Any, residuals: Any,
                             axis_name: Optional[str] = "pod",
                             ) -> Tuple[Any, Any]:
    """Quantise (grads + residuals), mean-reduce over ``axis_name``,
    return (reduced fp32 grads, new residuals).

    Residual tree must match grads (zeros on step 0)."""
    def one(g, r):
        g_comp = g.astype(jnp.float32) + r
        q, scale = compress_int8(g_comp)
        deq = decompress_int8(q, scale)
        new_r = g_comp - deq                     # local error feedback
        if axis_name is not None:
            deq = jax.lax.pmean(deq, axis_name)
        return deq, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    red = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return red, res


def init_residuals(grads_or_params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), grads_or_params)

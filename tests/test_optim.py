"""Optimizer + gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import OptConfig, adamw_init, adamw_update
from repro.optim.compression import (compress_int8, decompress_int8,
                                     error_feedback_allreduce,
                                     init_residuals)
from repro.optim.schedules import cosine_schedule


def test_adamw_converges_on_quadratic():
    cfg = OptConfig(lr=0.1, weight_decay=0.0, moment_dtype="float32")
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    target = jnp.asarray([1.0, 1.0, 1.0])
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)  # noqa: E731
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_master_weights_created_for_bf16_params():
    cfg = OptConfig()
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert "master" in state
    assert state["master"]["w"].dtype == jnp.float32
    # fp32 params need no master
    state2 = adamw_init({"w": jnp.zeros((4,), jnp.float32)}, cfg)
    assert "master" not in state2


def test_master_weights_preserve_precision():
    """bf16 params + fp32 master accumulate small updates that bf16 alone
    would lose (the reason masters exist)."""
    cfg = OptConfig(lr=1e-4, weight_decay=0.0, grad_clip=0.0,
                    moment_dtype="float32")
    params = {"w": jnp.ones((1,), jnp.bfloat16) * 256.0}
    state = adamw_init(params, cfg)
    for _ in range(100):
        g = {"w": jnp.ones((1,), jnp.bfloat16)}
        params, state, _ = adamw_update(params, g, state, cfg)
    # master moved even though each bf16 step may round to nothing
    assert float(state["master"]["w"][0]) < 256.0


def test_grad_clip_bounds_update():
    cfg = OptConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0,
                    moment_dtype="float32")
    params = {"w": jnp.zeros((3,))}
    state = adamw_init(params, cfg)
    g = {"w": jnp.asarray([1e6, 1e6, 1e6])}
    _, _, metrics = adamw_update(params, g, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 100, 10)) < 0.2
    assert abs(float(cosine_schedule(10, 100, 10)) - 1.0) < 0.01
    assert float(cosine_schedule(100, 100, 10)) <= 0.11


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)) * 7, jnp.float32)
    q, s = compress_int8(x)
    deq = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_identity():
    """residual_new + dequantised == grad + residual_old, exactly —
    no information is lost across steps (error feedback invariant)."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    r = init_residuals(g)
    red, r_new = error_feedback_allreduce(g, r, axis_name=None)
    np.testing.assert_allclose(np.asarray(red["w"] + r_new["w"]),
                               np.asarray(g["w"] + r["w"]), rtol=1e-6,
                               atol=1e-6)


def test_error_feedback_converges_to_true_mean():
    """Accumulated compressed updates converge to the uncompressed sum."""
    rng = np.random.default_rng(2)
    true_sum = np.zeros(32)
    sent_sum = np.zeros(32)
    r = init_residuals({"w": jnp.zeros((32,))})
    for t in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
        red, r = error_feedback_allreduce(g, r, axis_name=None)
        true_sum += np.asarray(g["w"])
        sent_sum += np.asarray(red["w"])
    resid = np.asarray(r["w"])
    np.testing.assert_allclose(sent_sum + resid, true_sum, atol=1e-3)

"""Distribution layer tests: sharding rules, straggler policy, elastic
plans, overlapped collectives (multi-device via subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.fault_tolerance import (ElasticPlan, StragglerConfig,
                                               StragglerMonitor)
from repro.distributed.sharding_rules import param_sharding
from repro.launch.mesh import make_host_mesh
from repro.models import param_shapes


def test_param_sharding_divisibility_guard():
    """Non-divisible dims must fall back to replicated, never crash."""
    cfg = get_config("mixtral-8x7b")   # 8 experts, 16-way model axis
    mesh = make_host_mesh(1)
    p_sds = param_shapes(cfg)
    sh = param_sharding(p_sds, mesh, moe_mode=cfg.expert_sharding)
    # just materialising the full tree without error is the test on 1 dev;
    # every leaf must be a NamedSharding
    leaves = jax.tree_util.tree_leaves(
        sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves) > 10


def test_straggler_monitor_flags_slow_host():
    cfg = StragglerConfig(window=10, ratio_threshold=1.5, patience=3)
    mon = StragglerMonitor(n_hosts=4, cfg=cfg)
    actions = []
    for step in range(10):
        times = {h: 1.0 for h in range(4)}
        times[2] = 2.5   # persistent straggler
        actions += mon.record_step(times)
    assert ("rebalance", 2) in actions
    # share shifted away from the straggler
    assert mon.microbatch_share[2] < 0.25
    assert abs(sum(mon.microbatch_share.values()) - 1.0) < 1e-9


def test_straggler_monitor_ignores_transients():
    mon = StragglerMonitor(n_hosts=2, cfg=StragglerConfig(patience=5))
    acts = mon.record_step({0: 1.0, 1: 9.0})   # single spike
    acts += mon.record_step({0: 1.0, 1: 1.0})
    assert acts == []


def test_elastic_plan_keeps_tp_fixed():
    p = ElasticPlan.plan(n_devices=256, model_parallel=16, global_batch=256)
    assert p.mesh_shape == (16, 16)
    # lose a host: 240 devices
    p2 = ElasticPlan.plan(n_devices=240, model_parallel=16,
                          global_batch=256)
    assert p2.mesh_shape == (15, 16)
    assert p2.global_batch % 15 == 0
    with pytest.raises(ValueError):
        ElasticPlan.plan(n_devices=250, model_parallel=16, global_batch=256)


_OVERLAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import ag_matmul_overlapped, psum_scatter_matmul
mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
got = ag_matmul_overlapped(x, w, mesh, axis="model")
np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-4, atol=1e-4)
got2 = psum_scatter_matmul(x, w, mesh, axis="model")
np.testing.assert_allclose(np.asarray(got2), np.asarray(x @ w), rtol=1e-4, atol=1e-4)
# the overlapped form must contain collective-permute, not one big all-gather
hlo = jax.jit(lambda a, b: ag_matmul_overlapped(a, b, mesh)).lower(x, w).compile().as_text()
assert "collective-permute" in hlo, "expected ring ppermute schedule"
print("OVERLAP_OK")
"""


def test_overlapped_ag_matmul_multidevice():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _OVERLAP_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.getcwd())
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OVERLAP_OK" in r.stdout


def test_overlapped_ag_matmul_single_device():
    mesh = make_host_mesh(1)
    from repro.distributed.collectives import ag_matmul_overlapped
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    got = ag_matmul_overlapped(x, w, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)

"""Execution-mode consistency for the MoR FFN (dense/exact/tiled/kernel)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoRConfig
from repro.core import (build_mor_layer, cluster_layer, finalize_regression,
                        init_accumulator, update_accumulator)
from repro.core.masked_ffn import mor_relu_matmul, mor_ffn_apply
from repro.core.predictor import binary_preact

RNG = np.random.default_rng(4)


@pytest.fixture(scope="module")
def calibrated():
    K, N, T = 96, 256, 1024
    base = RNG.normal(size=(K, 32))
    w = np.stack([base[:, RNG.integers(32)] + 0.3 * RNG.normal(size=K)
                  for _ in range(N)], 1).astype(np.float32)
    x = RNG.normal(size=(T, K)).astype(np.float32)
    acc = init_accumulator(N)
    xj, wj = jnp.asarray(x[:768]), jnp.asarray(w)
    acc = update_accumulator(acc, binary_preact(xj, wj), xj @ wj)
    m, b, c = finalize_regression(acc)
    cl = cluster_layer(w, 85.0)
    mor = build_mor_layer(np.asarray(m), np.asarray(b), np.asarray(c), cl,
                          MoRConfig(corr_threshold=0.5))
    w_perm = wj[:, mor["perm"]]
    xe = jnp.asarray(x[768:])
    return xe, w_perm, mor


def test_exact_zeroes_only_skipped(calibrated):
    xe, w_perm, mor = calibrated
    y_exact, st = mor_relu_matmul(xe, w_perm, mor, activation="relu",
                                  mode="exact")
    y_dense, _ = mor_relu_matmul(xe, w_perm, None, activation="relu",
                                 mode="dense")
    diff = np.asarray(y_exact) != np.asarray(y_dense)
    # wherever outputs differ, the exact-mode output is zero (a skip)
    assert np.all(np.asarray(y_exact)[diff] == 0.0)
    assert 0.0 < float(st["frac_computed"]) <= 1.0


def test_tiled_equals_kernel(calibrated):
    xe, w_perm, mor = calibrated
    y_t, st_t = mor_relu_matmul(xe, w_perm, mor, activation="relu",
                                mode="tiled", tile_m=8, tile_n=128)
    y_k, st_k = mor_relu_matmul(xe, w_perm, mor, activation="relu",
                                mode="kernel", tile_m=8, tile_n=128)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_k),
                               rtol=2e-4, atol=2e-3)
    assert float(st_t["frac_tiles_live"]) == float(st_k["frac_tiles_live"])


def test_tiled_is_superset_of_exact(calibrated):
    """Tile granularity can only compute MORE neurons than exact mode
    (a tile is live if any neuron in it is live)."""
    xe, w_perm, mor = calibrated
    _, st_e = mor_relu_matmul(xe, w_perm, mor, activation="relu",
                              mode="exact")
    _, st_t = mor_relu_matmul(xe, w_perm, mor, activation="relu",
                              mode="tiled")
    assert float(st_t["frac_tiles_live"]) >= float(st_e["frac_computed"]) - 1e-6


def test_relu2_activation(calibrated):
    xe, w_perm, mor = calibrated
    y, _ = mor_relu_matmul(xe, w_perm, mor, activation="relu2", mode="exact")
    assert np.all(np.asarray(y) >= 0.0)


def test_glu_ffn_applies_same_mask_to_up(calibrated):
    xe, w_perm, mor = calibrated
    K, N = w_perm.shape
    w_up = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    w_down = jnp.asarray(RNG.normal(size=(N, K)), jnp.float32)
    y, st = mor_ffn_apply(xe, w_up, w_down, mor, activation="relu",
                          mode="tiled", w_gate=w_perm)
    y_d, _ = mor_ffn_apply(xe, w_up, w_down, None, activation="relu",
                           mode="dense", w_gate=w_perm)
    assert y.shape == y_d.shape
    assert np.isfinite(np.asarray(y)).all()


def test_bad_activation_raises():
    x = jnp.ones((4, 8))
    w = jnp.ones((8, 16))
    from repro.core.predictor import make_identity_layer
    with pytest.raises(ValueError):
        mor_relu_matmul(x, w, make_identity_layer(16), activation="silu",
                        mode="exact")


def test_expert_level_mor_exact_mode():
    """MoR inside routed experts (DESIGN §Arch-applicability): a vmapped
    hybrid predictor zeroes predicted-dead expert neurons; router-dropped
    experts are already the coarse zero prediction."""
    import jax
    from repro.configs import get_config, reduce_config
    from repro.core.predictor import make_identity_layer
    from repro.models.layers import moe

    cfg = reduce_config(get_config("mixtral-8x7b")).replace(
        n_shared_experts=0, capacity_factor=4.0)
    key = jax.random.PRNGKey(0)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts

    one = make_identity_layer(f)
    em = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (E,) + a.shape), one)
    y_off, _ = moe.moe_apply(params, cfg, x)
    # nothing enabled -> identical to dense
    y_id, _ = moe.moe_apply(params, cfg, x, mor={"experts": em},
                            mor_mode="exact")
    np.testing.assert_allclose(np.asarray(y_off), np.asarray(y_id),
                               rtol=1e-5, atol=1e-5)
    # force the binary rookie to predict zero everywhere it can
    # (m=0, b=-1 -> p_hat < 0; enable all; proxy sentinel -1 = binary-only)
    em_on = dict(em)
    em_on["enable"] = jnp.ones((E, f), bool)
    em_on["m"] = jnp.zeros((E, f), jnp.float32)
    em_on["b"] = jnp.full((E, f), -1.0, jnp.float32)
    em_on["is_proxy"] = jnp.zeros((E, f), bool)
    em_on["proxy_slot"] = jnp.full((E, f), -1, jnp.int32)
    y_all_skip, _ = moe.moe_apply(params, cfg, x, mor={"experts": em_on},
                                  mor_mode="exact")
    # every gate neuron predicted zero -> relufied GLU output is zero
    assert float(jnp.max(jnp.abs(y_all_skip))) < 1e-6

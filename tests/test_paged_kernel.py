"""Differential tests for the fused paged flash-decode kernel (PR 6):
``gqa_paged_flash`` / ``mla_paged_flash`` vs the dense ``ref`` oracles
(null-page and foreign-page grid skips, sliding windows, fully-masked
slots, the partial flash stats merged shard-style), the engine-level
kernel-vs-jnp token identity across the 5-family matrix (ragged prefill
chunks, sliding-window ring wrap, prefix cache on/off — the existing
serving matrix ties the jnp path to slotted and teacher-forced, so
equality here closes the chain), and the 4-shard subprocess run with
the kernel forced on (partial stats + flash_merge branch)."""
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.kernels import paged_attention as pk
from repro.kernels import ref
from repro.models import get_model
from repro.serving import Engine


def _reduced(arch):
    cfg = reduce_config(get_config(arch))
    if arch == "deepseek-v2-236b":
        cfg = cfg.replace(family="dense", n_experts=0, top_k=0,
                          first_k_dense=0, n_shared_experts=0)
    return cfg


# -- synthetic paged rings --------------------------------------------------

def _gqa_case(seed, B=3, C=3, n_blocks=4, page=4, hkv=2, G=2, D=8,
              n_pages=11):
    """Random pools + a block table exercising every grid-skip case:
    null pages (global id 0), partially-written pages (-1 tags), and
    slot B-1 entirely null (a fully-masked query row)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, C, hkv * G, D), jnp.float32)
    kpool = jax.random.normal(ks[1], (n_pages, page, hkv, D), jnp.float32)
    vpool = jax.random.normal(ks[2], (n_pages, page, hkv, D), jnp.float32)
    ring = n_blocks * page
    ppool = jax.random.randint(ks[3], (n_pages, page), -1, ring,
                               dtype=jnp.int32)
    ppool = ppool.at[0].set(-1)          # the null page is never written
    tbl = jax.random.randint(ks[4], (B, n_blocks), 0, n_pages,
                             dtype=jnp.int32)
    tbl = tbl.at[B - 1].set(0)           # fully-masked slot
    qpos = jnp.arange(ring // 2, ring // 2 + B * C,
                      dtype=jnp.int32).reshape(B, C)
    return q, kpool, vpool, ppool, tbl, qpos


def _mla_case(seed, B=3, C=2, n_blocks=4, page=4, h=3, kr=8, rd=4,
              n_pages=11):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q_lat = jax.random.normal(ks[0], (B, C, h, kr), jnp.float32)
    q_pe = jax.random.normal(ks[1], (B, C, h, rd), jnp.float32)
    ck = jax.random.normal(ks[2], (n_pages, page, kr), jnp.float32)
    cpe = jax.random.normal(ks[3], (n_pages, page, rd), jnp.float32)
    ring = n_blocks * page
    cp = jax.random.randint(ks[4], (n_pages, page), -1, ring,
                            dtype=jnp.int32)
    cp = cp.at[0].set(-1)
    tbl = jax.random.randint(ks[5], (B, n_blocks), 0, n_pages,
                             dtype=jnp.int32)
    tbl = tbl.at[B - 1].set(0)
    qpos = jnp.arange(ring // 2, ring // 2 + B * C,
                      dtype=jnp.int32).reshape(B, C)
    return q_lat, q_pe, ck, cpe, cp, tbl, qpos


def _merge_partials(parts):
    """Exact local merge of (m, l, acc) flash stats — the single-device
    mirror of ``collectives.flash_merge``, with the same fully-masked
    liveness guard (m stays at NEG_INF only when no page contributed)."""
    m = functools.reduce(jnp.maximum, [p[0] for p in parts])
    l = sum(pl * jnp.exp(pm - m) for pm, pl, _ in parts)
    acc = sum(pa * jnp.exp(pm - m)[..., None] for pm, _, pa in parts)
    live = m > -1e29
    o = acc / jnp.where(live, l, 1.0)[..., None]
    return jnp.where(live[..., None], o, 0.0)


# -- kernel vs oracle (interpret mode) --------------------------------------

@pytest.mark.parametrize("window", [0, 7])
def test_gqa_kernel_matches_ref(window):
    """Fused GQA kernel == dense oracle over a table mixing live, null
    and partially-written pages, plus one fully-masked slot (emits
    zeros, not NaNs), causal and sliding-window."""
    q, kp, vp, pp, tbl, qpos = _gqa_case(0)
    out = pk.gqa_paged_flash(q, kp, vp, pp, tbl, qpos, window=window,
                             interpret=True)
    want = ref.gqa_paged_ref(q, kp, vp, pp, tbl, qpos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    assert np.all(np.isfinite(np.asarray(out)))
    assert np.allclose(np.asarray(out)[-1], 0.0)   # fully-masked slot


def test_gqa_kernel_foreign_pages():
    """With a shard window [lo, lo + n_local) the kernel must skip
    foreign pages exactly like the oracle's masked gather."""
    q, kp, vp, pp, tbl, qpos = _gqa_case(1)
    lo, n_local = 4, 3
    out = pk.gqa_paged_flash(q, kp[lo:lo + n_local], vp[lo:lo + n_local],
                             pp[lo:lo + n_local], tbl, qpos,
                             lo=lo, n_local=n_local, interpret=True)
    want = ref.gqa_paged_ref(q, kp[lo:lo + n_local], vp[lo:lo + n_local],
                             pp[lo:lo + n_local], tbl, qpos,
                             lo=lo, n_local=n_local)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_gqa_partial_stats_merge_to_full():
    """Partial (m, l, acc) stats from two disjoint shard windows, merged
    flash_merge-style, equal the unsharded kernel AND oracle outputs —
    the correctness core of the sharded kernel decode path."""
    q, kp, vp, pp, tbl, qpos = _gqa_case(2)
    n_pages = kp.shape[0]
    parts = []
    for lo, hi in [(1, 6), (6, n_pages)]:
        parts.append(pk.gqa_paged_flash(
            q, kp[lo:hi], vp[lo:hi], pp[lo:hi], tbl, qpos,
            lo=lo, n_local=hi - lo, partial=True, interpret=True))
    merged = _merge_partials(parts)            # (B, hkv, G, C, Dv)
    B, C, H, D = q.shape
    got = merged.transpose(0, 3, 1, 2, 4).reshape(B, C, H, -1)
    full = pk.gqa_paged_flash(q, kp, vp, pp, tbl, qpos, interpret=True)
    want = ref.gqa_paged_ref(q, kp, vp, pp, tbl, qpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_mla_kernel_matches_ref():
    ql, qe, ck, cpe, cp, tbl, qpos = _mla_case(3)
    scale = (ql.shape[-1] + qe.shape[-1]) ** -0.5
    out = pk.mla_paged_flash(ql, qe, ck, cpe, cp, tbl, qpos, scale=scale,
                             interpret=True)
    want = ref.mla_paged_ref(ql, qe, ck, cpe, cp, tbl, qpos, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    assert np.all(np.isfinite(np.asarray(out)))
    assert np.allclose(np.asarray(out)[-1], 0.0)


def test_mla_partial_stats_merge_to_full():
    ql, qe, ck, cpe, cp, tbl, qpos = _mla_case(4)
    scale = (ql.shape[-1] + qe.shape[-1]) ** -0.5
    n_pages = ck.shape[0]
    parts = []
    for lo, hi in [(1, 6), (6, n_pages)]:
        parts.append(pk.mla_paged_flash(
            ql, qe, ck[lo:hi], cpe[lo:hi], cp[lo:hi], tbl, qpos,
            scale=scale, lo=lo, n_local=hi - lo, partial=True,
            interpret=True))
    merged = _merge_partials(parts)            # (B, h, C, kr)
    got = merged.transpose(0, 2, 1, 3)
    want = ref.mla_paged_ref(ql, qe, ck, cpe, cp, tbl, qpos, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# -- engine-level kernel == jnp token identity ------------------------------

_TRACE_KEY = {"deepseek-v2-236b": "mla"}


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen2-7b",
                                  "deepseek-v2-236b", "mixtral-8x7b",
                                  "zamba2-7b"])
def test_engine_kernel_matches_jnp(arch, monkeypatch):
    """REPRO_PAGED_KERNEL=1 must be token-identical to the jnp gather
    fallback on ragged prompts (3-13 toks vs chunk 8 → partial final
    chunks); mixtral keeps sliding_window=16 and generates past it, so
    its ring wraps through the kernel's window mask.  The jnp path is
    already tied to slotted and teacher-forced by test_serving, so this
    closes kernel == jnp == slotted == teacher-forced."""
    cfg = _reduced(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size,
                          size=int(rng.integers(3, 14))),
             int(rng.integers(3, 6))) for _ in range(3)]
    if arch == "mixtral-8x7b":          # force a ring wrap past window=16
        reqs[0] = (rng.integers(0, cfg.vocab_size, size=22), 6)
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "0")
    res_jnp = Engine(cfg, params, n_slots=2, max_len=64,
                     layout="paged").run(list(reqs))
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "1")
    pk.reset_kernel_traces()
    res_k = Engine(cfg, params, n_slots=2, max_len=64,
                   layout="paged").run(list(reqs))
    assert res_k == res_jnp, f"{arch}: kernel tokens diverge from jnp"
    key = _TRACE_KEY.get(arch, "gqa")
    assert pk.kernel_traces()[key] > 0, \
        f"{arch}: kernel path never traced ({pk.kernel_traces()})"


def test_engine_kernel_prefix_cache_on_off(monkeypatch):
    """Kernel path with the shared-prefix dedup engaged (warm pool must
    actually skip chunks) == kernel path cold == jnp cold."""
    cfg = _reduced("granite-3-2b")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    reqs = [(np.concatenate([prefix,
                             rng.integers(0, cfg.vocab_size, size=4)]), 4)
            for _ in range(3)]
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "0")
    res_jnp = Engine(cfg, params, n_slots=2, max_len=64,
                     prefix_cache=False).run(list(reqs))
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "1")
    cold = Engine(cfg, params, n_slots=2, max_len=64,
                  prefix_cache=False).run(list(reqs))
    warm_eng = Engine(cfg, params, n_slots=2, max_len=64)
    warm = warm_eng.run(list(reqs))
    assert cold == res_jnp
    assert warm == res_jnp
    assert warm_eng._prefix_counters()["chunks_skipped"] > 0


# -- 4-shard subprocess with the kernel forced on ---------------------------

_SHARDED_KERNEL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["REPRO_PAGED_KERNEL"] = "1"
import jax, numpy as np
from repro.configs import get_config, reduce_config
from repro.models import get_model
from repro.serving import Engine
from repro.launch.mesh import make_page_mesh
from repro.kernels import paged_attention as pk

cfg = reduce_config(get_config("granite-3-2b"))
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
reqs = [(rng.integers(0, cfg.vocab_size, size=10), 4) for _ in range(2)]
res_p = Engine(cfg, params, n_slots=2, max_len=64,
               layout="paged").run(list(reqs))
pk.reset_kernel_traces()
mesh = make_page_mesh(4)
res_m = Engine(cfg, params, n_slots=2, max_len=64,
               layout="paged-sharded", mesh=mesh).run(list(reqs))
assert res_m == res_p, "sharded kernel tokens diverge from single-device"
assert pk.kernel_traces()["gqa"] > 0, pk.kernel_traces()
print("SHARDED_KERNEL_OK")
"""


def test_paged_sharded_kernel_multidevice():
    """The sharded decode path with REPRO_PAGED_KERNEL=1 (partial flash
    stats + one flash_merge per layer) is token-identical to the
    single-device kernel engine, on 4 forced host devices in a
    subprocess (jax device count locks at first init).  Kept to one
    small GQA run: interpret-mode Pallas serialises the page grid."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__),
                                       "..", "src"))
    r = subprocess.run([sys.executable, "-c", _SHARDED_KERNEL_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=1800)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARDED_KERNEL_OK" in r.stdout

"""Checkpoint fault-tolerance tests: atomic commit, keep-k, async save,
crash-resume determinism, elastic re-mesh restore (subprocess with fake
device counts, since device count locks at jax init)."""
import json
import os
import shutil
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.serialization import load_pytree, save_pytree


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "x"), {"step": 7})
    restored, extra = load_pytree(t, str(tmp_path / "x"))
    assert extra["step"] == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        t, restored)


def test_commit_protocol_ignores_partial_writes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(10, _tree(), block=True)
    # simulate a crashed writer: step dir without COMMIT
    bad = tmp_path / "step_00000020"
    bad.mkdir()
    (bad / "state.json").write_text("{}")
    assert mgr.latest_step() == 10


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), block=True)
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000003", "step_00000004"]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree(5)
    mgr.save(42, t)
    mgr.wait()
    restored, extra = mgr.restore(t)
    assert extra["step"] == 42
    np.testing.assert_array_equal(np.asarray(t["a"]),
                                  np.asarray(restored["a"]))


def test_resume_determinism(tmp_path):
    """Train 2x20 steps with a checkpoint/restore in the middle == 40
    straight steps (same data stream, same final loss)."""
    from repro.launch.train import main as train_main
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    args = ["--arch", "granite-3-2b", "--reduced", "--batch", "4",
            "--seq", "32", "--log-every", "100"]
    r_straight = train_main(args + ["--steps", "24", "--ckpt-dir", d1,
                                    "--save-every", "100"])
    # interrupted run: 12 steps, then resume to 24
    train_main(args + ["--steps", "12", "--ckpt-dir", d2,
                       "--save-every", "12"])
    r_resumed = train_main(args + ["--steps", "24", "--ckpt-dir", d2,
                                   "--save-every", "100"])
    assert abs(r_straight["loss_last"] - r_resumed["loss_last"]) < 1e-3


_ELASTIC_SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import CheckpointManager
mesh = jax.make_mesh((%d, %d), ("data", "model"))
tmpl = {"w": jnp.zeros((16, 32), jnp.float32)}
sh = {"w": NamedSharding(mesh, P("data", "model"))}
mgr = CheckpointManager(sys.argv[1])
if sys.argv[2] == "save":
    w = jnp.arange(16*32, dtype=jnp.float32).reshape(16, 32)
    w = jax.device_put(w, sh["w"])
    mgr.save(1, {"w": w}, block=True)
else:
    st, _ = mgr.restore(tmpl, shardings=sh)
    assert st["w"].sharding.is_equivalent_to(sh["w"], 2)
    assert float(st["w"].sum()) == float(sum(range(16*32)))
    print("RESTORED_OK", len(jax.devices()))
"""


@pytest.mark.parametrize("save_mesh,load_mesh", [((4, 2), (2, 2)),
                                                 ((2, 2), (4, 2))])
def test_elastic_restore_across_device_counts(tmp_path, save_mesh,
                                              load_mesh):
    """The same checkpoint restores onto meshes with different device
    counts (8 -> 4 and 4 -> 8): the npz payload is mesh-agnostic and
    restore re-places under the new mesh's shardings."""
    env = dict(os.environ, PYTHONPATH="src")
    def run(n, shape, mode):
        code = _ELASTIC_SCRIPT % (n, shape[0], shape[1])
        return subprocess.run(
            [sys.executable, "-c", code, str(tmp_path), mode],
            capture_output=True, text=True, env=env, cwd=os.getcwd())
    r = run(save_mesh[0] * save_mesh[1], save_mesh, "save")
    assert r.returncode == 0, r.stderr[-2000:]
    r = run(load_mesh[0] * load_mesh[1], load_mesh, "load")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "RESTORED_OK" in r.stdout

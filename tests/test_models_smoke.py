"""Per-architecture smoke tests: reduced same-family config, one forward
(+ decode where the family has one), output shapes + finite values.
The FULL configs are exercised only by the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import get_model, supports_long_context

LM_ARCHS = ["qwen1.5-110b", "granite-20b", "granite-3-2b", "qwen2-7b",
            "deepseek-v2-236b", "mixtral-8x7b", "rwkv6-3b",
            "phi-3-vision-4.2b", "zamba2-7b", "hubert-xlarge"]


def _batch(cfg, key, B=2, S=32):
    if cfg.frontend == "vision_stub":
        return {"tokens": jnp.zeros((B, S), jnp.int32),
                "patch_embeds": jnp.zeros(
                    (B, cfg.frontend_tokens, cfg.d_model), cfg.jdtype)}
    if cfg.frontend == "audio_stub":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model),
                                            cfg.jdtype)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_smoke(arch):
    cfg = reduce_config(get_config(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = api.forward(params, cfg, batch)
    B = 2
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    if cfg.family == "moe":
        assert "lb_loss" in aux


@pytest.mark.parametrize("arch", [a for a in LM_ARCHS
                                  if a != "hubert-xlarge"])
def test_decode_smoke(arch):
    cfg = reduce_config(get_config(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    cache = api.cache_init(cfg, 2, 64, cfg.jdtype)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == 3


def test_encoder_only_has_no_decode():
    cfg = reduce_config(get_config("hubert-xlarge"))
    api = get_model(cfg)
    assert not api.has_decode


def test_long_context_support_flags():
    assert supports_long_context(get_config("rwkv6-3b"))
    assert supports_long_context(get_config("zamba2-7b"))
    assert supports_long_context(get_config("mixtral-8x7b"))  # SWA
    assert not supports_long_context(get_config("qwen2-7b"))
    assert not supports_long_context(get_config("deepseek-v2-236b"))


def test_decode_matches_forward_rwkv():
    """Recurrent decode must agree with the parallel forward (same model,
    same tokens) — validates the wkv state recurrence."""
    cfg = reduce_config(get_config("rwkv6-3b"))
    api = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init(key, cfg)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    logits_par, _ = api.forward(params, cfg, {"tokens": toks})
    cache = api.cache_init(cfg, 1, 16, cfg.jdtype)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, cfg, toks[:, t:t + 1], cache)
        outs.append(lg)
    logits_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(logits_par, np.float32),
                               np.asarray(logits_seq, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_gqa():
    """KV-cache decode agrees with teacher-forced forward (GQA + RoPE)."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = api.init(key, cfg)
    toks = jax.random.randint(key, (2, 6), 0, cfg.vocab_size)
    logits_par, _ = api.forward(params, cfg, {"tokens": toks})
    cache = api.cache_init(cfg, 2, 8, cfg.jdtype)
    outs = []
    for t in range(6):
        lg, cache = api.decode_step(params, cfg, toks[:, t:t + 1], cache)
        outs.append(lg)
    logits_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(logits_par, np.float32),
                               np.asarray(logits_seq, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_mamba_decode_matches_forward():
    """Mamba2 chunked SSD forward == recurrent decode (zamba2 backbone)."""
    from repro.models.layers import ssm
    cfg = reduce_config(get_config("zamba2-7b"))
    key = jax.random.PRNGKey(3)
    params = ssm.mamba2_init(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32) * 0.5
    y_par = ssm.mamba2_forward(params, cfg, x)
    cache = ssm.mamba2_cache_init(cfg, 2, jnp.float32)
    outs = []
    for t in range(8):
        y, cache = ssm.mamba2_decode(params, cfg, x[:, t:t + 1], cache)
        outs.append(y[:, 0])
    y_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=5e-3, atol=5e-3)


def test_swa_banded_equals_dense_mask():
    """Banded sliding-window attention == full attention w/ window mask."""
    from repro.models.layers import attention as attn
    cfg = reduce_config(get_config("mixtral-8x7b")).replace(
        sliding_window=16)
    key = jax.random.PRNGKey(4)
    B, S, H, D = 1, 64, 4, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(5), (B, S, 2, D))
    v = jax.random.normal(jax.random.PRNGKey(6), (B, S, 2, D))
    pos = jnp.arange(S)
    # banded path (chunk > window forces the dynamic-slice route)
    import repro.models.layers.attention as A
    old = A._CHUNK
    A._CHUNK = 32
    try:
        got = A._banded(q, k, v, pos, pos, 16)
    finally:
        A._CHUNK = old
    bias = A._mask_bias(pos, pos, True, 16)
    want = A._sdpa(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_equals_sdpa():
    from repro.models.layers import attention as A
    key = jax.random.PRNGKey(7)
    B, S, H, D = 2, 96, 4, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(8), (B, S, 2, D))
    v = jax.random.normal(jax.random.PRNGKey(9), (B, S, 2, D))
    pos = jnp.arange(S)
    old = A._CHUNK
    A._CHUNK = 32
    try:
        got = A._flash(q, k, v, pos, pos, True, 0)
    finally:
        A._CHUNK = old
    want = A._sdpa(q, k, v, A._mask_bias(pos, pos, True, 0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v2-236b",
                                  "mixtral-8x7b"])
def test_batched_prefill_matches_forward(arch):
    """One-step batched prefill (GQA / MLA / MoE) reproduces the
    teacher-forced forward logits exactly, and a decode step continues
    consistently from the prefilled cache."""
    cfg = reduce_config(get_config(arch))
    api = get_model(cfg)
    assert api.prefill is not None
    params = api.init(jax.random.PRNGKey(0), cfg)
    B, P = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P + 1), 0,
                              cfg.vocab_size)
    logits_f, _ = api.forward(params, cfg, {"tokens": toks[:, :P]})
    cache = api.cache_init(cfg, B, P + 8, jnp.float32)
    lg_p, cache = api.prefill(params, cfg, toks[:, :P], cache)
    np.testing.assert_allclose(np.asarray(lg_p),
                               np.asarray(logits_f[:, -1, :]),
                               rtol=2e-4, atol=2e-4)
    lg_d, _ = api.decode_step(params, cfg, toks[:, P:P + 1], cache)
    if cfg.family == "moe":
        # expert-capacity dropping depends on T (tokens compete for
        # capacity across the whole forward batch), so teacher-forced
        # forward and single-token decode legitimately diverge
        assert bool(jnp.all(jnp.isfinite(lg_d)))
    else:
        logits_f2, _ = api.forward(params, cfg, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(lg_d),
                                   np.asarray(logits_f2[:, -1, :]),
                                   rtol=2e-4, atol=2e-4)

"""Predictor-quality observability tests (shadow-oracle scoring +
drift detection): exact false-skip/false-keep tile counts against a
host-side numpy oracle, scored-mode bitwise identity with the tiled
path, engine token identity shadow-on vs shadow-off across all three
architecture families and both cache layouts, drift detector unit
behaviour (EWMA two-flush crossing, Page-Hinkley, rebase semantics)
and engine-level firing on an injected coefficient perturbation only,
no extra device syncs or dispatches from the scoring machinery, the
Prometheus label-escaping fix, the empty-histogram quantile fix, and
the live metrics endpoint."""
import json
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core.executor import MoRExecutionPlan
from repro.models import get_model
from repro.obs import (DriftDetector, MetricsRegistry, MetricsServer,
                       Observability, inject_coefficient_drift)
from repro.serving import Engine


# -- numpy oracle for the shadow scores ------------------------------------

def _np_tiles(mask, tile_m, tile_n):
    M, N = mask.shape
    pm, pn = (-M) % tile_m, (-N) % tile_n
    p = np.pad(mask, ((0, pm), (0, pn)))
    t = p.reshape((M + pm) // tile_m, tile_m, (N + pn) // tile_n, tile_n)
    return t.any(axis=(1, 3))


def _np_shadow_oracle(x, w, mor, tile_m, tile_n):
    """Host-side reimplementation of hybrid_predict + the shadow tile
    scoring, in numpy float32.  With quantised inputs (all intermediate
    values dyadic rationals well inside float32's exact-integer range)
    every comparison is exact, so the counts must match the jitted
    plan's BITWISE."""
    x = np.asarray(x, np.float32)
    w = np.asarray(w, np.float32)
    m, b = np.asarray(mor["m"]), np.asarray(mor["b"])
    bs, bb = np.asarray(mor["bn_scale"]), np.asarray(mor["bn_bias"])
    pslot = np.asarray(mor["proxy_slot"])
    enable = np.asarray(mor["enable"])
    is_proxy = np.asarray(mor["is_proxy"])
    # proxy rookie: evaluate the assigned proxy column at base precision
    slot = np.maximum(pslot, 0)
    proxy_relu_in = (x @ w[:, slot]) * bs[slot] + bb[slot]
    proxy_zero = (proxy_relu_in < 0.0) | (pslot < 0)
    # binary rookie: sign dot -> fitted line -> BN fold
    xs = np.where(x > 0, 1, -1).astype(np.int32)
    ws = np.where(w >= 0, 1, -1).astype(np.int32)
    p_hat = (m * (xs @ ws).astype(np.float32) + b) * bs + bb
    skip = proxy_zero & (p_hat < 0.0) & enable & ~is_proxy
    computed = ~skip
    kept = _np_tiles(computed, tile_m, tile_n)
    truth = ((x @ w) * bs + bb) > 0.0
    truth_tiles = _np_tiles(truth, tile_m, tile_n)
    return {
        "shadow_tiles": int(truth_tiles.size),
        "shadow_false_skip": int((truth_tiles & ~kept).sum()),
        "shadow_false_keep": int((kept & ~truth_tiles).sum()),
        "shadow_truth_live": int(truth_tiles.sum()),
        "shadow_sign_agree": float((computed == truth).mean()),
    }


def _quantised_case(seed=0, T=24, K=32, N=128):
    """Seeded (x, w, mor) whose every intermediate (matmuls, BN folds,
    fitted lines) is an exactly-representable float32, so numpy and XLA
    agree bitwise regardless of accumulation order.  Two engineered
    column spans guarantee both error kinds at TILE granularity
    (tile_n=16): columns 32..63 carry broken fitted lines (predictor
    skips whole tile columns that are truly live -> false skips),
    columns 96..127 are disabled with a hard-negative BN bias (always
    computed, truth all-dead -> false keeps)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-2, 3, size=(T, K)).astype(np.float32)
    w = rng.integers(-2, 3, size=(K, N)).astype(np.float32)
    m = (rng.integers(-8, 9, size=N) / 4.0).astype(np.float32)
    b = (rng.integers(-8, 9, size=N) / 4.0 + 0.125).astype(np.float32)
    bn_scale = rng.choice([0.5, 1.0, 2.0], size=N).astype(np.float32)
    # odd multiples of 1/4: pre*bn_scale is a multiple of 1/2, so
    # pre_bn is never exactly zero -> the > 0 truth test has no ties
    bn_bias = ((2 * rng.integers(-4, 4, size=N) + 1) / 4.0
               ).astype(np.float32)
    proxy_slot = rng.integers(-1, N, size=N).astype(np.int32)
    is_proxy = np.zeros(N, bool)
    is_proxy[np.unique(np.maximum(proxy_slot, 0))[:N // 8]] = True
    enable = rng.random(N) < 0.8
    # false-skip span: binary rookie always says zero, no proxy veto,
    # force-enabled -> the predictor kills these tile columns outright
    broken = np.arange(32, 64)
    b[broken] = -100.0
    m[broken] = 0.0
    proxy_slot[broken] = -1
    enable[broken] = True
    is_proxy[broken] = False
    # false-keep span: disabled (always computed) but truly all-dead
    dead = np.arange(96, 128)
    enable[dead] = False
    is_proxy[dead] = False
    bn_bias[dead] = -1000.25
    mor = {
        "m": jnp.asarray(m), "b": jnp.asarray(b),
        "enable": jnp.asarray(enable),
        "proxy_slot": jnp.asarray(proxy_slot),
        "is_proxy": jnp.asarray(is_proxy),
        "perm": jnp.arange(N, dtype=jnp.int32),
        "inv_perm": jnp.arange(N, dtype=jnp.int32),
        "bn_scale": jnp.asarray(bn_scale),
        "bn_bias": jnp.asarray(bn_bias),
    }
    return x, w, mor


@pytest.mark.parametrize("mode", ["shadow", "scored"])
@pytest.mark.parametrize("seed", [0, 1])
def test_shadow_counts_match_numpy_oracle(mode, seed):
    x, w, mor = _quantised_case(seed=seed)
    tile_m, tile_n = 8, 16
    want = _np_shadow_oracle(x, w, mor, tile_m, tile_n)
    # non-degenerate: the seeded case must exercise both error kinds
    assert want["shadow_false_skip"] > 0
    assert want["shadow_false_keep"] > 0
    assert 0 < want["shadow_truth_live"] < want["shadow_tiles"]
    plan = MoRExecutionPlan(mor, mode=mode, tile_m=tile_m, tile_n=tile_n)
    _, stats = plan.relu_matmul(jnp.asarray(x), jnp.asarray(w))
    for k in ("shadow_tiles", "shadow_false_skip", "shadow_false_keep",
              "shadow_truth_live"):
        assert int(stats[k]) == want[k], (k, int(stats[k]), want[k])
    assert float(stats["shadow_sign_agree"]) == pytest.approx(
        want["shadow_sign_agree"], abs=1e-6)
    assert 0.0 <= float(stats["shadow_err"]) <= 1.0


def test_scored_output_bitwise_equals_tiled():
    """A scored dispatch REPLACES the tiled primary, so its output must
    be bitwise identical to the tiled plan's — and the shadow twin's
    output must be the dense reference."""
    x, w, mor = _quantised_case(seed=2)
    kw = dict(tile_m=8, tile_n=16)
    y_tiled, _ = MoRExecutionPlan(mor, mode="tiled", **kw).relu_matmul(
        jnp.asarray(x), jnp.asarray(w))
    y_scored, _ = MoRExecutionPlan(mor, mode="scored", **kw).relu_matmul(
        jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(y_tiled), np.asarray(y_scored))
    y_shadow, _ = MoRExecutionPlan(mor, mode="shadow", **kw).relu_matmul(
        jnp.asarray(x), jnp.asarray(w))
    pre_bn = ((np.asarray(x) @ np.asarray(w))
              * np.asarray(mor["bn_scale"]) + np.asarray(mor["bn_bias"]))
    np.testing.assert_array_equal(np.asarray(y_shadow),
                                  np.maximum(pre_bn, 0.0).astype(np.float32))
    # and the tiled output differs from dense somewhere (skips happened)
    assert not np.array_equal(np.asarray(y_tiled), np.asarray(y_shadow))


def test_as_scored_rejects_non_tiled_plans():
    x, w, mor = _quantised_case(seed=3)
    plan = MoRExecutionPlan(mor, mode="kernel", tile_m=8, tile_n=128)
    with pytest.raises(AssertionError):
        plan.as_scored()
    tiled = MoRExecutionPlan(mor, mode="tiled", tile_m=8, tile_n=128)
    assert tiled.as_scored().mode == "scored"
    assert tiled.as_scored().as_scored().mode == "scored"   # idempotent
    assert plan.as_shadow().mode == "shadow"


# -- engine integration: token identity + zero overhead machinery ----------

_CAL = {}


def _calibrated_arch(arch, seed=0):
    if arch not in _CAL:
        from repro.core.deploy import calibrate_hybrid, calibrate_lm
        from repro.data.pipeline import synthetic_lm_batch
        cfg = reduce_config(get_config(arch))
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(seed), cfg)

        def batches():
            s = 0
            while True:
                b = synthetic_lm_batch(cfg, 2, 32, seed=seed, step=s)
                yield {"tokens": jnp.asarray(b["tokens"])}
                s += 1
        cal = calibrate_hybrid if cfg.family == "hybrid" else calibrate_lm
        params, mor, _ = cal(params, cfg, api.forward, batches(), 2)
        _CAL[arch] = (cfg, api, params, mor)
    return _CAL[arch]


def _run_engine(arch, layout, shadow_rate, mor_mode="tiled", gen=4,
                drift_threshold=0.25):
    cfg, _api, params, mor = _calibrated_arch(arch)
    rng = np.random.default_rng(11)
    reqs = [(rng.integers(1, cfg.vocab_size, size=n).astype(np.int32), gen)
            for n in (6, 11)]
    eng = Engine(cfg, params, mor=mor, mor_mode=mor_mode, n_slots=2,
                 max_len=64, chunk=8, layout=layout,
                 obs=Observability(), shadow_rate=shadow_rate,
                 drift_threshold=drift_threshold)
    out = eng.run(reqs)
    return eng, {r: list(map(int, np.asarray(t))) for r, t in out.items()}


@pytest.mark.parametrize("layout", ["paged", "slotted"])
@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b", "zamba2-7b"])
def test_engine_shadow_token_identity(arch, layout):
    """Shadow-on must be token-identical to shadow-off: the scored
    dispatch is bitwise the tiled forward, only instrumented."""
    _e0, out0 = _run_engine(arch, layout, shadow_rate=0.0)
    e1, out1 = _run_engine(arch, layout, shadow_rate=0.5)
    assert out1 == out0
    dm = e1._last_device_metrics
    assert dm["shadow_dispatches"] > 0
    q = e1.report()["quality"]
    assert q["shadow_dispatches"] == dm["shadow_dispatches"]
    g = next(iter(q["groups"].values()))
    assert g["shadow_tiles"] > 0 and g["truth_live"] > 0


def test_engine_shadow_twin_mode_kernel():
    """Non-tiled plans cannot be replaced in-step; the engine falls back
    to the standalone shadow twin and tokens still match."""
    _e0, out0 = _run_engine("granite-3-2b", "paged", 0.0, mor_mode="kernel")
    e1, out1 = _run_engine("granite-3-2b", "paged", 0.5, mor_mode="kernel")
    assert out1 == out0
    assert e1._shadow_step is not None       # twin path, not scored
    assert e1._last_device_metrics["shadow_dispatches"] > 0


def test_engine_shadow_rate_zero_no_extra_syncs(monkeypatch):
    """shadow_rate=0 must build NO twin and add NO device reads: step
    count equals the dispatch count and the metrics block drains exactly
    once, at run()'s flush — same budget as plain observability."""
    cfg, _api, params, mor = _calibrated_arch("granite-3-2b")
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(1, cfg.vocab_size, size=9).astype(np.int32), 4)]
    eng = Engine(cfg, params, mor=mor, mor_mode="tiled", n_slots=2,
                 max_len=64, chunk=8, obs=Observability(), shadow_rate=0.0)
    assert eng._shadow_every is None and eng._shadow_step is None
    assert eng._shadow_mor is None and eng.drift is None
    calls = {"step": 0, "drain": 0}
    inner_step = eng._step

    def counting_step(*a, **kw):
        calls["step"] += 1
        return inner_step(*a, **kw)

    eng._step = counting_step
    inner_read = eng._mspec.read
    monkeypatch.setattr(eng._mspec, "read",
                        lambda blk: (calls.__setitem__(
                            "drain", calls["drain"] + 1), inner_read(blk))[1])
    eng.run(reqs)
    assert calls["step"] == eng.counters["dispatches"]
    assert calls["drain"] == 1


def test_engine_scored_shadow_adds_no_dispatches(monkeypatch):
    """Even at shadow_rate=1.0 the tiled engine issues ZERO extra
    dispatches and ZERO extra drains — every sampled step IS the primary
    step, swapped to the scored plan tree."""
    cfg, _api, params, mor = _calibrated_arch("granite-3-2b")
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(1, cfg.vocab_size, size=9).astype(np.int32), 4)]
    eng = Engine(cfg, params, mor=mor, mor_mode="tiled", n_slots=2,
                 max_len=64, chunk=8, obs=Observability(), shadow_rate=1.0)
    assert eng._shadow_step is None          # tiled -> scored, no twin
    calls = {"step": 0, "drain": 0}
    inner_step = eng._step

    def counting_step(*a, **kw):
        calls["step"] += 1
        return inner_step(*a, **kw)

    eng._step = counting_step
    inner_read = eng._mspec.read
    monkeypatch.setattr(eng._mspec, "read",
                        lambda blk: (calls.__setitem__(
                            "drain", calls["drain"] + 1), inner_read(blk))[1])
    eng.run(reqs)
    assert calls["step"] == eng.counters["dispatches"]
    assert calls["drain"] == 1
    assert eng._last_device_metrics["shadow_dispatches"] \
        == eng.counters["dispatches"]


# -- drift detection -------------------------------------------------------

def _dm(fs, tl, group="mor_stats"):
    return {"groups": {group: {"false_skip": np.asarray(fs, np.int64),
                               "truth_live": np.asarray(tl, np.int64)}}}


def test_drift_detector_ewma_needs_two_flushes():
    """The EWMA (alpha=0.5) is compared, not the raw sample: after a
    clean flush, one drifted flush at rate 0.5 smooths to exactly the
    0.25 threshold (not above), the second crosses it."""
    d = DriftDetector(threshold=0.25)
    assert d.update(_dm([0, 0], [10, 10])) == []        # clean baseline
    assert d.update(_dm([0, 5], [20, 20])) == []        # ewma == 0.25
    ev = d.update(_dm([0, 10], [30, 30]))               # ewma == 0.375
    assert ev == [{"group": "mor_stats", "layer": 1, "expert": None,
                   "rate": 0.5}]
    # already flagged: no duplicate event while the flag stays raised
    assert d.update(_dm([0, 15], [40, 40])) == []
    assert d.drifted_series() == [{"group": "mor_stats", "layer": 1,
                                   "expert": None, "rate": 0.5}]
    s = d.summary()
    assert s["n_drifted"] == 1 and s["detector"] == "ewma"


def test_drift_detector_min_tiles_and_rebase():
    d = DriftDetector(threshold=0.25, min_tiles=1)
    d.update(_dm([0, 0], [10, 10]))
    # no truly-live tiles since last flush -> series skipped entirely
    assert d.update(_dm([0, 0], [10, 10])) == []
    assert d.n_updates == 2
    # rebase forgets the cumulative snapshot (counters re-zeroed) but
    # keeps detector state: the same absolute counters re-read from
    # zero do not fire a fresh clean series
    d.rebase()
    assert d.update(_dm([0, 0], [10, 10])) == []
    # expert-shaped (L, E) groups carry the expert coordinate
    d2 = DriftDetector(threshold=0.1)
    d2.update(_dm([[0, 0]], [[4, 4]], group="moe"))
    # rate 1.0 smooths to ewma 0.5 > 0.1: fires on the second flush
    ev = d2.update(_dm([[0, 4]], [[8, 8]], group="moe"))
    assert ev == [{"group": "moe", "layer": 0, "expert": 1, "rate": 1.0}]


def test_drift_detector_page_hinkley():
    d = DriftDetector(threshold=0.3, detector="page-hinkley")
    for k in range(3):                                  # flat baseline
        assert d.update(_dm([0], [10 * (k + 1)])) == []
    ev = d.update(_dm([5], [40]))                       # mean shift up
    assert ev and ev[0]["layer"] == 0
    with pytest.raises(AssertionError):
        DriftDetector(detector="bogus")


def test_engine_drift_fires_on_injected_layer_only():
    """Clean serving stays silent; after inject_coefficient_drift on one
    layer the detector flags that layer and no other, the tracer records
    timeline events, and report()['quality'] surfaces the state."""
    cfg, _api, params, mor = _calibrated_arch("granite-3-2b")
    rng = np.random.default_rng(13)
    reqs = [(rng.integers(1, cfg.vocab_size, size=n).astype(np.int32), 4)
            for n in (7, 12)]
    eng = Engine(cfg, params, mor=mor, mor_mode="tiled", n_slots=2,
                 max_len=64, chunk=8, obs=Observability(),
                 shadow_rate=1.0, drift_threshold=0.25)
    eng.run([(p.copy(), g) for p, g in reqs])
    assert eng.drift.drifted_series() == []             # clean: silent
    inject_layer = 1
    eng.update_mor(inject_coefficient_drift(
        eng.raw_mor, "layers", inject_layer))
    # the EWMA needs two post-injection flushes to cross the threshold
    eng.run([(p.copy(), g) for p, g in reqs])
    eng.run([(p.copy(), g) for p, g in reqs])
    drifted = eng.drift.drifted_series()
    assert drifted, "injection did not fire the detector"
    assert {(e["layer"], e["expert"]) for e in drifted} \
        == {(inject_layer, None)}
    rep = eng.report()
    assert rep["quality"]["drift"]["n_drifted"] == 1
    assert rep["obs"]["tracing"]["n_drift_events"] >= 1
    # the gauge mirrors landed: drift flag 1 on the injected layer
    reg = eng.obs.registry
    lab = dict(layout="paged", group="mor_stats", layer=str(inject_layer))
    assert reg.get("repro_mor_drift").get(**lab) == 1.0
    assert reg.get("repro_mor_false_skip_rate").get(**lab) > 0.25


# -- registry fixes: label escaping + empty-histogram quantiles ------------

def test_prometheus_label_value_escaping():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "xs", ("k",))
    c.inc(1, k='a\\b"c\nd')
    txt = reg.to_prometheus()
    assert r'x_total{k="a\\b\"c\nd"} 1' in txt
    # the raw (unescaped) forms must NOT leak into the exposition
    assert 'a\\b"c\nd' not in txt


def test_histogram_quantile_empty_returns_none():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(1.0, 2.0))
    assert h.quantile(0.5) is None
    assert h.summary() == {"count": 0}
    hl = reg.histogram("lat2", "latency", ("k",), buckets=(1.0,))
    hl.observe(0.5, k="seen")
    assert hl.quantile(0.5, k="never") is None          # unseen series
    assert hl.summary(k="never") == {"count": 0}
    assert hl.quantile(0.5, k="seen") == pytest.approx(0.5)


# -- live metrics endpoint -------------------------------------------------

def test_metrics_server_endpoints():
    obs = Observability(tracing=False)
    obs.registry.counter("x_total", "xs").inc(3)
    srv = MetricsServer(obs, port=0)
    try:
        assert srv.port > 0
        txt = urllib.request.urlopen(
            f"{srv.url}/metrics", timeout=5).read().decode()
        assert "x_total 3" in txt
        js = json.loads(urllib.request.urlopen(
            f"{srv.url}/metrics.json", timeout=5).read().decode())
        assert js["metrics"]["x_total"]["values"][0]["value"] == 3
        # renders at request time: a later inc is visible to a re-scrape
        obs.registry.get("x_total").inc(2)
        txt = urllib.request.urlopen(
            f"{srv.url}/metrics", timeout=5).read().decode()
        assert "x_total 5" in txt
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{srv.url}/nope", timeout=5)
    finally:
        srv.close()
    with pytest.raises(OSError):
        urllib.request.urlopen(f"{srv.url}/metrics", timeout=2)

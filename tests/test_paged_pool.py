"""Paged KV pool: allocator + prefix-cache invariants.

The host half (``kv_pool.BlockAllocator``, ``prefix_cache.PrefixCache``)
is pure numpy/python, so the alloc/free/refcount/copy-on-write
invariants get hypothesis property tests with no device in the loop:

  * no page leaked: every non-null page is on the free list XOR
    referenced, and its refcount equals its holder count;
  * no page double-owned: a block about to be written has refcount 1
    and appears in exactly one block table;
  * COW never mutates a shared page: ``write_plan`` only ever returns
    copies whose source keeps its other holders (and the device test
    below checks the bytes of a shared page survive a co-tenant's
    writes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.serving.kv_pool import BlockAllocator, PagedPool
from repro.serving.prefix_cache import PrefixCache


# -- BlockAllocator unit behaviour -----------------------------------------

def test_allocator_alloc_free_cycle():
    a = BlockAllocator(n_pages=6, n_slots=2, n_blocks=2)
    pages = [a.alloc() for _ in range(5)]
    assert sorted(pages) == [1, 2, 3, 4, 5]
    assert a.alloc() is None                      # pool exhausted
    for p in pages:
        assert a.drop(p)
    assert sorted(a.free) == [1, 2, 3, 4, 5]
    a.check()


def test_allocator_share_and_release_refcounts():
    a = BlockAllocator(n_pages=8, n_slots=2, n_blocks=2)
    p = a.alloc()
    a.table[0, 0] = p
    a.share(1, 0, p)                              # slot 1 maps same page
    assert a.ref[p] == 2
    a.check()
    freed = a.release_slot(0)
    assert freed == [] and a.ref[p] == 1          # slot 1 still holds it
    freed = a.release_slot(1)
    assert freed == [p] and a.ref[p] == 0
    a.check()


def test_write_plan_cow_preserves_shared_page():
    """A shared block is copy-on-written: the writer gets a fresh page,
    the source keeps its remaining holders and is never the write
    target."""
    a = BlockAllocator(n_pages=8, n_slots=2, n_blocks=2)
    p = a.alloc()
    a.table[0, 0] = p
    a.share(1, 0, p)
    fresh, copies = a.write_plan(1, [0])
    assert fresh == [] and len(copies) == 1
    src, dst = copies[0]
    assert src == p and dst != p
    assert a.table[1, 0] == dst and a.table[0, 0] == p
    assert a.ref[p] == 1 and a.ref[dst] == 1      # both exclusive now
    a.check()
    # exclusive blocks need no work
    assert a.write_plan(1, [0]) == ([], [])


def test_write_plan_fresh_alloc_for_null_blocks():
    a = BlockAllocator(n_pages=8, n_slots=1, n_blocks=3)
    fresh, copies = a.write_plan(0, [0, 2])
    assert len(fresh) == 2 and copies == []
    assert a.table[0, 1] == 0                     # untouched block stays null
    a.check()


def test_allocator_exhaustion_raises():
    a = BlockAllocator(n_pages=2, n_slots=1, n_blocks=2)
    a.write_plan(0, [0])
    with pytest.raises(RuntimeError):
        a.write_plan(0, [1])


# -- randomized invariant machine ------------------------------------------
# (deterministic seeds here so the invariants run everywhere; the
# hypothesis twins with minimised counterexamples live in
# tests/test_property_hypothesis.py behind the dev extra)

N_SLOTS, N_BLOCKS, N_PAGES = 3, 4, 1 + 3 * 4 + 4


def run_allocator_ops(ops, n_shards: int = 1):
    """Drive write/share/release/publish/evict ops through an allocator,
    asserting after every op: no leak, no double-own, refcount ==
    holders (block tables + trie retains), COW sources keep their
    holders, written blocks exclusively owned.  With ``n_shards`` > 1
    additionally: every COW destination lands on its source's shard
    (shard-local device copies) and the per-shard occupancy accounting
    matches the refcounts (asserted inside ``check``)."""
    n_pages = N_PAGES + (-N_PAGES) % n_shards
    a = BlockAllocator(n_pages, N_SLOTS, N_BLOCKS, n_shards)
    trie: list = []                                  # published page ids

    def external():
        refs: dict = {}
        for p in trie:
            refs[p] = refs.get(p, 0) + 1
        return refs

    for item in ops:
        kind = item[0]
        if kind == "write":
            _, slot, blocks = item
            try:
                fresh, copies = a.write_plan(slot, blocks)
            except RuntimeError:
                continue                            # pool exhausted: fine
            for b in blocks:
                pg = int(a.table[slot, b])
                assert pg != 0 and a.ref[pg] == 1, \
                    "written block not exclusively owned"
            dsts = {d for _, d in copies}
            for src, dst in copies:
                assert a.ref[src] >= 1, "COW dropped the shared source"
                assert src not in dsts, "COW source is also a target"
                assert a.shard_of(src) == a.shard_of(dst), \
                    "COW destination left its source's shard"
        elif kind == "share":
            _, dst_slot, src_slot, block = item
            pg = int(a.table[src_slot, block])
            if pg != 0 and a.table[dst_slot, block] == 0:
                a.share(dst_slot, block, pg)
        elif kind == "release":
            a.release_slot(item[1])
        elif kind == "publish":
            _, slot, block = item
            pg = int(a.table[slot, block])
            if pg != 0:
                a.retain(pg)
                trie.append(pg)
        elif kind == "evict":
            if trie:
                a.drop(trie.pop(0))
        a.check(external())


def random_allocator_ops(rng, n):
    ops = []
    for _ in range(n):
        kind = rng.choice(["write", "write", "share", "release",
                           "publish", "evict"])
        if kind == "write":
            k = int(rng.integers(1, N_BLOCKS + 1))
            ops.append(("write", int(rng.integers(N_SLOTS)),
                        list(rng.choice(N_BLOCKS, size=k, replace=False))))
        elif kind == "share":
            ops.append(("share", int(rng.integers(N_SLOTS)),
                        int(rng.integers(N_SLOTS)),
                        int(rng.integers(N_BLOCKS))))
        elif kind == "release":
            ops.append(("release", int(rng.integers(N_SLOTS))))
        elif kind == "publish":
            ops.append(("publish", int(rng.integers(N_SLOTS)),
                        int(rng.integers(N_BLOCKS))))
        else:
            ops.append(("evict",))
    return ops


@pytest.mark.parametrize("seed", range(8))
def test_allocator_invariants_under_random_ops(seed):
    rng = np.random.default_rng(seed)
    run_allocator_ops(random_allocator_ops(rng, 60))


# -- mesh-sharded allocator (ISSUE 5): ownership + balance invariants ------

def test_sharded_allocator_round_robins_for_balance():
    """Fresh allocations spread across shards most-free-first: after
    2 * n_shards allocations from a balanced pool every shard carries
    the same occupancy (modulo the null page pinned to shard 0)."""
    a = BlockAllocator(n_pages=16, n_slots=2, n_blocks=4, n_shards=4)
    pages = [a.alloc() for _ in range(8)]
    assert None not in pages
    per_shard = [sum(1 for p in pages if a.shard_of(p) == s)
                 for s in range(4)]
    assert sorted(per_shard) == [2, 2, 2, 2], per_shard
    assert a.hiwater.tolist() == [2, 2, 2, 2]
    a.check({p: 1 for p in pages})         # floating allocs as externals


def test_sharded_cow_destination_stays_on_source_shard():
    """The ownership invariant that keeps every device page copy
    shard-local: a COW destination is allocated on the SOURCE page's
    shard even when other shards have more free pages."""
    a = BlockAllocator(n_pages=16, n_slots=2, n_blocks=2, n_shards=4)
    p = a.alloc()
    a.table[0, 0] = p
    a.share(1, 0, p)
    # drain the source's shard down to one free page so a balance-first
    # allocator would pick another shard — ownership must win
    src_shard = a.shard_of(p)
    held = [h for h in [a.alloc(prefer=src_shard)] if h is not None]
    fresh, copies = a.write_plan(1, [0])
    (src, dst), = copies
    assert src == p and a.shard_of(dst) == src_shard
    a.check({h: 1 for h in held})


def test_sharded_alloc_prefer_respects_shard_exhaustion():
    """alloc(prefer=s) returns None when shard s is exhausted even if
    other shards still have free pages (cross-shard copies are never
    silently introduced); un-preferred allocation still succeeds."""
    a = BlockAllocator(n_pages=8, n_slots=1, n_blocks=2, n_shards=4)
    held = [a.alloc(prefer=0)]
    assert held[0] is not None                 # shard 0: null + 1 usable
    assert a.alloc(prefer=0) is None
    held.append(a.alloc())                     # other shards still serve
    assert held[1] is not None
    a.check({h: 1 for h in held})


@pytest.mark.parametrize("seed,n_shards", [(s, n) for s in range(4)
                                           for n in (2, 4)])
def test_sharded_allocator_invariants_under_random_ops(seed, n_shards):
    rng = np.random.default_rng(seed)
    run_allocator_ops(random_allocator_ops(rng, 60), n_shards=n_shards)


def test_sharded_paged_pool_sizing_and_ops_rows():
    """Host-side half of the sharded pool (the device-level matrix
    lives in test_serving's forced-4-device subprocess test): pool
    sizes round to an even per-shard split, and the packed ops build
    emits one row per shard with shard-LOCAL copy indices.  The mesh is
    only needed at build() time, so a placeholder suffices here."""
    cfg = reduce_config(get_config("granite-3-2b"))
    pool = PagedPool(cfg, 2, 64, chunk=8, n_shards=4, mesh=object())
    assert pool.n_pages % 4 == 0
    assert pool.kv.pages_per_shard == pool.n_pages // 4
    prompt = np.arange(16, dtype=np.int32)
    pool.admit(0, prompt)
    pool.plan_writes(np.array([8, 0]))
    ops = np.asarray(pool._build_ops())
    assert ops.ndim == 2 and ops.shape[0] == 4
    # every row replicates the block table section and the local reset
    # flags only mark pages this shard holds
    n_slots, n_blocks = pool.n_slots, pool.n_blocks
    tbl = ops[:, n_slots:n_slots + n_slots * n_blocks]
    assert (tbl == tbl[0]).all(), "block table rows differ across shards"
    pps = pool.kv.pages_per_shard
    base = n_slots + n_slots * n_blocks
    reset = ops[:, base:base + pps]
    assert reset.sum() >= 1 and (reset <= 1).all()
    # copy pads are the OOB sentinel (pages_per_shard), never (0, 0):
    # local page 0 is a REAL page on shards >= 1 and a (0, 0) pad could
    # clobber a genuine copy targeting it in the same scatter
    src = ops[:, base + pps:base + pps + pool.kv_copy_max]
    dst = ops[:, base + pps + pool.kv_copy_max:]
    assert (src == pps).all() and (dst == pps).all()


def test_apply_cache_ops_drops_oob_copy_pads():
    """Device-level regression for the pad-collision fix: a real copy
    whose destination is LOCAL page 0 must win even when OOB pad
    entries ride in the same packed scatter (duplicate-index scatters
    may otherwise let the stale pad write through)."""
    import jax.numpy as jnp
    from repro.serving.kv_pool import apply_cache_ops
    n_slots, n_blocks, npp, page, cmax = 1, 2, 4, 2, 3
    k = jnp.arange(npp * page * 2, dtype=jnp.float32).reshape(
        1, npp, page, 1, 2)
    cache = {
        "pos": jnp.zeros((n_slots,), jnp.int32),
        "block_table": jnp.zeros((n_slots, n_blocks), jnp.int32),
        "layers": {"k": k, "v": k + 100.0,
                   "pos": jnp.arange(npp * page, dtype=jnp.int32
                                     ).reshape(1, npp, page)},
    }
    ops = jnp.asarray(np.concatenate([
        np.zeros((n_slots,), np.int32),                  # pos
        np.zeros((n_slots * n_blocks,), np.int32),       # block table
        np.zeros((npp,), np.int32),                      # no tag resets
        np.array([2, npp, npp], np.int32),               # src: real + pads
        np.array([0, npp, npp], np.int32),               # dst: local 0!
    ]))
    out = apply_cache_ops(cache, ops, cmax, 0)
    np.testing.assert_array_equal(np.asarray(out["layers"]["k"])[:, 0],
                                  np.asarray(k)[:, 2],
                                  "pad write clobbered the real copy")
    np.testing.assert_array_equal(np.asarray(out["layers"]["pos"])[:, 0],
                                  np.asarray(cache["layers"]["pos"])[:, 2])
    # pages 1..3 untouched
    np.testing.assert_array_equal(np.asarray(out["layers"]["k"])[:, 1:],
                                  np.asarray(k)[:, 1:])


def check_prefix_trie_prefix_property(prompts, page):
    """Whatever gets published, a match never claims pages beyond the
    true common prefix, never past len(prompt)-1, and matched ids equal
    the publisher's for exactly the shared full pages."""
    pc = PrefixCache(page)
    published = {}
    next_page = [1]
    for prompt in prompts:
        prompt = np.asarray(prompt, np.int32)
        n_full = (len(prompt) // page) * page

        def get_page(i, base=next_page[0]):
            return base + i
        new = pc.insert_pages(prompt, n_full, get_page)
        next_page[0] += len(new)
        for i in range(n_full // page):
            key = prompt[:(i + 1) * page].tobytes()
            published.setdefault(key, pc.pages[key].page)
    for prompt in prompts:
        prompt = np.asarray(prompt, np.int32)
        got = pc.match_pages(prompt, len(prompt) - 1)
        assert len(got) * page <= len(prompt) - 1
        for i, pg in enumerate(got):
            key = prompt[:(i + 1) * page].tobytes()
            assert published[key] == pg, "matched page id != published id"


@pytest.mark.parametrize("seed", range(5))
def test_prefix_trie_matches_are_true_prefixes(seed):
    rng = np.random.default_rng(seed)
    page = int(rng.integers(2, 6))
    prompts = [list(rng.integers(0, 8, size=int(rng.integers(2, 25))))
               for _ in range(int(rng.integers(1, 9)))]
    check_prefix_trie_prefix_property(prompts, page)


def test_state_snapshot_match_is_longest_and_exact():
    pc = PrefixCache(4)
    base = np.arange(24, dtype=np.int32)
    pc.insert_state(base, 8, spage=3, kv_pages=[1, 2])
    pc.insert_state(base, 16, spage=4, kv_pages=[1, 2, 5, 6])
    hit = pc.match_state(base, limit=23)
    assert hit is not None and hit.n_tokens == 16 and hit.spage == 4
    assert pc.match_state(base, limit=12).n_tokens == 8
    # a diverging prompt must not match deeper than the divergence
    other = base.copy()
    other[10] = 99
    assert pc.match_state(other, limit=23).n_tokens == 8
    other[3] = 99
    assert pc.match_state(other, limit=23) is None
    # LRU eviction returns entries for the caller to unref
    e = pc.evict_lru_snap()
    assert e is not None and pc.evict_lru_snap() is not None
    assert pc.evict_lru_snap() is None


# -- device-level COW: shared pages are never mutated ----------------------

def test_paged_pool_cow_never_mutates_shared_page():
    """Two slots share a prompt's pages; the sharer then writes past the
    prefix (and, with a sliding window, wraps INTO shared blocks).  The
    physical bytes of every page still referenced by the prefix trie
    must be bit-identical before and after the co-tenant's writes."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(
        sliding_window=16, serve_chunk=8)
    from repro.models import get_model
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    pool = PagedPool(cfg, 2, 64, chunk=8)
    cache = pool.build()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)

    def run_chunks(cache, slot, toks, start):
        off = 0
        while off < len(toks):
            take = min(8, len(toks) - off)
            nv = np.zeros((2,), np.int64)
            nv[slot] = take
            batch = np.zeros((2, 8), np.int32)
            batch[slot, :take] = toks[off:off + take]
            cache = pool.prepare(cache, nv)
            _, cache, _ = api.prefill_chunk(
                params, cfg, jnp.asarray(batch), cache,
                n_valid=jnp.asarray(nv, jnp.int32))
            pool.advance(nv)
            off += take
        return cache

    # slot 0 prefills the prompt and publishes its 2 full pages
    assert pool.admit(0, prompt) == 0
    cache = run_chunks(cache, 0, prompt, 0)
    pool.publish(0, prompt)
    shared = [int(pool.kv.table[0, i]) for i in range(2)]
    snap_k = np.asarray(cache["layers"]["k"])[:, shared].copy()
    snap_p = np.asarray(cache["layers"]["pos"])[:, shared].copy()

    # slot 1 hits both pages, then writes 24 more tokens — enough to
    # wrap the 16+8 ring back over the shared blocks (forcing COW)
    hit = pool.admit(1, np.concatenate([prompt, prompt]).astype(np.int32))
    assert hit == 16
    tail = np.concatenate([prompt, prompt])[16:]
    cache = run_chunks(cache, 1, tail, 16)
    assert pool.counters["pages_cowed"] > 0, "wrap never triggered COW"
    np.testing.assert_array_equal(
        np.asarray(cache["layers"]["k"])[:, shared], snap_k,
        "COW mutated a shared page's keys")
    np.testing.assert_array_equal(
        np.asarray(cache["layers"]["pos"])[:, shared], snap_p,
        "COW mutated a shared page's position tags")


def test_pending_copy_src_pinned_against_eviction():
    """A queued COW copy pins its source: until the ops batch is built,
    the source page is neither evictable (trie predicate sees ref > 1)
    nor freeable — so an interleaved allocation can never recycle and
    tag-reset a page an in-flight copy still has to read."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(serve_chunk=8)
    pool = PagedPool(cfg, 2, 64, chunk=8)
    cache = pool.build()
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    pool.admit(0, prompt)
    pool.kv.write_plan(0, [0], alloc=pool._kv_alloc)
    pool.publish(0, prompt)                       # trie pins page
    shared = int(pool.kv.table[0, 0])
    pool.release(0)
    assert pool.admit(1, np.concatenate([prompt, [3, 4]])) == 8
    # slot 1 writes block 1 onward is fine; force a COW on block 0 by
    # planning a wrapped write — queue it and check the pin
    fresh, copies = pool.kv.write_plan(1, [0], alloc=pool._kv_alloc,
                                       on_copy=pool._push_kv_copy)
    assert copies and copies[0][0] == shared
    assert pool.kv.ref[shared] == 2               # trie ref + pending pin
    # the eviction predicate refuses it while pinned
    assert pool.prefix.evict_lru_page(
        lambda q: pool.kv.ref[q] == 1) is None
    # building the ops batch releases the pin; now only the trie holds it
    pool._build_ops()
    assert pool.kv.ref[shared] == 1
    assert pool.prefix.evict_lru_page(
        lambda q: pool.kv.ref[q] == 1) == shared


def test_paged_pool_release_returns_pages_and_trie_pins_survive():
    """Releasing a slot frees its exclusive pages but trie-pinned pages
    survive for future hits; evicting the trie frees them too."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(serve_chunk=8)
    pool = PagedPool(cfg, 2, 64, chunk=8)
    pool.build()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    pool.admit(0, prompt)
    fresh, _ = pool.kv.write_plan(0, [0, 1], alloc=pool._kv_alloc)
    assert len(fresh) == 2
    pool.publish(0, prompt)
    pinned = [int(pool.kv.table[0, i]) for i in range(2)]
    pool.release(0)
    assert all(pool.kv.ref[p] == 1 for p in pinned), "trie pin lost"
    pool.kv.check({p: 1 for p in pinned})
    # a new request hits the surviving pages
    assert pool.admit(1, np.concatenate([prompt, prompt[:4]])) == 16
    # evicting the whole trie releases them
    while (pg := pool.prefix.evict_lru_page()) is not None:
        pool.kv.drop(pg)
    pool.release(1)
    assert all(pool.kv.ref[p] == 0 for p in pinned)
    pool.kv.check()


# -- admission rollback + spill/restore refcount invariants (ISSUE 8) ------

def test_admit_rollback_on_state_exhaustion_leaks_nothing():
    """State-pool exhaustion mid-``admit`` must roll back everything the
    admission already attached — the shared prefix KV pages and the
    snapshot pin — and surface ``PoolExhausted`` (deferrable), leaving
    every refcount exactly as before the attempt.  The old RuntimeError
    path left the slot half-attached and the trie pages over-retained."""
    from repro.serving.kv_pool import PoolExhausted
    cfg = reduce_config(get_config("zamba2-7b")).replace(serve_chunk=8)
    pool = PagedPool(cfg, 2, 64, chunk=8)
    pool.build()
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, size=17).astype(np.int32)

    # slot 0 "prefills": allocate its pages, snapshot state at the
    # page-aligned offset 16 (host accounting only — the queued device
    # copies are irrelevant to the refcount invariants under test)
    assert pool.admit(0, prompt) == 0
    pool.kv.write_plan(0, [0, 1, 2], alloc=pool._kv_alloc)
    pool.advance(np.array([17, 0]))
    pool.maybe_snapshot(0, prompt, 16)
    assert pool.counters["snapshots"] == 1
    pool.release(0)
    snap = pool.prefix.match_state(prompt, len(prompt))
    assert snap is not None and snap.kv_pages
    # pin the snapshot so pool-pressure eviction can't reclaim it (the
    # eviction predicate requires a sole-ref spage) — the failing admit
    # below must reach the SHARED-PAGES-ATTACHED state before its state
    # alloc fails, which is exactly the rollback under test
    pool.st.retain(snap.spage)
    held = []
    while (p := pool._st_alloc()) is not None:
        held.append(p)
    ext = {**{p: 1 for p in held}, snap.spage: 1}
    kv_refs_before = pool.kv.ref.copy()
    st_refs_before = pool.st.ref.copy()

    with pytest.raises(PoolExhausted):
        pool.admit(1, np.concatenate([prompt, prompt[:4]]))
    assert not pool.kv.table[1].any(), "rollback left shared pages mapped"
    np.testing.assert_array_equal(pool.kv.ref, kv_refs_before)
    np.testing.assert_array_equal(pool.st.ref, st_refs_before)
    pool.kv.check(pool.external_refs("kv"))
    st_ext = pool.external_refs("state")
    for p, n in ext.items():
        st_ext[p] = st_ext.get(p, 0) + n
    pool.st.check(st_ext)

    # returning the held pages (and the pin) makes the SAME admit
    # succeed — the failure was deferrable, nothing was lost
    for p in held:
        pool.st.drop(p)
    pool.st.drop(snap.spage)
    assert pool.admit(1, np.concatenate([prompt, prompt[:4]])) == 16


def test_spill_and_restore_keep_allocator_invariants():
    """``spill`` moves a slot's exclusive pages to host (shared pages
    retained by reference into the spill record) and ``restore`` replays
    them into another slot: the allocator invariants must hold at every
    intermediate point with the spill record counted as an external
    holder, and the block-table shape must round-trip exactly."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(serve_chunk=8)
    pool = PagedPool(cfg, 2, 64, chunk=8)
    cache = pool.build()
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)

    # slot 0 writes 2 full pages + publishes them; a 4-token tail makes
    # a third, EXCLUSIVE page (its content must be copied on spill)
    full = np.concatenate([prompt, prompt[:4]]).astype(np.int32)
    assert pool.admit(0, full) == 0
    pool.kv.write_plan(0, [0, 1, 2], alloc=pool._kv_alloc)
    pool.advance(np.array([20, 0]))
    pool.publish(0, full)
    table_before = pool.kv.table[0].copy()
    shared = [int(pool.kv.table[0, i]) for i in range(2)]

    cache, rec = pool.spill(0, cache)
    assert pool.spill_events["spills"] == 1
    assert not pool.kv.table[0].any()
    assert rec.pos == 20
    assert [pg for _, pg in rec.kv_kept] == shared
    assert len(rec.kv_host) > 0                  # exclusive page copied
    # trie ref + spill-record ref keep the shared pages alive
    assert all(pool.kv.ref[p] == 2 for p in shared)
    pool.kv.check(pool.external_refs("kv"))

    cache = pool.restore(1, rec, cache)
    assert pool.spill_events["restores"] == 1
    # shared entries re-attach to the SAME physical pages; the spilled
    # exclusive block gets a fresh (nonzero) page for its upload
    assert [int(pool.kv.table[1, i]) for i in range(2)] == shared
    assert pool.kv.table[1, 2] > 0
    assert np.count_nonzero(pool.kv.table[1]) == \
        np.count_nonzero(table_before)
    assert pool.pos[1] == 20
    assert all(pool.kv.ref[p] == 2 for p in shared)  # trie + slot 1
    pool.kv.check(pool.external_refs("kv"))
    pool.release(1)
    pool.kv.check(pool.external_refs("kv"))

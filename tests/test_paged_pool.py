"""Paged KV pool: allocator + prefix-cache invariants.

The host half (``kv_pool.BlockAllocator``, ``prefix_cache.PrefixCache``)
is pure numpy/python, so the alloc/free/refcount/copy-on-write
invariants get hypothesis property tests with no device in the loop:

  * no page leaked: every non-null page is on the free list XOR
    referenced, and its refcount equals its holder count;
  * no page double-owned: a block about to be written has refcount 1
    and appears in exactly one block table;
  * COW never mutates a shared page: ``write_plan`` only ever returns
    copies whose source keeps its other holders (and the device test
    below checks the bytes of a shared page survive a co-tenant's
    writes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.serving.kv_pool import BlockAllocator, PagedPool
from repro.serving.prefix_cache import PrefixCache


# -- BlockAllocator unit behaviour -----------------------------------------

def test_allocator_alloc_free_cycle():
    a = BlockAllocator(n_pages=6, n_slots=2, n_blocks=2)
    pages = [a.alloc() for _ in range(5)]
    assert sorted(pages) == [1, 2, 3, 4, 5]
    assert a.alloc() is None                      # pool exhausted
    for p in pages:
        assert a.drop(p)
    assert sorted(a.free) == [1, 2, 3, 4, 5]
    a.check()


def test_allocator_share_and_release_refcounts():
    a = BlockAllocator(n_pages=8, n_slots=2, n_blocks=2)
    p = a.alloc()
    a.table[0, 0] = p
    a.share(1, 0, p)                              # slot 1 maps same page
    assert a.ref[p] == 2
    a.check()
    freed = a.release_slot(0)
    assert freed == [] and a.ref[p] == 1          # slot 1 still holds it
    freed = a.release_slot(1)
    assert freed == [p] and a.ref[p] == 0
    a.check()


def test_write_plan_cow_preserves_shared_page():
    """A shared block is copy-on-written: the writer gets a fresh page,
    the source keeps its remaining holders and is never the write
    target."""
    a = BlockAllocator(n_pages=8, n_slots=2, n_blocks=2)
    p = a.alloc()
    a.table[0, 0] = p
    a.share(1, 0, p)
    fresh, copies = a.write_plan(1, [0])
    assert fresh == [] and len(copies) == 1
    src, dst = copies[0]
    assert src == p and dst != p
    assert a.table[1, 0] == dst and a.table[0, 0] == p
    assert a.ref[p] == 1 and a.ref[dst] == 1      # both exclusive now
    a.check()
    # exclusive blocks need no work
    assert a.write_plan(1, [0]) == ([], [])


def test_write_plan_fresh_alloc_for_null_blocks():
    a = BlockAllocator(n_pages=8, n_slots=1, n_blocks=3)
    fresh, copies = a.write_plan(0, [0, 2])
    assert len(fresh) == 2 and copies == []
    assert a.table[0, 1] == 0                     # untouched block stays null
    a.check()


def test_allocator_exhaustion_raises():
    a = BlockAllocator(n_pages=2, n_slots=1, n_blocks=2)
    a.write_plan(0, [0])
    with pytest.raises(RuntimeError):
        a.write_plan(0, [1])


# -- randomized invariant machine ------------------------------------------
# (deterministic seeds here so the invariants run everywhere; the
# hypothesis twins with minimised counterexamples live in
# tests/test_property_hypothesis.py behind the dev extra)

N_SLOTS, N_BLOCKS, N_PAGES = 3, 4, 1 + 3 * 4 + 4


def run_allocator_ops(ops):
    """Drive write/share/release/publish/evict ops through an allocator,
    asserting after every op: no leak, no double-own, refcount ==
    holders (block tables + trie retains), COW sources keep their
    holders, written blocks exclusively owned."""
    a = BlockAllocator(N_PAGES, N_SLOTS, N_BLOCKS)
    trie: list = []                                  # published page ids

    def external():
        refs: dict = {}
        for p in trie:
            refs[p] = refs.get(p, 0) + 1
        return refs

    for item in ops:
        kind = item[0]
        if kind == "write":
            _, slot, blocks = item
            try:
                fresh, copies = a.write_plan(slot, blocks)
            except RuntimeError:
                continue                            # pool exhausted: fine
            for b in blocks:
                pg = int(a.table[slot, b])
                assert pg != 0 and a.ref[pg] == 1, \
                    "written block not exclusively owned"
            dsts = {d for _, d in copies}
            for src, dst in copies:
                assert a.ref[src] >= 1, "COW dropped the shared source"
                assert src not in dsts, "COW source is also a target"
        elif kind == "share":
            _, dst_slot, src_slot, block = item
            pg = int(a.table[src_slot, block])
            if pg != 0 and a.table[dst_slot, block] == 0:
                a.share(dst_slot, block, pg)
        elif kind == "release":
            a.release_slot(item[1])
        elif kind == "publish":
            _, slot, block = item
            pg = int(a.table[slot, block])
            if pg != 0:
                a.retain(pg)
                trie.append(pg)
        elif kind == "evict":
            if trie:
                a.drop(trie.pop(0))
        a.check(external())


def random_allocator_ops(rng, n):
    ops = []
    for _ in range(n):
        kind = rng.choice(["write", "write", "share", "release",
                           "publish", "evict"])
        if kind == "write":
            k = int(rng.integers(1, N_BLOCKS + 1))
            ops.append(("write", int(rng.integers(N_SLOTS)),
                        list(rng.choice(N_BLOCKS, size=k, replace=False))))
        elif kind == "share":
            ops.append(("share", int(rng.integers(N_SLOTS)),
                        int(rng.integers(N_SLOTS)),
                        int(rng.integers(N_BLOCKS))))
        elif kind == "release":
            ops.append(("release", int(rng.integers(N_SLOTS))))
        elif kind == "publish":
            ops.append(("publish", int(rng.integers(N_SLOTS)),
                        int(rng.integers(N_BLOCKS))))
        else:
            ops.append(("evict",))
    return ops


@pytest.mark.parametrize("seed", range(8))
def test_allocator_invariants_under_random_ops(seed):
    rng = np.random.default_rng(seed)
    run_allocator_ops(random_allocator_ops(rng, 60))


def check_prefix_trie_prefix_property(prompts, page):
    """Whatever gets published, a match never claims pages beyond the
    true common prefix, never past len(prompt)-1, and matched ids equal
    the publisher's for exactly the shared full pages."""
    pc = PrefixCache(page)
    published = {}
    next_page = [1]
    for prompt in prompts:
        prompt = np.asarray(prompt, np.int32)
        n_full = (len(prompt) // page) * page

        def get_page(i, base=next_page[0]):
            return base + i
        new = pc.insert_pages(prompt, n_full, get_page)
        next_page[0] += len(new)
        for i in range(n_full // page):
            key = prompt[:(i + 1) * page].tobytes()
            published.setdefault(key, pc.pages[key].page)
    for prompt in prompts:
        prompt = np.asarray(prompt, np.int32)
        got = pc.match_pages(prompt, len(prompt) - 1)
        assert len(got) * page <= len(prompt) - 1
        for i, pg in enumerate(got):
            key = prompt[:(i + 1) * page].tobytes()
            assert published[key] == pg, "matched page id != published id"


@pytest.mark.parametrize("seed", range(5))
def test_prefix_trie_matches_are_true_prefixes(seed):
    rng = np.random.default_rng(seed)
    page = int(rng.integers(2, 6))
    prompts = [list(rng.integers(0, 8, size=int(rng.integers(2, 25))))
               for _ in range(int(rng.integers(1, 9)))]
    check_prefix_trie_prefix_property(prompts, page)


def test_state_snapshot_match_is_longest_and_exact():
    pc = PrefixCache(4)
    base = np.arange(24, dtype=np.int32)
    pc.insert_state(base, 8, spage=3, kv_pages=[1, 2])
    pc.insert_state(base, 16, spage=4, kv_pages=[1, 2, 5, 6])
    hit = pc.match_state(base, limit=23)
    assert hit is not None and hit.n_tokens == 16 and hit.spage == 4
    assert pc.match_state(base, limit=12).n_tokens == 8
    # a diverging prompt must not match deeper than the divergence
    other = base.copy()
    other[10] = 99
    assert pc.match_state(other, limit=23).n_tokens == 8
    other[3] = 99
    assert pc.match_state(other, limit=23) is None
    # LRU eviction returns entries for the caller to unref
    e = pc.evict_lru_snap()
    assert e is not None and pc.evict_lru_snap() is not None
    assert pc.evict_lru_snap() is None


# -- device-level COW: shared pages are never mutated ----------------------

def test_paged_pool_cow_never_mutates_shared_page():
    """Two slots share a prompt's pages; the sharer then writes past the
    prefix (and, with a sliding window, wraps INTO shared blocks).  The
    physical bytes of every page still referenced by the prefix trie
    must be bit-identical before and after the co-tenant's writes."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(
        sliding_window=16, serve_chunk=8)
    from repro.models import get_model
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    pool = PagedPool(cfg, 2, 64, chunk=8)
    cache = pool.build()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)

    def run_chunks(cache, slot, toks, start):
        off = 0
        while off < len(toks):
            take = min(8, len(toks) - off)
            nv = np.zeros((2,), np.int64)
            nv[slot] = take
            batch = np.zeros((2, 8), np.int32)
            batch[slot, :take] = toks[off:off + take]
            cache = pool.prepare(cache, nv)
            _, cache, _ = api.prefill_chunk(
                params, cfg, jnp.asarray(batch), cache,
                n_valid=jnp.asarray(nv, jnp.int32))
            pool.advance(nv)
            off += take
        return cache

    # slot 0 prefills the prompt and publishes its 2 full pages
    assert pool.admit(0, prompt) == 0
    cache = run_chunks(cache, 0, prompt, 0)
    pool.publish(0, prompt)
    shared = [int(pool.kv.table[0, i]) for i in range(2)]
    snap_k = np.asarray(cache["layers"]["k"])[:, shared].copy()
    snap_p = np.asarray(cache["layers"]["pos"])[:, shared].copy()

    # slot 1 hits both pages, then writes 24 more tokens — enough to
    # wrap the 16+8 ring back over the shared blocks (forcing COW)
    hit = pool.admit(1, np.concatenate([prompt, prompt]).astype(np.int32))
    assert hit == 16
    tail = np.concatenate([prompt, prompt])[16:]
    cache = run_chunks(cache, 1, tail, 16)
    assert pool.counters["pages_cowed"] > 0, "wrap never triggered COW"
    np.testing.assert_array_equal(
        np.asarray(cache["layers"]["k"])[:, shared], snap_k,
        "COW mutated a shared page's keys")
    np.testing.assert_array_equal(
        np.asarray(cache["layers"]["pos"])[:, shared], snap_p,
        "COW mutated a shared page's position tags")


def test_pending_copy_src_pinned_against_eviction():
    """A queued COW copy pins its source: until the ops batch is built,
    the source page is neither evictable (trie predicate sees ref > 1)
    nor freeable — so an interleaved allocation can never recycle and
    tag-reset a page an in-flight copy still has to read."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(serve_chunk=8)
    pool = PagedPool(cfg, 2, 64, chunk=8)
    cache = pool.build()
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    pool.admit(0, prompt)
    pool.kv.write_plan(0, [0], alloc=pool._kv_alloc)
    pool.publish(0, prompt)                       # trie pins page
    shared = int(pool.kv.table[0, 0])
    pool.release(0)
    assert pool.admit(1, np.concatenate([prompt, [3, 4]])) == 8
    # slot 1 writes block 1 onward is fine; force a COW on block 0 by
    # planning a wrapped write — queue it and check the pin
    fresh, copies = pool.kv.write_plan(1, [0], alloc=pool._kv_alloc,
                                       on_copy=pool._push_kv_copy)
    assert copies and copies[0][0] == shared
    assert pool.kv.ref[shared] == 2               # trie ref + pending pin
    # the eviction predicate refuses it while pinned
    assert pool.prefix.evict_lru_page(
        lambda q: pool.kv.ref[q] == 1) is None
    # building the ops batch releases the pin; now only the trie holds it
    pool._build_ops()
    assert pool.kv.ref[shared] == 1
    assert pool.prefix.evict_lru_page(
        lambda q: pool.kv.ref[q] == 1) == shared


def test_paged_pool_release_returns_pages_and_trie_pins_survive():
    """Releasing a slot frees its exclusive pages but trie-pinned pages
    survive for future hits; evicting the trie frees them too."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(serve_chunk=8)
    pool = PagedPool(cfg, 2, 64, chunk=8)
    pool.build()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    pool.admit(0, prompt)
    fresh, _ = pool.kv.write_plan(0, [0, 1], alloc=pool._kv_alloc)
    assert len(fresh) == 2
    pool.publish(0, prompt)
    pinned = [int(pool.kv.table[0, i]) for i in range(2)]
    pool.release(0)
    assert all(pool.kv.ref[p] == 1 for p in pinned), "trie pin lost"
    pool.kv.check({p: 1 for p in pinned})
    # a new request hits the surviving pages
    assert pool.admit(1, np.concatenate([prompt, prompt[:4]])) == 16
    # evicting the whole trie releases them
    while (pg := pool.prefix.evict_lru_page()) is not None:
        pool.kv.drop(pg)
    pool.release(1)
    assert all(pool.kv.ref[p] == 0 for p in pinned)
    pool.kv.check()

"""Data pipeline: determinism, host disjointness, learnable structure."""
import numpy as np

from repro.configs import SHAPES, get_config, reduce_config
from repro.data import DataConfig
from repro.data.pipeline import (make_batch, make_train_iterator,
                                 synthetic_image_batch, synthetic_lm_batch)
from repro.configs.base import ShapeSpec


def test_lm_batch_deterministic():
    cfg = reduce_config(get_config("granite-3-2b"))
    a = synthetic_lm_batch(cfg, 4, 32, seed=7, step=3)
    b = synthetic_lm_batch(cfg, 4, 32, seed=7, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_lm_batch(cfg, 4, 32, seed=7, step=4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens():
    cfg = reduce_config(get_config("granite-3-2b"))
    d = synthetic_lm_batch(cfg, 2, 16, seed=0, step=0)
    np.testing.assert_array_equal(d["tokens"][:, 1:], d["labels"][:, :-1])


def test_hosts_draw_disjoint_streams():
    cfg = reduce_config(get_config("granite-3-2b"))
    a = synthetic_lm_batch(cfg, 4, 32, seed=7, step=3, host=0, n_hosts=4)
    b = synthetic_lm_batch(cfg, 4, 32, seed=7, step=3, host=1, n_hosts=4)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_image_batch_class_structure():
    cfg = reduce_config(get_config("paper-cnn10"))
    d = synthetic_image_batch(cfg, 64, seed=1, step=0)
    assert d["images"].shape == (64, cfg.img_size, cfg.img_size, 3)
    assert d["labels"].min() >= 0 and d["labels"].max() < 10
    # same-class images correlate more than cross-class ones
    imgs, labels = d["images"], d["labels"]
    flat = imgs.reshape(64, -1)
    same, diff = [], []
    for i in range(20):
        for j in range(i + 1, 20):
            cc = np.corrcoef(flat[i], flat[j])[0, 1]
            (same if labels[i] == labels[j] else diff).append(cc)
    if same and diff:
        assert np.mean(same) > np.mean(diff)


def test_prefetch_iterator_matches_direct():
    cfg = reduce_config(get_config("granite-3-2b"))
    shape = ShapeSpec("t", 16, 4, "train")
    dcfg = DataConfig(seed=3)
    it = make_train_iterator(cfg, shape, dcfg, start_step=5)
    got = next(it)
    it.close()
    want = make_batch(cfg, shape, dcfg, 5)
    np.testing.assert_array_equal(got["tokens"], want["tokens"])

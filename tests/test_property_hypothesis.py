"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.policy import expand_tile_mask, tile_mask_from_neuron_mask
from repro.core.predictor import binary_preact
from repro.kernels.ref import binary_dot_ref
from repro.optim.compression import (compress_int8, decompress_int8,
                                     error_feedback_allreduce,
                                     init_residuals)

floats = st.floats(-100, 100, allow_nan=False, width=32)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=1, max_side=24),
                  elements=floats),
       st.integers(1, 24))
def test_binary_preact_equals_oracle_any_input(x, n):
    """Including zeros, negatives, repeated values."""
    k = x.shape[1]
    w = np.linspace(-1, 1, k * n, dtype=np.float32).reshape(k, n)
    got = np.asarray(binary_preact(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(binary_dot_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.bool_, hnp.array_shapes(min_dims=2, max_dims=2,
                                             min_side=1, max_side=64)),
       st.sampled_from([1, 2, 8]), st.sampled_from([1, 4, 16]))
def test_tile_mask_roundtrip_is_superset(mask, tm, tn):
    """expand(reduce(mask)) >= mask pointwise: tile granularity may only
    ADD computed neurons, never drop one (correctness invariant that
    makes tiled mode safe)."""
    m = jnp.asarray(mask)
    tiles = tile_mask_from_neuron_mask(m, tm, tn)
    back = expand_tile_mask(tiles, tm, tn, mask.shape[0], mask.shape[1])
    assert bool(jnp.all(back >= m))
    # and a tile is live only if some neuron in it was live
    assert int(tiles.sum()) <= mask.sum() + tiles.size - 1 or mask.any()


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.float32, st.integers(1, 257), elements=floats))
def test_int8_compression_error_bound(x):
    q, s = compress_int8(jnp.asarray(x))
    deq = np.asarray(decompress_int8(q, s))
    assert np.all(np.abs(deq - x) <= float(s) * 0.5 + 1e-5)


@settings(max_examples=25, deadline=None)
@given(hnp.arrays(np.float32, 33, elements=floats),
       hnp.arrays(np.float32, 33, elements=floats))
def test_error_feedback_conservation(g, r):
    """deq + new_residual == grad + residual exactly (nothing lost)."""
    grads = {"w": jnp.asarray(g)}
    resid = {"w": jnp.asarray(r)}
    red, r_new = error_feedback_allreduce(grads, resid, axis_name=None)
    np.testing.assert_allclose(
        np.asarray(red["w"]) + np.asarray(r_new["w"]),
        g.astype(np.float64) + r.astype(np.float64), rtol=1e-5, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(1, 3), st.integers(1, 24),
       st.integers(1, 12), st.integers(0, 2 ** 32 - 1), st.floats(0, 0.5))
def test_dispatch_indices_kept_once_drops_only_on_overflow(
        E, k, T, C, seed, mask_frac):
    """MoE dispatch invariants (ISSUE 3): every kept (token, k) pair
    lands in its chosen expert's buffer exactly once; pairs are dropped
    ONLY on capacity overflow (kept-per-expert == min(count, C), earlier
    tokens winning); sentinel pairs (masked tokens, expert id == E) land
    exactly on the E*C drop slot."""
    from repro.models.layers.moe import _dispatch_indices
    k = min(k, E)
    rng = np.random.default_rng(seed)
    top = np.stack([rng.choice(E, size=k, replace=False)
                    for _ in range(T)]).astype(np.int32)
    top[rng.random(T) < mask_frac] = E
    slot = np.asarray(_dispatch_indices(jnp.asarray(top), E, C))
    seen = {}
    for t in range(T):
        for kk in range(k):
            e, s = top[t, kk], slot[t, kk]
            if e >= E:
                assert s == E * C
            elif s < E * C:
                assert s // C == e
                assert (e, s % C) not in seen
                seen[(e, s % C)] = t
    counts = np.bincount(top[top < E].reshape(-1), minlength=E)
    for e in range(E):
        kept_ts = sorted(t for (ee, _), t in seen.items() if ee == e)
        assert len(kept_ts) == min(counts[e], C)
        dropped_ts = [t for t in range(T) for kk in range(k)
                      if top[t, kk] == e and slot[t, kk] == E * C]
        assert all(kt <= dt for kt in kept_ts for dt in dropped_ts), \
            "a later token displaced an earlier one"


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.floats(0.1, 1.0),
       st.floats(0.05, 1.0), st.integers(0, 2 ** 32 - 1))
def test_gather_matmul_cap_live_clamp(nm, nn, cap_frac, cap_live, seed):
    """The real Pallas gather_matmul under the traced cap_live clamp:
    count outputs never exceed min(capacity, cap_live, n_live); computed
    tiles match x @ w; clamped/dead tiles are EXACT zeros."""
    from repro.kernels import ops as kops
    from repro.kernels.ref import gather_matmul_cap_ref
    rng = np.random.default_rng(seed)
    tm, tn = 8, 16
    x = jnp.asarray(rng.normal(size=(nm * tm, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, nn * tn)), jnp.float32)
    mask = jnp.asarray(rng.random((nm, nn)) > 0.4)
    out, n_live, n_comp = kops.gather_matmul(
        x, w, mask, capacity_frac=cap_frac, capacity_frac_live=cap_live,
        tile_m=tm, tile_n=tn, with_counts=True)
    n_tiles = nm * nn
    cap = max(1, int(cap_frac * n_tiles))
    cl = max(1, int(np.ceil(cap_live * n_tiles)))
    assert int(n_live) == int(np.asarray(mask).sum())
    assert int(n_comp) <= min(cap, cl, int(n_live))
    want = np.asarray(gather_matmul_cap_ref(x, w, mask, tm, tn,
                                            capacity=cap, cap_live=cl))
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-3)
    flat = np.asarray(mask).reshape(-1)
    kept = flat & (np.cumsum(flat) - 1 < min(cap, cl))
    for t in range(n_tiles):
        if not kept[t]:
            i, j = t // nn, t % nn
            assert np.all(np.asarray(out)[i * tm:(i + 1) * tm,
                                          j * tn:(j + 1) * tn] == 0.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.floats(0.05, 1.0))
def test_gather_capacity_never_exceeds(nm, nn, frac):
    """gather_matmul computes at most `capacity` tiles, whatever the mask."""
    from repro.kernels.ref import gather_matmul_ref, masked_matmul_ref
    rng = np.random.default_rng(nm * 7 + nn)
    tm = tn = 4
    x = jnp.asarray(rng.normal(size=(nm * tm, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, nn * tn)), jnp.float32)
    mask = jnp.asarray(rng.random((nm, nn)) > 0.5)
    cap = max(1, int(frac * nm * nn))
    out = np.asarray(gather_matmul_ref(x, w, mask, tm, tn, cap))
    nonzero_tiles = 0
    for i in range(nm):
        for j in range(nn):
            if np.any(out[i * tm:(i + 1) * tm, j * tn:(j + 1) * tn] != 0):
                nonzero_tiles += 1
    assert nonzero_tiles <= cap


# -- paged KV pool allocator (ISSUE 4) -------------------------------------
# hypothesis twins of tests/test_paged_pool.py's seeded machine: same
# invariants (no page leaked, no page double-owned, COW never drops a
# shared source), minimised counterexamples when they fail.

_alloc_op = st.one_of(
    st.tuples(st.just("write"), st.integers(0, 2),
              st.lists(st.integers(0, 3), min_size=1, max_size=4,
                       unique=True)),
    st.tuples(st.just("share"), st.integers(0, 2), st.integers(0, 2),
              st.integers(0, 3)),
    st.tuples(st.just("release"), st.integers(0, 2)),
    st.tuples(st.just("publish"), st.integers(0, 2), st.integers(0, 3)),
    st.tuples(st.just("evict"),),
)


@settings(max_examples=60, deadline=None)
@given(st.lists(_alloc_op, min_size=1, max_size=40))
def test_block_allocator_invariants(ops):
    from test_paged_pool import run_allocator_ops
    run_allocator_ops(ops)


@settings(max_examples=60, deadline=None)
@given(st.lists(_alloc_op, min_size=1, max_size=40),
       st.sampled_from([2, 4]))
def test_block_allocator_invariants_sharded(ops, n_shards):
    """Mesh-sharded twin (ISSUE 5): same machine, plus COW destinations
    never leave their source's shard and per-shard occupancy accounting
    stays consistent with the refcounts."""
    from test_paged_pool import run_allocator_ops
    run_allocator_ops(ops, n_shards=n_shards)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.lists(st.integers(0, 7), min_size=2, max_size=24),
                min_size=1, max_size=8),
       st.integers(2, 5))
def test_prefix_trie_prefix_property(prompts, page):
    from test_paged_pool import check_prefix_trie_prefix_property
    check_prefix_trie_prefix_property(prompts, page)

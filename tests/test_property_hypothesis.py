"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.policy import expand_tile_mask, tile_mask_from_neuron_mask
from repro.core.predictor import binary_preact
from repro.kernels.ref import binary_dot_ref
from repro.optim.compression import (compress_int8, decompress_int8,
                                     error_feedback_allreduce,
                                     init_residuals)

floats = st.floats(-100, 100, allow_nan=False, width=32)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=1, max_side=24),
                  elements=floats),
       st.integers(1, 24))
def test_binary_preact_equals_oracle_any_input(x, n):
    """Including zeros, negatives, repeated values."""
    k = x.shape[1]
    w = np.linspace(-1, 1, k * n, dtype=np.float32).reshape(k, n)
    got = np.asarray(binary_preact(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(binary_dot_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.bool_, hnp.array_shapes(min_dims=2, max_dims=2,
                                             min_side=1, max_side=64)),
       st.sampled_from([1, 2, 8]), st.sampled_from([1, 4, 16]))
def test_tile_mask_roundtrip_is_superset(mask, tm, tn):
    """expand(reduce(mask)) >= mask pointwise: tile granularity may only
    ADD computed neurons, never drop one (correctness invariant that
    makes tiled mode safe)."""
    m = jnp.asarray(mask)
    tiles = tile_mask_from_neuron_mask(m, tm, tn)
    back = expand_tile_mask(tiles, tm, tn, mask.shape[0], mask.shape[1])
    assert bool(jnp.all(back >= m))
    # and a tile is live only if some neuron in it was live
    assert int(tiles.sum()) <= mask.sum() + tiles.size - 1 or mask.any()


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.float32, st.integers(1, 257), elements=floats))
def test_int8_compression_error_bound(x):
    q, s = compress_int8(jnp.asarray(x))
    deq = np.asarray(decompress_int8(q, s))
    assert np.all(np.abs(deq - x) <= float(s) * 0.5 + 1e-5)


@settings(max_examples=25, deadline=None)
@given(hnp.arrays(np.float32, 33, elements=floats),
       hnp.arrays(np.float32, 33, elements=floats))
def test_error_feedback_conservation(g, r):
    """deq + new_residual == grad + residual exactly (nothing lost)."""
    grads = {"w": jnp.asarray(g)}
    resid = {"w": jnp.asarray(r)}
    red, r_new = error_feedback_allreduce(grads, resid, axis_name=None)
    np.testing.assert_allclose(
        np.asarray(red["w"]) + np.asarray(r_new["w"]),
        g.astype(np.float64) + r.astype(np.float64), rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.floats(0.05, 1.0))
def test_gather_capacity_never_exceeds(nm, nn, frac):
    """gather_matmul computes at most `capacity` tiles, whatever the mask."""
    from repro.kernels.ref import gather_matmul_ref, masked_matmul_ref
    rng = np.random.default_rng(nm * 7 + nn)
    tm = tn = 4
    x = jnp.asarray(rng.normal(size=(nm * tm, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, nn * tn)), jnp.float32)
    mask = jnp.asarray(rng.random((nm, nn)) > 0.5)
    cap = max(1, int(frac * nm * nn))
    out = np.asarray(gather_matmul_ref(x, w, mask, tm, tn, cap))
    nonzero_tiles = 0
    for i in range(nm):
        for j in range(nn):
            if np.any(out[i * tm:(i + 1) * tm, j * tn:(j + 1) * tn] != 0):
                nonzero_tiles += 1
    assert nonzero_tiles <= cap

"""Launch-layer tests: dry-run machinery on a small fake mesh
(subprocess: device count locks at jax init), roofline parsing, and the
experiments aggregation."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import roofline


_SMALL_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config, reduce_config, input_specs
from repro.configs.base import ShapeSpec
from repro.distributed.sharding_rules import (activation_context,
                                              batch_sharding, param_sharding)
from repro.launch.steps import make_train_step, make_serve_step
from repro.models import get_model, param_shapes, cache_shapes
from repro.optim import OptConfig, adamw_init

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = reduce_config(get_config("granite-3-2b")).replace(
    d_model=64, d_ff=128, n_heads=4, n_kv_heads=4, d_head=16)
p_sds = param_shapes(cfg)
p_sh = param_sharding(p_sds, mesh)
shape = ShapeSpec("t", 32, 8, "train")
data = input_specs(cfg, shape)
opt_sds = jax.eval_shape(lambda p: adamw_init(p, OptConfig()), p_sds)
with activation_context(mesh, sequence_parallel=True):
    step = make_train_step(cfg, OptConfig())
    lowered = jax.jit(step, in_shardings=(p_sh, None, batch_sharding(data, mesh))
                      ).lower(p_sds, opt_sds, data)
    compiled = lowered.compile()
assert compiled.memory_analysis() is not None
cost = compiled.cost_analysis()
print("TRAIN_LOWER_OK")

# decode on the same mesh (exercises _tp_flash_decode inside jit)
c_sds = cache_shapes(cfg, 8, 64)
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
with activation_context(mesh):
    serve = make_serve_step(cfg)
    comp2 = jax.jit(serve).lower(p_sds, c_sds, tok).compile()
hlo = comp2.as_text()
assert "all-reduce" in hlo or "collective" in hlo
print("DECODE_LOWER_OK")
"""


def test_small_mesh_lower_compile():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SMALL_DRYRUN],
                       capture_output=True, text=True, env=env,
                       cwd=os.getcwd(), timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "TRAIN_LOWER_OK" in r.stdout
    assert "DECODE_LOWER_OK" in r.stdout


def test_parse_collectives_factors():
    hlo = """
  %ag = f32[4,256]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}
  %ar.1 = f32[128]{0} all-reduce-start(%y), replica_groups=[4,2]<=[8]
  %rs = bf16[64,32]{1,0} reduce-scatter(%z), replica_groups={{0,1}}
"""
    out = roofline.parse_collectives(hlo)
    assert out["all-gather"] == 4 * 256 * 4 * 1.0
    assert out["all-reduce"] == 128 * 4 * 2.0          # 2x ring factor
    assert out["reduce-scatter"] == 64 * 32 * 2 * 1.0
    assert out["total_wire_bytes"] == (out["all-gather"] + out["all-reduce"]
                                       + out["reduce-scatter"])


def test_roofline_terms_dominance():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    colls = {"total_wire_bytes": 0.0, "dci_bytes": 0.0}
    t = roofline.roofline_terms(cost, colls)
    assert t["dominant"] == "memory"
    assert abs(t["t_compute_s"] - 1.0) < 1e-6
    assert abs(t["t_memory_s"] - 2.0) < 1e-6


def test_model_flops_conventions():
    from repro.configs import get_config, SHAPES
    cfg = get_config("qwen2-7b")
    mf_train = roofline.model_flops(cfg, SHAPES["train_4k"], 256)
    mf_dec = roofline.model_flops(cfg, SHAPES["decode_32k"], 256)
    # train = 6ND, decode = 2N * batch tokens
    assert mf_train["model_flops_per_chip"] > 1000 * mf_dec[
        "model_flops_per_chip"]
    assert mf_train["params_total"] == mf_train["params_active"]
    moe = get_config("mixtral-8x7b")
    mfm = roofline.model_flops(moe, SHAPES["train_4k"], 256)
    assert mfm["params_active"] < 0.4 * mfm["params_total"]


def test_experiments_md_generator(tmp_path, monkeypatch):
    """The generator runs against whatever records exist."""
    sys.path.insert(0, ".")
    from benchmarks import make_experiments_md
    monkeypatch.chdir(os.getcwd())
    make_experiments_md.main()
    text = open("EXPERIMENTS.md").read()
    for section in ("§Paper-validation", "§Dry-run", "§Roofline", "§Perf"):
        assert section in text

"""MoRExecutionPlan contract tests: ONE predictor evaluation per FFN
forward in every mode (incl. the GLU path), fused-kernel routing in
``kernel`` mode, capacity clipping, and the contraction-masked down
projection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoRConfig
from repro.core import (build_mor_layer, cluster_layer, finalize_regression,
                        init_accumulator, update_accumulator,
                        predictor_eval_count, reset_predictor_eval_count)
from repro.core.executor import MoRExecutionPlan, as_plan
from repro.core.masked_ffn import mor_ffn_apply, mor_relu_matmul
from repro.core.predictor import binary_preact

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def calibrated():
    K, N, T = 96, 256, 512
    base = RNG.normal(size=(K, 32))
    w = np.stack([base[:, RNG.integers(32)] + 0.3 * RNG.normal(size=K)
                  for _ in range(N)], 1).astype(np.float32)
    x = RNG.normal(size=(T, K)).astype(np.float32)
    acc = init_accumulator(N)
    xj, wj = jnp.asarray(x[:384]), jnp.asarray(w)
    acc = update_accumulator(acc, binary_preact(xj, wj), xj @ wj)
    m, b, c = finalize_regression(acc)
    cl = cluster_layer(w, 85.0)
    mor = build_mor_layer(np.asarray(m), np.asarray(b), np.asarray(c), cl,
                          MoRConfig(corr_threshold=0.5))
    w_perm = wj[:, mor["perm"]]
    xe = jnp.asarray(x[384:])
    return xe, w_perm, mor


MODES = ("exact", "tiled", "kernel")


@pytest.mark.parametrize("mode", MODES)
def test_predictor_runs_once_relu_matmul(calibrated, mode):
    xe, w_perm, mor = calibrated
    reset_predictor_eval_count()
    y, st = mor_relu_matmul(xe, w_perm, mor, activation="relu", mode=mode)
    assert predictor_eval_count() == 1, mode
    assert np.isfinite(np.asarray(y)).all()


@pytest.mark.parametrize("mode", MODES)
def test_predictor_runs_once_glu_ffn(calibrated, mode):
    """The acceptance criterion: the GLU path historically re-ran
    hybrid_predict for the up matmul; a plan's single prediction now
    gates gate, up, AND down projections."""
    xe, w_perm, mor = calibrated
    K, N = w_perm.shape
    w_up = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    w_down = jnp.asarray(RNG.normal(size=(N, K)), jnp.float32)
    reset_predictor_eval_count()
    y, st = mor_ffn_apply(xe, w_up, w_down, mor, activation="relu",
                          mode=mode, w_gate=w_perm)
    assert predictor_eval_count() == 1, mode
    assert np.isfinite(np.asarray(y)).all()


@pytest.mark.parametrize("mode", MODES)
def test_predictor_runs_once_nonglu_ffn(calibrated, mode):
    xe, w_perm, mor = calibrated
    K, N = w_perm.shape
    w_down = jnp.asarray(RNG.normal(size=(N, K)), jnp.float32)
    reset_predictor_eval_count()
    y, _ = mor_ffn_apply(xe, w_perm, w_down, mor, activation="relu",
                         mode=mode)
    assert predictor_eval_count() == 1, mode
    assert np.isfinite(np.asarray(y)).all()


def test_kernel_mode_uses_fused_predictor_not_jnp(calibrated, monkeypatch):
    """mode='kernel' must route through kernels.ops.mor_tile_mask +
    gather_matmul, never the jnp hybrid_predict oracle."""
    import repro.core.executor as executor
    from repro.kernels import ops as kops

    xe, w_perm, mor = calibrated

    def _boom(*a, **k):
        raise AssertionError("jnp hybrid_predict called in kernel mode")

    monkeypatch.setattr(executor, "hybrid_predict", _boom)
    called = {}
    real_gather = kops.gather_matmul

    def spy_gather(*a, **k):
        called["gather"] = True
        return real_gather(*a, **k)

    monkeypatch.setattr(kops, "gather_matmul", spy_gather)
    y, st = mor_relu_matmul(xe, w_perm, mor, activation="relu",
                            mode="kernel")
    assert called.get("gather"), "kernel mode must use gather_matmul"
    assert np.isfinite(np.asarray(y)).all()


def test_glu_kernel_equals_tiled(calibrated):
    """The full GLU FFN (gate + up + contraction-masked down) in kernel
    mode matches the pure-jnp tiled oracle."""
    xe, w_perm, mor = calibrated
    K, N = w_perm.shape
    w_up = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    w_down = jnp.asarray(RNG.normal(size=(N, K)), jnp.float32)
    y_t, st_t = mor_ffn_apply(xe, w_up, w_down, mor, activation="relu",
                              mode="tiled", w_gate=w_perm)
    y_k, st_k = mor_ffn_apply(xe, w_up, w_down, mor, activation="relu",
                              mode="kernel", w_gate=w_perm)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_k),
                               rtol=2e-4, atol=2e-3)
    assert float(st_t["frac_tiles_live"]) == float(st_k["frac_tiles_live"])


def test_masked_matmul_kdim_oracle():
    from repro.kernels import ops, ref
    M, K, N = 32, 512, 96
    tm, tk = 8, 128
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    mask = jnp.asarray(RNG.random((M // tm, K // tk)) > 0.4)
    # the MoR contract: dead x tiles are exact zeros
    from repro.core.policy import expand_tile_mask
    xz = jnp.where(expand_tile_mask(mask, tm, tk, M, K), x, 0.0)
    got = ops.masked_matmul_kdim(xz, w, mask, tile_m=tm, tile_k=tk)
    want = ref.masked_matmul_kdim_ref(xz, w, mask, tm, tk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    # and skipping really zeroes the dead tiles' contribution
    np.testing.assert_allclose(np.asarray(got), np.asarray(xz @ w),
                               rtol=2e-5, atol=2e-4)


def test_capacity_clip_limits_live_tiles(calibrated):
    xe, w_perm, mor = calibrated
    plan = as_plan(mor, mode="kernel", tile_m=8, tile_n=128,
                   capacity_frac=0.25)
    pred = plan.predict(xe, w_perm)
    n_tiles = pred.tiles.size
    assert int(jnp.sum(pred.kept)) <= max(1, int(0.25 * n_tiles))
    # kept is a subset of predicted-live
    assert bool(jnp.all(~pred.kept | pred.tiles))
    y, st = plan.relu_matmul(xe, w_perm, activation="relu")
    assert np.isfinite(np.asarray(y)).all()


def test_plan_is_a_pytree_and_scans():
    """Plans ride through tree_map and lax.scan: the MoRLayer is the
    child, mode/tiling are static aux — exactly what deploy.attach_plans
    relies on for scan-stacked models."""
    from repro.core.predictor import make_identity_layer
    L, N = 3, 128
    one = make_identity_layer(N)
    stacked = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), one)
    plan = MoRExecutionPlan(stacked, mode="tiled", tile_m=8, tile_n=128)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    plan2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert plan2.mode == "tiled" and plan2.tile_m == 8

    def body(carry, p):
        assert isinstance(p, MoRExecutionPlan) and p.mode == "tiled"
        return carry, p.mor["m"].sum()

    _, sums = jax.lax.scan(body, 0.0, plan)
    assert sums.shape == (L,)


def test_as_plan_passthrough_and_wrapping(calibrated):
    _, _, mor = calibrated
    p = as_plan(mor, mode="tiled", tile_m=8, tile_n=128)
    assert p.active and p.mode == "tiled"
    # an existing plan's own config is authoritative
    p2 = as_plan(p, mode="kernel")
    assert p2 is p
    # non-MoRLayer dicts (e.g. {"experts": ...}) deactivate cleanly
    p3 = as_plan({"experts": None}, mode="tiled")
    assert not p3.active
    assert not as_plan(None, mode="kernel").active
